# Repo tooling: tier-1 verification, the sub-minute fast lane, benchmarks.
#
#   make test       — the full tier-1 suite (what CI and ROADMAP.md reference)
#   make test-fast  — deselects @slow tests (subprocess drivers, full
#                     dry-runs); sub-minute signal while iterating
#   make test-engine— just the probe-engine + probe/stat layers
#   make bench      — the benchmark harness (paper tables + engine_speedup)
#   make bench-gate — the CI regression gate: gated bench rows vs the
#                     committed BENCH_BASELINE.json budgets
#   make discover-pallas — discovery through the real Pallas probe kernels
#                     (interpret mode), report printed as markdown
#   make serve      — HTTP front end over a populated topology store
#                     (examples/serve_topologies.py; STORE=dir PORT=n
#                     AUTH_TOKEN=secret WORKERS=n for remote discovery)
#   make test-serve — the live-server HTTP + remote-discovery lane only
#   make lint-docstrings — docstring-coverage lint (warn lane + strict set)

PY      ?= python
PYTEST  ?= $(PY) -m pytest
ENV      = PYTHONPATH=src$(if $(PYTHONPATH),:$(PYTHONPATH),)
PORT    ?= 8423

.PHONY: test test-fast test-engine test-serve bench bench-gate \
	discover-pallas serve lint-docstrings

test:
	$(ENV) $(PYTEST) -x -q

test-fast:
	$(ENV) $(PYTEST) -q -m "not slow"

test-engine:
	$(ENV) $(PYTEST) -q tests/test_engine.py tests/test_probes.py \
		tests/test_stats.py tests/test_discovery.py \
		tests/test_runner_protocol.py

test-serve:
	$(ENV) $(PYTEST) -q tests/test_http_serve.py \
		tests/test_remote_discovery.py tests/test_jobs.py \
		tests/test_topology_service.py tests/test_store.py

bench:
	$(ENV) $(PY) benchmarks/run.py

bench-gate:
	$(PY) benchmarks/check_regression.py --self-test
	$(ENV) $(PY) benchmarks/run.py --json \
		--only engine_speedup,adaptive_speedup,topology_query,pallas_interp,topology_http,remote_discovery,fault_recovery,parallel_speedup \
		--out bench_current.json
	$(PY) benchmarks/check_regression.py bench_current.json BENCH_BASELINE.json

discover-pallas:
	$(ENV) $(PY) examples/discover_topology.py --device pallas --markdown

serve:
	$(ENV) $(PY) examples/serve_topologies.py --populate --port $(PORT) \
		$(if $(STORE),--store $(STORE),) \
		$(if $(AUTH_TOKEN),--auth-token $(AUTH_TOKEN),) \
		$(if $(WORKERS),--workers $(WORKERS),)

lint-docstrings:
	$(PY) benchmarks/check_docstrings.py --self-test
	$(PY) benchmarks/check_docstrings.py
