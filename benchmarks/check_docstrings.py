"""Docstring-coverage lint for the serving and engine layers (ISSUE 7).

AST-based (no imports, no third-party deps — the ``check_regression.py``
style): walks the WARN_LANE trees, computes public-docstring coverage per
file (module docstring + every public ``def``/``class``; a leading ``_``
or a nested function is private and exempt), and prints a coverage table.

Two severity lanes, mirroring the CI wiring:

* **warn lane** (``WARN_LANE``) — ``src/repro/serve/`` and
  ``src/repro/core/engine/``: coverage below ``WARN_THRESHOLD`` prints a
  warning but never fails the build, so pre-existing gaps don't block
  unrelated PRs;
* **strict set** (``STRICT_FILES``) — files this PR touched: any public
  function/class with *no* docstring hard-fails (exit 1).  New code ships
  documented; old code is nudged.

``--self-test`` verifies the checker itself on synthetic sources (must
flag a missing public docstring, must exempt private/nested defs) so a
broken linter cannot silently pass CI.

Usage:  ``python benchmarks/check_docstrings.py [--self-test] [--strict]``
(``--strict`` promotes the warn lane to hard failures — local use only).
"""
from __future__ import annotations

import argparse
import ast
import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

WARN_LANE = ("src/repro/serve", "src/repro/core/engine")
WARN_THRESHOLD = 0.9

# Files touched by the remote-discovery PR: public objects here must be
# documented outright.  Grow this set as later PRs touch more files.
STRICT_FILES = (
    "src/repro/serve/__init__.py",
    "src/repro/serve/client.py",
    "src/repro/serve/engine.py",
    "src/repro/serve/http.py",
    "src/repro/serve/jobs.py",
    "src/repro/core/discover.py",
    "src/repro/core/errors.py",
    "src/repro/core/probes/chaos.py",
    "src/repro/core/engine/engine.py",
    "src/repro/core/engine/parallel.py",
    "src/repro/core/engine/planner.py",
    "src/repro/core/engine/fusion.py",
    "src/repro/kernels/pchase_probe.py",
)


def public_objects(tree: ast.Module) -> list[tuple[str, int, bool]]:
    """``(qualified name, line, has_docstring)`` for the module and every
    public top-level / class-level ``def`` and ``class``.

    Private names (leading ``_``) and function-nested defs are exempt —
    the contract is for the API surface, not implementation detail.
    """
    out = [("<module>", 1, ast.get_docstring(tree) is not None)]

    def visit(node, prefix):
        for child in ast.iter_child_nodes(node):
            if not isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                      ast.ClassDef)):
                continue
            if child.name.startswith("_"):
                continue
            name = f"{prefix}{child.name}"
            out.append((name, child.lineno,
                        ast.get_docstring(child) is not None))
            if isinstance(child, ast.ClassDef):     # methods, not nested defs
                visit(child, f"{name}.")

    visit(tree, "")
    return out


def check_file(path: str) -> tuple[int, int, list[tuple[str, int]]]:
    """``(documented, total, [(name, line) missing])`` for one file."""
    with open(path, encoding="utf-8") as f:
        tree = ast.parse(f.read(), filename=path)
    objs = public_objects(tree)
    missing = [(name, line) for name, line, ok in objs if not ok]
    return len(objs) - len(missing), len(objs), missing


def iter_py_files(root: str):
    for dirpath, _, files in os.walk(root):
        for fn in sorted(files):
            if fn.endswith(".py"):
                yield os.path.join(dirpath, fn)


def run(strict_all: bool = False) -> int:
    strict = {os.path.join(REPO, p) for p in STRICT_FILES}
    failures: list[str] = []
    warnings: list[str] = []
    rows: list[tuple[str, int, int]] = []

    seen = set()
    for lane in WARN_LANE:
        for path in iter_py_files(os.path.join(REPO, lane)):
            seen.add(path)
    seen.update(strict)

    for path in sorted(seen):
        if not os.path.exists(path):
            failures.append(f"{path}: strict file missing from the tree")
            continue
        documented, total, missing = check_file(path)
        rel = os.path.relpath(path, REPO)
        rows.append((rel, documented, total))
        hard = path in strict or strict_all
        for name, line in missing:
            msg = f"{rel}:{line}: public `{name}` has no docstring"
            (failures if hard else warnings).append(msg)
        if not hard and total and documented / total < WARN_THRESHOLD:
            warnings.append(
                f"{rel}: coverage {documented}/{total} below "
                f"{WARN_THRESHOLD:.0%} — warn only")

    width = max(len(r) for r, _, _ in rows)
    for rel, documented, total in rows:
        pct = documented / total if total else 1.0
        tag = " (strict)" if os.path.join(REPO, rel) in strict else ""
        print(f"{rel:<{width}}  {documented:>3}/{total:<3} {pct:>4.0%}{tag}")
    for w in warnings:
        print(f"WARN: {w}")
    for f in failures:
        print(f"FAIL: {f}")
    if failures:
        print(f"docstring lint: FAILED ({len(failures)} undocumented "
              f"public object(s) in strict files)")
        return 1
    print(f"docstring lint: OK ({len(warnings)} warning(s), "
          f"{len(rows)} file(s))")
    return 0


def self_test() -> int:
    """The checker must flag missing public docstrings and exempt private
    and nested defs; 0 iff it behaves."""
    documented = (
        '"""Module doc."""\n'
        "def pub():\n    '''doc'''\n"
        "class C:\n    '''doc'''\n"
        "    def method(self):\n        '''doc'''\n"
        "    def _private(self):\n        pass\n"
        "def _helper():\n    pass\n"
        "def outer():\n    '''doc'''\n"
        "    def nested():\n        pass\n"
    )
    undocumented = (
        "def pub():\n    pass\n"
        "class C:\n    def method(self):\n        pass\n"
    )
    d_doc, t_doc, miss_doc = _check_source(documented)
    d_un, t_un, miss_un = _check_source(undocumented)
    checks = [
        ("documented source is fully covered", miss_doc == [], True),
        ("private/nested defs are exempt", t_doc == 5, True),
        ("missing module docstring flagged",
         ("<module>", 1) in miss_un, True),
        ("missing def/class/method docstrings flagged",
         {n for n, _ in miss_un} == {"<module>", "pub", "C", "C.method"},
         True),
        ("coverage arithmetic", (d_un, t_un) == (0, 4), True),
    ]
    bad = [label for label, got, want in checks if got != want]
    for label, got, want in checks:
        print(f"self-test: {label}: {'ok' if got == want else 'BROKEN'}")
    if bad:
        print(f"self-test FAILED: linter misbehaved on: {bad}")
        return 1
    print("self-test passed: linter flags gaps and exempts private scope")
    return 0


def _check_source(source: str):
    objs = public_objects(ast.parse(source))
    missing = [(name, line) for name, line, ok in objs if not ok]
    return len(objs) - len(missing), len(objs), missing


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--self-test", action="store_true",
                    help="verify the linter on synthetic sources")
    ap.add_argument("--strict", action="store_true",
                    help="promote the warn lane to hard failures")
    args = ap.parse_args(argv)
    if args.self_test:
        return self_test()
    return run(strict_all=args.strict)


if __name__ == "__main__":
    sys.exit(main())
