"""CI bench-regression gate over ``benchmarks/run.py --json`` rows.

Compares a current run against the committed ``BENCH_BASELINE.json``:

* **correctness fields hard-fail**: ``identical=True`` flipping to False,
  a gated row erroring or disappearing, or the query ``found`` fraction
  dropping — these mean the engine/store changed *answers*, not speed;
* **ratio metrics hard-fail on >tol regression**: ``engine_speedup``'s
  ``speedup`` and ``topology_query``'s ``warm_speedup`` are wall-time
  *ratios* measured within one process, so they are stable on shared CI
  boxes where absolute wall times are not (default tol: 25%);
* **absolute wall times warn only**: ``us`` and throughput fields
  (``batched_qps``) vary with CI-box steal time; a >tol slowdown prints a
  warning but does not fail the build.

Exit status: 0 clean (warnings allowed), 1 on any hard failure.

``--self-test`` verifies the gate itself: it injects a speed regression and
a correctness flip into synthetic rows and exits nonzero unless the checker
flags both (and passes the clean pair) — CI runs this so a broken gate
cannot silently wave regressions through.
"""
from __future__ import annotations

import argparse
import json
import sys
from dataclasses import dataclass

# Per gated row: which derived metrics are ratios (hard gate, higher is
# better), which are costs (hard gate, lower is better, with optional hard
# ceilings), which are correctness fields (hard gate, exact/at-least), and
# which warn only.
GATES: dict[str, dict] = {
    "engine_speedup": {
        "ratios": ("speedup",),
        # ISSUE 4 acceptance: the adaptive planner must push the engine to
        # >=3x over the legacy sequential loop (was gated at ~2x).
        "ratio_floors": {"speedup": 3.0},
        "bools": ("identical",),
    },
    # ISSUE 4 tentpole row: planner-vs-dense probe volume.  ``identical``
    # is the oracle contract (discrete attributes equal, confidence
    # excluded); ``row_ratio`` is rows_dense / rows_planned.
    "adaptive_speedup": {
        "ratios": ("row_ratio",),
        "ratio_floors": {"row_ratio": 1.25},
        "bools": ("identical",),
    },
    "topology_query": {
        "ratios": ("warm_speedup",),
        "ratio_floors": {"warm_speedup": 10.0},   # acceptance: >=10x warm hit
        "bools": ("identical",),
        "fractions": ("found",),
        "warn_metrics": ("batched_qps",),
    },
    # ISSUE 6 tentpole row: the HTTP front end under concurrent batched
    # traffic.  Correctness hard-gated (every lookup found, zero transport/
    # 5xx errors); qps and latency percentiles warn-only at first — they
    # measure the CI box's loopback + GIL, not the serving design.
    "topology_http": {
        "bools": ("ok",),
        "fractions": ("found",),
        "warn_metrics": ("batched_qps",),
    },
    # ISSUE 7 tentpole row: the remote discovery write path.  Completion,
    # retry survival, idempotent store hit, and direct-vs-remote topology
    # equality are all correctness (hard-gated); the submit->done wall
    # time warns only — it measures loopback HTTP on the CI box.
    "remote_discovery": {
        "bools": ("retried_ok", "idem_ok", "correct", "ok"),
        "fractions": ("completed",),
    },
    # Pallas-interpret backend: correctness hard-gated (discovered discrete
    # attributes vs configured ground truth; store hit serving the identical
    # document; §IV-F/G/H rows actually coalescing onto shared eviction
    # grids), wall time warn-only — interpret-mode kernel timings
    # characterize the CI box, not the backend.  kernel_calls is a *count*,
    # not a wall time, so it is hard-gated: regressions beyond tol fail,
    # and the ISSUE 8 acceptance ceiling (950 -> <=500, was 2868 at the
    # ISSUE 4 seed) must hold outright.
    "pallas_interp": {
        "bools": ("discrete_ok", "store_hit", "eviction_fusion"),
        "warn_metrics": ("warm_speedup",),
        "costs": ("kernel_calls",),
        "cost_ceilings": {"kernel_calls": 500.0},
    },
    # ISSUE 10 tentpole row: multiprocess sharding of the batched probe
    # calls.  ``identical`` is the entire correctness claim — pooled and
    # inline runs must produce byte-for-byte equal sample matrices
    # (request-keyed sampling makes row placement invisible).  ``speedup``
    # warns only: it measures the CI box's core count, not the design.
    "parallel_speedup": {
        "bools": ("identical",),
        "warn_metrics": ("speedup",),
    },
    # ISSUE 9 tentpole row: fault-tolerant discovery.  Clean-vs-faulted
    # topology equivalence, graceful degradation, and zero-recompute
    # checkpoint resume are all correctness (hard-gated); the
    # faulted/clean wall-time ratio is a cost with a hard ceiling —
    # retries must cost bounded re-dispatches, never a from-scratch rerun.
    "fault_recovery": {
        "bools": ("equivalent", "degraded_ok", "resume_ok", "ok"),
        "costs": ("retry_overhead",),
        "cost_ceilings": {"retry_overhead": 3.0},
    },
}


def parse_derived(derived: str) -> dict[str, str]:
    """``"cold=123us_warm_speedup=2.2x_identical=True"`` -> {...}.

    Tokens are ``_``-separated.  Metric *names* contain underscores
    (``warm_speedup``, ``batched_qps``) while gated *values* do not, so a
    run of tokens without ``=`` is the prefix of the next key; a trailing
    run with no following key joins the previous value (keeps free-text
    rows like ``25/25_attrs`` from crashing the parser).
    """
    out: dict[str, str] = {}
    pending: list[str] = []
    last = None
    for tok in derived.split("_"):
        if "=" in tok:
            k, _, v = tok.partition("=")
            key = "_".join(pending + [k])
            out[key] = v
            pending, last = [], key
        else:
            pending.append(tok)
    if pending and last is not None:
        out[last] += "_" + "_".join(pending)
    return out


def as_number(raw: str) -> float | None:
    """Strip unit suffixes (``us``, ``x``) / parse ``a/b`` fractions."""
    s = raw.strip()
    if "/" in s:
        num, _, den = s.partition("/")
        try:
            return float(num) / float(den)
        except (ValueError, ZeroDivisionError):
            return None
    while s and not (s[-1].isdigit() or s[-1] == "."):
        s = s[:-1]
    try:
        return float(s)
    except ValueError:
        return None


@dataclass
class GateReport:
    failures: list[str]
    warnings: list[str]

    @property
    def ok(self) -> bool:
        return not self.failures


def _index(rows: list[dict]) -> dict[str, dict]:
    return {r["name"]: r for r in rows}


def compare(current: list[dict], baseline: list[dict], *,
            ratio_tol: float = 0.25, wall_tol: float = 0.25) -> GateReport:
    cur, base = _index(current), _index(baseline)
    failures: list[str] = []
    warnings: list[str] = []

    for name, gate in GATES.items():
        b = base.get(name)
        if b is None:
            warnings.append(f"{name}: not in baseline — skipped")
            continue
        c = cur.get(name)
        if c is None:
            failures.append(f"{name}: gated row missing from current run")
            continue
        if c["derived"].startswith("ERROR_"):
            failures.append(f"{name}: errored — {c['derived']}")
            continue
        cd, bd = parse_derived(c["derived"]), parse_derived(b["derived"])

        for metric in gate.get("bools", ()):
            if cd.get(metric) != "True":
                failures.append(
                    f"{name}: correctness field {metric}={cd.get(metric)} "
                    f"(must be True)")

        for metric in gate.get("fractions", ()):
            cv, bv = as_number(cd.get(metric, "")), as_number(bd.get(metric, ""))
            if cv is None or (bv is not None and cv < bv):
                failures.append(
                    f"{name}: correctness field {metric} dropped "
                    f"({bd.get(metric)} -> {cd.get(metric)})")

        for metric in gate.get("ratios", ()):
            cv, bv = as_number(cd.get(metric, "")), as_number(bd.get(metric, ""))
            if cv is None:
                failures.append(f"{name}: ratio metric {metric} missing")
                continue
            floor = gate.get("ratio_floors", {}).get(metric)
            if floor is not None and cv < floor:
                failures.append(
                    f"{name}: {metric}={cv:.2f} below hard floor {floor:.0f}")
            if bv is not None and cv < bv * (1.0 - ratio_tol):
                failures.append(
                    f"{name}: {metric} regressed >{ratio_tol:.0%} "
                    f"({bv:.2f} -> {cv:.2f})")

        for metric in gate.get("costs", ()):
            cv, bv = as_number(cd.get(metric, "")), as_number(bd.get(metric, ""))
            if cv is None:
                failures.append(f"{name}: cost metric {metric} missing")
                continue
            ceiling = gate.get("cost_ceilings", {}).get(metric)
            if ceiling is not None and cv > ceiling:
                failures.append(
                    f"{name}: {metric}={cv:.0f} above hard ceiling "
                    f"{ceiling:.0f}")
            if bv is not None and cv > bv * (1.0 + ratio_tol):
                failures.append(
                    f"{name}: {metric} regressed >{ratio_tol:.0%} "
                    f"({bv:.0f} -> {cv:.0f})")

        for metric in gate.get("warn_metrics", ()):
            cv, bv = as_number(cd.get(metric, "")), as_number(bd.get(metric, ""))
            if cv is not None and bv is not None and cv < bv * (1.0 - wall_tol):
                warnings.append(
                    f"{name}: {metric} down >{wall_tol:.0%} "
                    f"({bv:.0f} -> {cv:.0f}) — wall-clock, warn only")

        cu, bu = float(c.get("us", 0)), float(b.get("us", 0))
        if bu > 0 and cu > bu * (1.0 + wall_tol):
            warnings.append(
                f"{name}: wall time up >{wall_tol:.0%} "
                f"({bu:.0f}us -> {cu:.0f}us) — warn only")
    return GateReport(failures, warnings)


def _load(path: str) -> list[dict]:
    with open(path) as f:
        rows = json.load(f)
    if not isinstance(rows, list):
        raise SystemExit(f"{path}: expected a JSON array of bench rows")
    return rows


def self_test() -> int:
    """Exercise the gate on injected regressions; 0 iff the gate behaves."""
    baseline = [
        {"name": "engine_speedup", "us": 160000.0,
         "derived": "legacy=560000us_speedup=3.60x_identical=True"},
        {"name": "adaptive_speedup", "us": 300000.0,
         "derived": "rows_dense=4800_rows_planned=3300_row_ratio=1.45x_"
                     "identical=True"},
        {"name": "topology_query", "us": 600.0,
         "derived": "cold=320000us_warm_speedup=500.0x_batched_qps=170000_"
                     "found=2000/2000_identical=True"},
        {"name": "pallas_interp", "us": 3000000.0,
         "derived": "discrete_ok=True_store_hit=True_eviction_fusion=True_"
                     "warm_speedup=9000.0x_kernel_calls=470"},
        {"name": "topology_http", "us": 4000000.0,
         "derived": "batched_qps=60000_p50=6000us_p99=15000us_"
                     "found=4000/4000_errors=0_ok=True"},
        {"name": "remote_discovery", "us": 800000.0,
         "derived": "completed=3/3_retried_ok=True_idem_ok=True_"
                     "correct=True_ok=True"},
        {"name": "fault_recovery", "us": 70000.0,
         "derived": "equivalent=True_degraded_ok=True_resume_ok=True_"
                     "retry_overhead=1.10_ok=True"},
        {"name": "parallel_speedup", "us": 90000.0,
         "derived": "inline=180000us_speedup=2.00x_workers=4_rows=512_"
                     "identical=True"},
    ]
    clean = [
        {"name": "engine_speedup", "us": 170000.0,
         "derived": "legacy=540000us_speedup=3.41x_identical=True"},
        {"name": "adaptive_speedup", "us": 310000.0,
         "derived": "rows_dense=4810_rows_planned=3350_row_ratio=1.44x_"
                     "identical=True"},
        {"name": "topology_query", "us": 640.0,
         "derived": "cold=315000us_warm_speedup=492.2x_batched_qps=165000_"
                     "found=2000/2000_identical=True"},
        {"name": "pallas_interp", "us": 3400000.0,    # slower wall: warn only
         "derived": "discrete_ok=True_store_hit=True_eviction_fusion=True_"
                     "warm_speedup=8421.7x_kernel_calls=479"},
        {"name": "topology_http", "us": 4200000.0,    # slower qps: warn only
         "derived": "batched_qps=41000_p50=8000us_p99=22000us_"
                     "found=4000/4000_errors=0_ok=True"},
        {"name": "remote_discovery", "us": 1100000.0,  # slower wall: warn only
         "derived": "completed=3/3_retried_ok=True_idem_ok=True_"
                     "correct=True_ok=True"},
        {"name": "fault_recovery", "us": 82000.0,      # slower wall: warn only
         "derived": "equivalent=True_degraded_ok=True_resume_ok=True_"
                     "retry_overhead=1.15_ok=True"},
        {"name": "parallel_speedup", "us": 210000.0,   # 1-core box: warn only
         "derived": "inline=175000us_speedup=0.83x_workers=2_rows=512_"
                     "identical=True"},
    ]
    speed_regressed = json.loads(json.dumps(clean))
    speed_regressed[0]["derived"] = \
        "legacy=530000us_speedup=2.40x_identical=True"     # >25% ratio drop
    correctness_broken = json.loads(json.dumps(clean))
    correctness_broken[2]["derived"] = correctness_broken[2]["derived"] \
        .replace("identical=True", "identical=False")
    floor_broken = json.loads(json.dumps(clean))
    floor_broken[2]["derived"] = floor_broken[2]["derived"] \
        .replace("warm_speedup=492.2x", "warm_speedup=6.0x")
    pallas_broken = json.loads(json.dumps(clean))
    pallas_broken[3]["derived"] = pallas_broken[3]["derived"] \
        .replace("discrete_ok=True", "discrete_ok=False")
    planner_broken = json.loads(json.dumps(clean))
    planner_broken[1]["derived"] = planner_broken[1]["derived"] \
        .replace("identical=True", "identical=False")
    volume_regressed = json.loads(json.dumps(clean))
    volume_regressed[3]["derived"] = volume_regressed[3]["derived"] \
        .replace("kernel_calls=479", "kernel_calls=700")   # >25% + ceiling
    fusion_lost = json.loads(json.dumps(clean))
    fusion_lost[3]["derived"] = fusion_lost[3]["derived"] \
        .replace("eviction_fusion=True", "eviction_fusion=False")
    floor_3x_broken = json.loads(json.dumps(clean))
    floor_3x_broken[0]["derived"] = \
        "legacy=540000us_speedup=2.95x_identical=True"     # under hard floor
    http_broken = json.loads(json.dumps(clean))
    http_broken[4]["derived"] = http_broken[4]["derived"] \
        .replace("errors=0_ok=True", "errors=3_ok=False")
    http_lost = json.loads(json.dumps(clean))
    http_lost[4]["derived"] = http_lost[4]["derived"] \
        .replace("found=4000/4000", "found=3950/4000")
    remote_broken = json.loads(json.dumps(clean))
    remote_broken[5]["derived"] = remote_broken[5]["derived"] \
        .replace("idem_ok=True", "idem_ok=False") \
        .replace("ok=True", "ok=False")
    remote_incomplete = json.loads(json.dumps(clean))
    remote_incomplete[5]["derived"] = remote_incomplete[5]["derived"] \
        .replace("completed=3/3", "completed=2/3")
    recovery_broken = json.loads(json.dumps(clean))
    recovery_broken[6]["derived"] = recovery_broken[6]["derived"] \
        .replace("resume_ok=True", "resume_ok=False") \
        .replace("ok=True", "ok=False")
    retry_runaway = json.loads(json.dumps(clean))
    retry_runaway[6]["derived"] = retry_runaway[6]["derived"] \
        .replace("retry_overhead=1.15", "retry_overhead=3.40")  # over ceiling
    parallel_broken = json.loads(json.dumps(clean))
    parallel_broken[7]["derived"] = parallel_broken[7]["derived"] \
        .replace("identical=True", "identical=False")
    parallel_slow = json.loads(json.dumps(clean))
    parallel_slow[7]["derived"] = parallel_slow[7]["derived"] \
        .replace("speedup=0.83x", "speedup=0.30x")     # wall-only: warn

    checks = [
        ("clean run passes", compare(clean, baseline).ok, True),
        ("injected speed regression fails",
         compare(speed_regressed, baseline).ok, False),
        ("injected correctness flip fails",
         compare(correctness_broken, baseline).ok, False),
        ("warm-hit floor violation fails",
         compare(floor_broken, baseline).ok, False),
        ("pallas discrete-attribute drift fails",
         compare(pallas_broken, baseline).ok, False),
        ("planner-vs-dense identity flip fails",
         compare(planner_broken, baseline).ok, False),
        ("kernel-call volume regression fails",
         compare(volume_regressed, baseline).ok, False),
        ("eviction rows falling off the fused grids fails",
         compare(fusion_lost, baseline).ok, False),
        ("engine speedup under 3x hard floor fails",
         compare(floor_3x_broken, baseline).ok, False),
        ("http serving errors fail",
         compare(http_broken, baseline).ok, False),
        ("http found-fraction drop fails",
         compare(http_lost, baseline).ok, False),
        ("remote-discovery idempotency break fails",
         compare(remote_broken, baseline).ok, False),
        ("remote-discovery incomplete jobs fail",
         compare(remote_incomplete, baseline).ok, False),
        ("checkpoint-resume break fails",
         compare(recovery_broken, baseline).ok, False),
        ("runaway retry overhead fails",
         compare(retry_runaway, baseline).ok, False),
        ("pooled-vs-inline identity flip fails",
         compare(parallel_broken, baseline).ok, False),
        ("pooled speedup drop only warns",
         compare(parallel_slow, baseline).ok, True),
    ]
    bad = [label for label, got, want in checks if got != want]
    for label, got, want in checks:
        mark = "ok" if got == want else "BROKEN"
        print(f"self-test: {label}: {mark}")
    if bad:
        print(f"self-test FAILED: gate misbehaved on: {bad}")
        return 1
    print("self-test passed: gate flags injected regressions and passes clean runs")
    return 0


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("current", nargs="?", help="JSON rows from the current run")
    ap.add_argument("baseline", nargs="?", help="committed BENCH_BASELINE.json")
    ap.add_argument("--ratio-tol", type=float, default=0.25)
    ap.add_argument("--wall-tol", type=float, default=0.25)
    ap.add_argument("--self-test", action="store_true",
                    help="verify the gate flags injected regressions")
    args = ap.parse_args(argv)

    if args.self_test:
        return self_test()
    if not (args.current and args.baseline):
        ap.error("need CURRENT and BASELINE row files (or --self-test)")

    report = compare(_load(args.current), _load(args.baseline),
                     ratio_tol=args.ratio_tol, wall_tol=args.wall_tol)
    for w in report.warnings:
        print(f"WARN: {w}")
    for f in report.failures:
        print(f"FAIL: {f}")
    if report.ok:
        print("bench gate: OK "
              f"({len(report.warnings)} warning(s), 0 failures)")
        return 0
    print(f"bench gate: FAILED ({len(report.failures)} failure(s))")
    return 1


if __name__ == "__main__":
    sys.exit(main())
