"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (assignment deliverable (d)).

  table1_coverage    — paper Table I:   attribute coverage of discovery
  table3_validation  — paper Table III: discovered vs ground truth
  fig2_reduction     — paper Fig. 2:    eq.2 reduction + K-S change point
  runtime_breakdown  — paper §V-A:      per-family probe run times
  fig5_stream        — paper Fig. 5:    stream ns/B vs size, LLC boundary
  perfmodel          — paper §VI-A:     CWP/MWP verdicts from discovery
  roofline           — deliverable (g): per-cell terms from dry-run artifacts
  kernels            — Pallas kernels vs refs (correctness + ref wall time)
  train_step         — tiny end-to-end train step wall time
  topology_query     — cold discovery vs warm store hit vs batched queries
  topology_http      — live HTTP front end: concurrent batched qps +
                       p50/p99 request latency (correctness hard-gated)
  remote_discovery   — remote write path: sim jobs submitted over HTTP,
                       retry survival + idempotent store hit hard-gated
  adaptive_speedup   — probe rows: adaptive sweep planner vs dense sweeps
                       (discrete attributes must be identical)
  pallas_interp      — third-backend discovery through the real Pallas
                       kernels (interpret mode) vs configured ground truth

CLI (the CI bench-regression gate consumes the machine-readable form):

  --json             emit rows as a JSON array on stdout instead of CSV
  --out FILE         also write the JSON rows to FILE
  --only a,b,c       run only the named benchmarks (function-name suffixes)
"""
from __future__ import annotations

import json
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

ROWS: list[tuple[str, float, str]] = []
JSON_MODE = False


def row(name: str, us: float, derived: str) -> None:
    ROWS.append((name, us, derived))
    if not JSON_MODE:
        print(f"{name},{us:.1f},{derived}", flush=True)


def rows_as_json() -> list[dict]:
    return [{"name": n, "us": round(u, 1), "derived": d} for n, u, d in ROWS]


def _timed(fn, *args, repeats=3, **kw):
    fn(*args, **kw)
    best = np.inf
    out = None
    for _ in range(repeats):
        t0 = time.perf_counter_ns()
        out = fn(*args, **kw)
        best = min(best, time.perf_counter_ns() - t0)
    return out, best / 1e3


# ----------------------------------------------------------- paper tables
def bench_table1_coverage() -> None:
    """Attribute coverage on the simulated H100 (paper Table I)."""
    from repro.core import discover_sim, make_h100_like

    t0 = time.perf_counter_ns()
    topo, _ = discover_sim(make_h100_like(seed=42), n_samples=17)
    us = (time.perf_counter_ns() - t0) / 1e3
    covered = total = 0
    for me in topo.memory:
        for attr in ("size", "load_latency", "line_size", "fetch_granularity",
                     "amount"):
            if me.kind == "cache" or attr in ("size", "load_latency"):
                total += 1
                covered += me.get(attr) is not None
    row("table1_coverage", us, f"{covered}/{total}_attrs")


def bench_table3_validation() -> None:
    """Discovered values vs simulated ground truth (paper Table III)."""
    from repro.core import discover_sim, make_h100_like, make_mi210_like

    for make, name in ((make_h100_like, "h100"), (make_mi210_like, "mi210")):
        dev = make(seed=43)
        t0 = time.perf_counter_ns()
        topo, _ = discover_sim(dev, n_samples=17)
        us = (time.perf_counter_ns() - t0) / 1e3
        gt = dev.ground_truth()
        ok = bad = 0
        for lvl, truth in gt.items():
            me = topo.find_memory(lvl)
            if me is None:
                continue
            for attr, want in truth.items():
                if attr in ("physical_group", "scope"):
                    continue
                got = me.get(attr if attr != "latency" else "load_latency")
                if got is None:
                    continue
                tol = 0.1 if attr in ("size", "latency") else 0.0
                good = (abs(got - want) <= tol * want) if tol else got == want
                ok += bool(good)
                bad += not good
        row(f"table3_validation_{name}", us, f"{ok}ok_{bad}bad")


def bench_fig2_reduction() -> None:
    """eq.2 reduction + K-S change point on a size sweep (paper Fig. 2)."""
    from repro.core import make_h100_like
    from repro.core.probes import SimRunner, find_size

    runner = SimRunner(make_h100_like(seed=44))
    res, us = _timed(find_size, runner, "L1", repeats=1, n_samples=17)
    row("fig2_reduction", us,
        f"size={res.size}B_conf={res.confidence:.2f}_pts={res.reduced.size}")


def bench_runtime_breakdown() -> None:
    """Per-family probe run times (paper §V-A)."""
    from repro.core import discover_sim, make_h100_like

    _, timings = discover_sim(make_h100_like(seed=45), n_samples=17)
    for fam, secs in sorted(timings.per_family.items()):
        row(f"runtime_{fam}", secs * 1e6, f"{secs/timings.total:.1%}_of_total")


def bench_engine_speedup() -> None:
    """Engine vs legacy discovery wall time (the engine's headline row —
    since ISSUE 4, the engine side runs the adaptive sweep planner, so the
    gate floor moved from 2x to 3x).  Summed over the two validation
    devices; topologies are checked equivalent first — a speedup over
    different answers would be meaningless.  'Identical' means the
    ROADMAP-prescribed contract: discrete attributes exactly equal, floats
    within rel-tol, confidence excluded (the planner computes it from a
    boundary window instead of the full sweep series)."""
    from repro.core import (SweepBudget, discover_sim, discover_sim_legacy,
                            make_h100_like, make_mi210_like,
                            topology_equivalent)

    legacy_s = engine_s = 0.0
    identical = True
    for make in (make_h100_like, make_mi210_like):
        legacy_best = engine_best = np.inf
        # Best-of-5, interleaved: this box is a 2-core shared VM with heavy
        # steal time, and a single steal burst inside a ~200 ms engine run
        # would otherwise dominate the ratio.
        for _ in range(5):
            t0 = time.perf_counter()
            topo_l, _ = discover_sim_legacy(make(seed=48), n_samples=17)
            legacy_best = min(legacy_best, time.perf_counter() - t0)
            t0 = time.perf_counter()
            topo_e, _ = discover_sim(make(seed=48), n_samples=17,
                                     max_workers=0, budget=SweepBudget())
            engine_best = min(engine_best, time.perf_counter() - t0)
        legacy_s += legacy_best
        engine_s += engine_best
        if not topology_equivalent(topo_l, topo_e, rel_tol=1e-6,
                                   compare_confidence=False):
            identical = False
    row("engine_speedup", engine_s * 1e6,
        f"legacy={legacy_s*1e6:.0f}us_speedup={legacy_s/engine_s:.2f}x_"
        f"identical={identical}")


def bench_adaptive_speedup() -> None:
    """ISSUE 4 tentpole row: probe volume of the adaptive planner vs the
    dense sweeps, same devices, same seeds.  ``identical`` (hard-gated) is
    the planner-vs-dense oracle contract — every discrete attribute equal,
    floats within rel-tol, confidence excluded; ``row_ratio`` (ratio-gated)
    is rows_dense / rows_planned, the probe-volume cut every backend
    inherits."""
    from repro.core import (SweepBudget, discover_sim, make_h100_like,
                            make_mi210_like, topology_equivalent)

    rows_dense = rows_planned = 0
    identical = True
    t0 = time.perf_counter()
    for make in (make_h100_like, make_mi210_like):
        topo_d, td = discover_sim(make(seed=48), n_samples=17, max_workers=0)
        topo_p, tp = discover_sim(make(seed=48), n_samples=17, max_workers=0,
                                  budget=SweepBudget())
        rows_dense += td.probe_rows
        rows_planned += tp.probe_rows
        if not topology_equivalent(topo_d, topo_p, rel_tol=1e-6,
                                   compare_confidence=False):
            identical = False
    us = (time.perf_counter() - t0) * 1e6
    row("adaptive_speedup", us,
        f"rows_dense={rows_dense}_rows_planned={rows_planned}_"
        f"row_ratio={rows_dense/rows_planned:.2f}x_identical={identical}")


def bench_parallel_speedup() -> None:
    """ISSUE 10 tentpole row: multiprocess sharding of batched probe calls.

    One large fused-style ``pchase_many`` batch (512 rows x 2001 samples)
    run inline and through a dedicated worker-process pool with
    shared-memory sample transport.  ``identical`` (hard-gated) is the
    whole correctness claim — request-keyed sampling makes row placement
    invisible, so the pooled matrix must equal the inline one byte for
    byte.  ``speedup`` is warn-only: it measures the CI box's core count
    (a 1-2 core container *loses* to inline; the >=1.8x acceptance number
    needs >=4 real cores), not the sharding design.
    """
    from repro.core import make_h100_like
    from repro.core.engine.parallel import (ParallelConfig, ParallelPool,
                                            effective_cpu_count,
                                            maybe_parallel_runner)
    from repro.core.probes import SimRunner

    reqs = [("L2", 256 * 1024 + 4096 * i, 64) for i in range(512)]
    n_samples = 2001
    inline = SimRunner(make_h100_like(seed=50))
    inline.pchase_many(reqs[:8], n_samples)        # touch code paths once
    t0 = time.perf_counter()
    want = np.asarray(inline.pchase_many(reqs, n_samples))
    inline_s = time.perf_counter() - t0

    workers = max(2, min(4, effective_cpu_count()))
    cfg = ParallelConfig(workers=workers)
    with ParallelPool(cfg) as pool:
        pooled = maybe_parallel_runner(SimRunner(make_h100_like(seed=50)),
                                       cfg, pool=pool)
        pooled.pchase_many(reqs[:workers], 5)      # warm: spawn + rebuild
        t0 = time.perf_counter()
        got = np.asarray(pooled.pchase_many(reqs, n_samples))
        pooled_s = time.perf_counter() - t0
    identical = bool(np.array_equal(want, got))
    row("parallel_speedup", pooled_s * 1e6,
        f"inline={inline_s*1e6:.0f}us_speedup={inline_s/pooled_s:.2f}x_"
        f"workers={workers}_rows={len(reqs)}_identical={identical}")


def bench_pallas_interp() -> None:
    """Third-backend row (ISSUE 3 tentpole): full discovery through the
    real Pallas probe kernels in interpret mode, via the same engine path
    as the sim backend.  Correctness fields (hard-gated): the discovered
    discrete attributes must match the configured ground truth (cache
    spaces exact, <=64 B sweep-grid quantization on the word-granular
    scratchpad), and a second store-backed discovery must be a pure hit
    returning the identical document.  Wall time is warn-only — interpret
    mode characterizes this container, not a TPU.

    One retry on a discrete mismatch: probes here are *real timed
    measurements* on a shared box, and a sustained steal burst can defeat
    even the drift-hardened detection (a few-percent tail).  A genuine
    regression fails deterministically on both attempts; independent
    drift flukes square away.  Retries are reported in the derived field.
    """
    import tempfile

    from repro.core import discover_pallas
    from repro.core.engine.store import TopologyStore
    from repro.core.probes import PallasRunner, make_pallas_model

    def attempt():
        with tempfile.TemporaryDirectory() as td:
            store = TopologyStore(td)
            model = make_pallas_model()
            runner = PallasRunner(model)
            t0 = time.perf_counter()
            topo, _ = discover_pallas(runner=runner, n_samples=9, store=store)
            cold_s = time.perf_counter() - t0

            gt = model.ground_truth()
            ok = True
            for name in ("L1", "L2"):
                me = topo.find_memory(name)
                ok = ok and me is not None \
                    and me.get("size") == gt[name]["size"] \
                    and me.get("line_size") == gt[name]["line_size"] \
                    and me.get("fetch_granularity") == gt[name][
                        "fetch_granularity"]
            vmem = topo.find_memory("VMEM")
            ok = ok and vmem is not None and vmem.get("size") is not None \
                and abs(vmem.get("size") - gt["VMEM"]["size"]) <= 64

            calls = runner.kernel_calls
            # §IV-F/G/H rows coalesced onto shared eviction grids: more
            # rows than dispatches means the fusion actually batched them.
            fused = (runner.eviction_grid_calls > 0
                     and runner.eviction_grid_rows
                     > runner.eviction_grid_calls)
            t0 = time.perf_counter()
            topo_hit, _ = discover_pallas(runner=runner, n_samples=9,
                                          store=store)
            hit_s = max(time.perf_counter() - t0, 1e-9)
            served = (topo_hit.to_json() == topo.to_json()
                      and runner.kernel_calls == calls)
            return bool(ok), bool(served), bool(fused), cold_s, hit_s, calls

    ok, served, fused, cold_s, hit_s, calls = attempt()
    retried = False
    if not (ok and served):
        retried = True
        ok, served, fused, cold_s, hit_s, calls = attempt()
    row("pallas_interp", cold_s * 1e6,
        f"discrete_ok={ok}_store_hit={served}_eviction_fusion={fused}_"
        f"warm_speedup={cold_s/hit_s:.1f}x_kernel_calls={calls}_"
        f"retried={retried}")


def bench_fig5_stream() -> None:
    """Stream ns/B vs array size on the host; detect the cache boundary
    (paper Fig. 5). The transition on a shared VM is gradual, so the
    parametric PELT segmentation (one of the paper's 'other algorithms')
    locates the mean shift on the short series."""
    import jax
    import jax.numpy as jnp
    from repro.core.stats import pelt_segments

    sizes = [1 << s for s in range(19, 27)]        # 512 KiB .. 64 MiB
    ns_per_b = []
    t0 = time.perf_counter_ns()
    for n in sizes:
        x = jnp.arange(n // 4, dtype=jnp.float32)
        f = jax.jit(jnp.sum)
        f(x).block_until_ready()                   # warm-up
        reps = max(3, (1 << 24) // n)
        t1 = time.perf_counter_ns()
        for _ in range(reps):
            f(x).block_until_ready()
        dt = (time.perf_counter_ns() - t1) / reps
        ns_per_b.append(dt / n)
    us = (time.perf_counter_ns() - t0) / 1e3
    cps = pelt_segments(np.asarray(ns_per_b))
    boundary = sizes[cps[0] - 1] if cps else -1
    row("fig5_stream", us, f"cache_boundary={boundary}B_ncps={len(cps)}")


def bench_perfmodel() -> None:
    """CWP/MWP verdicts with MT4G-discovered parameters (paper §VI-A)."""
    from repro.core import discover_sim, make_h100_like
    from repro.core.perfmodel import (AppParams, evaluate,
                                      gpu_params_from_topology)

    topo, _ = discover_sim(make_h100_like(seed=46), n_samples=9)
    gpu = gpu_params_from_topology(topo)
    stream_app = AppParams(comp_cycles=20, mem_cycles=4000, loads_per_warp=32,
                           active_warps_per_sm=48)
    gemm_app = AppParams(comp_cycles=8000, mem_cycles=400, loads_per_warp=2,
                         active_warps_per_sm=48)
    r1, us = _timed(evaluate, stream_app, gpu, repeats=3)
    r2 = evaluate(gemm_app, gpu)
    row("perfmodel", us,
        f"stream_membound={r1.memory_bound}_gemm_membound={r2.memory_bound}")


def bench_link_adjacency() -> None:
    """Pod-level §IV-H analogue: recover a 4x8 torus's direct ICI links."""
    from repro.core.probes.adjacency import SimPod, find_link_adjacency

    pod = SimPod(rows=4, cols=8, seed=47)
    res, us = _timed(find_link_adjacency, pod, repeats=1, n_samples=9)
    correct = sum(res.neighbors[c] == pod.neighbors(c)
                  for c in range(pod.n_chips))
    row("link_adjacency", us,
        f"{correct}/{pod.n_chips}_chips_exact_thr={res.threshold_us:.2f}us")


def bench_topology_query() -> None:
    """The serving story: cold discovery vs warm store hit vs batched query
    throughput over the topology service (ISSUE 2 tentpole headline: a warm
    hit must be >=10x faster than cold discovery — re-serving a stored
    topology is a pure read, not a re-measurement)."""
    import tempfile

    from repro.core import discover_sim, make_h100_like, make_mi210_like
    from repro.core.engine.store import TopologyStore
    from repro.serve.topology_service import TopologyService

    with tempfile.TemporaryDirectory() as td:
        store = TopologyStore(td)
        t0 = time.perf_counter()
        topo_cold, _ = discover_sim(make_h100_like(seed=49), n_samples=17,
                                    store=store)
        cold_s = time.perf_counter() - t0
        warm_s = np.inf
        for _ in range(5):
            t0 = time.perf_counter()
            topo_warm, _ = discover_sim(make_h100_like(seed=49), n_samples=17,
                                        store=store)
            warm_s = min(warm_s, time.perf_counter() - t0)
        identical = topo_cold.to_json() == topo_warm.to_json()

        discover_sim(make_mi210_like(seed=49), n_samples=17, store=store)
        svc = TopologyService(store, hot_set=8)
        paths = ("L1.size", "L2.load_latency", "hbm.bandwidth",
                 "DeviceMemory.read_bw", "L2.segment_size")
        reqs = [(k, p) for k in store.keys() for p in paths] * 200
        svc.query_batch(reqs[:10])       # warm the hot set
        t0 = time.perf_counter()
        answers = svc.query_batch(reqs)
        q_s = time.perf_counter() - t0
        found = sum(a.found for a in answers)
        row("topology_query", warm_s * 1e6,
            f"cold={cold_s*1e6:.0f}us_warm_speedup={cold_s/warm_s:.1f}x_"
            f"batched_qps={len(reqs)/q_s:.0f}_found={found}/{len(reqs)}_"
            f"identical={identical}")


def bench_topology_http() -> None:
    """ISSUE 6 tentpole row: the HTTP front end under concurrent batched
    traffic.  Correctness fields (hard-gated): every lookup found, zero
    transport/5xx errors (``ok``).  Throughput (``batched_qps``) and the
    per-request latency percentiles are warn-only at first — they
    characterize the CI box's loopback + GIL, not the serving design."""
    import tempfile
    import threading

    from repro.core import discover_sim, make_h100_like, make_mi210_like
    from repro.core.engine.store import TopologyStore
    from repro.serve import TopologyClient, TopologyHTTPServer

    with tempfile.TemporaryDirectory() as td:
        store = TopologyStore(td)
        discover_sim(make_h100_like(seed=49), n_samples=9, store=store)
        discover_sim(make_mi210_like(seed=49), n_samples=9, store=store)

        paths = ("L1.size", "L2.load_latency", "hbm.bandwidth",
                 "DeviceMemory.read_bw", "general.clock_domain")
        with TopologyHTTPServer(store) as server:
            keys = store.keys()
            batch = [(k, p) for k in keys for p in paths] * 10   # 100 pairs
            n_threads, n_reqs = 4, 10
            latencies: list[list[float]] = [[] for _ in range(n_threads)]
            found = [0] * n_threads
            errors = [0] * n_threads

            def worker(tid: int) -> None:
                client = TopologyClient(server.url)
                for _ in range(n_reqs):
                    t0 = time.perf_counter()
                    try:
                        results = client.query_batch(batch)
                        found[tid] += sum(r["found"] for r in results)
                    except Exception:   # noqa: BLE001 — counted, gated
                        errors[tid] += 1
                    latencies[tid].append(time.perf_counter() - t0)

            TopologyClient(server.url).query_batch(batch[:10])   # warm
            threads = [threading.Thread(target=worker, args=(i,))
                       for i in range(n_threads)]
            t0 = time.perf_counter()
            for t in threads:
                t.start()
            for t in threads:
                t.join()
            wall_s = time.perf_counter() - t0

        lat_us = np.sort(np.concatenate(latencies)) * 1e6
        total = len(batch) * n_threads * n_reqs
        total_found = sum(found)
        total_errors = sum(errors)
        ok = total_found == total and total_errors == 0
        row("topology_http", wall_s * 1e6,
            f"batched_qps={total/wall_s:.0f}_"
            f"p50={np.percentile(lat_us, 50):.0f}us_"
            f"p99={np.percentile(lat_us, 99):.0f}us_"
            f"found={total_found}/{total}_errors={total_errors}_ok={ok}")


def bench_remote_discovery() -> None:
    """ISSUE 7 tentpole row: the remote discovery write path end to end.

    Submits three sim-backed discovery jobs over a live authenticated
    server (one with an injected transient runner fault that must be
    retried to success), then resubmits one request to prove idempotency
    (store hit, zero runner probes) and compares the remotely-discovered
    topology against a direct ``discover_sim`` of the same request.
    Correctness fields are hard-gated (``completed``, ``retried_ok``,
    ``idem_ok``, ``correct``, ``ok``); the submit->done wall time is
    warn-only — it measures loopback HTTP + the CI box, not the design.
    """
    import tempfile

    from repro.core import discover_sim
    from repro.core.engine.store import TopologyStore
    from repro.core.simulate import SIM_DEVICES
    from repro.serve import TopologyClient, TopologyHTTPServer
    from repro.serve.jobs import JobEngine, TransientRunnerError

    requests = [{"backend": "sim", "device": d, "seed": 7, "n_samples": 9}
                for d in ("h100", "mi210", "v5e")]
    faulted = {"left": 1}

    def inject(job, attempt):
        # exactly one transient fault, on the first attempt the pool makes
        if faulted["left"] > 0 and attempt == 0:
            faulted["left"] -= 1
            raise TransientRunnerError("injected bench fault")

    with tempfile.TemporaryDirectory() as td:
        store = TopologyStore(os.path.join(td, "store"))
        engine = JobEngine(store, workers=2, backoff_base_s=0.01,
                           on_attempt=inject)
        with TopologyHTTPServer(store, auth_token="bench-token",
                                job_engine=engine, job_poll_s=0) as server:
            client = TopologyClient(server.url, auth_token="bench-token",
                                    max_retries=2)
            t0 = time.perf_counter()
            jobs = [client.submit_discovery(r) for r in requests]
            finals = [client.wait(j["job_id"], timeout_s=120, poll_s=0.05)
                      for j in jobs]
            wall_s = time.perf_counter() - t0

            completed = sum(f["state"] == "done" for f in finals)
            # one job ate the injected fault and recovered on attempt 2
            retried_ok = (faulted["left"] == 0
                          and sorted(f["attempts"] for f in finals)
                          == [1, 1, 2]
                          and all(f["result"]["store_hit"] is False
                                  for f in finals))
            # idempotency: resubmitting a completed request is a pure
            # store hit — zero runner probes
            again = client.wait(
                client.submit_discovery(requests[0])["job_id"],
                timeout_s=120, poll_s=0.05)
            idem_ok = (again["state"] == "done"
                       and again["key"] == finals[0]["key"]
                       and again["result"]["store_hit"] is True)

        # the remotely-written topology equals a direct discovery of the
        # same request (modulo free-text notes, which embed wall times)
        direct_store = TopologyStore(os.path.join(td, "direct"))
        discover_sim(SIM_DEVICES["sim-h100"](seed=7), n_samples=9,
                     store=direct_store)

        def doc(s, key):
            return {k: v for k, v in s.get(key).topology.to_json().items()
                    if k != "notes"}

        key = finals[0]["key"]
        correct = (direct_store.keys() == [key]
                   and doc(direct_store, key) == doc(store, key))

    ok = completed == 3 and retried_ok and idem_ok and correct
    row("remote_discovery", wall_s * 1e6,
        f"completed={completed}/3_retried_ok={retried_ok}_"
        f"idem_ok={idem_ok}_correct={correct}_ok={ok}")


def bench_fault_recovery() -> None:
    """ISSUE 9 tentpole row: discovery reliability under injected faults.

    Four legs against one h100 sim device, all hard-gated except the
    overhead ratio's exact value:

    * ``equivalent`` — a discovery under a value-preserving transient
      fault schedule (every fault retried by the engine) is
      ``topology_equivalent`` to the clean run;
    * ``degraded_ok`` — a permanently-failing family lands as an
      ``"unknown"`` attribute with ``degraded`` provenance instead of
      aborting the run;
    * ``resume_ok`` — a discovery killed mid-run leaves a checkpoint, and
      the rerun resumes from it re-probing ZERO persisted rows (exact
      sample-cache miss arithmetic) before producing the equivalent
      topology and clearing the spent checkpoint;
    * ``retry_overhead`` — faulted/clean wall-time ratio, gated against a
      ceiling: retries must cost bounded re-dispatches, not a rerun.
    """
    import tempfile

    from repro.core import make_h100_like
    from repro.core.discover import (DiscoveryRequest, discover,
                                     discover_sim, sim_request_descriptor)
    from repro.core.engine.store import TopologyStore, request_key
    from repro.core.errors import Resilience
    from repro.core.probes import ChaosRunner, FaultSchedule, SimRunner
    from repro.core.topology import PROVENANCE_DEGRADED, topology_equivalent

    n = 9
    families = ("sharing", "device_memory_latency",
                "device_memory_bandwidth")
    policy = Resilience(max_retries=3, sleep=lambda _s: None)

    def request(make_runner, resilience=policy):
        dev = make_h100_like(seed=3)
        return DiscoveryRequest(
            descriptor=sim_request_descriptor(dev, n, None,
                                              resilience=resilience),
            vendor=dev.vendor, model=dev.name,
            backend=f"simulated:{dev.name}",
            make_runner=make_runner, n_samples=n,
            device_families=families, resilience=resilience)

    # leg 1: clean vs transient-faulted equivalence (+ overhead ratio)
    t0 = time.perf_counter()
    clean_topo, clean_t = discover_sim(make_h100_like(seed=3), n_samples=n)
    clean_s = time.perf_counter() - t0
    chaos = {}

    def mk_flaky():
        chaos["r"] = ChaosRunner(
            SimRunner(make_h100_like(seed=3)),
            FaultSchedule(seed=11, transient_rate=0.05,
                          max_faults_per_request=1))
        return chaos["r"]

    t0 = time.perf_counter()
    faulted_topo, faulted_t = discover(request(mk_flaky))
    faulted_s = time.perf_counter() - t0
    equivalent = (chaos["r"].faults_injected > 0
                  and faulted_t.meta["resilience"]["retries"] > 0
                  and faulted_t.meta["resilience"]["degraded"] == []
                  and topology_equivalent(clean_topo, faulted_topo,
                                          rel_tol=1e-6))
    retry_overhead = faulted_s / clean_s

    # leg 2: permanent fault degrades the family, never aborts the run
    topo, t = discover(request(
        lambda: ChaosRunner(SimRunner(make_h100_like(seed=3)),
                            FaultSchedule(seed=7,
                                          permanent_kinds=("bandwidth",)))))
    attr = topo.find_memory("L2").attrs.get("read_bw")
    degraded_ok = ("L2/bandwidth" in t.meta["resilience"]["degraded"]
                   and attr is not None and attr.value == "unknown"
                   and attr.provenance == PROVENANCE_DEGRADED)

    # leg 3: kill mid-run, resume from the checkpoint with zero recompute
    with tempfile.TemporaryDirectory() as td:
        store = TopologyStore(os.path.join(td, "store"))
        try:
            discover(request(
                lambda: ChaosRunner(SimRunner(make_h100_like(seed=3)),
                                    FaultSchedule(seed=5, kill_after=40))),
                store=store)
            resume_ok = False            # the kill never fired: no resume
        except RuntimeError:
            key = request_key(request(
                lambda: SimRunner(make_h100_like(seed=3))).descriptor)
            ckpt = store.load_checkpoint(key)
            resumed, rt = discover(request(
                lambda: SimRunner(make_h100_like(seed=3))), store=store)
            resume_ok = (
                ckpt is not None
                and rt.meta["resume"]["rows"] == len(ckpt[0])
                and rt.meta["cache"]["misses"] + len(ckpt[0])
                == clean_t.meta["cache"]["misses"]
                and topology_equivalent(clean_topo, resumed, rel_tol=1e-6)
                and not store.has_checkpoint(key))

    ok = equivalent and degraded_ok and resume_ok
    row("fault_recovery", faulted_s * 1e6,
        f"equivalent={equivalent}_degraded_ok={degraded_ok}_"
        f"resume_ok={resume_ok}_retry_overhead={retry_overhead:.2f}_ok={ok}")


# ------------------------------------------------------------- framework
def bench_roofline() -> None:
    """Roofline terms per (arch x shape) from the dry-run artifacts."""
    from repro.analysis.report import roofline_table

    terms = roofline_table()
    if not terms:
        row("roofline", 0.0, "no_artifacts_run_dryrun_first")
        return
    for t in terms:
        row(f"roofline_{t.arch}_{t.shape}", t.step_time_s * 1e6,
            f"bound={t.bound}_frac={t.roofline_fraction:.3f}_useful="
            f"{t.useful_ratio:.2f}")


def bench_kernels() -> None:
    import jax
    import jax.numpy as jnp
    from repro.kernels import ops, ref
    from repro.kernels.flash_attention import flash_attention

    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (1, 4, 256, 64), jnp.float32)
    k = jax.random.normal(ks[1], (1, 2, 256, 64), jnp.float32)
    v = jax.random.normal(ks[2], (1, 2, 256, 64), jnp.float32)
    want, us_ref = _timed(lambda: np.asarray(ref.attention_ref(q, k, v)))
    got = np.asarray(flash_attention(q, k, v, block_q=128, block_k=128))
    err = float(np.max(np.abs(got - want)))
    row("kernel_flash_attention", us_ref, f"maxerr={err:.1e}_vs_dense_ref")

    r = jax.random.normal(ks[0], (1, 64, 2, 16), jnp.float32)
    kk = jax.random.normal(ks[1], (1, 64, 2, 16), jnp.float32)
    vv = jax.random.normal(ks[2], (1, 64, 2, 16), jnp.float32)
    w = jax.random.uniform(ks[0], (1, 64, 2, 16), jnp.float32, 0.1, 0.95)
    u = jax.random.normal(ks[1], (2, 16), jnp.float32)
    (want_y, _), us_ref = _timed(lambda: ref.wkv6_ref(r, kk, vv, w, u))
    got_y, _ = ops.wkv6(r, kk, vv, w, u, chunk=16)
    err = float(np.max(np.abs(np.asarray(got_y) - np.asarray(want_y))))
    row("kernel_wkv6", us_ref, f"maxerr={err:.1e}_vs_scan_ref")


def bench_train_step() -> None:
    import jax
    from repro.configs import get_config
    from repro.data import ByteCorpus, DataConfig
    from repro.models import get_model
    from repro.train import TrainConfig, init_train_state, make_train_step

    cfg = get_config("internlm2-1.8b").smoke().replace(dtype="float32")
    model = get_model(cfg)
    tc = TrainConfig()
    data = ByteCorpus(DataConfig(vocab_size=cfg.vocab_size, seq_len=64,
                                 global_batch=8))
    state, _ = init_train_state(model, jax.random.PRNGKey(0), tc)
    step = jax.jit(make_train_step(model, tc))
    batch = data.batch_at(0)
    state, m = step(state, batch)              # compile
    t0 = time.perf_counter_ns()
    for i in range(5):
        state, m = step(state, data.batch_at(i + 1))
    jax.block_until_ready(state)
    us = (time.perf_counter_ns() - t0) / 5e3
    row("train_step_smoke", us, f"loss={float(m['loss']):.3f}")


ALL_BENCHES = (bench_table1_coverage, bench_table3_validation,
               bench_fig2_reduction, bench_runtime_breakdown,
               bench_engine_speedup, bench_adaptive_speedup,
               bench_topology_query, bench_topology_http,
               bench_remote_discovery, bench_fault_recovery,
               bench_parallel_speedup, bench_pallas_interp, bench_fig5_stream,
               bench_perfmodel, bench_link_adjacency, bench_roofline,
               bench_kernels, bench_train_step)


def main(argv: list[str] | None = None) -> None:
    global JSON_MODE
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", action="store_true",
                    help="emit a JSON array of rows on stdout instead of CSV")
    ap.add_argument("--out", default="bench_current.json",
                    help="also write the JSON rows to this file (default "
                         "bench_current.json — a git-ignored generated "
                         "artifact; pass --out '' to skip writing)")
    ap.add_argument("--only", default=None,
                    help="comma-separated benchmark names "
                         "(e.g. engine_speedup,topology_query)")
    args = ap.parse_args(argv)
    JSON_MODE = args.json

    benches = ALL_BENCHES
    if args.only:
        wanted = {w.strip() for w in args.only.split(",") if w.strip()}
        benches = [fn for fn in ALL_BENCHES
                   if fn.__name__.removeprefix("bench_") in wanted]
        missing = wanted - {fn.__name__.removeprefix("bench_")
                            for fn in benches}
        if missing:
            ap.error(f"unknown benchmarks: {sorted(missing)}")

    for fn in benches:
        try:
            fn()
        except Exception as e:  # noqa: BLE001
            # Same name a successful row would use, so the CI gate can match
            # a crashed gated bench and surface the exception in its report.
            row(fn.__name__.removeprefix("bench_"), 0.0,
                f"ERROR_{type(e).__name__}_{e}")

    if args.json:
        print(json.dumps(rows_as_json(), indent=2), flush=True)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(rows_as_json(), f, indent=2)


if __name__ == "__main__":
    main()
