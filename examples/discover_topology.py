"""MT4G-equivalent CLI: discover and report a device topology.

    PYTHONPATH=src python examples/discover_topology.py --device sim-h100 -j out.json
    PYTHONPATH=src python examples/discover_topology.py --device host --quick

Mirrors the paper's tool surface: full-suite by default, JSON to stdout,
optional markdown report, per-family timing like §V-A.
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.core import SIM_DEVICES, discover_host, discover_sim


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--device", default="sim-h100",
                    choices=sorted(SIM_DEVICES) + ["host"])
    ap.add_argument("--samples", type=int, default=17)
    ap.add_argument("--elements", nargs="*", default=None,
                    help="restrict to these memory elements (like mt4g CLI)")
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("-j", "--json-out", default=None)
    ap.add_argument("-p", "--markdown", action="store_true")
    args = ap.parse_args()

    if args.device == "host":
        topo, timings = discover_host(quick=args.quick)
    else:
        dev = SIM_DEVICES[args.device](seed=0)
        topo, timings = discover_sim(dev, n_samples=args.samples,
                                     elements=args.elements)

    if args.markdown:
        print(topo.to_markdown())
    else:
        print(topo.dumps())
    print(f"\n# timings: total {timings.total:.2f}s "
          f"{ {k: round(v, 3) for k, v in timings.per_family.items()} }",
          file=sys.stderr)
    if args.json_out:
        with open(args.json_out, "w") as f:
            f.write(topo.dumps())
        print(f"# wrote {args.json_out}", file=sys.stderr)


if __name__ == "__main__":
    main()
