"""MT4G-equivalent CLI: discover and report a device topology.

    PYTHONPATH=src python examples/discover_topology.py --device sim-h100 -j out.json
    PYTHONPATH=src python examples/discover_topology.py --device host --quick
    PYTHONPATH=src python examples/discover_topology.py --device pallas -p
    PYTHONPATH=src python examples/discover_topology.py --device sim-h100 \
        --store /tmp/topo-store        # second run: pure store hit, 0 probes

Mirrors the paper's tool surface: full-suite by default, JSON to stdout,
optional markdown report, per-family timing like §V-A.  ``--store DIR``
makes discovery read-/write-through the persistent topology store
(``--refresh`` forces a re-measure that still writes through).
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.core import SIM_DEVICES, discover_host, discover_pallas, discover_sim


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--device", default="sim-h100",
                    choices=sorted(SIM_DEVICES) + ["host", "pallas"])
    ap.add_argument("--samples", type=int, default=17)
    ap.add_argument("--elements", nargs="*", default=None,
                    help="restrict to these memory elements (like mt4g CLI)")
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--store", default=None, metavar="DIR",
                    help="persistent topology store directory "
                         "(read-through/write-through)")
    ap.add_argument("--refresh", action="store_true",
                    help="with --store: re-measure even on a stored hit")
    ap.add_argument("--adaptive", action="store_true",
                    help="adaptive coarse-to-fine sweeps (SweepBudget "
                         "defaults) instead of dense sweeps; identical "
                         "discrete attributes, a fraction of the probes "
                         "(the Pallas backend plans by default)")
    ap.add_argument("--gc-max-entries", type=int, default=None,
                    help="with --store: retention sweep after persisting "
                         "(keep at most N newest topologies)")
    ap.add_argument("-j", "--json-out", default=None)
    ap.add_argument("-p", "--markdown", action="store_true")
    args = ap.parse_args()

    store = None
    if args.store:
        from repro.core.engine.store import TopologyStore
        store = TopologyStore(args.store)
    gc_policy = None
    if args.gc_max_entries is not None:
        from repro.core import GcPolicy
        gc_policy = GcPolicy(max_entries=args.gc_max_entries)
    budget = None
    if args.adaptive:
        from repro.core import SweepBudget
        budget = SweepBudget()

    if args.device == "host":
        topo, timings = discover_host(quick=args.quick, store=store,
                                      refresh=args.refresh,
                                      gc_policy=gc_policy)
    elif args.device == "pallas":
        topo, timings = discover_pallas(n_samples=min(args.samples, 9),
                                        elements=args.elements, store=store,
                                        refresh=args.refresh,
                                        gc_policy=gc_policy)
    else:
        dev = SIM_DEVICES[args.device](seed=0)
        topo, timings = discover_sim(dev, n_samples=args.samples,
                                     elements=args.elements, store=store,
                                     refresh=args.refresh, budget=budget,
                                     gc_policy=gc_policy)
    if store is not None:
        print(f"# store: {store.stats()}", file=sys.stderr)

    if args.markdown:
        print(topo.to_markdown())
    else:
        print(topo.dumps())
    print(f"\n# timings: total {timings.total:.2f}s "
          f"{ {k: round(v, 3) for k, v in timings.per_family.items()} }",
          file=sys.stderr)
    if args.json_out:
        with open(args.json_out, "w") as f:
            f.write(topo.dumps())
        print(f"# wrote {args.json_out}", file=sys.stderr)


if __name__ == "__main__":
    main()
