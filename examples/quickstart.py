"""Quickstart: discover a topology, consult the perf model, train a few steps.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys

sys.path.insert(0, "src")

import jax

from repro.core import discover_sim, make_v5e_like, spec_from_topology, TPU_V5E
from repro.core.perfmodel import AppParams, evaluate, gpu_params_from_topology
from repro.configs import get_config
from repro.data import ByteCorpus, DataConfig
from repro.models import get_model
from repro.train import TrainConfig, train_loop


def main() -> None:
    # 1. MT4G-style auto-discovery (simulated v5e here; HostRunner/TPU on
    #    real hardware) -> topology report.
    topo, timings = discover_sim(make_v5e_like(seed=0), n_samples=9)
    print(topo.to_markdown())
    print(f"[discovery took {timings.total:.2f}s]")

    # 2. The discovered values parameterize the Hong&Kim perf model (§VI-A).
    gpu = gpu_params_from_topology(topo)
    app = AppParams(comp_cycles=200, mem_cycles=3000, loads_per_warp=8,
                    active_warps_per_sm=16)
    verdict = evaluate(app, gpu)
    print(f"perf model: CWP={verdict.cwp:.1f} MWP={verdict.mwp:.1f} "
          f"memory_bound={verdict.memory_bound}")

    # 3. ... and overlay onto the catalog record the roofline analyzer uses.
    spec = spec_from_topology(topo, TPU_V5E)
    print(f"spec: hbm_bw={spec.hbm_bandwidth/1e9:.0f} GB/s "
          f"(catalog said {TPU_V5E.hbm_bandwidth/1e9:.0f})")

    # 4. Train a tiny model for a few steps on the byte corpus.
    cfg = get_config("internlm2-1.8b").smoke().replace(dtype="float32")
    model = get_model(cfg)
    tc = TrainConfig()
    data = ByteCorpus(DataConfig(vocab_size=cfg.vocab_size, seq_len=64,
                                 global_batch=8))
    state, hist = train_loop(model, tc, data, steps=10)
    print("loss:", " -> ".join(f"{m['loss']:.3f}" for _, m in hist[::3]))


if __name__ == "__main__":
    main()
