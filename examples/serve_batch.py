"""Serving example: batched requests through prefill + continuous-batching
decode on a small model.

    PYTHONPATH=src python examples/serve_batch.py
"""
import sys

sys.path.insert(0, "src")

import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import get_model
from repro.serve import Engine, ServeConfig


def main() -> None:
    cfg = get_config("internlm2-1.8b").smoke().replace(dtype="float32")
    model = get_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    eng = Engine(model, params, ServeConfig(max_len=64, slots=4))

    rng = np.random.default_rng(0)
    requests = [rng.integers(0, cfg.vocab_size, size=8).astype(np.int32)
                for _ in range(10)]
    t0 = time.perf_counter()
    outs = eng.serve(requests, max_new=16)
    dt = time.perf_counter() - t0
    tokens = sum(o.size for o in outs)
    print(f"served {len(requests)} requests, {tokens} new tokens in "
          f"{dt:.2f}s ({tokens/dt:.1f} tok/s on CPU smoke model)")
    for i, o in enumerate(outs[:3]):
        print(f"req{i}: prompt={requests[i][:4]}... -> {o}")

    # Decode correctness contract: engine output == argmax of full forwards.
    from repro.models import Runtime
    fwd = jax.jit(lambda p, b: model.forward(p, b, Runtime(q_chunk=0)))
    toks = requests[0][None, :]
    import jax.numpy as jnp
    for step in range(4):
        logits, _ = fwd(params, {"tokens": jnp.asarray(toks, jnp.int32)})
        nxt = int(np.argmax(np.asarray(logits, np.float32)[0, -1]))
        assert nxt == int(outs[0][step]), "engine/decode mismatch"
        toks = np.concatenate([toks, [[nxt]]], axis=1)
    print("decode path verified against full forward (first 4 tokens).")


if __name__ == "__main__":
    main()
