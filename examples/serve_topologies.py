"""Serve a topology store over HTTP (the MT4G §V consumption path).

    PYTHONPATH=src python examples/serve_topologies.py --store /tmp/topo-store
    PYTHONPATH=src python examples/serve_topologies.py --populate --port 8423

Starts the threaded JSON front end (``repro.serve.TopologyHTTPServer``)
over a persistent ``TopologyStore``.  ``--populate`` discovers the two
simulated validation devices into the store first if it is empty, so a
fresh checkout can demo the full loop:

    curl -s localhost:8423/topologies | python -m json.tool
    curl -s "localhost:8423/topologies/<key>/query?path=L1.size"
    curl -s localhost:8423/metrics | python -m json.tool

The server also accepts remote discovery jobs (``POST /discoveries``,
see docs/HTTP_API.md); ``--workers`` sizes the job pool and
``--auth-token`` gates the mutating endpoints behind a bearer token:

    curl -s -X POST localhost:8423/discoveries \
         -H 'Authorization: Bearer secret' \
         -d '{"backend": "sim", "device": "v5e", "seed": 3}'

Runs until interrupted; Ctrl-C drains in-flight requests before exiting.
"""
import argparse
import sys
import tempfile

sys.path.insert(0, "src")

from repro.core import discover_sim, make_h100_like, make_mi210_like
from repro.core.engine.store import TopologyStore
from repro.serve import TopologyHTTPServer


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--store", default=None, metavar="DIR",
                    help="topology store directory (default: a temp dir)")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8423,
                    help="listen port (0 = ephemeral)")
    ap.add_argument("--hot-set", type=int, default=8,
                    help="LRU hot-set size of the query service")
    ap.add_argument("--populate", action="store_true",
                    help="discover the simulated validation devices into "
                         "the store first when it is empty")
    ap.add_argument("--samples", type=int, default=9)
    ap.add_argument("--auth-token", default=None, metavar="TOKEN",
                    help="require 'Authorization: Bearer TOKEN' on the "
                         "mutating endpoints (reads stay open)")
    ap.add_argument("--workers", type=int, default=2,
                    help="discovery job worker pool size (default 2)")
    args = ap.parse_args()

    root = args.store or tempfile.mkdtemp(prefix="mt4g-store-")
    store = TopologyStore(root)
    if args.populate and not store.keys():
        print(f"# populating {root} from the simulated validation devices",
              file=sys.stderr)
        for make, seed in ((make_h100_like, 71), (make_mi210_like, 72)):
            topo, _ = discover_sim(make(seed=seed), n_samples=args.samples,
                                   store=store)
            print(f"#   discovered {topo.model}", file=sys.stderr)
    if not store.keys():
        print(f"# warning: store {root} is empty — every key lookup will "
              f"404 (use --populate or discover with --store first)",
              file=sys.stderr)

    server = TopologyHTTPServer(store, host=args.host, port=args.port,
                                hot_set=args.hot_set,
                                auth_token=args.auth_token,
                                job_workers=args.workers)
    server.start()
    print(f"# serving {len(store.keys())} topologies on {server.url} "
          f"(store: {root}, {args.workers} discovery workers, "
          f"auth {'on' if args.auth_token else 'off'})", file=sys.stderr)
    print(f"#   try: curl -s {server.url}/topologies", file=sys.stderr)
    try:
        while True:
            server._thread.join(timeout=3600)
    except KeyboardInterrupt:
        print("\n# draining in-flight requests (Ctrl-C again to abandon)...",
              file=sys.stderr)
        try:
            server.stop()
        except KeyboardInterrupt:
            # terminals deliver Ctrl-C to the whole process group, so a
            # second interrupt mid-drain is common — abandon, don't traceback
            print("# abandoning drain", file=sys.stderr)
            sys.exit(130)
        print(f"# final stats: {server.service.stats()}", file=sys.stderr)


if __name__ == "__main__":
    main()
