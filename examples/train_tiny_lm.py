"""End-to-end training driver: byte-level LM on the bundled corpus with
checkpointing, supervised restart, and straggler detection.

Default is a ~10M-param model x 200 steps (CPU-friendly); ``--preset 100m``
selects a ~100M-param config for real hardware.

    PYTHONPATH=src python examples/train_tiny_lm.py --steps 200
"""
import argparse
import sys

sys.path.insert(0, "src")

import jax

from repro.checkpoint import Checkpointer
from repro.configs import get_config
from repro.data import ByteCorpus, DataConfig
from repro.ft import StragglerDetector, Supervisor
from repro.models import get_model
from repro.train import OptConfig, TrainConfig, init_train_state, \
    make_train_step, train_loop


def build(preset: str):
    base = get_config("internlm2-1.8b")
    if preset == "100m":
        cfg = base.replace(name="bytes-100m", n_layers=12, d_model=768,
                           n_heads=12, n_kv_heads=4, head_dim=64, d_ff=2048,
                           vocab_size=256, dtype="float32")
    else:
        cfg = base.replace(name="bytes-10m", n_layers=4, d_model=256,
                           n_heads=8, n_kv_heads=4, head_dim=32, d_ff=1024,
                           vocab_size=256, dtype="float32")
    return cfg


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--preset", choices=["10m", "100m"], default="10m")
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--ckpt-dir", default="artifacts/tiny_lm_ckpt")
    args = ap.parse_args()

    cfg = build(args.preset)
    model = get_model(cfg)
    n_params = sum(x.size for x in jax.tree.leaves(model.param_shapes()))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M")

    tc = TrainConfig(
        opt=OptConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps),
        microbatches=2, ckpt_every=50)
    data = ByteCorpus(DataConfig(vocab_size=256, seq_len=args.seq,
                                 global_batch=args.batch))
    ck = Checkpointer(args.ckpt_dir)
    straggler = StragglerDetector()
    state, _ = init_train_state(model, jax.random.PRNGKey(0), tc)
    step_fn = jax.jit(make_train_step(model, tc), donate_argnums=0)

    def train_fn(st, start):
        return train_loop(model, tc, data, steps=args.steps, state=st,
                          start_step=start, checkpointer=ck, step_fn=step_fn,
                          straggler=straggler)

    sup = Supervisor(ck, max_restarts=3)
    state, hist = sup.run(train_fn, state)

    losses = [m["loss"] for _, m in hist]
    times = [m["step_time_s"] for _, m in hist]
    print(f"steps={len(hist)} loss {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"(ln(256)={5.545:.3f} is uniform)")
    print(f"median step {sorted(times)[len(times)//2]*1e3:.0f} ms; "
          f"stragglers flagged: {len(straggler.flagged)}; "
          f"restarts: {sup.restarts}")
    assert losses[-1] < losses[0] * 0.7, "training failed to reduce loss"


if __name__ == "__main__":
    main()
