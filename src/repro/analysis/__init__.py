from .hlo import CollectiveStats, parse_collectives
from .roofline import RooflineTerms, model_flops, roofline_from_cell

__all__ = ["CollectiveStats", "parse_collectives", "RooflineTerms",
           "model_flops", "roofline_from_cell"]
