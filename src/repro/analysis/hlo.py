"""Parse collective-communication bytes out of compiled HLO text.

``compiled.cost_analysis()`` has no collective term, so — per the assignment —
we sum operand sizes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute op in the (post-SPMD, per-device) module.

Byte convention (documented because parsers differ): for every collective we
count the bytes of the op's *result* shape(s) on one device — for all-gather
that is the gathered output (what crosses links, up to the (n-1)/n factor the
roofline model treats as ~1), for all-reduce/reduce-scatter/all-to-all/
permute the result equals the participating buffer. ``-done`` halves of
async pairs are skipped so nothing is double-counted.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

__all__ = ["CollectiveStats", "parse_collectives", "DTYPE_BYTES"]

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
        "collective-permute")

# e.g.  %all-gather.1 = f32[256,128]{1,0} all-gather(...)
#       %ar = (f32[8], f32[16]) all-reduce-start(...)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_LINE_RE = re.compile(
    r"=\s*(?P<shapes>\([^)]*\)|\S+)\s+(?P<op>" + "|".join(_OPS) +
    r")(?P<suffix>[-\w.]*)\(")


@dataclass
class CollectiveStats:
    bytes_by_op: dict[str, int] = field(default_factory=dict)
    count_by_op: dict[str, int] = field(default_factory=dict)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_op.values())

    @property
    def total_count(self) -> int:
        return sum(self.count_by_op.values())

    def to_json(self) -> dict:
        return {"bytes_by_op": self.bytes_by_op,
                "count_by_op": self.count_by_op,
                "total_bytes": self.total_bytes,
                "total_count": self.total_count}


def _shape_bytes(text: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dtype]
    return total


def parse_collectives(hlo_text: str) -> CollectiveStats:
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _LINE_RE.search(line)
        if not m:
            continue
        if m.group("suffix").startswith("-done"):
            continue                      # async pair: count the -start only
        op = m.group("op")
        nbytes = _shape_bytes(m.group("shapes"))
        stats.bytes_by_op[op] = stats.bytes_by_op.get(op, 0) + nbytes
        stats.count_by_op[op] = stats.count_by_op.get(op, 0) + 1
    return stats
