"""Trip-count-aware cost model over optimized HLO text.

``compiled.cost_analysis()`` counts every ``while`` body ONCE — for
scan-over-layers models that undercounts FLOPs/bytes/collectives by the
layer count x microbatch count (observed 100-366x on the baseline table).
This module re-derives the three roofline inputs from the optimized HLO:

  * computations are parsed (name -> instructions, shapes);
  * ``while`` trip counts are read from the loop condition's
    ``compare(.., constant(N)), direction=LT`` pattern (jax scans lower to
    exactly this; unknown conditions conservatively count as 1 and are
    reported);
  * a DFS from ENTRY accumulates, per instruction, multiplier-weighted:
      - dot FLOPs (2 x output elements x contraction size) — MXU flops,
        including dots inside fusion subcomputations (XLA's own convention);
      - bytes accessed at fusion boundaries (output + operands of top-level
        ops; ops fused into a computation don't touch HBM — again XLA's
        convention);
      - collective bytes by op kind.

Validated in tests against hand-computable modules (scan of matmuls).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

__all__ = ["HloCost", "analyze_hlo"]

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# instruction line:  %name = TYPE opcode(operands...), attrs
# NOTE: tuple types contain /*index=N*/ comments (with '='), so the tuple
# branch matches anything up to the first ')' that closes it — tuple types in
# HLO never nest parens.
_INSTR_RE = re.compile(
    r"^\s*(?P<root>ROOT\s+)?%([\w.\-]+)\s*=\s*((?:\([^()]*\))|(?:[\w\[\],{}\/\* ]+?))\s+"
    r"([\w\-]+)\((.*?)\)(.*)$")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\((.*?)\)\s*->")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_elems(type_str: str) -> int:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return 0
    n = 1
    for d in m.group(2).split(","):
        if d:
            n *= int(d)
    return n


@dataclass
class _Instr:
    name: str
    type_str: str
    opcode: str
    operands: list[str]
    attrs: str
    raw_operands: str = ""
    is_root: bool = False


@dataclass
class _Computation:
    name: str
    instrs: list[_Instr] = field(default_factory=list)


@dataclass
class HloCost:
    dot_flops: float = 0.0
    bytes_accessed: float = 0.0
    collective_bytes: dict[str, float] = field(default_factory=dict)
    collective_counts: dict[str, float] = field(default_factory=dict)
    unknown_trip_loops: int = 0
    bytes_by_op: dict[str, float] = field(default_factory=dict)

    def _add_bytes(self, op: str, n: float) -> None:
        self.bytes_accessed += n
        self.bytes_by_op[op] = self.bytes_by_op.get(op, 0.0) + n

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())

    def to_json(self) -> dict:
        return {"dot_flops": self.dot_flops,
                "bytes_accessed": self.bytes_accessed,
                "bytes_by_op": {k: round(v) for k, v in sorted(
                    self.bytes_by_op.items(), key=lambda kv: -kv[1])},
                "collective_bytes": self.collective_bytes,
                "collective_counts": self.collective_counts,
                "total_collective_bytes": self.total_collective_bytes,
                "unknown_trip_loops": self.unknown_trip_loops}


def _parse_module(text: str):
    comps: dict[str, _Computation] = {}
    types: dict[str, str] = {}
    entry: str | None = None
    cur: _Computation | None = None

    for raw in text.splitlines():
        line = raw.strip()
        if not line:
            continue
        if (("->" in line) and line.endswith("{")
                and (line.startswith("%") or line.startswith("ENTRY"))):
            m = _COMP_HDR_RE.match(line)
            if m:
                cur = _Computation(m.group(1))
                comps[cur.name] = cur
                if line.startswith("ENTRY"):
                    entry = cur.name
                # parameters: "name: type, name: type"
                params = m.group(2)
                for pm in re.finditer(r"([\w.\-]+)\s*:\s*((?:\([^()]*\))|[^,]+)",
                                      params):
                    types[pm.group(1)] = pm.group(2)
                continue
        if line == "}":
            continue
        if cur is None:
            continue
        im = _INSTR_RE.match(line)
        if not im:
            continue
        root, name, type_str, opcode, operand_str, attrs = im.groups()
        operands = _OPERAND_RE.findall(operand_str)
        cur.instrs.append(_Instr(name, type_str.strip(), opcode, operands,
                                 attrs, operand_str, is_root=bool(root)))
        types[name] = type_str.strip()
    return comps, types, entry


def _trip_count(cond: _Computation) -> int | None:
    """jax scan conditions: compare(counter, constant(N)), direction=LT.

    Constants print as ``%c = s32[] constant(24)`` — the literal lands in the
    operand field of the parsed instruction line.
    """
    consts: dict[str, int] = {}
    for ins in cond.instrs:
        if ins.opcode == "constant":
            blob = ins.raw_operands + " " + ins.attrs
            mm = re.search(r"(-?\d+)", blob)
            if mm:
                consts[ins.name] = int(mm.group(1))
    for ins in cond.instrs:
        if ins.opcode == "compare" and "direction=LT" in ins.attrs:
            for op in ins.operands:
                if op in consts:
                    return consts[op]
    return None


def _dot_flops(ins: _Instr, types: dict[str, str]) -> float:
    out_elems = _shape_elems(ins.type_str)
    # contraction size from lhs operand shape + lhs_contracting_dims
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.attrs)
    if not m or not ins.operands:
        return 2.0 * out_elems  # degenerate
    lhs_type = types.get(ins.operands[0], "")
    sm = _SHAPE_RE.search(lhs_type)
    if not sm:
        return 2.0 * out_elems
    dims = [int(d) for d in sm.group(2).split(",") if d]
    csize = 1
    for idx in (int(i) for i in m.group(1).split(",") if i):
        if idx < len(dims):
            csize *= dims[idx]
    return 2.0 * out_elems * csize


_SKIP_BYTES = {"parameter", "constant", "tuple", "get-tuple-element",
               "bitcast", "while", "call", "conditional", "after-all",
               "iota", "broadcast"}

_SLICE_OPS = {"dynamic-slice", "slice"}


def _fusion_bytes(ins: _Instr, comps: dict, types: dict,
                  called: str | None) -> float:
    """Bytes at a fusion boundary with XLA's in-place conventions:

    * an operand whose only in-fusion consumers are (dynamic-)slices is
      charged those slices' outputs, not the whole buffer (loop-carried KV
      caches are read one layer-slice at a time);
    * a fusion whose root is dynamic-update-slice updates in place: charge
      the update bytes rather than the whole result.
    """
    out_bytes = _shape_bytes(ins.type_str)
    opnd_bytes = sum(_shape_bytes(types.get(o, "")) for o in ins.operands)
    comp = comps.get(called) if called else None
    if comp is None or not comp.instrs:
        return out_bytes + opnd_bytes

    local_types = {i.name: i.type_str for i in comp.instrs}
    root = next((i for i in comp.instrs if i.is_root), comp.instrs[-1])
    dus_root = root.opcode == "dynamic-update-slice"
    dus_target = root.operands[0] if dus_root and root.operands else None
    # parameter index -> instruction name
    param_name: dict[int, str] = {}
    for i in comp.instrs:
        if i.opcode == "parameter":
            mm = re.search(r"parameter\((\d+)\)",
                           f"parameter({i.raw_operands})")
            if mm:
                param_name[int(mm.group(1))] = i.name

    opnd_bytes = 0.0
    for idx, o in enumerate(ins.operands):
        pname = param_name.get(idx)
        full = _shape_bytes(types.get(o, ""))
        if pname is None:
            opnd_bytes += full
            continue
        consumers = [i for i in comp.instrs if pname in i.operands]
        if dus_root and dus_target is not None and consumers == [root] \
                and pname == dus_target:
            continue  # in-place DUS target: aliased, not re-read
        if consumers and all(i.opcode in _SLICE_OPS for i in consumers):
            opnd_bytes += sum(_shape_bytes(i.type_str) for i in consumers)
        else:
            opnd_bytes += full

    if dus_root and len(root.operands) > 1:
        upd = _shape_bytes(local_types.get(root.operands[1], ""))
        out_bytes = 2 * upd
    return out_bytes + opnd_bytes


def analyze_hlo(text: str) -> HloCost:
    comps, types, entry = _parse_module(text)
    cost = HloCost()
    if entry is None:
        return cost

    # constant parse for while conditions happens lazily per computation.
    def walk(comp_name: str, mult: float, seen: tuple):
        comp = comps.get(comp_name)
        if comp is None or comp_name in seen:
            return
        seen = seen + (comp_name,)
        for ins in comp.instrs:
            op = ins.opcode
            if op == "while":
                body = re.search(r"body=%?([\w.\-]+)", ins.attrs)
                cond = re.search(r"condition=%?([\w.\-]+)", ins.attrs)
                trips = None
                tc = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', ins.attrs)
                if tc:
                    trips = int(tc.group(1))
                elif cond and cond.group(1) in comps:
                    trips = _trip_count(comps[cond.group(1)])
                if trips is None:
                    trips = 1
                    cost.unknown_trip_loops += 1
                if body:
                    walk(body.group(1), mult * trips, seen)
                continue
            if op == "fusion":
                fc = re.search(r"calls=%?([\w.\-]+)", ins.attrs)
                if fc:
                    _flops_only(fc.group(1), mult, seen)
                cost._add_bytes("fusion",
                                mult * _fusion_bytes(ins, comps, types,
                                                     fc.group(1) if fc else None))
                continue
            if op in ("call", "conditional"):
                for target in re.findall(
                        r"(?:to_apply|calls|branch_computations=\{)[=%]*([\w.\-]+)",
                        ins.attrs):
                    walk(target, mult, seen)
                continue
            if op == "dot":
                cost.dot_flops += mult * _dot_flops(ins, types)
            if op in _COLLECTIVES or any(
                    op == c + "-start" for c in _COLLECTIVES):
                base = op[:-6] if op.endswith("-start") else op
                nbytes = mult * _shape_bytes(ins.type_str)
                cost.collective_bytes[base] = \
                    cost.collective_bytes.get(base, 0.0) + nbytes
                cost.collective_counts[base] = \
                    cost.collective_counts.get(base, 0.0) + mult
            if op.endswith("-done"):
                continue
            if op in _SKIP_BYTES:
                continue
            if op in ("dynamic-slice", "slice"):
                # XLA's HloCostAnalysis convention: a slice reads only the
                # sliced bytes, not the whole operand buffer.
                cost._add_bytes(op, mult * 2 * _shape_bytes(ins.type_str))
                continue
            if op == "dynamic-update-slice":
                # In-place update: read+write of the update operand only.
                upd = (_shape_bytes(types.get(ins.operands[1], ""))
                       if len(ins.operands) > 1 else 0)
                cost._add_bytes(op, mult * 2 * upd)
                continue
            if op == "gather":
                idx = (_shape_bytes(types.get(ins.operands[1], ""))
                       if len(ins.operands) > 1 else 0)
                cost._add_bytes(op, mult * (2 * _shape_bytes(ins.type_str)
                                            + idx))
                continue
            cost._add_bytes(op, mult * (
                _shape_bytes(ins.type_str)
                + sum(_shape_bytes(types.get(o, "")) for o in ins.operands)))

    def _flops_only(comp_name: str, mult: float, seen: tuple):
        comp = comps.get(comp_name)
        if comp is None or comp_name in seen:
            return
        seen = seen + (comp_name,)
        for ins in comp.instrs:
            if ins.opcode == "dot":
                cost.dot_flops += mult * _dot_flops(ins, types)
            elif ins.opcode == "fusion":
                fc = re.search(r"calls=%?([\w.\-]+)", ins.attrs)
                if fc:
                    _flops_only(fc.group(1), mult, seen)

    walk(entry, 1.0, ())
    return cost
