"""Roofline report generation from dry-run artifacts (EXPERIMENTS.md §Roofline)."""
from __future__ import annotations

import glob
import json
import os

from ..configs import get_config, shape_for
from ..core.catalog import TPU_V5E, HardwareSpec
from .roofline import RooflineTerms, roofline_from_cell

__all__ = ["load_cells", "roofline_table", "markdown_table"]


def load_cells(art_dir: str = "artifacts/dryrun", mesh: str = "single"):
    cells = []
    for f in sorted(glob.glob(os.path.join(art_dir, mesh, "*.json"))):
        d = json.load(open(f))
        if d.get("ok") and "cost" in d:
            cells.append(d)
    return cells


def roofline_table(art_dir: str = "artifacts/dryrun", mesh: str = "single",
                   hw: HardwareSpec = TPU_V5E) -> list[RooflineTerms]:
    out = []
    for cell in load_cells(art_dir, mesh):
        cfg = get_config(cell["arch"])
        shape = shape_for(cell["shape"])
        out.append(roofline_from_cell(cell, cfg, shape, hw,
                                      chips=cell["devices"]))
    return out


def _advice(t: RooflineTerms) -> str:
    if t.bound == "collective":
        return "cut collective bytes (sharding/overlap/compression)"
    if t.bound == "memory":
        if t.shape.startswith("decode") or t.shape.startswith("long"):
            return "decode is cache-read bound: shrink KV bytes (quant/GQA)"
        return "reduce HBM traffic (fusion/remat policy/dtype)"
    return "compute-bound: raise MFU via larger per-chip tiles"


def markdown_table(terms: list[RooflineTerms]) -> str:
    lines = [
        "| arch | shape | compute_s | memory_s | collective_s | bound | "
        "MODEL_FLOPS | useful | roofline_frac | next lever |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for t in sorted(terms, key=lambda t: (t.arch, t.shape)):
        lines.append(
            f"| {t.arch} | {t.shape} | {t.compute_s:.3e} | {t.memory_s:.3e} "
            f"| {t.collective_s:.3e} | **{t.bound}** | {t.model_flops:.2e} "
            f"| {t.useful_ratio:.2f} | {t.roofline_fraction:.2%} "
            f"| {_advice(t)} |")
    return "\n".join(lines)


if __name__ == "__main__":
    terms = roofline_table()
    print(markdown_table(terms))
    print()
    worst = sorted(terms, key=lambda t: t.roofline_fraction)[:5]
    print("worst roofline fractions:")
    for t in worst:
        print(f"  {t.arch}/{t.shape}: {t.roofline_fraction:.2%} ({t.bound})")
    coll = sorted(terms, key=lambda t: -(t.collective_s / t.step_time_s))[:5]
    print("most collective-bound:")
    for t in coll:
        print(f"  {t.arch}/{t.shape}: coll {t.collective_s:.3e}s vs step "
              f"{t.step_time_s:.3e}s")
