"""Three-term roofline from the compiled dry-run (assignment §Roofline).

    compute    = HLO_FLOPs_per_device   / peak_FLOP/s_per_chip
    memory     = HLO_bytes_per_device   / HBM_bw_per_chip
    collective = coll_bytes_per_device  / (ici_links_per_chip * link_bw)

``cost_analysis``/HLO text are per-device (post-SPMD) so per-chip constants
divide directly — equivalent to the assignment's total/(chips x bw) form.
Hardware constants come from ``core.catalog`` (or a discovered topology via
``spec_from_topology`` — the MT4G integration point, paper §VI-B).

Also reported per cell: MODEL_FLOPS = 6*N*D (dense; 6*N_active*D for MoE;
x3 only for training — fwd 2ND + bwd 4ND), the MODEL/HLO flops ratio
(remat/redundancy waste detector), the dominant term, and the roofline
fraction = dominant / sum(terms proxy).
"""
from __future__ import annotations

from dataclasses import dataclass

from ..core.catalog import HardwareSpec

__all__ = ["RooflineTerms", "roofline_from_cell", "model_flops"]


@dataclass(frozen=True)
class RooflineTerms:
    arch: str
    shape: str
    mesh: str
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float
    hlo_flops_per_device: float
    useful_ratio: float        # MODEL_FLOPS / (HLO_FLOPs * chips)
    bound: str                 # compute | memory | collective
    step_time_s: float         # max of the three terms (overlap-optimistic)
    roofline_fraction: float   # compute_s / step_time_s ("MFU-at-roofline")

    def to_json(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "model_flops": self.model_flops,
            "hlo_flops_per_device": self.hlo_flops_per_device,
            "useful_ratio": self.useful_ratio, "bound": self.bound,
            "step_time_s": self.step_time_s,
            "roofline_fraction": self.roofline_fraction,
        }


def model_flops(cfg, shape) -> float:
    """6*N*D with D = processed tokens; decode processes B tokens/step."""
    n = cfg.param_count(active_only=cfg.family == "moe")
    d = shape.tokens
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n * d


def roofline_from_cell(cell: dict, cfg, shape, hw: HardwareSpec,
                       chips: int) -> RooflineTerms:
    """``cell`` is one dry-run artifact (see launch/dryrun.py).

    Prefers the trip-count-aware ``hlo_cost`` record (scan bodies x trips);
    raw ``cost_analysis`` numbers (which count loop bodies once) are the
    fallback for artifacts produced before hlo_cost existed."""
    hc = cell.get("hlo_cost")
    if hc:
        flops_dev = float(hc["dot_flops"])
        bytes_dev = float(hc["bytes_accessed"])
        coll_dev = float(hc["total_collective_bytes"])
    else:
        flops_dev = float(cell["cost"].get("flops", 0.0))
        bytes_dev = float(cell["cost"].get("bytes accessed", 0.0))
        coll_dev = float(cell["collectives"]["total_bytes"])

    compute_s = flops_dev / hw.peak_bf16_flops
    memory_s = bytes_dev / hw.hbm_bandwidth
    collective_s = coll_dev / (hw.ici_links_per_chip * hw.ici_link_bandwidth)

    mf = model_flops(cfg, shape)
    total_hlo = flops_dev * chips
    useful = mf / total_hlo if total_hlo else 0.0

    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    bound = max(terms, key=terms.get)
    step = max(terms.values()) or 1e-30
    return RooflineTerms(
        arch=cell["arch"], shape=cell["shape"], mesh=cell["mesh"],
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        model_flops=mf, hlo_flops_per_device=flops_dev, useful_ratio=useful,
        bound=bound, step_time_s=step,
        roofline_fraction=compute_s / step,
    )
