"""Sharded checkpointing: npz shards + JSON manifest, async save,
reshard-on-restore (elastic).

Design (multi-host-ready, exercised single-process here):
  * every process writes only its addressable shards to
    ``step_<N>/proc_<id>.npz`` (flattened key-path -> array);
  * ``manifest.json`` records the tree structure, shapes, dtypes, step and
    mesh shape — restore validates against it;
  * restore accepts *different* shardings than save: arrays are loaded on
    host and ``jax.device_put`` against the new sharding, which is how an
    elastic resize (lose a slice, rebuild a smaller mesh) re-ingests state;
  * ``save_async`` snapshots to host memory synchronously (cheap) and writes
    in a background thread so the train loop never blocks on disk.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
import time

import jax
import numpy as np

__all__ = ["Checkpointer"]

_SEP = "::"


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        flat[key] = np.asarray(leaf)
    return flat


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None

    # ----------------------------------------------------------------- io
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:08d}")

    def steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            m = re.fullmatch(r"step_(\d+)", name)
            if m and os.path.exists(os.path.join(self.dir, name, "manifest.json")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> int | None:
        s = self.steps()
        return s[-1] if s else None

    # --------------------------------------------------------------- save
    def save(self, step: int, tree, extra: dict | None = None) -> str:
        host_tree = jax.tree.map(np.asarray, tree)   # device -> host snapshot
        return self._write(step, host_tree, extra or {})

    def save_async(self, step: int, tree, extra: dict | None = None) -> None:
        self.wait()                                   # one writer at a time
        host_tree = jax.tree.map(np.asarray, tree)   # snapshot NOW
        self._thread = threading.Thread(
            target=self._write, args=(step, host_tree, extra or {}),
            daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host_tree, extra: dict) -> str:
        d = self._step_dir(step)
        tmp = d + ".tmp"
        os.makedirs(tmp, exist_ok=True)
        flat = _flatten(host_tree)
        proc = jax.process_index()
        np.savez(os.path.join(tmp, f"proc_{proc}.npz"), **flat)
        manifest = {
            "step": step,
            "time": time.time(),
            "n_processes": jax.process_count(),
            "keys": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                     for k, v in flat.items()},
            "extra": extra,
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(d):
            shutil.rmtree(d)
        os.replace(tmp, d)
        self._gc()
        return d

    def _gc(self) -> None:
        steps = self.steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # ------------------------------------------------------------ restore
    def restore(self, template, step: int | None = None,
                shardings=None) -> tuple:
        """Restore into the structure of ``template``.

        ``shardings`` (optional pytree of NamedSharding, possibly for a
        *different* mesh than the one that saved) enables elastic restore.
        Returns (tree, manifest_extra).
        """
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        d = self._step_dir(step)
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        data = {}
        for name in os.listdir(d):
            if name.endswith(".npz"):
                with np.load(os.path.join(d, name)) as z:
                    data.update({k: z[k] for k in z.files})

        paths = jax.tree_util.tree_flatten_with_path(template)[0]
        leaves = []
        for path, leaf in paths:
            key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                            for p in path)
            if key not in data:
                raise KeyError(f"checkpoint missing '{key}'")
            arr = data[key]
            if hasattr(leaf, "shape") and tuple(arr.shape) != tuple(leaf.shape):
                raise ValueError(
                    f"shape mismatch for '{key}': ckpt {arr.shape} vs "
                    f"template {leaf.shape}")
            if hasattr(leaf, "dtype"):
                arr = arr.astype(leaf.dtype)
            leaves.append(arr)
        tree = jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(template), leaves)
        if shardings is not None:
            tree = jax.tree.map(lambda a, s: jax.device_put(a, s), tree,
                                shardings)
        return tree, manifest.get("extra", {})
