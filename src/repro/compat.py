"""Compatibility shims for JAX API drift.

The code targets the current names — ``jax.shard_map(check_vma=...)``,
``jax.make_mesh(..., axis_types=(AxisType.Auto, ...))`` — but the installed
runtime may be an older 0.4.x where ``shard_map`` lives in
``jax.experimental.shard_map`` (with ``check_rep`` instead of ``check_vma``)
and ``jax.sharding.AxisType`` does not exist (every axis is implicitly Auto,
which is exactly what the call sites request).  These wrappers resolve to the
native API when present and degrade losslessly otherwise.
"""
from __future__ import annotations

import jax
import numpy as np

__all__ = ["default_axis_types", "make_mesh", "mesh_from_devices", "shard_map"]


def default_axis_types(n: int):
    """``(AxisType.Auto,) * n`` on new JAX, None (implicit Auto) on old."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    return (axis_type.Auto,) * n if axis_type is not None else None


def make_mesh(axis_shapes, axis_names, *, devices=None):
    """``jax.make_mesh`` with Auto axis types wherever the API supports them."""
    axis_types = default_axis_types(len(axis_names))
    if axis_types is not None:
        return jax.make_mesh(axis_shapes, axis_names, devices=devices,
                             axis_types=axis_types)
    return jax.make_mesh(axis_shapes, axis_names, devices=devices)


def mesh_from_devices(device_array, axis_names):
    """``jax.sharding.Mesh`` over an explicit device array, Auto-typed."""
    device_array = np.asarray(device_array)
    axis_types = default_axis_types(len(axis_names))
    if axis_types is not None:
        return jax.sharding.Mesh(device_array, axis_names,
                                 axis_types=axis_types)
    return jax.sharding.Mesh(device_array, axis_names)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool | None = None):
    """``jax.shard_map``, falling back to ``jax.experimental.shard_map``.

    ``check_vma`` maps onto the old ``check_rep`` flag — both gate the same
    replication/varying-axis validation pass.
    """
    native = getattr(jax, "shard_map", None)
    if native is not None:
        kw = {} if check_vma is None else {"check_vma": check_vma}
        return native(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **kw)
    from jax.experimental.shard_map import shard_map as legacy
    kw = {} if check_vma is None else {"check_rep": check_vma}
    return legacy(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)
