"""Assigned-architecture configs (--arch <id> resolves here)."""
from .base import SHAPES, ModelConfig, ShapeSpec, shape_for

from . import (codeqwen15_7b, internlm2_1_8b, musicgen_large, paligemma_3b,
               qwen3_14b, qwen3_32b, qwen3_moe_30b_a3b, qwen3_moe_235b_a22b,
               rwkv6_3b, zamba2_2_7b)

ARCHS: dict[str, ModelConfig] = {
    m.CONFIG.name: m.CONFIG
    for m in (qwen3_14b, codeqwen15_7b, qwen3_32b, internlm2_1_8b, rwkv6_3b,
              zamba2_2_7b, qwen3_moe_30b_a3b, qwen3_moe_235b_a22b,
              musicgen_large, paligemma_3b)
}


def get_config(name: str) -> ModelConfig:
    if name.endswith("-smoke"):
        return get_config(name[: -len("-smoke")]).smoke()
    try:
        return ARCHS[name]
    except KeyError as e:
        raise KeyError(f"unknown arch '{name}'; known: {sorted(ARCHS)}") from e


__all__ = ["ARCHS", "SHAPES", "ModelConfig", "ShapeSpec", "get_config",
           "shape_for"]
