"""Architecture + shape configuration system.

Every assigned architecture is a ``ModelConfig`` in ``repro.configs.<id>``;
``--arch <id>`` resolves through ``repro.configs.get_config``. Each config
also provides a reduced ``smoke()`` variant of the same family for real
CPU execution in tests; the full configs are exercised via the dry-run only.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field

__all__ = ["ModelConfig", "ShapeSpec", "SHAPES", "shape_for"]


@dataclass(frozen=True)
class ModelConfig:
    # identity
    name: str
    family: str                 # dense | moe | ssm | hybrid | audio | vlm
    source: str = ""            # provenance tag from the assignment table

    # transformer backbone
    n_layers: int = 0
    d_model: int = 0
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0           # 0 -> d_model // n_heads
    d_ff: int = 0
    vocab_size: int = 0
    qk_norm: bool = False
    rope_theta: float = 1e6
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    logit_softcap: float = 0.0          # gemma-style; 0 = off
    embed_scale: bool = False           # gemma multiplies embeds by sqrt(d)

    # MoE
    moe_experts: int = 0
    moe_top_k: int = 0
    moe_capacity_factor: float = 1.25

    # SSM / RWKV
    ssm_state: int = 0                  # mamba2 N
    ssm_head_dim: int = 64              # mamba2 P / rwkv head size
    ssm_expand: int = 2                 # mamba2 d_inner = expand * d_model
    ssm_conv_width: int = 4
    rwkv_decay_lora: int = 64

    # hybrid (zamba2)
    shared_attn_every: int = 6          # apply the shared block every N layers

    # audio (musicgen)
    n_codebooks: int = 0

    # vlm (paligemma)
    vision_embed_dim: int = 0           # SigLIP output width (stub frontend)
    n_patches: int = 0
    prefix_lm: bool = False

    # numerics / training
    dtype: str = "bfloat16"
    subquadratic: bool = False          # can run long_500k

    # ---------------------------------------------------------------- util
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def smoke(self) -> "ModelConfig":
        """Reduced same-family config: runs one real step on CPU."""
        kw = dict(
            name=self.name + "-smoke",
            n_layers=2,
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 4) or 0,
            head_dim=16,
            d_ff=128,
            vocab_size=256,
        )
        if self.family == "moe":
            kw.update(moe_experts=4, moe_top_k=2)
        if self.family in ("ssm", "hybrid"):
            kw.update(ssm_state=8, ssm_head_dim=8, rwkv_decay_lora=8)
        if self.family == "hybrid":
            kw.update(shared_attn_every=2)
        if self.family == "audio":
            kw.update(n_codebooks=self.n_codebooks, vocab_size=64)
        if self.family == "vlm":
            kw.update(vision_embed_dim=24, n_patches=8, head_dim=16)
        return self.replace(**kw)

    # parameter count (for MODEL_FLOPS = 6 N D)
    def param_count(self, active_only: bool = False) -> int:
        d, ff, v, L = self.d_model, self.d_ff, self.vocab_size, self.n_layers
        hd = self.resolved_head_dim
        nq, nkv = self.n_heads, self.n_kv_heads
        emb = v * d * (1 if self.tie_embeddings else 2)
        if self.family == "audio":
            emb = self.n_codebooks * v * d * 2
        if self.family == "vlm":
            emb += self.vision_embed_dim * d
        attn = d * nq * hd + 2 * d * nkv * hd + nq * hd * d
        mlp = 3 * d * ff
        if self.family == "moe":
            e = self.moe_top_k if active_only else self.moe_experts
            mlp = 3 * d * ff * e + d * self.moe_experts  # experts + router
        if self.family == "ssm":                          # rwkv6
            att_like = 4 * d * d + 2 * d * self.rwkv_decay_lora * 2
            mlp_like = 2 * d * ff
            return emb + L * (att_like + mlp_like)
        if self.family == "hybrid":                       # zamba2
            d_in = self.ssm_expand * d
            mamba = d * (2 * d_in + 2 * self.ssm_state) + d_in * d
            shared = (2 * d) * d + attn + mlp             # projector + block
            return emb + L * mamba + shared
        per_layer = attn + mlp
        if self.family == "hybrid":
            per_layer = mlp
        return emb + L * per_layer


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                   # train | prefill | decode

    @property
    def tokens(self) -> int:
        if self.kind == "decode":
            return self.global_batch      # one new token per sequence
        return self.global_batch * self.seq_len


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def shape_for(name: str) -> ShapeSpec:
    try:
        return SHAPES[name]
    except KeyError as e:
        raise KeyError(f"unknown shape '{name}'; known: {sorted(SHAPES)}") from e
