"""musicgen-large [audio] — 48L d_model=2048 32H (GQA kv=32) d_ff=8192
vocab=2048 — decoder-only over EnCodec tokens [arXiv:2306.05284; hf].

The EnCodec frontend is a stub per the assignment: ``input_specs()``
supplies the 4-codebook token ids the decoder consumes."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large", family="audio", source="arXiv:2306.05284; hf",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32, head_dim=64,
    d_ff=8192, vocab_size=2048, rope_theta=1e4,
    n_codebooks=4,
)
