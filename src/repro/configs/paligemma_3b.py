"""paligemma-3b [vlm] — 18L d_model=2048 8H (GQA kv=1) d_ff=16384
vocab=257216 — SigLIP + gemma [arXiv:2407.07726; hf].

The SigLIP frontend is a stub per the assignment: ``input_specs()``
supplies precomputed patch embeddings (B, 256, 1152)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b", family="vlm", source="arXiv:2407.07726; hf",
    n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1, head_dim=256,
    d_ff=16384, vocab_size=257216, rope_theta=1e4,
    vision_embed_dim=1152, n_patches=256, prefix_lm=True,
    logit_softcap=30.0, embed_scale=True,
)
