"""qwen3-moe-30b-a3b [moe] — 48L d_model=2048 32H (GQA kv=4) d_ff=768
vocab=151936, MoE 128e top-8 [hf:Qwen/Qwen3-30B-A3B; hf]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b", family="moe", source="hf:Qwen/Qwen3-30B-A3B; hf",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4, head_dim=128,
    d_ff=768, vocab_size=151936, qk_norm=True, rope_theta=1e6,
    moe_experts=128, moe_top_k=8,
)
