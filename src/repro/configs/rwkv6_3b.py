"""rwkv6-3b [ssm] — 32L d_model=2560 (attn-free) d_ff=8960 vocab=65536
— Finch: data-dependent decay [arXiv:2404.05892; hf]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b", family="ssm", source="arXiv:2404.05892; hf",
    n_layers=32, d_model=2560, d_ff=8960, vocab_size=65536,
    ssm_head_dim=64, rwkv_decay_lora=64, subquadratic=True,
)
