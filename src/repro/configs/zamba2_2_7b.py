"""zamba2-2.7b [hybrid] — 54L d_model=2560 32H (GQA kv=32) d_ff=10240
vocab=32000, ssm_state=64 — Mamba2 + shared attn blocks
[arXiv:2411.15242; hf]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b", family="hybrid", source="arXiv:2411.15242; hf",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32, head_dim=80,
    d_ff=10240, vocab_size=32000, ssm_state=64, ssm_head_dim=64,
    ssm_expand=2, shared_attn_every=6, subquadratic=True,
)
