"""The paper's primary contribution: reliable auto-discovery of compute and
memory topologies (MT4G), adapted TPU-native and consumed by the framework's
distribution, roofline, and performance-model layers."""
from .topology import (Attribute, ComputeElement, Link, MemoryElement,
                       Topology, topology_equivalent)
from .catalog import CATALOG, HOST_CPU, TPU_V4, TPU_V5E, HardwareSpec, get_spec
from .simulate import (SIM_DEVICES, SimDevice, SimLevel, make_h100_like,
                       make_mi210_like, make_v5e_like)
from .discover import (DiscoveryRequest, DiscoveryTimings, discover,
                       discover_host, discover_pallas, discover_sim,
                       discover_sim_legacy, spec_from_topology)
from .engine.planner import SweepBudget
from .engine.store import GcPolicy
from .errors import DegradedResult, Resilience, TransientRunnerError

__all__ = [
    "Attribute", "ComputeElement", "Link", "MemoryElement", "Topology",
    "topology_equivalent",
    "CATALOG", "HOST_CPU", "TPU_V4", "TPU_V5E", "HardwareSpec", "get_spec",
    "SIM_DEVICES", "SimDevice", "SimLevel", "make_h100_like",
    "make_mi210_like", "make_v5e_like",
    "DiscoveryRequest", "DiscoveryTimings", "discover", "discover_host",
    "discover_pallas", "discover_sim", "discover_sim_legacy",
    "spec_from_topology", "SweepBudget", "GcPolicy",
    "DegradedResult", "Resilience", "TransientRunnerError",
]
