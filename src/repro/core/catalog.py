"""Known-hardware catalog.

MT4G consults vendor APIs/datasheets where information is programmatically
available and benchmarks the rest (paper §III). On the TPU side the analogue
of "API-provided" values is this catalog (populated from published TPU specs),
plus live ``jax.devices()`` queries. The roofline analyzer and the perf model
consume ``HardwareSpec`` records; ``core.discover`` emits the same record
shape, so a *discovered* topology can replace a catalog entry on real
hardware — exactly the paper's substitution of benchmarks for datasheets.
"""
from __future__ import annotations

from dataclasses import dataclass, field

__all__ = ["HardwareSpec", "TPU_V5E", "TPU_V4", "HOST_CPU", "get_spec",
           "spec_from_store", "CATALOG"]


@dataclass(frozen=True)
class HardwareSpec:
    """Per-chip performance constants used by roofline + perf model."""

    name: str
    peak_bf16_flops: float        # FLOP/s per chip
    hbm_bandwidth: float          # bytes/s per chip
    hbm_bytes: int                # capacity per chip
    ici_link_bandwidth: float     # bytes/s per ICI link (one direction)
    ici_links_per_chip: int       # usable links per chip in a 2-D torus
    dcn_bandwidth: float          # bytes/s per host across pods
    vmem_bytes: int               # on-chip vector memory per core
    smem_bytes: int               # scalar memory per core
    mxu_shape: tuple[int, int] = (128, 128)
    notes: str = ""


# Google TPU v5e (the production target mesh: 16x16 per pod).
# Constants per the assignment: 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link.
TPU_V5E = HardwareSpec(
    name="tpu-v5e",
    peak_bf16_flops=197e12,
    hbm_bandwidth=819e9,
    hbm_bytes=16 * 1024**3,
    ici_link_bandwidth=50e9,
    ici_links_per_chip=4,
    dcn_bandwidth=25e9,
    vmem_bytes=128 * 1024**2 // 8,   # ~16 MiB VMEM per core
    smem_bytes=1024 * 1024 // 8,
    notes="v5e: 1 TensorCore/chip, 4 ICI links, 2D torus",
)

TPU_V4 = HardwareSpec(
    name="tpu-v4",
    peak_bf16_flops=275e12,
    hbm_bandwidth=1228e9,
    hbm_bytes=32 * 1024**3,
    ici_link_bandwidth=50e9,
    ici_links_per_chip=6,
    dcn_bandwidth=25e9,
    vmem_bytes=16 * 1024**2,
    smem_bytes=128 * 1024,
    notes="v4: 2 TensorCores/chip, 3D torus",
)

# The CPU this container runs on — filled conservatively; the discovery
# pipeline measures the real values and overrides these.
HOST_CPU = HardwareSpec(
    name="host-cpu",
    peak_bf16_flops=5e10,
    hbm_bandwidth=10e9,
    hbm_bytes=32 * 1024**3,
    ici_link_bandwidth=10e9,
    ici_links_per_chip=1,
    dcn_bandwidth=1e9,
    vmem_bytes=1 * 1024**2,
    smem_bytes=64 * 1024,
    mxu_shape=(1, 1),
    notes="placeholder — discovery overrides",
)

CATALOG: dict[str, HardwareSpec] = {
    s.name: s for s in (TPU_V5E, TPU_V4, HOST_CPU)
}


def get_spec(name: str, store=None) -> HardwareSpec:
    """Resolve a hardware spec, preferring *discovered* values.

    With a ``TopologyStore``, a stored discovered topology for ``name``
    (matched on model or spec name, newest first) overlays its measured
    values onto the static record — the paper's substitution of benchmarks
    for datasheets, made durable.  Without a store (or a stored entry) the
    static datasheet record answers as before.
    """
    if store is not None:
        spec = spec_from_store(name, store)
        if spec is not None:
            return spec
    try:
        return CATALOG[name]
    except KeyError as e:
        raise KeyError(f"unknown hardware '{name}'; known: {sorted(CATALOG)}") from e


def spec_from_store(name: str, store) -> HardwareSpec | None:
    """Newest stored discovered topology for ``name`` overlaid onto the
    static base record (``HOST_CPU`` when the name has no datasheet entry)."""
    from .discover import spec_from_topology  # late: discover imports catalog

    entries = store.find(model=name)
    if not entries:
        return None
    base = CATALOG.get(name, HOST_CPU)
    spec = spec_from_topology(entries[0].topology, base)
    if spec is base:
        return None                     # nothing measured worth overlaying
    import dataclasses
    return dataclasses.replace(spec, name=name,
                               notes=f"{base.notes} [overlaid from discovered "
                                     f"topology {entries[0].key}]".strip())
