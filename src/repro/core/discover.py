"""Discovery orchestration — the ``mt4g`` entry point equivalent (paper C1).

Runs the full probe suite against a runner, auto-evaluates every result with
the statistics layer, and assembles a ``Topology`` report with provenance and
confidence annotations. Mirrors the MT4G CLI behavior: the whole suite by
default, an optional restriction to specific memory elements, and timing of
each benchmark family (paper §V-A reports per-family run times).

The center of this module is the **unified, runner-agnostic driver**
``discover(request)``: one implementation of request descriptors and
content-addressed store read-/write-through, sample-cache preload, engine
invocation, and topology assembly, shared by every backend.  The public
entry points are thin wrappers that only say what is genuinely
backend-specific:

* ``discover_sim``    — a ``SimRunner`` over a virtual device with known
  ground truth (the validation backend);
* ``discover_host``   — real CPU measurements through a custom work-item
  plan (the hierarchy has one probeable space, so it skips the registry);
* ``discover_pallas`` — the ``PallasRunner``: real Pallas kernels
  (``repro.kernels.pchase_probe``/``stream_probe``) in interpret mode,
  timed end-to-end against a configured ground-truth hierarchy.

A fourth path, ``discover_sim_legacy`` (also ``discover_sim(engine=False)``)
keeps the paper-faithful sequential loop: one probe at a time, exactly as
the paper's tool runs them — the reference implementation and the baseline
of the ``engine_speedup`` benchmark.

Engine and legacy results are identical for simulated devices because those
runners key every sample stream by the request itself
(``simulate._KeyedSampler``): scheduling, batching, and caching change when
samples are drawn, never what is drawn.

The same wrappers also back the remote write path: ``serve/jobs.py``
parses a wire-format request into the identical descriptor (so the job's
content-addressed key equals the store key the run persists under) and
invokes these functions server-side from ``POST /discoveries``.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable

import numpy as np

from .catalog import HardwareSpec
from .errors import DegradedResult
from .probes.amount import align_segments, find_amount, find_cu_sharing, find_sharing
from .probes.bandwidth import measure_bandwidth
from .probes.latency import measure_latency
from .probes.linesize import find_fetch_granularity, find_line_size
from .probes.runners import HostRunner, SimRunner
from .probes.size import find_size
from .topology import (PROVENANCE_API, PROVENANCE_BENCHMARK,
                       PROVENANCE_DEGRADED, ComputeElement, MemoryElement,
                       Topology)

__all__ = ["DiscoveryTimings", "DiscoveryRequest", "discover",
           "discover_sim", "discover_sim_legacy", "discover_host",
           "discover_pallas", "spec_from_topology", "default_sweep_budget",
           "sim_request_descriptor", "host_request_descriptor",
           "pallas_request_descriptor"]

KIB = 1024


@dataclass
class DiscoveryTimings:
    """Per-family wall times + probe-volume diagnostics for one discovery
    (paper §V-A reports per-family run times)."""

    per_family: dict[str, float] = field(default_factory=dict)
    # Probe-volume diagnostics for the run (cache hits/misses, fusion round
    # count, planner mode).  Not persisted — a store hit reconstructs only
    # the per-family timings, since no probes ran.
    meta: dict = field(default_factory=dict)

    def add(self, family: str, seconds: float) -> None:
        """Accumulate seconds onto one benchmark family's total."""
        self.per_family[family] = self.per_family.get(family, 0.0) + seconds

    @property
    def total(self) -> float:
        """Summed per-family wall time for the whole run."""
        return sum(self.per_family.values())

    @property
    def probe_rows(self) -> int | None:
        """Grid rows actually sampled (cache misses) — the probe volume the
        adaptive planner minimizes; None when unknown (store hit, legacy)."""
        cache = self.meta.get("cache")
        return None if cache is None else int(cache["misses"])


class _Timer:
    def __init__(self, timings: DiscoveryTimings, family: str):
        self.t, self.f = timings, family

    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self.t.add(self.f, time.perf_counter() - self.t0)
        return False


# --------------------------------------------------------------------------
# Request descriptors (content addresses for the TopologyStore)
# --------------------------------------------------------------------------
def default_sweep_budget():
    """Default sweep budget for backends that plan adaptively out of the
    box (Pallas).  Exposed so request descriptors computed by callers
    (e.g. ``serve/jobs.py``) match the ones discovery uses internally."""
    from .engine.planner import SweepBudget

    return SweepBudget()


_DEFAULT_BUDGET = object()       # sentinel: "the backend's default budget"


def _budget_descriptor(budget) -> dict | None:
    return None if budget is None else budget.descriptor()


def sim_request_descriptor(device, n_samples: int,
                           elements: list[str] | None, budget=None,
                           survey: bool = False, resilience=None) -> dict:
    """Everything that determines a ``discover_sim`` result — and nothing
    that does not.  Worker count, engine-vs-legacy, batching, and fusion
    are excluded: request-keyed sample streams make them result-invisible
    up to the ``topology_equivalent`` contract (discrete attributes exact,
    floats within rel-tol — and bit-identical in practice on the validation
    devices), so the key addresses that equivalence class.  A ``budget``
    IS part of the key (planned confidence metrics come from a window, not
    the full series); ``budget=None`` keys exactly as before, so existing
    stores stay valid.  A ``resilience`` policy keys in only through its
    statistical knobs (``Resilience.descriptor_entry``): retry/backoff
    settings never change what a clean run measures, so a resilient rerun
    of a clean request is a pure store hit."""
    d = {
        "kind": "discover_sim",
        "backend": f"simulated:{device.name}",
        "device": device.name,
        "vendor": device.vendor,
        "seed": device.seed,
        "n_samples": int(n_samples),
        "elements": sorted(elements) if elements else None,
    }
    if budget is not None:
        d["budget"] = _budget_descriptor(budget)
    res_entry = None if resilience is None else resilience.descriptor_entry()
    if res_entry is not None:
        d["resilience"] = res_entry
    if survey:
        # Survey results are spot-check-verified copies, not full measures —
        # they must never collide with a full run's key.  Only present when
        # on, so pre-survey stores keep their keys.
        d["survey"] = True
    return d


def host_request_descriptor(max_bytes: int, n_samples: int,
                            quick: bool) -> dict:
    """Content address of a ``discover_host`` request: sweep ceiling,
    sample count, and the quick-mode flag are all that shape the result
    (the host hierarchy itself has one probeable space)."""
    return {"kind": "discover_host", "max_bytes": int(max_bytes),
            "n_samples": int(n_samples), "quick": bool(quick)}


def pallas_request_descriptor(model, n_samples: int,
                              elements: list[str] | None,
                              budget=_DEFAULT_BUDGET,
                              survey: bool = False, resilience=None) -> dict:
    """Content address of a ``discover_pallas`` request.

    Keyed like the sim descriptor — model identity + seed + sample count +
    element restriction + sweep budget — so Pallas topologies are stored/
    served through the same ``TopologyStore`` machinery as sim/host ones.
    Measured values vary run to run (real timings); the *request* is what
    is addressed.  The budget defaults to the backend's default
    (``SweepBudget()``), matching ``discover_pallas``.  ``resilience`` keys
    in only through ``Resilience.descriptor_entry`` (statistical knobs),
    exactly as on the sim descriptor.
    """
    if budget is _DEFAULT_BUDGET:
        budget = default_sweep_budget()
    d = {
        "kind": "discover_pallas",
        "backend": f"pallas-interp:{model.name}",
        "model": model.name,
        "vendor": model.vendor,
        "seed": model.seed,
        "n_samples": int(n_samples),
        "elements": sorted(elements) if elements else None,
        "budget": _budget_descriptor(budget),
    }
    res_entry = None if resilience is None else resilience.descriptor_entry()
    if res_entry is not None:
        d["resilience"] = res_entry
    if survey:
        d["survey"] = True      # keyed apart from full runs (see sim twin)
    return d


# --------------------------------------------------------------------------
# Store read-through: hit/persist helpers (shared by every backend)
# --------------------------------------------------------------------------
def _store_lookup(store, descriptor: dict):
    """(key, stored-result-or-None): a hit reconstructs the timings the
    original run recorded, so callers see the same (topo, timings) shape."""
    from .engine.store import request_key

    key = request_key(descriptor)
    entry = store.get(key)
    if entry is None:
        return key, None
    timings = DiscoveryTimings()
    timings.per_family.update(entry.meta.get("timings", {}))
    return key, (entry.topology, timings)


def _store_persist(store, key: str, descriptor: dict, topo: Topology,
                   timings: DiscoveryTimings, cache=None) -> None:
    """Write the topology + sample cache as one locked transaction, so a
    concurrent discovery on the same store cannot interleave a topology
    from one run with samples from another."""
    with store.lock():
        store.put(key, topo, meta={"request": descriptor,
                                   "timings": dict(timings.per_family)})
        if cache is not None and len(cache):
            store.put_samples(key, cache.snapshot())


# --------------------------------------------------------------------------
# Fleet survey mode: verify a sibling topology with a spot-check subset
# --------------------------------------------------------------------------
def _survey_spot_check(runner, topo, request) -> bool:
    """Planned spot-check: does this device match a sibling's topology?

    Probes a few decisive rows per discrete attribute instead of running
    the full sweeps — boundary straddles for sizes (margins from
    ``budget.target_resolution``), the classification flip for fetch
    granularity, two §IV-E score rows for core-scope line sizes, and one
    eviction row per §IV-F/§IV-G/§IV-H family.  Latency/bandwidth floats
    are NOT verified (they are measurements, not discrete attributes — a
    surveyed entry reports the sibling's).  Returns False on ANY
    disagreement; the caller then runs the full discovery, so a spot-check
    can only trade a failed shortcut for a full measure, never accuracy.
    """
    from .probes.amount import _hit_miss_refs, _is_miss, amount_ladder
    from .probes.linesize import granularity_refs, hit_scores
    from .probes.size import ShiftClassifier, classification_jump

    n_samples = request.n_samples
    tr = int(getattr(request.budget, "target_resolution", None) or 0)
    infos = {i.name: i for i in runner.spaces()}
    api_size = getattr(runner, "api_size", lambda _s: None)

    for me in topo.memory:
        info = infos.get(me.name)
        if info is None:
            if me.name in ("DeviceMemory", "DRAM"):
                continue            # float-only elements: nothing discrete
            return False            # sibling claims a space we cannot see
        size = me.get("size")
        if size:
            if info.scope == "chip":
                # chip totals are API-reported: a free exact comparison
                if api_size(me.name) != size:
                    return False
            else:
                # two rows straddling the capacity boundary must classify
                # unshifted below / shifted above, vs the dense base row
                step = 4 if info.kind == "scratchpad" else 32
                margin = max(tr, int(size) // 16, 8 * step)
                base = runner.pchase(me.name, 1 * KIB, step, n_samples)
                clf = ShiftClassifier(base, 0.01, classification_jump(runner))
                if clf.shifted(runner.pchase(me.name, int(size) - margin,
                                             step, n_samples)):
                    return False
                if not clf.shifted(runner.pchase(me.name, int(size) + margin,
                                                 step, n_samples)):
                    return False

        g = me.get("fetch_granularity")
        if g and info.supports_cold:
            # the stored granularity must be the §IV-D classification flip:
            # all-miss at g, still mixing hits one grid notch below
            _h, _m, thresh, hit_med, miss_med = granularity_refs(
                runner, me.name, 64 * KIB, 512, n_samples, 4)
            if miss_med < hit_med * 1.5:
                return False
            n_loads = 16 * n_samples
            min_frac = max(0.005, 2.0 / n_loads)

            def mixed(s: int) -> bool:
                arr = max(64 * KIB, s * (n_loads + 1))
                row = np.asarray(runner.cold_chase(me.name, arr, s, n_loads))
                return float(np.mean(row < thresh)) > min_frac

            if mixed(int(g)):
                return False
            if int(g) > 4 and not mixed(int(g) - 4):
                return False

        line = me.get("line_size")
        if line and size and g and info.supports_cold \
                and info.scope != "chip":
            # two §IV-E score rows bracketing the stored line's transition
            # (chip-scope lines are skipped: their sweeps are keyed on the
            # measured segment, which a survey does not re-derive)
            g2 = max(int(g) // 2, 4)
            arr = int(int(size) * 1.0625)
            pivot = runner.pchase(me.name, arr, g2, n_samples)
            href = runner.pchase(me.name, arr, 1024 * 8, n_samples)
            hi = runner.pchase(me.name, arr, 2 * int(line), n_samples)
            if float(hit_scores(hi, pivot, href)[0]) <= 0:
                return False
            if int(line) >= 8 * g2:
                lo = runner.pchase(me.name, arr, int(line) // 4, n_samples)
                if float(hit_scores(lo, pivot, href)[0]) > 0:
                    return False

        am = me.get("amount")
        if am and info.supports_amount and size:
            # one §IV-F eviction row at the stored boundary rung (plus its
            # evicting predecessor when the ladder has one)
            cores = runner.cores_per_sm
            arr = int(int(size) * 0.9)
            h_ref, m_ref = _hit_miss_refs(runner, me.name, arr, int(size),
                                          n_samples)
            ladder = amount_ladder(cores)
            if not ladder:
                pass
            elif int(am) <= 1:
                # amount 1 = even the largest rung still evicted
                row = runner.amount_probe(me.name, 0, ladder[-1], arr,
                                          n_samples)
                if not _is_miss(row, h_ref, m_ref):
                    return False
            else:
                b_star = max(cores // int(am), 1)
                row = runner.amount_probe(me.name, 0, b_star, arr, n_samples)
                if _is_miss(row, h_ref, m_ref):
                    return False
                if b_star >= 2:
                    row = runner.amount_probe(me.name, 0, b_star // 2, arr,
                                              n_samples)
                    if not _is_miss(row, h_ref, m_ref):
                        return False

    # ---- one §IV-G sharing row for the first name-sharing leader pair
    def _cu_grouped(name: str) -> bool:
        el = topo.find_memory(name)
        return el is not None and el.get("exclusive_cus") is not None

    ss = [i.name for i in runner.spaces()
          if i.supports_sharing and i.scope == "core"
          and not _cu_grouped(i.name)]
    if len(ss) >= 2:
        ea = topo.find_memory(ss[0])
        if ea is not None and ea.get("size") \
                and topo.find_memory(ss[1]) is not None:
            expected = ss[1] in ea.shared_with
            res = find_sharing(runner, ss[0], ss[1], int(ea.get("size")),
                               n_samples=n_samples)
            if res.shared != expected:
                return False

    # ---- one §IV-H row inside the first CU group + one across groups
    sl1d = topo.find_memory(request.cu_space)
    if sl1d is not None and sl1d.get("exclusive_cus") is not None \
            and sl1d.get("size"):
        groups = [tuple(int(x) for x in s.split(","))
                  for s in sl1d.shared_with]
        cu_ns = max(n_samples // 2, 9)
        size = int(sl1d.get("size"))
        arr = int(size * 0.9)
        h_ref, m_ref = _hit_miss_refs(runner, request.cu_space, arr, size,
                                      cu_ns)
        if groups:
            a, b = groups[0][0], groups[0][1]
            row = runner.cu_sharing_probe(a, b, arr, cu_ns,
                                          space=request.cu_space)
            if not _is_miss(row, h_ref, m_ref):
                return False
            other = (groups[1][0] if len(groups) > 1 else
                     (sl1d.get("exclusive_cus") or [None])[0])
            if other is not None:
                row = runner.cu_sharing_probe(a, int(other), arr, cu_ns,
                                              space=request.cu_space)
                if _is_miss(row, h_ref, m_ref):
                    return False
    return True


def _survey_discovery(request: DiscoveryRequest, store, key: str):
    """Serve a survey request from a verified sibling, or None to go full.

    Picks the newest stored entry with the same vendor/model/backend whose
    provenance is a real measure (surveys never chain off surveys), spot
    checks it against this request's runner, and on agreement persists the
    sibling's topology under THIS request's key with ``survey`` provenance
    and the reference key in the meta — auditable, and an ordinary store
    hit for every later lookup of the same request.
    """
    ref = None
    for entry in store.find(model=request.model, vendor=request.vendor,
                            backend=request.backend):
        if entry.key != key and entry.meta.get("provenance") != "survey":
            ref = entry
            break
    if ref is None:
        return None
    from .engine import SampleCache
    from .engine.cache import CachingRunner

    timings = DiscoveryTimings()
    cached = CachingRunner(request.make_runner(), cache=SampleCache())
    with _Timer(timings, "survey"):
        ok = _survey_spot_check(cached, ref.topology, request)
    timings.meta["cache"] = cached.cache.stats()
    timings.meta["survey"] = {"reference": ref.key, "verified": bool(ok)}
    if not ok:
        return None
    with store.lock():
        store.put(key, ref.topology,
                  meta={"request": request.descriptor,
                        "timings": dict(timings.per_family),
                        "provenance": "survey", "survey_of": ref.key})
    return ref.topology, timings


# --------------------------------------------------------------------------
# The unified runner-agnostic driver
# --------------------------------------------------------------------------
@dataclass
class DiscoveryRequest:
    """One backend's worth of 'what is different': identity, runner, plan.

    Everything else — store lookup/persist, timings, sample-cache preload,
    engine invocation, topology assembly — is the shared ``discover()``
    implementation.  Registry-driven backends (sim, pallas) leave ``plan``
    unset and get the full (space x family) engine; backends with a bespoke
    probe set (host) provide a ``plan`` building scheduler work items and an
    ``assemble`` turning the schedule result into a ``Topology``.
    """

    descriptor: dict
    vendor: str
    model: str
    backend: str
    make_runner: Callable[[], object]
    n_samples: int = 33
    elements: list[str] | None = None
    device_families: tuple[str, ...] = ()
    max_workers: int | None = None
    clock_domain: str = "cycles"
    cu_space: str = "sL1d"            # the space CU-sharing groups attach to
    # Preloading persisted samples re-serves *recorded* probe rows.  That is
    # sound only for runners whose sample streams are request-keyed (sim);
    # measuring backends (host, pallas) must re-measure instead.
    preload_samples: bool = True
    # Adaptive sweep planning (engine/planner.SweepBudget): None keeps the
    # dense sweeps — the equivalence oracle.  The budget must already be
    # reflected in ``descriptor`` (the wrappers handle this).
    budget: object | None = None
    # Cross-family batch fusion (engine/fusion.py): coalesce concurrently
    # ready probe rounds into single batched dispatches.  Kernel execution
    # stays serial, so it composes with timing-sensitive backends.
    fuse: bool = False
    # Fault-tolerance policy (errors.Resilience): per-item transient retry
    # with graceful degradation, plus — with a store and preloadable
    # samples — periodic checkpointing so an interrupted discovery resumes
    # without re-probing persisted rows.  The policy's statistical knobs
    # must already be reflected in ``descriptor`` (the wrappers handle
    # this); retry knobs deliberately are not (they never change what a
    # clean run measures).
    resilience: object | None = None
    # Multiprocess probe execution (engine/parallel.ParallelConfig): shard
    # the batched capability calls across the persistent worker-process
    # pool.  Deliberately EXCLUDED from the request descriptor — pooled
    # and inline runs are bit-identical (request-keyed sampling), so they
    # must share a content address.  Runners without a RunnerSpec (and
    # boxes under the effective-core floor) silently stay inline.
    parallel: object | None = None
    # Fleet survey mode: instead of a full discovery, verify a stored
    # sibling topology (same vendor/model/backend, full provenance) with a
    # planned spot-check subset of probe rows and write it through under
    # THIS request's key with ``survey`` provenance.  Any mismatch — or no
    # usable sibling — silently degrades to the full discovery, so a survey
    # can be slower but never wrong.  Requires a ``store``.
    survey: bool = False
    plan: Callable[[object], list] | None = None
    assemble: Callable[[object, DiscoveryTimings], Topology] | None = None


def discover(request: DiscoveryRequest, *, store=None, refresh: bool = False,
             gc_policy=None) -> tuple[Topology, DiscoveryTimings]:
    """Run one discovery request end to end (the backend-neutral core).

    ``store`` (a ``TopologyStore``) makes discovery read-through/write-
    through persistent: a stored result for the same content-addressed
    request is returned without issuing a single runner probe, and a fresh
    run persists both the topology and the engine's sample cache.
    ``refresh=True`` skips the read (re-measures) but still writes through.

    ``gc_policy`` (a ``store.GcPolicy``) opts the write path into a
    retention sweep: after persisting, the oldest entries beyond the
    policy's ceilings are evicted (topology + samples pairs, under the
    store lock).  Ignored without a ``store``.
    """
    from .engine import SampleCache, run_probes
    from .engine.cache import CachingRunner
    from .engine.scheduler import run_work_items

    key = None
    if store is not None:
        if not refresh:
            key, hit = _store_lookup(store, request.descriptor)
            if hit is not None:
                return hit
        else:
            from .engine.store import request_key
            key = request_key(request.descriptor)

    if request.survey and store is not None:
        surveyed = _survey_discovery(request, store, key)
        if surveyed is not None:
            return surveyed
        # no usable sibling / spot-check mismatch: full discovery below

    timings = DiscoveryTimings()
    cache = SampleCache()
    if (store is not None and not refresh and request.preload_samples):
        # Partial-recovery path: a quarantined topology with intact samples
        # re-assembles from disk-served probe rows instead of re-measuring.
        # Never under refresh=True — that contract is a real re-measure.
        persisted = store.load_samples(key)
        if persisted:
            cache.preload(persisted)
        elif request.resilience is not None:
            # Resume path: an interrupted resilient discovery left a
            # checkpoint (sample cache + completed families) instead of a
            # final topology.  Preloading it re-serves every persisted
            # probe row from disk, so the rerun re-probes zero of them.
            ckpt = store.load_checkpoint(key)
            if ckpt is not None:
                entries, families = ckpt
                cache.preload(entries)
                timings.meta["resume"] = {"rows": len(entries),
                                          "families_done": len(families)}

    runner = request.make_runner()
    checkpoint = None
    if (store is not None and request.resilience is not None
            and request.preload_samples):
        # Checkpoint write-through: after each completed work item, persist
        # the sample cache + completed-item manifest under the request key.
        # Gated on ``preload_samples`` because resume re-serves recorded
        # rows — only sound for request-keyed (replayable) runners.
        done_items: list[str] = []

        def checkpoint(item_key):
            done_items.append("/".join(map(str, item_key)))
            store.put_checkpoint(key, cache.snapshot(), done_items)

    if request.plan is None:
        eng = run_probes(runner, n_samples=request.n_samples,
                         elements=request.elements,
                         device_families=request.device_families,
                         max_workers=request.max_workers, timings=timings,
                         cache=cache, budget=request.budget,
                         fuse=request.fuse, resilience=request.resilience,
                         checkpoint=checkpoint, parallel=request.parallel)
        timings.meta["cache"] = eng.cache_stats
        timings.meta["planned"] = request.budget is not None
        if eng.degraded or eng.retries:
            timings.meta["resilience"] = {
                "retries": eng.retries,
                "degraded": [d.key for d in eng.degraded]}
        topo = _assemble_engine_topology(request, runner, eng, timings)
    else:
        from .engine.parallel import maybe_parallel_runner

        cached = CachingRunner(
            maybe_parallel_runner(runner, request.parallel), cache=cache)
        sched = run_work_items(request.plan(cached),
                               max_workers=request.max_workers,
                               timings=timings,
                               resilience=request.resilience,
                               on_item_done=checkpoint,
                               parallel=request.parallel)
        timings.meta["cache"] = cached.cache.stats()
        topo = request.assemble(sched, timings)

    if store is not None:
        _store_persist(store, key, request.descriptor, topo, timings,
                       cache=cache)
        if checkpoint is not None:
            # The run completed and persisted: its checkpoint is spent.
            store.clear_checkpoint(key)
        if gc_policy is not None:
            store.gc(max_entries=gc_policy.max_entries,
                     max_age_s=gc_policy.max_age_s)
    return topo, timings


# Degraded probe family -> the topology attribute it would have filled.
_DEGRADED_ATTR = {"size": "size", "fetch_granularity": "fetch_granularity",
                  "latency": "load_latency", "line_size": "line_size",
                  "amount": "amount", "bandwidth": "read_bw"}


def _mark_degraded(topo: Topology, element, family: str, dr) -> None:
    """Record one degraded probe family on its element.

    Graceful degradation's assembly half: the attribute the family would
    have measured lands as ``"unknown"`` with ``degraded`` provenance and
    zero confidence, and the retry diagnostics go into the report notes —
    the topology stays structurally complete instead of aborting, and the
    gap is attributable (paper's reliability contract: never silently
    report a value that was not measured)."""
    attr = _DEGRADED_ATTR.get(family, family)
    element.set(attr, "unknown", "", PROVENANCE_DEGRADED, 0.0)
    topo.notes.append(
        f"{element.name}/{family}: degraded after {dr.attempts} attempts "
        f"({dr.error})")


def _assemble_engine_topology(request: DiscoveryRequest, runner, eng,
                              timings: DiscoveryTimings) -> Topology:
    """Registry results -> ``Topology``, in probe order (mirrors the legacy
    sequential loop so engine and legacy reports stay comparable).

    Backend-neutral by construction: API capacities come from the runner's
    ``api_size`` hook, core counts from ``cores_per_sm`` — never from a
    concrete device object.  Families that exhausted their transient-retry
    budget arrive as ``errors.DegradedResult`` sentinels; each is recorded
    via ``_mark_degraded`` (attribute ``"unknown"``, ``degraded``
    provenance) instead of crashing the assembly.
    """
    topo = Topology(vendor=request.vendor, model=request.model,
                    backend=request.backend)
    topo.set_general("clock_domain", request.clock_domain,
                     provenance=PROVENANCE_API)
    topo.compute.append(ComputeElement("cores_per_sm", runner.cores_per_sm))

    api_size = getattr(runner, "api_size", lambda _s: None)

    # ---- per-space assembly, in probe order
    for info in eng.infos:
        res = eng.space_results[info.name]
        me = MemoryElement(info.name, info.kind, info.scope)

        sr = res["size"]
        if isinstance(sr, DegradedResult):
            _mark_degraded(topo, me, "size", sr)
        elif sr.found:
            if info.scope == "chip":
                # Paper Table I: L2-style totals come from the API; the
                # benchmark contributes the per-core segment size (§IV-F.1).
                me.set("size", api_size(info.name), "B", PROVENANCE_API)
            else:
                me.set("size", sr.size, "B", PROVENANCE_BENCHMARK,
                       sr.confidence)
                if not sr.cusum_agrees:
                    topo.notes.append(
                        f"{info.name}: CUSUM cross-check disagrees with the "
                        f"K-S change point — size result is suspect")

        gr = res.get("fetch_granularity")
        if isinstance(gr, DegradedResult):
            _mark_degraded(topo, me, "fetch_granularity", gr)
        elif gr is not None and gr.found:
            me.set("fetch_granularity", gr.granularity, "B",
                   PROVENANCE_BENCHMARK, 1.0)

        lat = res["latency"]
        if isinstance(lat, DegradedResult):
            _mark_degraded(topo, me, "latency", lat)
        else:
            me.set("load_latency", round(lat.p50, 1), "cyc",
                   PROVENANCE_BENCHMARK)
            me.set("load_latency_mean", round(lat.mean, 1), "cyc",
                   PROVENANCE_BENCHMARK)
            me.set("load_latency_p95", round(lat.p95, 1), "cyc",
                   PROVENANCE_BENCHMARK)

        ls = res.get("line_size")
        if isinstance(ls, DegradedResult):
            _mark_degraded(topo, me, "line_size", ls)
        elif ls is not None and ls.found:
            me.set("line_size", ls.line_size, "B", PROVENANCE_BENCHMARK, 1.0)

        am = res.get("amount")
        if isinstance(am, DegradedResult):
            _mark_degraded(topo, me, "amount", am)
        elif am is not None:
            kind, payload = am
            if kind == "per_core" and payload.found:
                me.set("amount", payload.amount, "", PROVENANCE_BENCHMARK, 1.0)
            elif kind == "aligned":
                # L2-style: align measured segment to the API-reported total.
                with _Timer(timings, "amount"):
                    k, aligned, conf = align_segments(api_size(info.name),
                                                      payload)
                me.set("amount", k, "", PROVENANCE_BENCHMARK, conf)
                me.set("segment_size", aligned, "B", PROVENANCE_BENCHMARK,
                       conf)

        bw = res.get("bandwidth")
        if isinstance(bw, DegradedResult):
            _mark_degraded(topo, me, "bandwidth", bw)
        elif bw is not None:
            me.set("read_bw", round(bw.read_bw / 1e9, 1), "GB/s",
                   PROVENANCE_BENCHMARK)
            me.set("write_bw", round(bw.write_bw / 1e9, 1), "GB/s",
                   PROVENANCE_BENCHMARK)
        topo.memory.append(me)

    # ---- physical sharing between logical spaces (NVIDIA-style, §IV-G)
    shares = eng.device_results.get("sharing", [])
    if isinstance(shares, DegradedResult):
        topo.notes.append(f"sharing: degraded after {shares.attempts} "
                          f"attempts ({shares.error})")
        shares = []
    for share in shares:
        if not share.shared:
            continue
        ma = topo.find_memory(share.space_a)
        mb = topo.find_memory(share.space_b)
        if mb and mb.name not in ma.shared_with:
            ma.shared_with.append(mb.name)
        if ma and ma.name not in mb.shared_with:
            mb.shared_with.append(ma.name)

    # ---- AMD-style CU<->sL1d sharing (§IV-H)
    cus = eng.device_results.get("cu_sharing")
    if isinstance(cus, DegradedResult):
        sl1d = topo.find_memory(request.cu_space)
        if sl1d is not None:
            _mark_degraded(topo, sl1d, "cu_sharing", cus)
    elif cus is not None:
        sl1d = topo.find_memory(request.cu_space)
        sl1d.shared_with = [",".join(map(str, g)) for g in cus.groups
                            if len(g) > 1]
        sl1d.set("exclusive_cus", cus.exclusive, "", PROVENANCE_BENCHMARK)

    # ---- device memory
    if "device_memory_latency" in eng.device_results:
        dm = MemoryElement("DeviceMemory", "memory", "chip")
        lat = eng.device_results["device_memory_latency"]
        if isinstance(lat, DegradedResult):
            _mark_degraded(topo, dm, "latency", lat)
        else:
            dm.set("load_latency", round(lat.p50, 1), "cyc",
                   PROVENANCE_BENCHMARK)
        bw = eng.device_results.get("device_memory_bandwidth")
        if isinstance(bw, DegradedResult):
            _mark_degraded(topo, dm, "bandwidth", bw)
        elif bw is not None:
            dm.set("read_bw", round(bw.read_bw / 1e9, 1), "GB/s",
                   PROVENANCE_BENCHMARK)
            dm.set("write_bw", round(bw.write_bw / 1e9, 1), "GB/s",
                   PROVENANCE_BENCHMARK)
        topo.memory.append(dm)

    topo.notes.append(
        f"discovery wall time: {eng.wall_seconds:.2f}s (engine; "
        f"per-family cpu { {k: round(v, 2) for k, v in timings.per_family.items()} }; "
        f"cache {eng.cache_stats['hits']} hits / "
        f"{eng.cache_stats['misses']} misses)")
    return topo


# --------------------------------------------------------------------------
# Backend wrappers: simulated devices
# --------------------------------------------------------------------------
def discover_sim(device, n_samples: int = 33,
                 elements: list[str] | None = None, *,
                 engine: bool = True, max_workers: int | None = None,
                 store=None, refresh: bool = False, budget=None,
                 fuse: bool = False, gc_policy=None, survey: bool = False,
                 resilience=None, parallel=None,
                 ) -> tuple[Topology, DiscoveryTimings]:
    """Full MT4G-style discovery of a simulated device.

    ``engine=True`` (default) routes through the unified driver and the
    batched probe engine; ``engine=False`` runs the legacy sequential loop.
    Both produce the same topology for a fixed device seed.  ``store`` /
    ``refresh`` / ``gc_policy`` behave as documented on ``discover()``.

    ``budget`` (a ``SweepBudget``) turns on the adaptive sweep planner —
    identical discrete attributes, confidence metrics from a boundary
    window instead of the full sweep series, ~3-5x fewer probed rows.
    The default stays dense: the sim backend is the validation oracle.
    ``fuse=True`` coalesces concurrently ready probe rounds into single
    batched dispatches (a wall-clock win on dispatch-bound runners).

    ``survey=True`` (fleet survey mode, needs a ``store``) verifies a
    stored sibling topology with a planned spot-check subset instead of a
    full discovery, writing it through under this request's key with
    ``survey`` provenance; see ``DiscoveryRequest.survey``.

    ``resilience`` (an ``errors.Resilience``) turns on fault tolerance:
    transient probe failures are retried with capped backoff, families past
    the budget degrade to ``"unknown"`` attributes instead of aborting,
    and — with a ``store`` — the run checkpoints after every completed
    work item so an interrupted discovery resumes without re-probing.

    ``parallel`` (an ``engine.parallel.ParallelConfig``) shards batched
    probe calls across the persistent worker-process pool — bit-identical
    results (request-keyed sampling), so it shares the inline run's store
    key; it is pure wall-clock, like ``fuse``.
    """
    descriptor = sim_request_descriptor(device, n_samples, elements, budget,
                                        survey=survey, resilience=resilience)

    if not engine:
        key = None
        if store is not None:
            if not refresh:
                key, hit = _store_lookup(store, descriptor)
                if hit is not None:
                    return hit
            else:
                from .engine.store import request_key
                key = request_key(descriptor)
        topo, timings = discover_sim_legacy(device, n_samples, elements)
        if store is not None:
            _store_persist(store, key, descriptor, topo, timings)
        return topo, timings

    device_families = ["sharing", "device_memory_latency",
                       "device_memory_bandwidth"]
    if device.cu_share_groups and (not elements or "sL1d" in elements):
        device_families.insert(1, "cu_sharing")

    request = DiscoveryRequest(
        descriptor=descriptor,
        vendor=device.vendor, model=device.name,
        backend=f"simulated:{device.name}",
        make_runner=lambda: SimRunner(device),
        n_samples=n_samples, elements=elements,
        device_families=tuple(device_families),
        max_workers=max_workers,
        preload_samples=True,           # request-keyed streams: sound
        budget=budget, fuse=fuse, survey=survey, resilience=resilience,
        parallel=parallel,
    )
    return discover(request, store=store, refresh=refresh,
                    gc_policy=gc_policy)


# --------------------------------------------------------------------------
# Backend wrappers: Pallas kernels (interpret mode)
# --------------------------------------------------------------------------
def discover_pallas(model=None, n_samples: int = 9,
                    elements: list[str] | None = None, *,
                    runner=None, max_workers: int | None = 0,
                    store=None, refresh: bool = False,
                    budget=_DEFAULT_BUDGET, fuse: bool = True,
                    gc_policy=None, survey: bool = False, resilience=None,
                    parallel=None,
                    ) -> tuple[Topology, DiscoveryTimings]:
    """Discovery through the real Pallas probe kernels (third backend).

    Same engine, same registry, same statistics as ``discover_sim`` — the
    runner is the only moving part, which is the point: the probe stack is
    genuinely backend-neutral.  ``model`` is the configured ground-truth
    hierarchy (default ``make_pallas_model()``); pass ``runner`` to reuse a
    warmed ``PallasRunner`` (compiled kernels) across discoveries.

    Kernel launches are the dominant cost of this backend (a timed
    dispatch plus its calibration twin per sample), so it defaults to the
    probe-volume optimizers: the adaptive sweep planner
    (``budget=SweepBudget()``; pass ``budget=None`` to force dense sweeps)
    and cross-family batch fusion (``fuse=True``), which coalesces every
    concurrently ready probe round onto one ``pchase_many`` /
    ``cold_chase_many`` grid launch.  Fused rounds are *executed serially
    by the coordinator*, preserving the no-co-running-kernels guarantee
    the inline schedule (``max_workers=0``) provides in unfused mode.
    Persisted samples are never preloaded (a re-measure is a re-measure).
    Topologies are content-addressed in the ``TopologyStore`` by
    ``pallas_request_descriptor`` and served through ``TopologyService``
    exactly like sim/host ones.
    """
    from .probes.pallas_runner import PallasRunner, make_pallas_model

    if budget is _DEFAULT_BUDGET:
        budget = default_sweep_budget()
    if runner is not None:
        model = runner.model
    elif model is None:
        model = make_pallas_model()

    device_families = ["sharing", "device_memory_latency",
                       "device_memory_bandwidth"]
    if model.cu_share_groups and (not elements or "sL1d" in elements):
        device_families.insert(1, "cu_sharing")

    request = DiscoveryRequest(
        descriptor=pallas_request_descriptor(model, n_samples, elements,
                                             budget, survey=survey,
                                             resilience=resilience),
        vendor=model.vendor, model=model.name,
        backend=f"pallas-interp:{model.name}",
        make_runner=(lambda: runner) if runner is not None
        else (lambda: PallasRunner(model)),
        n_samples=n_samples, elements=elements,
        device_families=tuple(device_families),
        max_workers=max_workers,
        clock_domain="interp-cycles",   # chain-length units, timed end-to-end
        preload_samples=False,          # real measurements: always re-measure
        budget=budget, fuse=fuse, survey=survey, resilience=resilience,
        # PallasRunner publishes no RunnerSpec (compiled kernels don't
        # round-trip a pickle), so pooling degrades to inline — the config
        # is accepted for interface symmetry with the other backends.
        parallel=parallel,
    )
    return discover(request, store=store, refresh=refresh,
                    gc_policy=gc_policy)


# --------------------------------------------------------------------------
# Backend wrappers: this machine's CPU hierarchy
# --------------------------------------------------------------------------
def discover_host(max_bytes: int = 128 * 1024**2, n_samples: int = 9,
                  quick: bool = True, *, store=None, refresh: bool = False,
                  gc_policy=None, parallel=None
                  ) -> tuple[Topology, DiscoveryTimings]:
    """Live discovery of this machine's CPU hierarchy (real measurements).

    The host hierarchy has one probeable space, so instead of the registry
    it hands the unified driver a small custom work-item plan (size ∥
    latencies ∥ bandwidths, all independent on real hardware) — sharing the
    same store, caching, scheduling, and timing machinery as the other
    backends.  ``store`` works as in ``discover()`` — host measurements are
    slow and real, so serving a prior run of the same request from the
    store is the common production path; ``refresh=True`` forces a
    re-measure.
    """
    from .engine import WorkItem

    def plan(runner):
        return [
            WorkItem(key="size", family="size", fn=lambda _r: find_size(
                runner, "host-cache", lo=8 * KIB, step=4 * KIB,
                n_samples=n_samples, max_bytes=max_bytes, max_points=24,
                max_widenings=1, batched=True)),
            WorkItem(key="lat_small", family="latency", fn=lambda _r:
                     measure_latency(runner, "host-cache",
                                     fetch_granularity=64,
                                     n_samples=n_samples, array_factor=256)),
            WorkItem(key="lat_big", family="latency", fn=lambda _r:
                     measure_latency(runner, "host-cache",
                                     fetch_granularity=4096,
                                     n_samples=n_samples,
                                     array_factor=max_bytes // 4096 // 2)),
            WorkItem(key="bw_read", family="bandwidth",
                     fn=lambda _r: runner.bandwidth("DRAM", "read")),
            WorkItem(key="bw_write", family="bandwidth",
                     fn=lambda _r: runner.bandwidth("DRAM", "write")),
        ]

    def assemble(sched, timings):
        topo = Topology(vendor="host", model="cpu", backend="cpu")
        me = MemoryElement("host-cache", "cache", "host")
        sr = sched.results["size"]
        if sr.found:
            me.set("size", sr.size, "B", PROVENANCE_BENCHMARK, sr.confidence)
        me.set("load_latency", round(sched.results["lat_small"].mean, 2),
               "ns", PROVENANCE_BENCHMARK)
        topo.memory.append(me)

        dram = MemoryElement("DRAM", "memory", "host")
        dram.set("load_latency", round(sched.results["lat_big"].mean, 2),
                 "ns", PROVENANCE_BENCHMARK)
        dram.set("read_bw", round(sched.results["bw_read"] / 1e9, 2), "GB/s",
                 PROVENANCE_BENCHMARK)
        dram.set("write_bw", round(sched.results["bw_write"] / 1e9, 2),
                 "GB/s", PROVENANCE_BENCHMARK)
        topo.memory.append(dram)
        topo.notes.append("host runner: per-sample = mean ns/load of a "
                          "jitted dependent chase (DESIGN.md adaptation "
                          "note 1)")
        return topo

    request = DiscoveryRequest(
        descriptor=host_request_descriptor(max_bytes, n_samples, quick),
        vendor="host", model="cpu", backend="cpu",
        make_runner=lambda: HostRunner(
            max_bytes=max_bytes, iters=1 << 14 if quick else 1 << 16),
        n_samples=n_samples,
        # Real measurements are perturbed by co-running probes: keep the
        # host schedule serial so timings stay trustworthy — the engine's
        # value here is the shared orchestration, not parallelism.
        max_workers=1,
        preload_samples=False,          # real measurements: always re-measure
        plan=plan, assemble=assemble, parallel=parallel,
    )
    return discover(request, store=store, refresh=refresh,
                    gc_policy=gc_policy)


# --------------------------------------------------------------------------
# Legacy sequential discovery (reference implementation + benchmark baseline)
# --------------------------------------------------------------------------
def discover_sim_legacy(device, n_samples: int = 33,
                        elements: list[str] | None = None
                        ) -> tuple[Topology, DiscoveryTimings]:
    """The paper-faithful sequential loop: one probe family at a time."""
    runner = SimRunner(device)
    topo = Topology(vendor=device.vendor, model=device.name,
                    backend=f"simulated:{device.name}")
    timings = DiscoveryTimings()

    topo.set_general("clock_domain", "cycles", provenance=PROVENANCE_API)
    topo.compute.append(ComputeElement("cores_per_sm", device.cores_per_sm))

    for info in runner.spaces():
        if elements and info.name not in elements:
            continue
        lvl = device.level(info.name)
        me = MemoryElement(info.name, info.kind, info.scope)

        # ---- size (benchmark; scratchpads would be API on real hardware).
        # Scratchpads are word-granular: probe them at 4 B steps, caches at
        # the 32 B default until the cold-pass granularity is known (§IV-D).
        step0 = 4 if info.kind == "scratchpad" else 32
        with _Timer(timings, "size"):
            sr = find_size(runner, info.name, lo=1 * KIB, step=step0,
                           n_samples=n_samples, max_bytes=info.max_bytes)
        if sr.found:
            if info.scope == "chip":
                # Paper Table I: L2-style totals come from the API; the
                # benchmark contributes the per-core segment size (§IV-F.1).
                me.set("size", lvl.size, "B", PROVENANCE_API)
            else:
                me.set("size", sr.size, "B", PROVENANCE_BENCHMARK, sr.confidence)
                if not sr.cusum_agrees:
                    topo.notes.append(
                        f"{info.name}: CUSUM cross-check disagrees with the "
                        f"K-S change point — size result is suspect")

        # ---- fetch granularity (cold-pass; caches only)
        fetch = 32
        if info.supports_cold:
            with _Timer(timings, "fetch_granularity"):
                gr = find_fetch_granularity(runner, info.name,
                                            n_samples=n_samples)
            if gr.found:
                fetch = gr.granularity
                me.set("fetch_granularity", gr.granularity, "B",
                       PROVENANCE_BENCHMARK, 1.0)

        # ---- load latency (p50 headline: robust to the rare large
        # outliers the K-S machinery is built to absorb — the mean is kept
        # as a secondary stat, cf. paper §IV-C's statistics set)
        # Small caches: keep the fixed-size latency array inside capacity
        # (paper §IV-C uses 256 x granularity; a 2 KiB constant cache needs
        # a smaller factor).
        factor = 256
        if sr.found:
            factor = max(min(256, sr.size // (2 * fetch)), 8)
        with _Timer(timings, "latency"):
            lat = measure_latency(runner, info.name, fetch_granularity=fetch,
                                  n_samples=n_samples * 4 + 1,
                                  array_factor=factor)
        me.set("load_latency", round(lat.p50, 1), "cyc", PROVENANCE_BENCHMARK)
        me.set("load_latency_mean", round(lat.mean, 1), "cyc",
               PROVENANCE_BENCHMARK)
        me.set("load_latency_p95", round(lat.p95, 1), "cyc", PROVENANCE_BENCHMARK)

        # ---- cache line size
        if info.supports_cold and sr.found:
            with _Timer(timings, "line_size"):
                ls = find_line_size(runner, info.name, sr.size, fetch,
                                    n_samples=n_samples)
            if ls.found:
                me.set("line_size", ls.line_size, "B", PROVENANCE_BENCHMARK, 1.0)

        # ---- amount per SM / per GPU
        if info.supports_amount and sr.found:
            with _Timer(timings, "amount"):
                am = find_amount(runner, info.name, sr.size,
                                 runner.cores_per_sm, n_samples=n_samples)
            if am.found:
                me.set("amount", am.amount, "", PROVENANCE_BENCHMARK, 1.0)
        elif info.scope == "chip" and sr.found:
            # L2-style: align measured segment to the API-reported total.
            with _Timer(timings, "amount"):
                k, aligned, conf = align_segments(lvl.size, sr.size)
            me.set("amount", k, "", PROVENANCE_BENCHMARK, conf)
            me.set("segment_size", aligned, "B", PROVENANCE_BENCHMARK, conf)

        # ---- bandwidth: higher-level caches + device memory only (Table I †)
        if info.scope == "chip" or info.kind == "memory":
            with _Timer(timings, "bandwidth"):
                bw = measure_bandwidth(runner, info.name)
            me.set("read_bw", round(bw.read_bw / 1e9, 1), "GB/s",
                   PROVENANCE_BENCHMARK)
            me.set("write_bw", round(bw.write_bw / 1e9, 1), "GB/s",
                   PROVENANCE_BENCHMARK)
        topo.memory.append(me)

    # ---- physical sharing between logical spaces (NVIDIA-style, §IV-G)
    cache_spaces = [i for i in runner.spaces()
                    if i.supports_sharing and i.scope == "core"
                    and (not elements or i.name in elements)]
    with _Timer(timings, "sharing"):
        for i, a in enumerate(cache_spaces):
            for b in cache_spaces[i + 1:]:
                size_a = topo.find_memory(a.name)
                size_a = size_a.get("size") if size_a else None
                if not size_a:
                    continue
                res = find_sharing(runner, a.name, b.name, size_a,
                                   n_samples=n_samples)
                if res.shared:
                    ma, mb = topo.find_memory(a.name), topo.find_memory(b.name)
                    if mb and mb.name not in ma.shared_with:
                        ma.shared_with.append(mb.name)
                    if ma and ma.name not in mb.shared_with:
                        mb.shared_with.append(ma.name)

    # ---- AMD-style CU<->sL1d sharing (§IV-H)
    if device.cu_share_groups and (not elements or "sL1d" in (elements or [])
                                   or elements is None):
        sl1d = topo.find_memory("sL1d")
        if sl1d and sl1d.get("size"):
            all_cus = sorted(cu for grp in device.cu_share_groups for cu in grp)
            with _Timer(timings, "cu_sharing"):
                cus = find_cu_sharing(runner, all_cus, sl1d.get("size"),
                                      n_samples=max(n_samples // 2, 9))
            sl1d.shared_with = [",".join(map(str, g)) for g in cus.groups
                                if len(g) > 1]
            sl1d.set("exclusive_cus", cus.exclusive, "", PROVENANCE_BENCHMARK)

    # ---- device memory
    dm = MemoryElement("DeviceMemory", "memory", "chip")
    with _Timer(timings, "latency"):
        lat = measure_latency(runner, "DeviceMemory", fetch_granularity=4096,
                              n_samples=n_samples * 4 + 1, array_factor=4096)
    dm.set("load_latency", round(lat.p50, 1), "cyc", PROVENANCE_BENCHMARK)
    with _Timer(timings, "bandwidth"):
        bw = measure_bandwidth(runner, "DeviceMemory")
    dm.set("read_bw", round(bw.read_bw / 1e9, 1), "GB/s", PROVENANCE_BENCHMARK)
    dm.set("write_bw", round(bw.write_bw / 1e9, 1), "GB/s", PROVENANCE_BENCHMARK)
    topo.memory.append(dm)

    topo.notes.append(f"discovery wall time: {timings.total:.2f}s "
                      f"({ {k: round(v, 2) for k, v in timings.per_family.items()} })")
    return topo, timings


def spec_from_topology(topo: Topology, base: HardwareSpec) -> HardwareSpec:
    """Overlay discovered values onto a catalog record (paper §VI-A usage:
    measured parameters feed the performance model)."""
    import dataclasses

    dm = topo.find_memory("DeviceMemory") or topo.find_memory("DRAM")
    updates = {}
    if dm is not None:
        if dm.get("read_bw"):
            updates["hbm_bandwidth"] = float(dm.get("read_bw")) * 1e9
        if dm.get("size"):
            updates["hbm_bytes"] = int(dm.get("size"))
    return dataclasses.replace(base, **updates) if updates else base
