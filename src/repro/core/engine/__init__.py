"""Batched, schedulable probe engine (discovery fast path).

Decomposes MT4G-style discovery into a declarative probe registry, a
dependency-aware concurrent scheduler, a keyed sample cache, and batched
runner calls — same statistics, same results, a fraction of the wall time.
See ``engine.run_probes`` for the entry point and ``discover.discover_sim``
for the driver that assembles a ``Topology`` from it.
"""
from .cache import CachingRunner, SampleCache
from .engine import DEVICE_KEY, EngineResult, run_probes
from .fusion import FusionDispatcher, run_fused
from .planner import SweepBudget
from .registry import (DEVICE_FAMILIES, SPACE_FAMILIES, ProbeContext,
                       ProbeSpec, device_probe_specs, space_probe_specs)
from .scheduler import ScheduleResult, WorkItem, run_work_items
from .store import (GcPolicy, StoredTopology, StoreLock, TopologyStore,
                    request_key)

__all__ = [
    "CachingRunner", "SampleCache",
    "DEVICE_KEY", "EngineResult", "run_probes",
    "FusionDispatcher", "run_fused", "SweepBudget",
    "DEVICE_FAMILIES", "SPACE_FAMILIES", "ProbeContext", "ProbeSpec",
    "device_probe_specs", "space_probe_specs",
    "ScheduleResult", "WorkItem", "run_work_items",
    "GcPolicy", "StoredTopology", "StoreLock", "TopologyStore",
    "request_key",
]
