"""Keyed sample cache + caching runner wrapper for the probe engine.

Discovery repeats many identical sample requests: every §IV-F/G/H workflow
re-derives the same warm-hit and certain-miss reference distributions, and
the §IV-B widening loop re-sweeps grid points it has already measured.  The
``SampleCache`` memoizes runner requests by their full signature; because
simulated runners also *key their random streams* by that same signature
(``simulate._KeyedSampler``), a cache hit returns byte-for-byte what a
re-execution would have — the cache is a pure time optimization, never a
behavioral one.

``CachingRunner`` wraps any ``ProbeRunner`` with the cache and is what the
engine hands to the probe workflows.  It is thread-safe (the scheduler runs
work items concurrently) and passes through the optional runner hooks the
engine uses (``api_size``, ``cu_ids``, ``cores_per_sm``).
"""
from __future__ import annotations

import threading
from typing import Callable

import numpy as np

__all__ = ["SampleCache", "CachingRunner"]


class SampleCache:
    """Thread-safe memo of probe sample requests with hit/miss counters."""

    def __init__(self):
        self._store: dict[tuple, np.ndarray] = {}
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0

    def get_or_run(self, key: tuple, fn: Callable[[], np.ndarray]) -> np.ndarray:
        with self._lock:
            if key in self._store:
                self.hits += 1
                return self._store[key]
        # Run outside the lock so independent probes proceed concurrently.
        # Two threads may race on the same key; keyed sampling makes their
        # results identical, so last-write-wins is safe.
        value = fn()
        with self._lock:
            self.misses += 1
            self._store[key] = value
        return value

    def peek(self, key: tuple) -> np.ndarray | None:
        with self._lock:
            return self._store.get(key)

    def __len__(self) -> int:
        with self._lock:
            return len(self._store)

    def stats(self) -> dict[str, int]:
        with self._lock:
            return {"hits": self.hits, "misses": self.misses,
                    "entries": len(self._store)}

    # --------------------------------------------------------- persistence
    def snapshot(self) -> dict[tuple, np.ndarray]:
        """All entries as *read-only views* (``TopologyStore.put_samples``).

        Copy-on-write contract: no sample matrix is duplicated here — the
        snapshot shares the cache's buffers, which is safe because cache
        writers replace references (never mutate arrays in place) and the
        views are frozen (``writeable=False``).  A consumer that needs a
        mutable matrix copies its own row; serialization (checkpoint and
        store write-through, the hot callers — the checkpoint hook fires
        after *every* work item) reads without doubling resident memory.
        """
        with self._lock:
            return {k: self._frozen_view(v) for k, v in self._store.items()}

    def preload(self, entries: dict) -> None:
        """Seed the cache from persisted entries (``load_samples``).

        Entries are shared as read-only views, not copied: resume and
        store-hit paths preload the full persisted sample set, and a deep
        copy here doubled resident sample memory for the whole run.  The
        probes treat served rows as read-only already; the frozen view
        turns any violation into a loud ``ValueError`` instead of silent
        cross-run corruption.  Preloaded rows count as neither hits nor
        misses at load time; the probes that later read them register as
        ordinary hits.
        """
        with self._lock:
            for k, v in entries.items():
                self._store.setdefault(tuple(k), self._frozen_view(v))

    @staticmethod
    def _frozen_view(value) -> np.ndarray:
        """A non-owning read-only view of ``value`` (zero-copy for arrays)."""
        view = np.asarray(value).view()
        view.flags.writeable = False
        return view


class CachingRunner:
    """ProbeRunner adapter that memoizes every sample request.

    Cached arrays are shared across probe workflows; the probes treat sample
    vectors as read-only (sorting/reduction all copy), which keeps sharing
    safe.
    """

    # Capability flag read by the planner's speculative prefetch: repeated
    # requests are served from the cache, so prefetching candidate rows is
    # free on replay (bare runners would pay for every speculative row).
    caches_requests = True

    def __init__(self, base, cache: SampleCache | None = None):
        self.base = base
        self.cache = cache if cache is not None else SampleCache()

    # ------------------------------------------------------------ probes
    def spaces(self):
        return self.base.spaces()

    def pchase(self, space, array_bytes, stride, n_samples):
        key = ("pchase", space, int(array_bytes), int(stride), int(n_samples))
        return self.cache.get_or_run(
            key, lambda: self.base.pchase(space, array_bytes, stride,
                                          n_samples))

    def pchase_batch(self, space, array_bytes_list, stride, n_samples):
        """Serve cached rows from the cache; fetch the rest in ONE base call."""
        sizes = [int(ab) for ab in array_bytes_list]
        keys = [("pchase", space, ab, int(stride), int(n_samples))
                for ab in sizes]
        rows: list[np.ndarray | None] = [self.cache.peek(k) for k in keys]
        missing = [i for i, r in enumerate(rows) if r is None]
        if missing:
            fetched = np.asarray(self.base.pchase_batch(
                space, [sizes[i] for i in missing], stride, n_samples))
            with self.cache._lock:
                for j, i in enumerate(missing):
                    self.cache.misses += 1
                    self.cache._store[keys[i]] = fetched[j]
                    rows[i] = fetched[j]
        if len(missing) < len(rows):
            with self.cache._lock:
                self.cache.hits += len(rows) - len(missing)
        return np.stack(rows)

    def pchase_many(self, requests, n_samples, fresh: bool = False):
        """Heterogeneous fused batch (per-row space/size/stride): cached rows
        served, duplicates folded, the rest fetched in ONE base call.

        This is the call the fusion dispatcher lands coalesced rounds on —
        several probe families' pending rows arrive as one request list, so
        dedup matters: two families asking for the same reference
        distribution must cost one probe.

        ``fresh=True`` bypasses cache *serving* (results still overwrite
        the cache): measuring runners need it when a row set must share one
        launch's clock — e.g. the boundary window the change-point scan
        runs over — instead of mixing rows recorded at different drift
        levels.  Request-keyed runners return identical values either way.
        """
        reqs = [(space, int(ab), int(stride))
                for space, ab, stride in requests]
        keys = [("pchase", space, ab, stride, int(n_samples))
                for space, ab, stride in reqs]
        if fresh:
            many = getattr(self.base, "pchase_many", None)
            if many is not None:           # base runners measure fresh always
                rows = np.asarray(many(reqs, n_samples))
            else:
                rows = np.stack([self.base.pchase(r[0], r[1], r[2], n_samples)
                                 for r in reqs])
            with self.cache._lock:
                for key, row in zip(keys, rows):
                    self.cache.misses += 1
                    self.cache._store[key] = row
            return rows
        return self._serve_many(
            keys, reqs, n_samples,
            many=getattr(self.base, "pchase_many", None),
            single=lambda r: self.base.pchase(r[0], r[1], r[2], n_samples))

    def cold_chase_many(self, requests, n_samples):
        """Cold-pass twin of ``pchase_many`` (per-row spaces and strides)."""
        reqs = [(space, int(ab), int(stride))
                for space, ab, stride in requests]
        keys = [("cold", space, ab, stride, int(n_samples))
                for space, ab, stride in reqs]
        return self._serve_many(
            keys, reqs, n_samples,
            many=getattr(self.base, "cold_chase_many", None),
            single=lambda r: self.base.cold_chase(r[0], r[1], r[2],
                                                  n_samples))

    def _serve_many(self, keys, reqs, n_samples, many, single):
        """Shared fused-batch cache logic: peek, dedupe, one base call."""
        rows: list[np.ndarray | None] = [self.cache.peek(k) for k in keys]
        missing_keys: dict[tuple, list[int]] = {}
        for i, r in enumerate(rows):
            if r is None:
                missing_keys.setdefault(keys[i], []).append(i)
        if missing_keys:
            uniq = list(missing_keys)
            uniq_reqs = [reqs[positions[0]]
                         for positions in missing_keys.values()]
            if many is not None:
                fetched = np.asarray(many(uniq_reqs, n_samples))
            else:
                fetched = np.stack([single(r) for r in uniq_reqs])
            with self.cache._lock:
                for j, key in enumerate(uniq):
                    self.cache.misses += 1
                    self.cache._store[key] = fetched[j]
                    for i in missing_keys[key]:
                        rows[i] = fetched[j]
        served = len(rows) - sum(len(v) for v in missing_keys.values())
        if served:
            with self.cache._lock:
                self.cache.hits += served
        return np.stack(rows)

    def cold_chase(self, space, array_bytes, stride, n_samples):
        key = ("cold", space, int(array_bytes), int(stride), int(n_samples))
        return self.cache.get_or_run(
            key, lambda: self.base.cold_chase(space, array_bytes, stride,
                                              n_samples))

    def cold_chase_batch(self, space, array_bytes_list, stride_list,
                         n_samples):
        """Cold-pass sweep rows: cached rows served, the rest in ONE base
        call.  Unlike ``pchase_batch`` the stride varies per row (the §IV-D
        granularity sweep grows both the stride and the array)."""
        sizes = [int(ab) for ab in array_bytes_list]
        strides = [int(s) for s in stride_list]
        keys = [("cold", space, ab, s, int(n_samples))
                for ab, s in zip(sizes, strides)]
        rows: list[np.ndarray | None] = [self.cache.peek(k) for k in keys]
        missing = [i for i, r in enumerate(rows) if r is None]
        if missing:
            if hasattr(self.base, "cold_chase_batch"):
                fetched = np.asarray(self.base.cold_chase_batch(
                    space, [sizes[i] for i in missing],
                    [strides[i] for i in missing], n_samples))
            else:
                fetched = np.stack([self.base.cold_chase(
                    space, sizes[i], strides[i], n_samples)
                    for i in missing])
            with self.cache._lock:
                for j, i in enumerate(missing):
                    self.cache.misses += 1
                    self.cache._store[keys[i]] = fetched[j]
                    rows[i] = fetched[j]
        if len(missing) < len(rows):
            with self.cache._lock:
                self.cache.hits += len(rows) - len(missing)
        return np.stack(rows)

    def amount_probe(self, space, core_a, core_b, array_bytes, n_samples):
        key = ("amount", space, int(core_a), int(core_b), int(array_bytes),
               int(n_samples))
        return self.cache.get_or_run(
            key, lambda: self.base.amount_probe(space, core_a, core_b,
                                                array_bytes, n_samples))

    def sharing_probe(self, space_a, space_b, array_bytes, n_samples):
        key = ("sharing", space_a, space_b, int(array_bytes), int(n_samples))
        return self.cache.get_or_run(
            key, lambda: self.base.sharing_probe(space_a, space_b,
                                                 array_bytes, n_samples))

    def cu_sharing_probe(self, cu_a, cu_b, array_bytes, n_samples,
                         space="sL1d"):
        key = ("cu", space, int(cu_a), int(cu_b), int(array_bytes),
               int(n_samples))
        return self.cache.get_or_run(
            key, lambda: self.base.cu_sharing_probe(cu_a, cu_b, array_bytes,
                                                    n_samples, space=space))

    def cu_sharing_probe_batch(self, cu_a, cu_bs, array_bytes, n_samples,
                               space="sL1d"):
        """Pairwise sweep rows: each pair is probed at most once per
        discovery, so skip the per-pair memo and issue one base call."""
        if hasattr(self.base, "cu_sharing_probe_batch"):
            rows = self.base.cu_sharing_probe_batch(cu_a, cu_bs, array_bytes,
                                                    n_samples, space=space)
        else:
            rows = np.stack([self.base.cu_sharing_probe(cu_a, b, array_bytes,
                                                        n_samples,
                                                        space=space)
                             for b in cu_bs])
        with self.cache._lock:
            self.cache.misses += len(cu_bs)
        return rows

    def eviction_many(self, requests, n_samples):
        """Mixed eviction-grid batch (§IV-F/G/H): cached rows served,
        duplicates deduped, the rest in ONE base ``eviction_many`` call.

        Rows share the memo keys of the single-probe paths
        (``amount_probe`` / ``sharing_probe`` / ``cu_sharing_probe``), so a
        row fetched through the grid is a cache hit for any later
        single-probe replay of the same request — and vice versa.
        """
        reqs = []
        keys = []
        for req in requests:
            kind = req[0]
            if kind == "amount":
                _, space, core_a, core_b, ab = req
                reqs.append((kind, space, int(core_a), int(core_b), int(ab)))
                keys.append(("amount", space, int(core_a), int(core_b),
                             int(ab), int(n_samples)))
            elif kind == "sharing":
                _, space_a, space_b, ab = req
                reqs.append((kind, space_a, space_b, int(ab)))
                keys.append(("sharing", space_a, space_b, int(ab),
                             int(n_samples)))
            elif kind == "cu":
                _, space, cu_a, cu_b, ab = req
                reqs.append((kind, space, int(cu_a), int(cu_b), int(ab)))
                keys.append(("cu", space, int(cu_a), int(cu_b), int(ab),
                             int(n_samples)))
            else:
                raise ValueError(f"unknown eviction request kind: {kind!r}")

        def single(req):
            if req[0] == "amount":
                return self.base.amount_probe(req[1], req[2], req[3], req[4],
                                              n_samples)
            if req[0] == "sharing":
                return self.base.sharing_probe(req[1], req[2], req[3],
                                               n_samples)
            return self.base.cu_sharing_probe(req[2], req[3], req[4],
                                              n_samples, space=req[1])

        return self._serve_many(
            keys, reqs, n_samples,
            many=getattr(self.base, "eviction_many", None),
            single=single)

    def bandwidth(self, space, mode="read"):
        # floats, not arrays — keyed on the runner side; no need to memoize.
        return self.base.bandwidth(space, mode)

    # ------------------------------------------------------------- hooks
    def api_size(self, space):
        fn = getattr(self.base, "api_size", None)
        return fn(space) if fn is not None else None

    def cu_ids(self):
        fn = getattr(self.base, "cu_ids", None)
        return fn() if fn is not None else []

    @property
    def cores_per_sm(self) -> int:
        return getattr(self.base, "cores_per_sm", 1)

    @property
    def deterministic(self) -> bool:
        """Whether repeated requests return bit-identical samples (the
        base runner's contract — caching doesn't change it)."""
        return getattr(self.base, "deterministic", False)

    def runner_spec(self):
        """The *base* runner's rebuild spec (``engine.parallel``), or None.

        The sample cache itself stays on the coordinator — pool workers
        only ever see cache-missing rows — so the worker-side rebuild is
        the bare runner, not another caching layer.
        """
        fn = getattr(self.base, "runner_spec", None)
        return fn() if fn is not None else None
