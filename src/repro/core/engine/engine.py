"""The probe engine: registry × spaces -> scheduled, cached, batched probes.

``run_probes`` is the engine entry point: it wraps a ``ProbeRunner`` in the
keyed sample cache, expands the probe registry into (space × family) work
items with their dependency edges, runs them on the concurrent scheduler,
and returns the raw probe results plus per-family timings and cache/order
diagnostics.  The unified ``discover.discover(request)`` core drives this
function for every backend (the ``discover_sim``/``discover_host``/
``discover_pallas`` wrappers only build the request): it assembles the
returned results into a ``Topology`` in exactly the order the legacy
sequential loop did, which is why engine and legacy discovery stay
bit-identical on simulated devices.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from ..errors import DegradedResult
from .cache import CachingRunner, SampleCache
from .registry import (DEVICE_FAMILIES, ProbeContext, space_probe_specs)
from .scheduler import WorkItem, run_work_items

__all__ = ["EngineResult", "run_probes", "DEVICE_KEY"]

DEVICE_KEY = "<device>"


@dataclass
class EngineResult:
    """Raw engine output, pre-topology-assembly."""

    space_results: dict = field(default_factory=dict)  # space -> family -> res
    device_results: dict = field(default_factory=dict)  # family -> result
    infos: list = field(default_factory=list)           # probed spaces, in order
    order: list = field(default_factory=list)           # completion order
    cache_stats: dict = field(default_factory=dict)
    wall_seconds: float = 0.0
    degraded: list = field(default_factory=list)        # DegradedResult, in order
    retries: int = 0                                    # transient retries spent


def run_probes(runner, n_samples: int = 33, elements: list[str] | None = None,
               *, device_families: tuple[str, ...] = (),
               max_workers: int | None = None, timings=None,
               cache: SampleCache | None = None, budget=None,
               fuse: bool = False, resilience=None,
               checkpoint=None, parallel=None) -> EngineResult:
    """Run the full registry against ``runner`` through the engine.

    ``device_families`` selects which device-scoped families to schedule
    (drivers gate e.g. ``cu_sharing`` on the device actually having CU
    groups, mirroring the legacy flow).

    ``budget`` (a ``planner.SweepBudget``) switches sweep-heavy families to
    the adaptive coarse-to-fine planner — identical discrete attributes,
    ~4-8x fewer probed rows.  ``fuse=True`` runs the schedule through the
    cross-family fusion dispatcher: concurrently ready items coalesce their
    probe rounds into single ``pchase_many``/``cold_chase_many`` dispatches
    (``max_workers`` is ignored in fused mode).

    ``resilience`` (an ``errors.Resilience``) turns on per-item transient
    retry with graceful degradation: an item that exhausts its retry
    budget lands as an ``errors.DegradedResult`` in the results (collected
    in ``EngineResult.degraded``) instead of aborting the run, and the
    policy's statistical knobs thread into the probe context.
    ``checkpoint(key)`` fires after every completed work item — the
    discovery layer's sample-cache write-through hook.

    ``parallel`` (an ``engine.parallel.ParallelConfig``) shards the
    batched capability calls across the persistent worker-process pool:
    the runner is wrapped in a ``ParallelRunner`` *below* the caching
    layer, so cached rows are served locally and only cache-missing rows
    cross the process boundary.  Runners without a ``RunnerSpec`` — or
    boxes below the config's effective-core floor — silently stay inline;
    results are bit-identical either way for deterministic runners.
    """
    if parallel is not None:
        from .parallel import maybe_parallel_runner

        runner = maybe_parallel_runner(runner, parallel)
    cached = CachingRunner(runner, cache=cache)
    dispatcher = None
    probe_runner = cached
    if fuse:
        from .fusion import FusionDispatcher

        dispatcher = FusionDispatcher(cached)
        probe_runner = dispatcher.proxy()
    infos = [i for i in cached.spaces()
             if not elements or i.name in elements]

    space_results: dict[str, dict] = {i.name: {} for i in infos}
    shared_ctx = ProbeContext(runner=probe_runner, n_samples=n_samples,
                              all_results=space_results, infos=infos,
                              budget=budget, resilience=resilience)

    degraded: list[DegradedResult] = []

    def on_exhausted(it, exc, attempts):
        """Stand-in result for an item past its retry budget.

        Space items write their result into ``space_results`` from inside
        ``fn`` — which raised — so the sentinel must be planted here for
        dependent families to see it (they all check ``.found`` first).
        """
        space, fam = it.key
        dr = DegradedResult(family=fam, key=f"{space}/{fam}",
                            error=f"{type(exc).__name__}: {exc}",
                            attempts=attempts)
        degraded.append(dr)
        if space in space_results:
            space_results[space][fam] = dr
        return dr

    items: list[WorkItem] = []
    scheduled: set[tuple[str, str]] = set()

    def make_space_item(info, spec, deps):
        ctx = ProbeContext(runner=probe_runner, n_samples=n_samples,
                           info=info, results=space_results[info.name],
                           all_results=space_results, infos=infos,
                           budget=budget, resilience=resilience)

        def fn(_results, spec=spec, ctx=ctx, name=info.name):
            value = spec.run(ctx)
            space_results[name][spec.family] = value
            return value
        return WorkItem(key=(info.name, spec.family), fn=fn, deps=deps,
                        family=spec.family)

    for info in infos:
        specs = space_probe_specs(info)
        families = {s.family for s in specs}
        for spec in specs:
            deps = tuple((info.name, d) for d in spec.depends
                         if d in families)
            items.append(make_space_item(info, spec, deps))
            scheduled.add((info.name, spec.family))

    # Device-scoped families: depend on every size result they might read.
    size_deps = tuple(k for k in scheduled if k[1] == "size")
    for spec in DEVICE_FAMILIES:
        if spec.family not in device_families:
            continue
        deps = size_deps if spec.family in ("sharing", "cu_sharing") else ()

        def fn(_results, spec=spec):
            return spec.run(shared_ctx)
        # Timing buckets match the legacy names (device-memory latency and
        # bandwidth fold into the per-family "latency"/"bandwidth" rows).
        bucket = {"device_memory_latency": "latency",
                  "device_memory_bandwidth": "bandwidth"}.get(spec.family,
                                                              spec.family)
        items.append(WorkItem(key=(DEVICE_KEY, spec.family), fn=fn,
                              deps=deps, family=bucket))

    sched = run_work_items(items, max_workers=max_workers, timings=timings,
                           fuser=dispatcher, resilience=resilience,
                           on_exhausted=on_exhausted if resilience else None,
                           on_item_done=checkpoint, parallel=parallel)

    device_results = {fam: sched.results[(DEVICE_KEY, fam)]
                      for fam in device_families
                      if (DEVICE_KEY, fam) in sched.results}
    return EngineResult(
        space_results=space_results,
        device_results=device_results,
        infos=infos,
        order=sched.order,
        cache_stats=cached.cache.stats(),
        wall_seconds=sched.wall_seconds,
        degraded=degraded,
        retries=sched.retries,
    )
