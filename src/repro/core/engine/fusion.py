"""Cross-family batch fusion: coalesce ready work items into one dispatch.

The scheduler already tracks which (space × family) work items are ready;
this module extends that into *round-based fusion*: every ready item runs
in its own worker thread against a transparent runner proxy, and each
runner call **parks** the thread instead of dispatching immediately.  When
every in-flight item is either finished or parked, the coordinator fuses
all parked requests that share a runner capability — warm chases onto one
``pchase_many``, cold passes onto one ``cold_chase_many``, eviction-pattern
probes onto one ``eviction_many`` grid — and executes
each fused group as a single dispatch on the coordinator thread, then wakes
the parked items with their slices.

Consequences:

* a refinement round costs ONE kernel launch for *all* concurrently
  active probe families instead of one per family — on the Pallas backend
  this is what collapses the per-discovery kernel-call count;
* actual kernel execution stays strictly serial (only the coordinator
  dispatches), so co-running probes never perturb each other's wall
  clocks — the property ``discover_pallas`` previously bought with an
  inline schedule;
* probe workflows are unchanged: the proxy exposes the ordinary
  ``ProbeRunner`` surface, and request-keyed runners return bit-identical
  samples no matter how calls are grouped.

Eviction-pattern probes (amount §IV-F, sharing §IV-G, cu-sharing §IV-H)
fuse too: they park as heterogeneous ``("evict", n_samples)`` rows and every
round coalesces them onto ONE ``eviction_many`` grid dispatch, mixing the
three families freely (the runners' eviction-grid capability keeps row i
bit-identical to the matching single-probe call).  Only bandwidth remains a
serial ``("exec",)`` call — it reports one scalar from its own stream-kernel
timing loop, so there is no row batching to coalesce — and it still executes
per-request inside the round, preserving the serial-execution guarantee.
Per-family timings include parked time and therefore overlap — they remain
useful as *shares*, not absolute wall seconds.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Callable

import numpy as np

from ..errors import TransientRunnerError

__all__ = ["FusionDispatcher", "run_fused"]


@dataclass
class _Pending:
    """One parked runner call awaiting the next fusion round."""

    group: tuple          # ("pchase", n) | ("cold", n) | ("evict", n) | ("exec",)
    rows: list = field(default_factory=list)   # fused-capability row requests
    thunk: Callable | None = None    # non-fusable: run against the runner
    result: object = None
    error: BaseException | None = None
    event: threading.Event = field(default_factory=threading.Event)


class _FusionRunner:
    """ProbeRunner facade that parks every probe call on the dispatcher.

    Hook-style accessors (``spaces``, ``api_size``, ``cu_ids``,
    ``cores_per_sm``) pass straight through — they read metadata, not
    kernels — everything that measures goes through ``_park``.
    """

    def __init__(self, dispatcher: "FusionDispatcher"):
        self._d = dispatcher
        self._base = dispatcher.runner
        # planner prefetch capability mirrors the wrapped runner's caching
        self.caches_requests = getattr(self._base, "caches_requests", False)

    # ------------------------------------------------------ fused: warm
    def pchase(self, space, array_bytes, stride, n_samples):
        rows = self._d.park(("pchase", int(n_samples)),
                            [(space, int(array_bytes), int(stride))])
        return rows[0]

    def pchase_batch(self, space, array_bytes_list, stride, n_samples):
        reqs = [(space, int(ab), int(stride)) for ab in array_bytes_list]
        return np.stack(self._d.park(("pchase", int(n_samples)), reqs))

    def pchase_many(self, requests, n_samples, fresh: bool = False):
        reqs = [(space, int(ab), int(s)) for space, ab, s in requests]
        group = ("pchase-fresh" if fresh else "pchase", int(n_samples))
        return np.stack(self._d.park(group, reqs))

    # ------------------------------------------------------ fused: cold
    def cold_chase(self, space, array_bytes, stride, n_samples):
        rows = self._d.park(("cold", int(n_samples)),
                            [(space, int(array_bytes), int(stride))])
        return rows[0]

    def cold_chase_batch(self, space, array_bytes_list, stride_list,
                         n_samples):
        reqs = [(space, int(ab), int(s))
                for ab, s in zip(array_bytes_list, stride_list)]
        return np.stack(self._d.park(("cold", int(n_samples)), reqs))

    def cold_chase_many(self, requests, n_samples):
        reqs = [(space, int(ab), int(s)) for space, ab, s in requests]
        return np.stack(self._d.park(("cold", int(n_samples)), reqs))

    # ------------------------------------------------ fused: eviction grid
    # Mixed amount/sharing/cu rows share one ("evict", n) group per round
    # and dispatch as a single eviction_many grid call (§IV-F/G/H).
    def amount_probe(self, space, core_a, core_b, array_bytes, n_samples):
        rows = self._d.park(("evict", int(n_samples)),
                            [("amount", space, int(core_a), int(core_b),
                              int(array_bytes))])
        return rows[0]

    def sharing_probe(self, space_a, space_b, array_bytes, n_samples):
        rows = self._d.park(("evict", int(n_samples)),
                            [("sharing", space_a, space_b,
                              int(array_bytes))])
        return rows[0]

    def cu_sharing_probe(self, cu_a, cu_b, array_bytes, n_samples,
                         space="sL1d"):
        rows = self._d.park(("evict", int(n_samples)),
                            [("cu", space, int(cu_a), int(cu_b),
                              int(array_bytes))])
        return rows[0]

    def cu_sharing_probe_batch(self, cu_a, cu_bs, array_bytes, n_samples,
                               space="sL1d"):
        reqs = [("cu", space, int(cu_a), int(cu_b), int(array_bytes))
                for cu_b in cu_bs]
        return np.stack(self._d.park(("evict", int(n_samples)), reqs))

    def eviction_many(self, requests, n_samples):
        reqs = [tuple(r) for r in requests]
        return np.stack(self._d.park(("evict", int(n_samples)), reqs))

    # ------------------------------------------ serialized, non-fused calls
    # Bandwidth reports one scalar from its own stream-kernel loop — no row
    # batching exists to coalesce, so it runs per-request inside the round.
    def bandwidth(self, space, mode="read"):
        return self._d.park_exec(lambda r: r.bandwidth(space, mode))

    # ------------------------------------------------------------ hooks
    def spaces(self):
        return self._base.spaces()

    def api_size(self, space):
        return self._base.api_size(space)

    def cu_ids(self):
        return self._base.cu_ids()

    @property
    def cores_per_sm(self):
        return self._base.cores_per_sm

    @property
    def deterministic(self) -> bool:
        return getattr(self._base, "deterministic", False)


class FusionDispatcher:
    """Round coordinator: park, coalesce, dispatch, wake.

    ``runner`` is the engine's ``CachingRunner`` — fused groups land on its
    ``pchase_many``/``cold_chase_many``/``eviction_many``, so cached rows
    are served and duplicate rows across families cost one probe.
    """

    def __init__(self, runner):
        self.runner = runner
        self._cv = threading.Condition()
        self._active = 0                 # threads running (not parked/done)
        self._pending: list[_Pending] = []
        self._aborted = False
        self.rounds = 0                  # fusion rounds dispatched
        self.fused_calls = 0             # fused-capability dispatches issued
        self.split_rounds = 0            # fused dispatches split after a fault

    def proxy(self) -> _FusionRunner:
        """A runner facade whose batch calls park on this dispatcher."""
        return _FusionRunner(self)

    # ----------------------------------------------------- thread-side API
    def thread_starting(self) -> None:
        """Register one item thread as in flight (coordinator waits on 0)."""
        with self._cv:
            self._active += 1

    def thread_finished(self) -> None:
        """Deregister an item thread; wakes a quiescence-waiting coordinator."""
        with self._cv:
            self._active -= 1
            self._cv.notify_all()

    def park(self, group: tuple, rows: list) -> list:
        """Park the calling thread's probe rows under a fusion group key and
        block until the coordinator dispatches the fused round; returns this
        caller's slice of the fused result."""
        p = _Pending(group=group, rows=rows)
        self._park(p)
        return p.result

    def park_exec(self, thunk: Callable):
        """Park an arbitrary thunk for serial execution on the coordinator
        thread (the escape hatch for calls with no fused capability)."""
        p = _Pending(group=("exec",), thunk=thunk)
        self._park(p)
        return p.result

    def _park(self, p: _Pending) -> None:
        with self._cv:
            if self._aborted:
                raise RuntimeError("fusion dispatcher aborted")
            self._pending.append(p)
            self._active -= 1
            self._cv.notify_all()
        p.event.wait()
        # NOTE: the coordinator re-activated this thread (active += 1) in
        # dispatch_round()/abort() *before* setting the event, so waking
        # must not increment again.
        if p.error is not None:
            raise p.error

    # ------------------------------------------------- coordinator-side API
    def wait_quiescent(self) -> None:
        """Block until every in-flight item thread is parked or finished."""
        with self._cv:
            while self._active > 0:
                self._cv.wait()

    def has_pending(self) -> bool:
        """True while parked rows await a fused dispatch round."""
        with self._cv:
            return bool(self._pending)

    def dispatch_round(self) -> None:
        """Execute one fused round on the calling (coordinator) thread."""
        with self._cv:
            batch, self._pending = self._pending, []
            self._active += len(batch)   # re-activate before waking
        self.rounds += 1
        groups: dict[tuple, list[_Pending]] = {}
        for p in batch:
            groups.setdefault(p.group, []).append(p)
        for key in sorted(groups, key=repr):
            ps = groups[key]
            if key[0] == "exec":
                for p in ps:
                    try:
                        p.result = p.thunk(self.runner)
                    except BaseException as e:  # noqa: BLE001 — delivered
                        p.error = e
                continue
            all_rows = [r for p in ps for r in p.rows]
            try:
                if key[0] == "pchase-fresh":
                    rows = np.asarray(self.runner.pchase_many(
                        all_rows, key[1], fresh=True))
                elif key[0] == "evict":
                    rows = np.asarray(self.runner.eviction_many(
                        all_rows, key[1]))
                else:
                    fn = (self.runner.pchase_many if key[0] == "pchase"
                          else self.runner.cold_chase_many)
                    rows = np.asarray(fn(all_rows, key[1]))
                self.fused_calls += 1
                at = 0
                for p in ps:
                    p.result = [rows[at + j] for j in range(len(p.rows))]
                    at += len(p.rows)
            except TransientRunnerError:
                # A fault inside a fused dispatch must not fail every item
                # that happened to share the round: split the group into
                # per-row single calls so only genuinely failing rows
                # poison their pending (already-fetched rows are served by
                # the caching runner at zero cost).
                self.split_rounds += 1
                for p in ps:
                    try:
                        p.result = [self._single_row(key, r) for r in p.rows]
                    except BaseException as e:  # noqa: BLE001 — delivered
                        p.error = e
            except BaseException as e:  # noqa: BLE001 — delivered per item
                for p in ps:
                    p.error = e
        for p in batch:
            p.event.set()

    def _single_row(self, group: tuple, row: tuple):
        """Serve one fused-group row via its single-probe equivalent (the
        split-and-retry fallback after a fused dispatch faulted)."""
        kind, n = group[0], group[1]
        if kind == "pchase":
            space, ab, stride = row
            return np.asarray(self.runner.pchase(space, ab, stride, n))
        if kind == "pchase-fresh":
            return np.asarray(self.runner.pchase_many([row], n,
                                                      fresh=True))[0]
        if kind == "cold":
            space, ab, stride = row
            return np.asarray(self.runner.cold_chase(space, ab, stride, n))
        tag = row[0]                     # evict rows carry their own kind
        if tag == "amount":
            _, space, a, b, ab = row
            return np.asarray(self.runner.amount_probe(space, a, b, ab, n))
        if tag == "sharing":
            _, sa, sb, ab = row
            return np.asarray(self.runner.sharing_probe(sa, sb, ab, n))
        _, space, a, b, ab = row
        return np.asarray(self.runner.cu_sharing_probe(a, b, ab, n,
                                                       space=space))

    def abort(self, exc: BaseException) -> None:
        """Release every parked thread with ``exc`` (error teardown)."""
        with self._cv:
            self._aborted = True
            batch, self._pending = self._pending, []
            self._active += len(batch)
        for p in batch:
            p.error = exc
            p.event.set()


def run_fused(items, dispatcher: FusionDispatcher, *, timings=None,
              resilience=None, on_exhausted=None, on_item_done=None):
    """Execute work items with round-based fusion (see module docstring).

    Dependency semantics match ``run_work_items``: an item starts once its
    deps completed; newly released items join the *current* round before it
    dispatches, so their first probes fuse with everyone else's.

    Fault tolerance mirrors the unfused scheduler: with a ``resilience``
    policy, an item that failed on a ``TransientRunnerError`` is restarted
    (up to ``max_retries`` times, capped backoff) — its already-fetched
    rows replay from the caching runner, so a retry only re-probes what
    actually failed — and past the budget it degrades through
    ``on_exhausted`` instead of aborting the whole fused run.
    """
    from .scheduler import ScheduleResult, check_items

    by_key = check_items(items)
    out = ScheduleResult()
    t_start = time.perf_counter()
    pending = dict(by_key)
    lock = threading.Lock()
    finished: list[tuple] = []
    threads: dict = {}
    attempts: dict = {}                  # item key -> transient retries spent

    def ready(it) -> bool:
        return all(d in out.results for d in it.deps)

    def start(it) -> None:
        def body():
            t0 = time.perf_counter()
            value = err = None
            try:
                value = it.fn(out.results)
            except BaseException as e:  # noqa: BLE001 — re-raised by driver
                err = e
            dt = time.perf_counter() - t0
            with lock:
                finished.append((it, value, err, dt))
            dispatcher.thread_finished()

        dispatcher.thread_starting()
        th = threading.Thread(target=body, daemon=True,
                              name=f"probe-{it.key}")
        threads[it.key] = th
        th.start()

    for it in [i for i in list(pending.values()) if ready(i)]:
        del pending[it.key]
        start(it)

    while threads or pending:
        dispatcher.wait_quiescent()
        with lock:
            done, finished[:] = finished[:], []
        for it, value, err, dt in done:
            threads.pop(it.key).join()
            if err is not None:
                transient = (resilience is not None
                             and isinstance(err, TransientRunnerError))
                spent = attempts.get(it.key, 0)
                if transient and spent < resilience.max_retries:
                    resilience.sleep(resilience.backoff(spent))
                    attempts[it.key] = spent + 1
                    out.retries += 1
                    start(it)            # restart; cached rows replay free
                    continue
                if (transient and resilience.degrade
                        and on_exhausted is not None):
                    out.degraded.append(it.key)
                    out.results[it.key] = on_exhausted(it, err, spent + 1)
                    out.order.append(it.key)
                    if on_item_done is not None:
                        on_item_done(it.key)
                    continue
                dispatcher.abort(RuntimeError(
                    f"work item {it.key!r} failed; fusion round aborted"))
                raise err
            out.results[it.key] = value
            out.order.append(it.key)
            if timings is not None and it.family:
                timings.add(it.family, dt)
            if on_item_done is not None:
                on_item_done(it.key)
        newly = [i for i in list(pending.values()) if ready(i)]
        for it in newly:
            del pending[it.key]
            start(it)
        if newly:
            continue                     # let them park into this round
        if dispatcher.has_pending():
            dispatcher.dispatch_round()
        elif threads:
            if not done:
                raise RuntimeError(
                    "fusion stall: running items neither finished nor parked")
        elif pending:
            raise ValueError("dependency cycle among work items: "
                             f"{sorted(map(str, pending))}")

    out.wall_seconds = time.perf_counter() - t_start
    return out
