"""Multiprocess probe execution: GIL-free sharding of batched capabilities.

The engine's remaining wall time after planning and fusion is single-core:
numpy probe synthesis holds the GIL, so the scheduler's thread pool cannot
scale past ~1 core and stays inline on small boxes (the oldest open
ROADMAP item).  This module moves the *batched capability calls* —
``pchase_batch``, ``cold_chase_batch``, ``pchase_many``,
``cold_chase_many``, ``eviction_many`` — into a persistent pool of worker
processes, sharded by rows, with sample matrices returned through
``multiprocessing.shared_memory`` segments instead of pickled copies.

Three properties make this sound:

* **Bit-identity.**  Request-keyed sampling (``simulate._KeyedSampler``)
  is counter-based and stateless: row i of a batch depends only on the
  request signature and the device seed, never on which process computes
  it or in what order.  Any row shard is therefore byte-identical to the
  inline dispatch — asserted by the ``TestParallelDispatch`` conformance
  suite and hard-gated by the ``parallel_speedup`` bench row.
* **Reconstructible runners.**  Workers rebuild the probe runner
  in-process from a picklable ``RunnerSpec`` (a module-level builder
  function plus its payload).  Sim/Host/Caching/Chaos runners publish
  specs; runners without one (e.g. a warmed ``PallasRunner``) make
  ``maybe_parallel_runner`` a no-op and execution stays inline.
* **Crash containment.**  A worker that dies or wedges mid-shard is
  killed and respawned, and the batch call raises
  ``TransientRunnerError`` — the same taxonomy the resilience path
  (retry -> split -> degrade) and the fusion dispatcher's round-splitting
  already handle, so a lost worker costs one retry, not a discovery.

Shared-memory ownership: the *coordinator* creates every segment (the
result shape ``(rows, n_samples)`` is known before dispatch), workers
attach and write in place, and the coordinator unlinks in a ``finally``
regardless of outcome — so a killed worker can never leak a segment.
``ParallelPool.close`` (also registered via ``atexit`` and available as a
context manager) unlinks any stragglers by pool-unique name prefix.
"""
from __future__ import annotations

import atexit
import itertools
import os
import pickle
import queue
import threading
from dataclasses import dataclass
from multiprocessing import shared_memory
from typing import Callable

import numpy as np

from ..errors import TransientRunnerError

__all__ = ["ParallelConfig", "ParallelPool", "ParallelRunner", "RunnerSpec",
           "effective_cpu_count", "get_global_pool", "shutdown_global_pools",
           "maybe_parallel_runner", "POOL_WORKER_ENV"]

#: set in every pool worker's environment — lets wrapped runners (e.g. the
#: chaos runner's ``kill_worker_after`` switch) detect in-worker execution
#: without importing this module.
POOL_WORKER_ENV = "MT4G_POOL_WORKER"

#: the five batched capabilities the pool shards by rows.
POOLED_METHODS = ("pchase_batch", "cold_chase_batch", "pchase_many",
                  "cold_chase_many", "eviction_many")


# --------------------------------------------------------------------------
# Effective core counting (cgroup/affinity aware)
# --------------------------------------------------------------------------
def _cgroup_cpu_quota() -> int | None:
    """CPU quota in whole cores from the cgroup limits, or None.

    ``os.cpu_count`` reports the host's cores; a containerized run with a
    2-core quota on a 64-core host must not size pools for 64.  Reads the
    v2 ``cpu.max`` (``"<quota> <period>"`` or ``"max <period>"``) and
    falls back to the v1 ``cfs_quota_us``/``cfs_period_us`` pair.
    """
    try:
        with open("/sys/fs/cgroup/cpu.max") as f:
            quota_s, period_s = f.read().split()[:2]
        if quota_s != "max" and int(period_s) > 0:
            return max(1, int(int(quota_s) / int(period_s)))
    except (OSError, ValueError):
        pass
    try:
        with open("/sys/fs/cgroup/cpu/cpu.cfs_quota_us") as f:
            quota = int(f.read())
        with open("/sys/fs/cgroup/cpu/cpu.cfs_period_us") as f:
            period = int(f.read())
        if quota > 0 and period > 0:
            return max(1, quota // period)
    except (OSError, ValueError):
        pass
    return None


def effective_cpu_count() -> int:
    """Cores this process may actually use: affinity mask capped by any
    cgroup CPU quota (``os.cpu_count`` ignores both)."""
    try:
        cores = len(os.sched_getaffinity(0))
    except (AttributeError, OSError):
        cores = os.cpu_count() or 1
    quota = _cgroup_cpu_quota()
    if quota is not None:
        cores = min(cores, quota)
    return max(1, cores)


# --------------------------------------------------------------------------
# Runner specs
# --------------------------------------------------------------------------
@dataclass(frozen=True)
class RunnerSpec:
    """Picklable recipe for rebuilding a probe runner in a worker process.

    ``builder`` must be a *module-level* function (pickled by qualified
    name, imported on the worker side); ``payload`` is its positional
    argument tuple and must itself pickle — device models, schedule
    dataclasses, plain config scalars.  Runners advertise a spec through a
    ``runner_spec()`` method; returning None (or not having the method)
    opts the runner out of pooling and keeps execution inline.
    """

    builder: Callable
    payload: tuple = ()

    def build(self):
        """Construct the runner this spec describes (worker side)."""
        return self.builder(*self.payload)


@dataclass(frozen=True)
class ParallelConfig:
    """Process-pool policy for one discovery (or a shared job engine).

    ``workers=None`` sizes the pool from ``effective_cpu_count()`` —
    leaving one core for the coordinator, capped at 8 — and falls back to
    inline execution entirely below ``min_cores`` effective cores, where
    process overhead would exceed the win.  An explicit ``workers`` count
    always pools (the testing/benching override).  The config is
    deliberately *not* part of the store request descriptor: pooled and
    inline runs are bit-identical, so they share a content address.
    """

    workers: int | None = None
    start_method: str = "spawn"      # or "forkserver"; never "fork" (jax)
    min_rows_per_shard: int = 8      # below this, one worker takes the batch
    call_timeout_s: float = 300.0    # per-shard wall ceiling -> worker killed
    min_cores: int = 4               # auto mode stays inline below this

    def resolved_workers(self) -> int:
        """Pool size after the core heuristic; 0 means stay inline."""
        if self.workers is not None:
            return max(1, int(self.workers))
        cores = effective_cpu_count()
        if cores < self.min_cores:
            return 0
        return min(8, cores - 1)


# --------------------------------------------------------------------------
# Worker side
# --------------------------------------------------------------------------
def _worker_main(conn) -> None:
    """Pool worker loop: rebuild runners from specs, serve shard calls.

    Each request carries a pickled ``RunnerSpec`` blob; the rebuilt runner
    is memoized by blob so the pool stays warm across batches *and across
    discoveries* that share a spec.  Results are written into the
    coordinator-owned shared-memory segment named in the request; the
    reply carries only ``("ok",)`` or ``("err", exception)``.
    """
    os.environ[POOL_WORKER_ENV] = "1"
    runners: dict[bytes, object] = {}
    while True:
        try:
            msg = conn.recv()
        except (EOFError, OSError):
            break
        if msg is None or msg[0] == "stop":
            break
        _, spec_blob, method, args, shm_name, shape = msg
        try:
            runner = runners.get(spec_blob)
            if runner is None:
                runner = pickle.loads(spec_blob).build()
                runners[spec_blob] = runner
            out = np.asarray(getattr(runner, method)(*args),
                             dtype=np.float64)
            if out.shape != tuple(shape):
                raise RuntimeError(
                    f"worker shard shape mismatch for {method}: "
                    f"{out.shape} != {tuple(shape)}")
            # Attach-side resource tracking is harmless here: spawn
            # children share the coordinator's resource tracker, whose
            # registry is a set — the attach re-register dedupes against
            # the coordinator's create-register, and the coordinator's
            # unlink balances both.  (Never unregister here: a second
            # unregister would make that unlink a tracker error.)
            shm = shared_memory.SharedMemory(name=shm_name)
            try:
                np.ndarray(tuple(shape), dtype=np.float64,
                           buffer=shm.buf)[...] = out
            finally:
                shm.close()
            reply = ("ok",)
        except BaseException as exc:  # noqa: BLE001 — delivered to caller
            try:
                pickle.dumps(exc)
                reply = ("err", exc)
            except Exception:  # noqa: BLE001 — unpicklable: re-wrap
                reply = ("err", RuntimeError(f"{type(exc).__name__}: {exc}"))
        try:
            conn.send(reply)
        except (BrokenPipeError, OSError):
            break
    conn.close()


# --------------------------------------------------------------------------
# Coordinator side
# --------------------------------------------------------------------------
class _Worker:
    """One pool worker: its process handle and the coordinator-side pipe."""

    __slots__ = ("proc", "conn")

    def __init__(self, proc, conn):
        self.proc = proc
        self.conn = conn


class _WorkerDied(Exception):
    """Internal marker: the worker serving a shard crashed or timed out."""


class ParallelPool:
    """Persistent worker-process pool sharding batched capability calls.

    Thread-safe: concurrent coordinator threads (the unfused scheduler's
    item threads, or concurrent ``JobEngine`` discoveries sharing the
    global pool) check workers out of a free list, so a worker never
    serves two shards at once.  Dead or timed-out workers are respawned
    in place and the affected batch raises ``TransientRunnerError``.

    Use as a context manager, or rely on ``close()`` — also registered
    with ``atexit`` — to stop workers and unlink any shared-memory
    segments (including by name-prefix sweep, covering abnormal exits).
    """

    def __init__(self, config: ParallelConfig | None = None):
        import multiprocessing

        self.config = config or ParallelConfig()
        n = max(1, self.config.resolved_workers())
        self._ctx = multiprocessing.get_context(self.config.start_method)
        self._prefix = f"mt4g{os.getpid()}p{id(self) % 100000:05d}"
        self._seq = itertools.count()
        self._lock = threading.Lock()
        self._free: queue.Queue = queue.Queue()
        self._live_segments: set[str] = set()
        self._closed = False
        self.respawns = 0                # workers replaced after crash/timeout
        self.calls = 0                   # run_batch invocations
        self.shards = 0                  # worker dispatches issued
        for _ in range(n):
            self._free.put(self._spawn())
        self.workers = n
        atexit.register(self.close)

    # ------------------------------------------------------------ lifecycle
    def _spawn(self) -> _Worker:
        parent, child = self._ctx.Pipe()
        proc = self._ctx.Process(target=_worker_main, args=(child,),
                                 daemon=True, name="mt4g-pool-worker")
        proc.start()
        child.close()
        return _Worker(proc, parent)

    def close(self) -> None:
        """Stop all workers and unlink every pool segment (idempotent)."""
        if self._closed:
            return
        self._closed = True
        try:
            atexit.unregister(self.close)
        except Exception:  # noqa: BLE001 — interpreter teardown ordering
            pass
        workers = []
        while True:
            try:
                workers.append(self._free.get_nowait())
            except queue.Empty:
                break
        for w in workers:
            try:
                w.conn.send(("stop",))
            except (BrokenPipeError, OSError):
                pass
            w.conn.close()
        for w in workers:
            w.proc.join(timeout=5.0)
            if w.proc.is_alive():
                w.proc.terminate()
        self._sweep_segments()

    def __enter__(self) -> "ParallelPool":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # ------------------------------------------------------ shared memory
    def _alloc(self, shape: tuple) -> shared_memory.SharedMemory:
        """Create one coordinator-owned result segment for a shard."""
        nbytes = max(8, int(np.prod(shape)) * 8)
        name = f"{self._prefix}n{next(self._seq)}"
        shm = shared_memory.SharedMemory(name=name, create=True, size=nbytes)
        with self._lock:
            self._live_segments.add(name)
        return shm

    def _release(self, shm: shared_memory.SharedMemory) -> None:
        """Close and unlink one segment; tolerates double release."""
        name = shm.name.lstrip("/")
        try:
            shm.close()
        finally:
            try:
                shm.unlink()
            except FileNotFoundError:
                pass
            with self._lock:
                self._live_segments.discard(name)

    def _sweep_segments(self) -> None:
        """Unlink tracked segments plus any /dev/shm entry with our prefix
        (the abnormal-exit backstop: a segment allocated but never released
        because the coordinator thread died mid-batch)."""
        with self._lock:
            leftovers = set(self._live_segments)
            self._live_segments.clear()
        if os.path.isdir("/dev/shm"):
            try:
                leftovers.update(n for n in os.listdir("/dev/shm")
                                 if n.startswith(self._prefix))
            except OSError:
                pass
        for name in leftovers:
            try:
                seg = shared_memory.SharedMemory(name=name)
                seg.close()
                seg.unlink()
            except FileNotFoundError:
                continue
            except OSError:
                continue

    # ------------------------------------------------------------ dispatch
    def _checkout(self, want: int) -> list[_Worker]:
        """Claim between 1 and ``want`` free workers (blocks for the first)."""
        if self._closed:
            raise RuntimeError("parallel pool is closed")
        try:
            workers = [self._free.get(timeout=self.config.call_timeout_s)]
        except queue.Empty:
            raise TransientRunnerError(
                "parallel pool starved: no worker freed within "
                f"{self.config.call_timeout_s}s") from None
        while len(workers) < want:
            try:
                workers.append(self._free.get_nowait())
            except queue.Empty:
                break
        return workers

    def _collect(self, w: _Worker):
        """Read one shard reply; crash/timeout kills + flags the worker.

        Returns ``(worker, error)`` where ``worker`` is ``w`` or a fresh
        respawn and ``error`` is None, the worker-raised exception, or a
        ``TransientRunnerError`` for a death/timeout.
        """
        try:
            if not w.conn.poll(self.config.call_timeout_s):
                raise _WorkerDied(
                    f"worker timed out after {self.config.call_timeout_s}s")
            reply = w.conn.recv()
        except (_WorkerDied, EOFError, OSError) as exc:
            try:
                w.conn.close()
            except OSError:
                pass
            if w.proc.is_alive():
                w.proc.terminate()
            w.proc.join(timeout=5.0)
            self.respawns += 1
            return self._spawn(), TransientRunnerError(
                f"pool worker died mid-shard ({exc}); respawned")
        if reply[0] == "ok":
            return w, None
        return w, reply[1]

    def run_batch(self, spec_blob: bytes, method: str, rows: list,
                  n_samples: int, make_args: Callable[[list], tuple]
                  ) -> np.ndarray:
        """Execute one batched capability call sharded across workers.

        ``rows`` is the per-row request list (whatever the capability
        shards over); ``make_args(shard_rows)`` builds the positional
        argument tuple the worker passes to ``runner.<method>``.  Large
        batches split into one contiguous shard per free worker (at least
        ``min_rows_per_shard`` rows each); small batches go to a single
        worker whole.  Returns the reassembled ``(len(rows), n_samples)``
        float64 matrix, bit-identical to the inline call.

        Raises whatever a worker's runner raised (``TransientRunnerError``
        passes through for the resilience path, ``NotImplementedError``
        etc. keep their types), or ``TransientRunnerError`` when a worker
        crashed or timed out (after respawning it).
        """
        n = int(n_samples)
        total = len(rows)
        out = np.empty((total, n), dtype=np.float64)
        if total == 0:
            return out
        want = max(1, min(self.workers,
                          total // max(1, self.config.min_rows_per_shard)))
        workers = self._checkout(want)
        k = len(workers)
        bounds = [(total * i // k, total * (i + 1) // k) for i in range(k)]
        self.calls += 1
        sent: list[tuple] = []          # (worker, shm, (lo, hi)) per shard
        errors: list[BaseException] = []
        returned: list[_Worker] = []
        try:
            for w, (lo, hi) in zip(workers, bounds):
                shape = (hi - lo, n)
                shm = self._alloc(shape)
                try:
                    w.conn.send(("call", spec_blob, method,
                                 make_args(rows[lo:hi]), shm.name.lstrip("/"),
                                 shape))
                    self.shards += 1
                    sent.append((w, shm, (lo, hi)))
                except (BrokenPipeError, OSError):
                    self._release(shm)
                    w, err = self._collect(w)     # reap + respawn
                    returned.append(w)
                    errors.append(err or TransientRunnerError(
                        "pool worker pipe broke before dispatch"))
            for w, shm, (lo, hi) in sent:
                w, err = self._collect(w)
                returned.append(w)
                if err is not None:
                    errors.append(err)
                else:
                    out[lo:hi] = np.ndarray((hi - lo, n), dtype=np.float64,
                                            buffer=shm.buf)
        finally:
            for _, shm, _ in sent:
                self._release(shm)
            for w in returned:
                self._free.put(w)
            # workers checked out but never dispatched (early error paths)
            for w in workers:
                if w not in returned and all(w is not s[0] for s in sent):
                    self._free.put(w)
        if errors:
            # Prefer the runner's own exception type (the resilience and
            # split paths dispatch on it); crash-transients only when no
            # worker produced a richer error.
            for err in errors:
                if not isinstance(err, TransientRunnerError):
                    raise err
            raise errors[0]
        return out


# --------------------------------------------------------------------------
# Runner facade
# --------------------------------------------------------------------------
class ParallelRunner:
    """ProbeRunner facade sharding the five batched capabilities by rows.

    Everything else — single probes, bandwidth, metadata hooks,
    ``deterministic`` — delegates to the local ``base`` runner via
    ``__getattr__``, so capability checks (``hasattr``) and the
    split-and-retry single-row fallback behave exactly as they would
    inline.  Sits *below* ``CachingRunner``: the coordinator keeps the
    sample cache and only cache-missing rows reach the pool.
    """

    def __init__(self, base, spec: RunnerSpec, pool: ParallelPool):
        self.base = base
        self.pool = pool
        self._spec_blob = pickle.dumps(spec)

    def __getattr__(self, name):
        return getattr(self.base, name)

    # ------------------------------------------------------ pooled methods
    def pchase_batch(self, space, array_bytes_list, stride, n_samples):
        """Size-sweep batch sharded by rows across the pool."""
        sizes = [int(ab) for ab in array_bytes_list]
        return self.pool.run_batch(
            self._spec_blob, "pchase_batch", sizes, n_samples,
            lambda rows: (space, rows, int(stride), int(n_samples)))

    def cold_chase_batch(self, space, array_bytes_list, stride_list,
                         n_samples):
        """Granularity stride-sweep batch sharded by rows."""
        pairs = [(int(ab), int(st))
                 for ab, st in zip(array_bytes_list, stride_list)]
        return self.pool.run_batch(
            self._spec_blob, "cold_chase_batch", pairs, n_samples,
            lambda rows: (space, [r[0] for r in rows], [r[1] for r in rows],
                          int(n_samples)))

    def pchase_many(self, requests, n_samples):
        """Heterogeneous fused warm batch sharded by rows."""
        reqs = [(sp, int(ab), int(st)) for sp, ab, st in requests]
        return self.pool.run_batch(
            self._spec_blob, "pchase_many", reqs, n_samples,
            lambda rows: (rows, int(n_samples)))

    def cold_chase_many(self, requests, n_samples):
        """Heterogeneous fused cold batch sharded by rows."""
        reqs = [(sp, int(ab), int(st)) for sp, ab, st in requests]
        return self.pool.run_batch(
            self._spec_blob, "cold_chase_many", reqs, n_samples,
            lambda rows: (rows, int(n_samples)))

    def eviction_many(self, requests, n_samples):
        """Mixed amount/sharing/cu eviction grid sharded by rows."""
        reqs = [tuple(v if isinstance(v, str) else int(v) for v in r)
                for r in requests]
        return self.pool.run_batch(
            self._spec_blob, "eviction_many", reqs, n_samples,
            lambda rows: (rows, int(n_samples)))


# --------------------------------------------------------------------------
# Shared pools + integration helper
# --------------------------------------------------------------------------
_POOLS: dict[tuple, ParallelPool] = {}
_POOLS_LOCK = threading.Lock()


def get_global_pool(config: ParallelConfig | None = None) -> ParallelPool:
    """The warm shared pool for ``config`` (created on first use).

    Keyed by ``(start_method, resolved worker count)`` so every discovery
    — including concurrent ``JobEngine`` jobs — with an equivalent config
    shares one set of worker processes; workers memoize rebuilt runners
    per spec, so repeat discoveries skip reconstruction too.
    """
    config = config or ParallelConfig()
    key = (config.start_method, config.resolved_workers())
    with _POOLS_LOCK:
        pool = _POOLS.get(key)
        if pool is None or pool._closed:
            pool = _POOLS[key] = ParallelPool(config)
        return pool


def shutdown_global_pools() -> None:
    """Close every shared pool (tests and embedders; atexit covers the rest)."""
    with _POOLS_LOCK:
        pools = list(_POOLS.values())
        _POOLS.clear()
    for pool in pools:
        pool.close()


def maybe_parallel_runner(runner, config: ParallelConfig | None,
                          pool: ParallelPool | None = None):
    """Wrap ``runner`` for pooled execution, or return it unchanged.

    Inline (identity) when ``config`` is None, when the effective-core
    heuristic says pooling cannot pay off, or when the runner publishes no
    ``RunnerSpec`` — the graceful-degradation contract that lets callers
    pass a config unconditionally.  ``pool`` overrides the shared global
    pool (tests that need an isolated lifecycle).
    """
    if config is None:
        return runner
    spec_fn = getattr(runner, "runner_spec", None)
    spec = spec_fn() if callable(spec_fn) else None
    if spec is None:
        return runner
    if pool is None:
        if config.resolved_workers() <= 0:
            return runner
        pool = get_global_pool(config)
    return ParallelRunner(runner, spec, pool)
