"""Adaptive coarse-to-fine sweep planner (the probe-volume optimizer).

MT4G's reliability comes from statistical change-point detection over
microbenchmark sweeps, but a *dense* sweep measures every grid point even
though the K-S statistics localize the boundary after a handful of rows.
This module plans sweeps instead of enumerating them:

* a **coarse logarithmic pass** (the §IV-B doubling ladder, issued in
  chunked batch calls) brackets the boundary octave;
* the dense workflow's own **binary bisection** narrows the interval —
  replayed probe-for-probe so the planner lands on the *identical sweep
  lattice* as the dense path;
* a **deterministic classification descent** (``descend_first_shifted``)
  walks O(log n) rows of that lattice to pin the discrete boundary, and a
  small window around the flip feeds the K-S confidence metric.

Identity contract: the dense sweeps (``budget=None``) remain the
equivalence oracle.  Discrete attributes — sizes, line size, fetch
granularity, amounts, sharing — are *identical* planner-vs-dense because
both paths evaluate the same local boundary rule over the same grid rows
(request-keyed streams on simulated runners; shared caches otherwise), and
every planned search **falls back to the dense sweep** whenever its local
monotonicity assumptions fail (non-monotone classifications, flukes near
the boundary, budget exhaustion).  Only the non-discrete floats
(confidence, p-value) may differ, computed from a window instead of the
full series.

Two mechanisms keep planned searches cheap in *rounds*, not just rows:

* **speculative quantile prefetch** — before replaying a bisection or
  descent, the planner fetches the next few generations of integer
  midpoints the search may visit as ONE fused dispatch
  (``pchase_many``/``cold_chase_batch``/``eviction_many``), pre-filling
  the engine's sample cache under the decision procedure's own request
  keys.  The unchanged procedure then replays over cache hits, so a
  sequential O(log n) round chain collapses to one or two fused rounds
  while the lattice, the predicates, and (on request-keyed runners) the
  row values stay exactly those of the sequential path;
* **pairwise lattice planning** — the §IV-F amount ladder bisects for the
  first non-evicting core doubling, and the §IV-G/§IV-H sharing lattices
  probe hypothesis-first (partition closure for space pairs, closed-pair
  spot checks for CU pairs), with every accepted shortcut verified by
  independent rows and any disagreement falling back to the dense
  pairwise sweep.

``SweepBudget`` is the knob set carried on ``DiscoveryRequest``: round and
row ceilings plus an optional target resolution for deliberately coarse
(non-oracle-identical) scans; ``target_resolution`` also drives the fleet
survey mode's spot-check margins (see ``core.discover``).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from ..probes.amount import (AmountResult, CuSharingResult, SharingResult,
                             _hit_miss_refs, amount_ladder, find_amount,
                             find_sharing_batch)
from ..probes.linesize import (GranularityResult, LineSizeResult,
                               find_fetch_granularity, find_line_size,
                               granularity_refs, line_size_from_first_hit)
from ..probes.size import (ShiftClassifier, SizeResult, bisect_interval,
                           boundary_window, classification_jump,
                           descend_first_shifted, finalize_size,
                           rescue_change_point, sweep_grid, sweep_rows,
                           widen_interval)
from ..stats import classify_miss_rows, geometric_reduction

__all__ = ["SweepBudget", "find_size_planned", "find_granularity_planned",
           "find_line_size_planned", "find_amount_planned",
           "find_sharing_planned", "find_cu_sharing_planned"]

KIB = 1024


@dataclass(frozen=True)
class SweepBudget:
    """Resource envelope for one planned family search.

    ``max_rounds`` bounds interval widenings plus ladder chunks, and
    ``max_rows`` is a ceiling on sampled grid rows per search — exhausting
    either falls back to the dense sweep, so a budget can make a search
    slower but never wrong.  ``target_resolution`` (bytes) coarsens the
    final lattice for deliberately cheap scans — the only knob that trades
    the dense-identity guarantee for speed, so it defaults to off.  (The
    boundary-detection window is deliberately NOT a knob:
    ``size.BOUNDARY_WINDOW`` is shared with the dense path because both
    must evaluate the identical window for their answers to be identical.)
    """

    max_rounds: int = 12
    max_rows: int | None = None
    target_resolution: int | None = None
    ladder_chunk: int = 4          # doubling-ladder batch size

    def descriptor(self) -> dict:
        """Stable content-address fragment for the TopologyStore."""
        return {
            "max_rounds": int(self.max_rounds),
            "max_rows": None if self.max_rows is None else int(self.max_rows),
            "target_resolution": (None if self.target_resolution is None
                                  else int(self.target_resolution)),
            "ladder_chunk": int(self.ladder_chunk),
        }


class _RowMeter:
    """Counts grid rows a planned search has fetched (max_rows accounting)."""

    def __init__(self, budget: SweepBudget):
        self.limit = budget.max_rows
        self.rows = 0

    def charge(self, n: int) -> None:
        self.rows += int(n)

    @property
    def exhausted(self) -> bool:
        return self.limit is not None and self.rows >= self.limit


def _fetch_window(runner, space: str, sizes: np.ndarray, step: int,
                  n_samples: int) -> np.ndarray:
    """Fetch a row window as ONE fresh dispatch when the runner supports it.

    The window change-point scan compares rows against each other, so on
    measuring runners they must share a launch clock; ``fresh=True``
    bypasses cache *serving* (identical values on request-keyed runners).
    """
    try:
        return np.asarray(runner.pchase_many(
            [(space, int(s), int(step)) for s in sizes], n_samples,
            fresh=True))
    except (AttributeError, TypeError):
        return sweep_rows(runner, space, sizes, step, n_samples,
                          batched=True)


def _bisect_midpoints(lo: int, hi: int, levels: int,
                      stop_span=None) -> list[int]:
    """The next ``levels`` generations of integer midpoints a binary search
    over ``(lo, hi)`` may visit.

    Enumerates BOTH child intervals of every midpoint — the search visits
    exactly one path, so roughly half the points are speculative.
    ``stop_span(a, b)`` prunes branches the search would already have
    terminated.  The midpoint rule ``(a + b) // 2`` is shared with
    ``bisect_interval`` and ``descend_first_shifted``, so prefetched points
    land on exactly the rows the replay asks for.
    """
    pts: list[int] = []
    frontier = [(int(lo), int(hi))]
    for _ in range(max(int(levels), 0)):
        nxt: list[tuple[int, int]] = []
        for a, b in frontier:
            if b - a <= 1 or (stop_span is not None and stop_span(a, b)):
                continue
            mid = (a + b) // 2
            pts.append(mid)
            nxt.append((a, mid))
            nxt.append((mid, b))
        frontier = nxt
    return pts


def _prefetch_pchase(runner, space: str, reqs, n_samples: int,
                     meter: "_RowMeter | None" = None) -> None:
    """Speculative prefetch: fill the sample cache with the warm-chase rows
    a bisection/descent is most likely to ask for, in ONE fused dispatch.

    ``reqs`` is a list of ``(array_bytes, stride)`` pairs.  The decision
    procedure then replays unchanged over cache hits — prefetching is
    result-invisible on request-keyed/cached runners and only converts a
    sequential round chain into one fused round.  Prefetched rows are
    charged to the meter when given (conservative: a speculative row the
    replay never consumes still counts toward ``max_rows``).  Runners
    without the ``caches_requests`` capability would pay a real probe for
    every speculative row AND the replay row — skip them entirely.
    """
    if not getattr(runner, "caches_requests", False):
        return
    uniq = sorted({(int(ab), int(s)) for ab, s in reqs})
    if not uniq:
        return
    try:
        runner.pchase_many([(space, ab, s) for ab, s in uniq], n_samples)
    except (AttributeError, TypeError, NotImplementedError):
        return
    if meter is not None:
        meter.charge(len(uniq))


def _prefetch_cold(runner, space: str, arrs, strides, n_loads: int,
                   meter: "_RowMeter | None" = None) -> None:
    """Cold-capability twin of ``_prefetch_pchase`` (one fused dispatch
    pre-filling the §IV-D probe's own ``cold_chase_batch`` row keys)."""
    if not arrs or not getattr(runner, "caches_requests", False):
        return
    try:
        runner.cold_chase_batch(space, list(arrs), list(strides), n_loads)
    except (AttributeError, NotImplementedError):
        return
    if meter is not None:
        meter.charge(len(arrs))


# --------------------------------------------------------------------------
# §IV-B size search
# --------------------------------------------------------------------------
def find_size_planned(runner, space: str, *, budget: SweepBudget,
                      lo: int = 1 * KIB, step: int = 32, n_samples: int = 33,
                      alpha: float = 0.01, max_points: int = 96,
                      max_widenings: int = 3,
                      max_bytes: int | None = None) -> SizeResult:
    """Coarse-to-fine §IV-B search; discrete-identical to dense ``find_size``.

    Stage 1 (coarse): the doubling ladder is issued in ``ladder_chunk``-row
    batch calls instead of one probe per doubling — same first-shifted
    decision, a fraction of the dispatches.  Stage 2: the dense bisection,
    replayed exactly.  Stage 3 (fine): the classification descent over the
    dense sweep lattice samples O(log n) rows where the dense path measures
    all of them; a ±``window`` row neighborhood of the flip is then fetched
    (one batch call, mostly cache hits) for the K-S confidence split.
    """
    from ..probes.size import find_size          # dense fallback

    max_bytes = max_bytes or 64 * 1024 * KIB
    meter = _RowMeter(budget)
    rounds = 0

    # -- coarse pass: chunked doubling ladder.  On caching runners the
    # base row and the WHOLE ladder go out as one fused dispatch up front —
    # a probe batch costs one launch regardless of row count, so this beats
    # paying a launch per chunk even though rungs past the boundary are
    # speculative; the chunked loop below replays over cache hits and keeps
    # the identical early-exit decision sequence.
    ladder = []
    size = lo
    while size <= max_bytes:
        size *= 2
        ladder.append(size)
    _prefetch_pchase(runner, space,
                     [(lo, step)] + [(sz, step) for sz in ladder],
                     n_samples, meter)

    base = runner.pchase(space, lo, step, n_samples)
    clf = ShiftClassifier(base, alpha, classification_jump(runner))
    meter.charge(1)

    first_bad = None
    probed = 0
    for c in range(0, len(ladder), max(budget.ladder_chunk, 1)):
        part = ladder[c: c + max(budget.ladder_chunk, 1)]
        rows = sweep_rows(runner, space, part, step, n_samples, batched=True)
        meter.charge(len(part))
        probed += len(part)
        rounds += 1
        for sz, row in zip(part, rows):
            if clf.shifted(row):
                first_bad = sz
                break
        if first_bad is not None or rounds >= budget.max_rounds:
            break
    if first_bad is None:
        if probed < len(ladder):
            # ladder cut short by the round budget: let the oracle decide
            return find_size(runner, space, lo=lo, step=step,
                             n_samples=n_samples, alpha=alpha,
                             max_points=max_points,
                             max_widenings=max_widenings,
                             max_bytes=max_bytes, batched=True)
        # No shifted rung: re-fetch the ladder as ONE fresh launch and look
        # for an inter-rung regime change (baseline-free — the dense path's
        # ladder_rescue over the same keyed rows on simulated runners).
        from ..probes.size import ladder_rescue

        fresh = _fetch_window(runner, space, np.asarray(ladder), step,
                              n_samples)
        meter.charge(len(ladder))
        first_bad = ladder_rescue(ladder, fresh, alpha)
    if first_bad is None:
        return SizeResult(-1, False, 0.0, 1.0, np.zeros(0), np.zeros(0),
                          0, n_samples)

    # -- bisection: identical to the dense path, probe for probe (single
    # rows go through the lean ``pchase`` cache path, not a 1-row batch)
    def shifted_at(sz: int) -> bool:
        meter.charge(1)
        return clf.shifted(runner.pchase(space, int(sz), step, n_samples))

    # Speculative quantile prefetch: the first three midpoint generations
    # of the bisection (pruned by its own termination rule) in ONE fused
    # dispatch; ``bisect_interval`` below replays over cache hits.
    def _bisect_done(a: int, b: int) -> bool:
        return b - a <= max(8 * step, (a + b) // 64)

    _prefetch_pchase(
        runner, space,
        [(sz, step) for sz in _bisect_midpoints(first_bad // 2, first_bad, 5,
                                                stop_span=_bisect_done)],
        n_samples, meter)
    sweep_lo, sweep_hi = bisect_interval(shifted_at, first_bad, step)

    eff_floor = step
    if budget.target_resolution is not None:
        eff_floor = max(step, budget.target_resolution // step * step)

    widenings = 0
    while True:
        G, eff_step = sweep_grid(sweep_lo, sweep_hi, step, max_points)
        if eff_floor > eff_step:
            # Deliberately coarse scan (non-oracle-identical, documented).
            # The bisected interval can be narrower than a few coarse
            # steps, so pad it — the descent needs a bracketable grid.
            pad = 4 * eff_floor
            glo, ghi = max(lo, sweep_lo - pad), min(max_bytes, sweep_hi + pad)
            G = np.arange(glo, ghi + eff_floor, eff_floor, dtype=np.int64)
            eff_step = eff_floor
        n = G.size
        if n < 4 or meter.exhausted:
            # unusably small lattice / row budget exhausted: the dense
            # sweep is slower but never wrong
            return find_size(runner, space, lo=lo, step=step,
                             n_samples=n_samples, alpha=alpha,
                             max_points=max_points,
                             max_widenings=max_widenings,
                             max_bytes=max_bytes, batched=True)

        memo: dict[int, np.ndarray] = {}

        def row_at(i: int) -> np.ndarray:
            if i not in memo:
                memo[i] = runner.pchase(space, int(G[i]), step, n_samples)
                meter.charge(1)
            return memo[i]

        # Descent prefetch: the top anchor plus four midpoint generations
        # of the index bisection (lo_known starts at -1), one fused
        # dispatch; ``descend_first_shifted`` replays over cache hits and
        # only its confirm rows near the landing fetch individually.
        spec = [n - 1] + _bisect_midpoints(-1, n - 1, 5)
        _prefetch_pchase(runner, space,
                         [(int(G[i]), step) for i in spec if 0 <= i < n],
                         n_samples, meter)
        flip = descend_first_shifted(lambda i: clf.shifted(row_at(i)), n)

        if (flip <= 2 or flip >= n - 2) and widenings < max_widenings:
            rounds += 1
            if rounds >= budget.max_rounds:
                return find_size(runner, space, lo=lo, step=step,
                                 n_samples=n_samples, alpha=alpha,
                                 max_points=max_points,
                                 max_widenings=max_widenings,
                                 max_bytes=max_bytes, batched=True)
            widenings += 1
            sweep_lo, sweep_hi = widen_interval(sweep_lo, sweep_hi, eff_step,
                                                lo, max_bytes)
            continue
        if 0 < flip < n:
            # The boundary window: the same fixed-width slice of the
            # lattice the dense path evaluates, fetched FRESH as one
            # dispatch — the window scan needs rows that share a launch
            # clock, not a mix of descent-time cache entries recorded at
            # different drift levels (request-keyed runners return
            # identical rows either way).
            wa, wb = boundary_window(flip, n)
            wrows = _fetch_window(runner, space, G[wa:wb], step, n_samples)
            meter.charge(wb - wa)
            result = finalize_size(G, wa, wrows, flip, widenings, n_samples,
                                   alpha)
        else:
            result = None
        if result is None:
            # Flip escaped/suspect: fetch the whole lattice (ONE fresh
            # launch — its rows share a scale) and run the same
            # scale-immune change-point rescue as the dense sweep.
            rows = _fetch_window(runner, space, G, step, n_samples)
            meter.charge(n)
            result = rescue_change_point(G, rows, widenings, n_samples,
                                         alpha)
        if not result.found and widenings < max_widenings:
            # same power-recovery widening as the dense sweep
            rounds += 1
            if rounds >= budget.max_rounds:
                return find_size(runner, space, lo=lo, step=step,
                                 n_samples=n_samples, alpha=alpha,
                                 max_points=max_points,
                                 max_widenings=max_widenings,
                                 max_bytes=max_bytes, batched=True)
            widenings += 1
            sweep_lo, sweep_hi = widen_interval(sweep_lo, sweep_hi, eff_step,
                                                lo, max_bytes)
            continue
        return result


# --------------------------------------------------------------------------
# §IV-D fetch-granularity search
# --------------------------------------------------------------------------
def find_granularity_planned(runner, space: str, *, budget: SweepBudget,
                             max_stride: int = 512,
                             array_bytes: int = 64 * 1024,
                             n_samples: int = 65, stride_step: int = 4,
                             confirm: int = 2) -> GranularityResult:
    """Bisection for the first all-miss stride + local run verification.

    The dense answer is the start of the first ``confirm + 1``-long run of
    all-miss strides; that is a local predicate of the stride grid, so a
    bisection that assumes "mixed below G, all-miss above" finds it in
    O(log n) rows and then *verifies* the run locally.  Any verification
    failure (a fluke hit past the candidate, a mixed stride at the grid
    top, hits at the first stride without a leading run) means the
    monotonicity assumption does not hold — fall back to the dense sweep,
    which is fluke-robust by construction.
    """
    def dense() -> GranularityResult:
        return find_fetch_granularity(
            runner, space, max_stride=max_stride, array_bytes=array_bytes,
            n_samples=n_samples, stride_step=stride_step, confirm=confirm,
            batched=True)

    hit_ref, miss_ref, thresh, hit_med, miss_med = granularity_refs(
        runner, space, array_bytes, max_stride, n_samples, stride_step)
    del hit_ref, miss_ref
    strides = np.arange(stride_step, max_stride + stride_step, stride_step)
    if miss_med < hit_med * 1.5:
        # same degenerate-references refusal as the dense sweep
        return GranularityResult(-1, False, strides[:0],
                                 np.zeros(0, dtype=bool))
    n = strides.size
    n_loads = 16 * n_samples
    min_frac = max(0.005, 2.0 / n_loads)

    memo: dict[int, bool] = {}

    def mixed(i: int) -> bool:
        if i not in memo:
            s = int(strides[i])
            arr = max(array_bytes, s * (n_loads + 1))
            row = np.asarray(runner.cold_chase_batch(space, [arr], [s],
                                                     n_loads))[0] \
                if hasattr(runner, "cold_chase_batch") else \
                runner.cold_chase(space, arr, s, n_loads)
            memo[i] = float(np.mean(np.asarray(row) < thresh)) > min_frac
        return memo[i]

    def prefetch(idxs) -> None:
        """One fused cold dispatch pre-filling ``mixed``'s own row keys."""
        todo = sorted({i for i in idxs if 0 <= i < n and i not in memo})
        _prefetch_cold(
            runner, space,
            [max(array_bytes, int(strides[i]) * (n_loads + 1)) for i in todo],
            [int(strides[i]) for i in todo], n_loads)

    # Speculative prefetch: both anchors plus the first three midpoint
    # generations of the bisection in ONE fused cold dispatch — the
    # sequential probes below replay over cache hits, collapsing the
    # dominant ~log n cold rounds of this search.
    prefetch(list(range(n - 1 - confirm, n)) + [0, 1, 2]
             + _bisect_midpoints(0, n - 1 - confirm, 3))

    # top anchor: the largest strides must be cleanly all-miss
    if any(mixed(i) for i in range(n - 1 - confirm, n)):
        return dense()
    if not mixed(0):
        # granularity at (or flukes near) the very first stride
        upto = min(confirm + 1, n)
        if all(not mixed(i) for i in range(upto)):
            m = np.array([mixed(i) for i in range(upto)], dtype=bool)
            return GranularityResult(int(strides[0]), True, strides[:upto], m)
        return dense()

    # Wave bisection: every three halvings, prefetch the next three
    # midpoint generations of the CURRENT interval as one fused dispatch —
    # a probe batch costs one launch regardless of row count, so covering
    # both children of every midpoint is cheaper than one sequential miss.
    lo, hi = 0, n - 1 - confirm
    while hi - lo > 1:
        prefetch(_bisect_midpoints(lo, hi, 4))
        for _ in range(4):
            if hi - lo <= 1:
                break
            mid = (lo + hi) // 2
            if mixed(mid):
                lo = mid
            else:
                hi = mid
    f = hi
    # Fetch the verification runs below in one fused dispatch too.
    prefetch([f + k for k in range(confirm + 1)] + [f - 1, f - 2])
    # Run verification: confirm successors all-miss, predecessors mixed.
    # TWO predecessors, not one — the bisection's landing flag and the
    # f-1 verification would otherwise be the same (possibly fluked) row,
    # and on measuring backends a single drifted launch can scale a whole
    # row across the hit/miss threshold.  Demanding an independent second
    # mixed row squares the fluke probability; any disagreement falls
    # back to the fluke-robust dense sweep.
    if any(mixed(f + k) for k in range(confirm + 1)):
        return dense()
    if any(not mixed(f - k) for k in (1, 2) if f - k >= 0):
        return dense()
    upto = f + confirm + 1
    m = np.zeros(upto, dtype=bool)
    for i, flag in memo.items():
        if i < upto:
            m[i] = flag
    return GranularityResult(int(strides[f]), True, strides[:upto], m)


# --------------------------------------------------------------------------
# §IV-E line-size search
# --------------------------------------------------------------------------
def find_line_size_planned(runner, space: str, cache_size: int,
                           fetch_granularity: int, *, budget: SweepBudget,
                           n_samples: int = 65, over_factor: float = 1.0625,
                           max_line: int = 1024) -> LineSizeResult:
    """Bisection for the first hit-classified step (§IV-E).

    The dense answer is the first step whose distribution sits closer to
    the certain-hit reference than to the certain-miss pivot — again a
    local predicate, structurally monotone (footprint shrinks below
    capacity exactly once as the step grows).  Verified at the flip;
    non-monotone scores fall back to the dense chunked sweep.
    """
    def dense() -> LineSizeResult:
        return find_line_size(runner, space, cache_size, fetch_granularity,
                              n_samples=n_samples, over_factor=over_factor,
                              max_line=max_line, batched=True)

    from ..probes.linesize import hit_scores

    g2 = max(fetch_granularity // 2, 4)
    arr = int(cache_size * over_factor)
    steps = np.arange(g2, max_line * 2 + g2, g2, dtype=np.int64)
    n = steps.size

    # Speculative prefetch: both references, the anchor steps, and four
    # midpoint generations of the bisection as ONE fused dispatch — the
    # sequential ``score`` probes below replay over cache hits.
    spec = [0, 1, 2, n - 1, n - 2] + _bisect_midpoints(0, n - 1, 5)
    _prefetch_pchase(runner, space,
                     [(arr, g2), (arr, max_line * 8)]
                     + [(arr, int(steps[i])) for i in spec if 0 <= i < n],
                     n_samples)

    pivot = runner.pchase(space, arr, g2, n_samples)
    hit_ref = runner.pchase(space, arr, max_line * 8, n_samples)

    memo: dict[int, float] = {}

    def score(i: int) -> float:
        if i not in memo:
            row = runner.pchase(space, arr, int(steps[i]), n_samples)
            memo[i] = float(hit_scores(row, pivot, hit_ref)[0])
        return memo[i]

    if score(0) > 0:
        # line <= granularity/2: every step hits — but demand independent
        # confirmation before accepting the degenerate answer
        if any(score(k) <= 0 for k in (1, 2) if k < n):
            return dense()
        first_hit_step = int(steps[0])
    elif score(n - 1) <= 0:
        # top step misses: demand an independent second row before the
        # terminal not-found (a single drifted launch must not erase the
        # attribute); disagreement lets dense rule
        if n >= 2 and score(n - 2) > 0:
            return dense()
        return LineSizeResult(-1, False, -1.0, steps,
                              np.array([score(0), score(n - 1)]))
    else:
        # same wave-prefetched bisection as the granularity planner
        lo, hi = 0, n - 1
        while hi - lo > 1:
            _prefetch_pchase(
                runner, space,
                [(arr, int(steps[i]))
                 for i in _bisect_midpoints(lo, hi, 4) if i not in memo],
                n_samples)
            for _ in range(4):
                if hi - lo <= 1:
                    break
                mid = (lo + hi) // 2
                if score(mid) > 0:
                    hi = mid
                else:
                    lo = mid
        # Verify with an extra independent below-flip row (mirrors the
        # granularity planner): non-monotone scores let dense rule.
        _prefetch_pchase(runner, space,
                         [(arr, int(steps[hi - k])) for k in (1, 2)
                          if hi - k >= 0 and hi - k not in memo], n_samples)
        if any(score(hi - k) > 0 for k in (1, 2) if hi - k >= 0):
            return dense()
        first_hit_step = int(steps[hi])

    line, raw = line_size_from_first_hit(first_hit_step, over_factor, g2)
    ks = sorted(memo)
    return LineSizeResult(line, True, raw, steps[ks],
                          np.array([memo[i] for i in ks]))


# --------------------------------------------------------------------------
# §IV-F amount ladder
# --------------------------------------------------------------------------
def find_amount_planned(runner, space: str, cache_size: int,
                        cores_per_sm: int, *, n_samples: int = 65,
                        budget: SweepBudget) -> AmountResult:
    """Bisection over the §IV-F core-B doubling ladder (first NON-evicting
    rung) with memo-consistency verification.

    The dense sweep probes every doubling until core B stops evicting core
    A; under the paper's segment model the evicts-flag is monotone in the
    rung index (True below the boundary, False at and above it), so a
    bisection pins the flip in O(log n) eviction rows.  Every rung the
    planner *did* probe is then checked against the monotone pattern and
    the two rungs below the flip are verified independently — any
    disagreement falls back to the dense ladder, so the discrete answer
    (amount, first disjoint core) is identical planner-vs-dense.  Rows go
    out as fused ``eviction_many`` grid calls where the runner supports
    them (speculative midpoints prefetched with the anchors in ONE
    dispatch), one ``amount_probe`` per rung otherwise.
    """
    def dense() -> AmountResult:
        return find_amount(runner, space, cache_size, cores_per_sm,
                           n_samples=n_samples, batched=True)

    bs = amount_ladder(cores_per_sm)
    if not bs:
        return AmountResult(1, True, -1, [])
    arr = int(cache_size * 0.9)
    hit_ref, miss_ref = _hit_miss_refs(runner, space, arr, cache_size,
                                       n_samples)
    meter = _RowMeter(budget)
    n = len(bs)
    memo: dict[int, bool] = {}

    def fetch(idxs) -> None:
        """Probe-and-classify rungs in ONE fused eviction dispatch."""
        todo = sorted({int(i) for i in idxs if 0 <= i < n and i not in memo})
        if not todo:
            return
        if hasattr(runner, "eviction_many"):
            rows = np.asarray(runner.eviction_many(
                [("amount", space, 0, bs[i], arr) for i in todo], n_samples))
        else:
            rows = np.stack([runner.amount_probe(space, 0, bs[i], arr,
                                                 n_samples) for i in todo])
        meter.charge(len(todo))
        for i, m in zip(todo, classify_miss_rows(rows, hit_ref, miss_ref)):
            memo[i] = bool(m)

    def evicts(i: int) -> bool:
        if i not in memo:
            fetch([i])
        return memo[i]

    # anchors + the first two midpoint generations, one fused dispatch
    fetch([0, n - 1] + _bisect_midpoints(0, n - 1, 2))
    if meter.exhausted:
        return dense()
    if not evicts(0):
        # core B = 1 already leaves A resident: the dense sweep would stop
        # at the very first rung
        return AmountResult(max(cores_per_sm // bs[0], 1), True, bs[0],
                            [bs[0]])
    if evicts(n - 1):
        # no non-evicting rung in sight: complete the ladder (one fused
        # call for the few unprobed rungs) and demand it be uniformly
        # evicting — mirroring the dense sweep's full walk exactly
        fetch(range(n))
        if any(not memo[i] for i in memo):
            return dense()
        return AmountResult(1, True, -1, list(bs))

    lo, hi = 0, n - 1            # evicts(lo) is True, evicts(hi) is False
    while hi - lo > 1:
        mid = (lo + hi) // 2
        if evicts(mid):
            lo = mid
        else:
            hi = mid
    f = hi
    # Two independent below-flip rungs must evict (same squared-fluke
    # argument as the granularity planner), and every memoized rung must
    # match the monotone pattern the bisection assumed.
    fetch([f - 1, f - 2])
    if any(not evicts(f - k) for k in (1, 2) if f - k >= 0):
        return dense()
    if any(memo[i] != (i < f) for i in memo):
        return dense()
    if meter.exhausted:
        return dense()
    tested = [bs[i] for i in sorted(memo) if i <= f]
    return AmountResult(max(cores_per_sm // bs[f], 1), True, bs[f], tested)


# --------------------------------------------------------------------------
# §IV-G sharing lattice
# --------------------------------------------------------------------------
def find_sharing_planned(runner, leaders, n_samples: int = 65, *,
                         budget: SweepBudget) -> list[SharingResult]:
    """Partition-closure planning for the §IV-G pairwise sharing lattice.

    ``leaders`` is the registry's ordered leader list — ``(space_a,
    cache_size, partners)`` triples covering every pair once.  Physical
    sharing is an equivalence relation (two spaces either occupy one cache
    or they don't), so once earlier leaders have probed a pair's relation
    to a common third space ``L`` with at least one positive edge, the
    pair's own flag is determined: ``a~p iff a~L and p~L``.  When EVERY
    partner of a leader is inferable this way, the planner spot-checks the
    first partner with a real probe row and accepts the inferred row on
    agreement; any disagreement — or any partner without a witnessing
    ``L`` — issues the dense ``find_sharing_batch`` row for that leader.

    Discrete identity: inferred flags equal dense flags whenever the
    equivalence hypothesis holds, and the hypothesis is spot-checked per
    leader; a violation (non-transitive measurements) is caught by the
    spot row or surfaces as an inference gap, both of which fall back to
    dense rows.  On lattices with only singleton/pair groups (e.g. the
    H100-like model) nothing is inferable and the planner degrades to the
    dense batch — the win is for fabrics where ≥3 spaces alias one cache.
    """
    meter = _RowMeter(budget)
    know: dict[frozenset, bool] = {}
    past: list[str] = []
    out: list[SharingResult] = []
    for space_a, cache_size, partners in leaders:
        partners = list(partners)

        def infer(p: str):
            """Flag for (space_a, p) via a witnessing earlier leader."""
            for lead in past:
                ka = know.get(frozenset((lead, space_a)))
                kp = know.get(frozenset((lead, p)))
                if ka is not None and kp is not None and (ka or kp):
                    return ka and kp
            return None

        inferred = [infer(p) for p in partners]
        if partners and all(v is not None for v in inferred) \
                and not meter.exhausted:
            spot = find_sharing_batch(runner, space_a, partners[:1],
                                      cache_size, n_samples)
            meter.charge(1)
            if spot[0].shared == inferred[0]:
                res = [SharingResult(bool(v), space_a, p)
                       for v, p in zip(inferred, partners)]
                for r in res:
                    know[frozenset((space_a, r.space_b))] = r.shared
                out.extend(res)
                past.append(space_a)
                continue
            # spot disagreed with the closure: dense row rules (the spot's
            # request key is cached, so the re-ask costs nothing extra)
        res = find_sharing_batch(runner, space_a, partners, cache_size,
                                 n_samples)
        meter.charge(len(partners))
        for r in res:
            know[frozenset((space_a, r.space_b))] = r.shared
        out.extend(res)
        past.append(space_a)
    return out


# --------------------------------------------------------------------------
# §IV-H CU-pair lattice
# --------------------------------------------------------------------------
def find_cu_sharing_planned(runner, cu_ids, cache_size: int, *,
                            n_samples: int = 33, space: str = "sL1d",
                            budget: SweepBudget) -> CuSharingResult:
    """Hypothesis-first planning for the §IV-H CU↔sL1d pairwise sweep.

    The dense sweep probes each ungrouped leader against every remaining
    candidate — O(n^2) eviction rows.  On real parts sL1d sharing comes in
    small contiguous groups (MI210: adjacent CU pairs), so the planner
    first spot-checks four candidates per leader (the first two, the
    middle, the last, in ONE fused eviction dispatch).  Exactly the
    closed-pair signature — first candidate shared, all other spots
    disjoint — accepts the hypothesis ``group = {leader, first candidate}``
    without probing the rest of the row; ANY other signature (including
    all-disjoint, i.e. an exclusive CU) issues the dense candidate row, so
    the grouping can only shortcut through the verified pair pattern.
    Every flag is a per-pair request-keyed row, identical between the spot
    path and the dense row, so accepted groups match the dense grouping.

    Residual (documented, same contract class as the granularity planner's
    distant-fluke window): a non-contiguous group larger than two whose
    extra members dodge all four spot columns would be split — the second
    member's own leader round then probes densely, bounding the error to
    that group.  Exhausting ``budget.max_rows`` degrades to dense rows.
    """
    cu_ids = list(cu_ids)
    arr = int(cache_size * 0.9)
    hit_ref, miss_ref = _hit_miss_refs(runner, space, arr, cache_size,
                                       n_samples)
    meter = _RowMeter(budget)
    flag_memo: dict[tuple[int, int], bool] = {}

    def shared_flags(cu_a: int, cu_bs) -> list[bool]:
        """Shared-flag for each (cu_a, b) pair; ONE fused eviction call."""
        todo = [b for b in cu_bs if (cu_a, b) not in flag_memo]
        if todo:
            if hasattr(runner, "eviction_many"):
                rows = np.asarray(runner.eviction_many(
                    [("cu", space, cu_a, b, arr) for b in todo], n_samples))
            else:
                rows = np.stack([runner.cu_sharing_probe(
                    cu_a, b, arr, n_samples, space=space) for b in todo])
            meter.charge(len(todo))
            for b, m in zip(todo, classify_miss_rows(rows, hit_ref,
                                                     miss_ref)):
                flag_memo[(cu_a, b)] = bool(m)
        return [flag_memo[(cu_a, b)] for b in cu_bs]

    assigned: dict[int, int] = {}
    groups: list[list[int]] = []
    for i, cu_a in enumerate(cu_ids):
        if cu_a in assigned:
            continue
        group = [cu_a]
        assigned[cu_a] = len(groups)
        candidates = [b for b in cu_ids[i + 1:] if b not in assigned]
        if candidates:
            done = False
            if len(candidates) > 3 and not meter.exhausted:
                mid = len(candidates) // 2
                spots = list(dict.fromkeys(
                    [candidates[0], candidates[1], candidates[mid],
                     candidates[-1]]))
                fmap = dict(zip(spots, shared_flags(cu_a, spots)))
                if fmap[candidates[0]] and \
                        not any(fmap[s] for s in spots if s != candidates[0]):
                    group.append(candidates[0])
                    assigned[candidates[0]] = assigned[cu_a]
                    done = True
            if not done:
                for b, m in zip(candidates, shared_flags(cu_a, candidates)):
                    if m:
                        group.append(b)
                        assigned[b] = assigned[cu_a]
        groups.append(group)
    exclusive = [g[0] for g in groups if len(g) == 1]
    return CuSharingResult(groups, exclusive)
