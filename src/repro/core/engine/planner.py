"""Adaptive coarse-to-fine sweep planner (the probe-volume optimizer).

MT4G's reliability comes from statistical change-point detection over
microbenchmark sweeps, but a *dense* sweep measures every grid point even
though the K-S statistics localize the boundary after a handful of rows.
This module plans sweeps instead of enumerating them:

* a **coarse logarithmic pass** (the §IV-B doubling ladder, issued in
  chunked batch calls) brackets the boundary octave;
* the dense workflow's own **binary bisection** narrows the interval —
  replayed probe-for-probe so the planner lands on the *identical sweep
  lattice* as the dense path;
* a **deterministic classification descent** (``descend_first_shifted``)
  walks O(log n) rows of that lattice to pin the discrete boundary, and a
  small window around the flip feeds the K-S confidence metric.

Identity contract: the dense sweeps (``budget=None``) remain the
equivalence oracle.  Discrete attributes — sizes, line size, fetch
granularity, amounts, sharing — are *identical* planner-vs-dense because
both paths evaluate the same local boundary rule over the same grid rows
(request-keyed streams on simulated runners; shared caches otherwise), and
every planned search **falls back to the dense sweep** whenever its local
monotonicity assumptions fail (non-monotone classifications, flukes near
the boundary, budget exhaustion).  Only the non-discrete floats
(confidence, p-value) may differ, computed from a window instead of the
full series.

``SweepBudget`` is the knob set carried on ``DiscoveryRequest``: round and
row ceilings plus an optional target resolution for deliberately coarse
(non-oracle-identical) scans.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from ..probes.linesize import (GranularityResult, LineSizeResult,
                               find_fetch_granularity, find_line_size,
                               granularity_refs, line_size_from_first_hit)
from ..probes.size import (ShiftClassifier, SizeResult, bisect_interval,
                           boundary_window, classification_jump,
                           descend_first_shifted, finalize_size,
                           rescue_change_point, sweep_grid, sweep_rows,
                           widen_interval)
from ..stats import geometric_reduction

__all__ = ["SweepBudget", "find_size_planned", "find_granularity_planned",
           "find_line_size_planned"]

KIB = 1024


@dataclass(frozen=True)
class SweepBudget:
    """Resource envelope for one planned family search.

    ``max_rounds`` bounds interval widenings plus ladder chunks, and
    ``max_rows`` is a ceiling on sampled grid rows per search — exhausting
    either falls back to the dense sweep, so a budget can make a search
    slower but never wrong.  ``target_resolution`` (bytes) coarsens the
    final lattice for deliberately cheap scans — the only knob that trades
    the dense-identity guarantee for speed, so it defaults to off.  (The
    boundary-detection window is deliberately NOT a knob:
    ``size.BOUNDARY_WINDOW`` is shared with the dense path because both
    must evaluate the identical window for their answers to be identical.)
    """

    max_rounds: int = 12
    max_rows: int | None = None
    target_resolution: int | None = None
    ladder_chunk: int = 4          # doubling-ladder batch size

    def descriptor(self) -> dict:
        """Stable content-address fragment for the TopologyStore."""
        return {
            "max_rounds": int(self.max_rounds),
            "max_rows": None if self.max_rows is None else int(self.max_rows),
            "target_resolution": (None if self.target_resolution is None
                                  else int(self.target_resolution)),
            "ladder_chunk": int(self.ladder_chunk),
        }


class _RowMeter:
    """Counts grid rows a planned search has fetched (max_rows accounting)."""

    def __init__(self, budget: SweepBudget):
        self.limit = budget.max_rows
        self.rows = 0

    def charge(self, n: int) -> None:
        self.rows += int(n)

    @property
    def exhausted(self) -> bool:
        return self.limit is not None and self.rows >= self.limit


def _fetch_window(runner, space: str, sizes: np.ndarray, step: int,
                  n_samples: int) -> np.ndarray:
    """Fetch a row window as ONE fresh dispatch when the runner supports it.

    The window change-point scan compares rows against each other, so on
    measuring runners they must share a launch clock; ``fresh=True``
    bypasses cache *serving* (identical values on request-keyed runners).
    """
    try:
        return np.asarray(runner.pchase_many(
            [(space, int(s), int(step)) for s in sizes], n_samples,
            fresh=True))
    except (AttributeError, TypeError):
        return sweep_rows(runner, space, sizes, step, n_samples,
                          batched=True)


# --------------------------------------------------------------------------
# §IV-B size search
# --------------------------------------------------------------------------
def find_size_planned(runner, space: str, *, budget: SweepBudget,
                      lo: int = 1 * KIB, step: int = 32, n_samples: int = 33,
                      alpha: float = 0.01, max_points: int = 96,
                      max_widenings: int = 3,
                      max_bytes: int | None = None) -> SizeResult:
    """Coarse-to-fine §IV-B search; discrete-identical to dense ``find_size``.

    Stage 1 (coarse): the doubling ladder is issued in ``ladder_chunk``-row
    batch calls instead of one probe per doubling — same first-shifted
    decision, a fraction of the dispatches.  Stage 2: the dense bisection,
    replayed exactly.  Stage 3 (fine): the classification descent over the
    dense sweep lattice samples O(log n) rows where the dense path measures
    all of them; a ±``window`` row neighborhood of the flip is then fetched
    (one batch call, mostly cache hits) for the K-S confidence split.
    """
    from ..probes.size import find_size          # dense fallback

    max_bytes = max_bytes or 64 * 1024 * KIB
    meter = _RowMeter(budget)
    rounds = 0

    base = runner.pchase(space, lo, step, n_samples)
    clf = ShiftClassifier(base, alpha, classification_jump(runner))
    meter.charge(1)

    # -- coarse pass: chunked doubling ladder
    ladder = []
    size = lo
    while size <= max_bytes:
        size *= 2
        ladder.append(size)
    first_bad = None
    probed = 0
    for c in range(0, len(ladder), max(budget.ladder_chunk, 1)):
        part = ladder[c: c + max(budget.ladder_chunk, 1)]
        rows = sweep_rows(runner, space, part, step, n_samples, batched=True)
        meter.charge(len(part))
        probed += len(part)
        rounds += 1
        for sz, row in zip(part, rows):
            if clf.shifted(row):
                first_bad = sz
                break
        if first_bad is not None or rounds >= budget.max_rounds:
            break
    if first_bad is None:
        if probed < len(ladder):
            # ladder cut short by the round budget: let the oracle decide
            return find_size(runner, space, lo=lo, step=step,
                             n_samples=n_samples, alpha=alpha,
                             max_points=max_points,
                             max_widenings=max_widenings,
                             max_bytes=max_bytes, batched=True)
        # No shifted rung: re-fetch the ladder as ONE fresh launch and look
        # for an inter-rung regime change (baseline-free — the dense path's
        # ladder_rescue over the same keyed rows on simulated runners).
        from ..probes.size import ladder_rescue

        fresh = _fetch_window(runner, space, np.asarray(ladder), step,
                              n_samples)
        meter.charge(len(ladder))
        first_bad = ladder_rescue(ladder, fresh, alpha)
    if first_bad is None:
        return SizeResult(-1, False, 0.0, 1.0, np.zeros(0), np.zeros(0),
                          0, n_samples)

    # -- bisection: identical to the dense path, probe for probe (single
    # rows go through the lean ``pchase`` cache path, not a 1-row batch)
    def shifted_at(sz: int) -> bool:
        meter.charge(1)
        return clf.shifted(runner.pchase(space, int(sz), step, n_samples))

    sweep_lo, sweep_hi = bisect_interval(shifted_at, first_bad, step)

    eff_floor = step
    if budget.target_resolution is not None:
        eff_floor = max(step, budget.target_resolution // step * step)

    widenings = 0
    while True:
        G, eff_step = sweep_grid(sweep_lo, sweep_hi, step, max_points)
        if eff_floor > eff_step:
            # Deliberately coarse scan (non-oracle-identical, documented).
            # The bisected interval can be narrower than a few coarse
            # steps, so pad it — the descent needs a bracketable grid.
            pad = 4 * eff_floor
            glo, ghi = max(lo, sweep_lo - pad), min(max_bytes, sweep_hi + pad)
            G = np.arange(glo, ghi + eff_floor, eff_floor, dtype=np.int64)
            eff_step = eff_floor
        n = G.size
        if n < 4 or meter.exhausted:
            # unusably small lattice / row budget exhausted: the dense
            # sweep is slower but never wrong
            return find_size(runner, space, lo=lo, step=step,
                             n_samples=n_samples, alpha=alpha,
                             max_points=max_points,
                             max_widenings=max_widenings,
                             max_bytes=max_bytes, batched=True)

        memo: dict[int, np.ndarray] = {}

        def row_at(i: int) -> np.ndarray:
            if i not in memo:
                memo[i] = runner.pchase(space, int(G[i]), step, n_samples)
                meter.charge(1)
            return memo[i]

        flip = descend_first_shifted(lambda i: clf.shifted(row_at(i)), n)

        if (flip <= 2 or flip >= n - 2) and widenings < max_widenings:
            rounds += 1
            if rounds >= budget.max_rounds:
                return find_size(runner, space, lo=lo, step=step,
                                 n_samples=n_samples, alpha=alpha,
                                 max_points=max_points,
                                 max_widenings=max_widenings,
                                 max_bytes=max_bytes, batched=True)
            widenings += 1
            sweep_lo, sweep_hi = widen_interval(sweep_lo, sweep_hi, eff_step,
                                                lo, max_bytes)
            continue
        if 0 < flip < n:
            # The boundary window: the same fixed-width slice of the
            # lattice the dense path evaluates, fetched FRESH as one
            # dispatch — the window scan needs rows that share a launch
            # clock, not a mix of descent-time cache entries recorded at
            # different drift levels (request-keyed runners return
            # identical rows either way).
            wa, wb = boundary_window(flip, n)
            wrows = _fetch_window(runner, space, G[wa:wb], step, n_samples)
            meter.charge(wb - wa)
            result = finalize_size(G, wa, wrows, flip, widenings, n_samples,
                                   alpha)
        else:
            result = None
        if result is None:
            # Flip escaped/suspect: fetch the whole lattice (ONE fresh
            # launch — its rows share a scale) and run the same
            # scale-immune change-point rescue as the dense sweep.
            rows = _fetch_window(runner, space, G, step, n_samples)
            meter.charge(n)
            result = rescue_change_point(G, rows, widenings, n_samples,
                                         alpha)
        if not result.found and widenings < max_widenings:
            # same power-recovery widening as the dense sweep
            rounds += 1
            if rounds >= budget.max_rounds:
                return find_size(runner, space, lo=lo, step=step,
                                 n_samples=n_samples, alpha=alpha,
                                 max_points=max_points,
                                 max_widenings=max_widenings,
                                 max_bytes=max_bytes, batched=True)
            widenings += 1
            sweep_lo, sweep_hi = widen_interval(sweep_lo, sweep_hi, eff_step,
                                                lo, max_bytes)
            continue
        return result


# --------------------------------------------------------------------------
# §IV-D fetch-granularity search
# --------------------------------------------------------------------------
def find_granularity_planned(runner, space: str, *, budget: SweepBudget,
                             max_stride: int = 512,
                             array_bytes: int = 64 * 1024,
                             n_samples: int = 65, stride_step: int = 4,
                             confirm: int = 2) -> GranularityResult:
    """Bisection for the first all-miss stride + local run verification.

    The dense answer is the start of the first ``confirm + 1``-long run of
    all-miss strides; that is a local predicate of the stride grid, so a
    bisection that assumes "mixed below G, all-miss above" finds it in
    O(log n) rows and then *verifies* the run locally.  Any verification
    failure (a fluke hit past the candidate, a mixed stride at the grid
    top, hits at the first stride without a leading run) means the
    monotonicity assumption does not hold — fall back to the dense sweep,
    which is fluke-robust by construction.
    """
    def dense() -> GranularityResult:
        return find_fetch_granularity(
            runner, space, max_stride=max_stride, array_bytes=array_bytes,
            n_samples=n_samples, stride_step=stride_step, confirm=confirm,
            batched=True)

    hit_ref, miss_ref, thresh, hit_med, miss_med = granularity_refs(
        runner, space, array_bytes, max_stride, n_samples, stride_step)
    del hit_ref, miss_ref
    strides = np.arange(stride_step, max_stride + stride_step, stride_step)
    if miss_med < hit_med * 1.5:
        # same degenerate-references refusal as the dense sweep
        return GranularityResult(-1, False, strides[:0],
                                 np.zeros(0, dtype=bool))
    n = strides.size
    n_loads = 16 * n_samples
    min_frac = max(0.005, 2.0 / n_loads)

    memo: dict[int, bool] = {}

    def mixed(i: int) -> bool:
        if i not in memo:
            s = int(strides[i])
            arr = max(array_bytes, s * (n_loads + 1))
            row = np.asarray(runner.cold_chase_batch(space, [arr], [s],
                                                     n_loads))[0] \
                if hasattr(runner, "cold_chase_batch") else \
                runner.cold_chase(space, arr, s, n_loads)
            memo[i] = float(np.mean(np.asarray(row) < thresh)) > min_frac
        return memo[i]

    # top anchor: the largest strides must be cleanly all-miss
    if any(mixed(i) for i in range(n - 1 - confirm, n)):
        return dense()
    if not mixed(0):
        # granularity at (or flukes near) the very first stride
        upto = min(confirm + 1, n)
        if all(not mixed(i) for i in range(upto)):
            m = np.array([mixed(i) for i in range(upto)], dtype=bool)
            return GranularityResult(int(strides[0]), True, strides[:upto], m)
        return dense()

    lo, hi = 0, n - 1 - confirm
    while hi - lo > 1:
        mid = (lo + hi) // 2
        if mixed(mid):
            lo = mid
        else:
            hi = mid
    f = hi
    # Run verification: confirm successors all-miss, predecessors mixed.
    # TWO predecessors, not one — the bisection's landing flag and the
    # f-1 verification would otherwise be the same (possibly fluked) row,
    # and on measuring backends a single drifted launch can scale a whole
    # row across the hit/miss threshold.  Demanding an independent second
    # mixed row squares the fluke probability; any disagreement falls
    # back to the fluke-robust dense sweep.
    if any(mixed(f + k) for k in range(confirm + 1)):
        return dense()
    if any(not mixed(f - k) for k in (1, 2) if f - k >= 0):
        return dense()
    upto = f + confirm + 1
    m = np.zeros(upto, dtype=bool)
    for i, flag in memo.items():
        if i < upto:
            m[i] = flag
    return GranularityResult(int(strides[f]), True, strides[:upto], m)


# --------------------------------------------------------------------------
# §IV-E line-size search
# --------------------------------------------------------------------------
def find_line_size_planned(runner, space: str, cache_size: int,
                           fetch_granularity: int, *, budget: SweepBudget,
                           n_samples: int = 65, over_factor: float = 1.0625,
                           max_line: int = 1024) -> LineSizeResult:
    """Bisection for the first hit-classified step (§IV-E).

    The dense answer is the first step whose distribution sits closer to
    the certain-hit reference than to the certain-miss pivot — again a
    local predicate, structurally monotone (footprint shrinks below
    capacity exactly once as the step grows).  Verified at the flip;
    non-monotone scores fall back to the dense chunked sweep.
    """
    def dense() -> LineSizeResult:
        return find_line_size(runner, space, cache_size, fetch_granularity,
                              n_samples=n_samples, over_factor=over_factor,
                              max_line=max_line, batched=True)

    from ..probes.linesize import hit_scores

    g2 = max(fetch_granularity // 2, 4)
    arr = int(cache_size * over_factor)
    pivot = runner.pchase(space, arr, g2, n_samples)
    hit_ref = runner.pchase(space, arr, max_line * 8, n_samples)
    steps = np.arange(g2, max_line * 2 + g2, g2, dtype=np.int64)
    n = steps.size

    memo: dict[int, float] = {}

    def score(i: int) -> float:
        if i not in memo:
            row = runner.pchase(space, arr, int(steps[i]), n_samples)
            memo[i] = float(hit_scores(row, pivot, hit_ref)[0])
        return memo[i]

    if score(0) > 0:
        # line <= granularity/2: every step hits — but demand independent
        # confirmation before accepting the degenerate answer
        if any(score(k) <= 0 for k in (1, 2) if k < n):
            return dense()
        first_hit_step = int(steps[0])
    elif score(n - 1) <= 0:
        # top step misses: demand an independent second row before the
        # terminal not-found (a single drifted launch must not erase the
        # attribute); disagreement lets dense rule
        if n >= 2 and score(n - 2) > 0:
            return dense()
        return LineSizeResult(-1, False, -1.0, steps,
                              np.array([score(0), score(n - 1)]))
    else:
        lo, hi = 0, n - 1
        while hi - lo > 1:
            mid = (lo + hi) // 2
            if score(mid) > 0:
                hi = mid
            else:
                lo = mid
        # Verify with an extra independent below-flip row (mirrors the
        # granularity planner): non-monotone scores let dense rule.
        if any(score(hi - k) > 0 for k in (1, 2) if hi - k >= 0):
            return dense()
        first_hit_step = int(steps[hi])

    line, raw = line_size_from_first_hit(first_hit_step, over_factor, g2)
    ks = sorted(memo)
    return LineSizeResult(line, True, raw, steps[ks],
                          np.array([memo[i] for i in ks]))
