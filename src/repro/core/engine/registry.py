"""Declarative probe registry: one spec per benchmark family (paper §IV).

Each family — size, fetch granularity, latency, line size, amount, sharing,
bandwidth — is registered with its dependencies on other families'
results, e.g. the line-size probe needs the discovered capacity *and* the
cold-pass fetch granularity.  The engine turns the registry into
(space × family) work items for the scheduler; the run functions hold the
probing policy that used to be inlined in ``discover.discover_sim``
(parameter choices, applicability rules, per-kind step sizes) and return
plain probe results that the discovery driver assembles into a
``Topology``.

All run functions take the engine's batched fast paths (``batched=True``
probe variants, vectorized K-S) — results are bit-identical to the legacy
sequential calls because sample streams are request-keyed.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable

from ..probes.amount import find_amount, find_cu_sharing, find_sharing_batch
from ..probes.bandwidth import measure_bandwidth
from ..probes.latency import measure_latency
from ..probes.linesize import find_fetch_granularity, find_line_size
from ..probes.runners import SpaceInfo
from ..probes.size import find_size

__all__ = ["ProbeContext", "ProbeSpec", "SPACE_FAMILIES", "DEVICE_FAMILIES",
           "space_probe_specs", "device_probe_specs"]

KIB = 1024


@dataclass
class ProbeContext:
    """Everything a probe family needs to run against one memory space."""

    runner: object                      # CachingRunner (batch-capable)
    n_samples: int
    info: SpaceInfo | None = None       # None for device-scope families
    results: dict = field(default_factory=dict)     # family -> result (space)
    all_results: dict = field(default_factory=dict)  # space -> family -> result
    infos: list = field(default_factory=list)        # probed SpaceInfos, in order
    budget: object | None = None        # SweepBudget -> adaptive planner
    resilience: object | None = None    # errors.Resilience -> MAD/resample


@dataclass(frozen=True)
class ProbeSpec:
    """One registered probe family."""

    family: str
    run: Callable[[ProbeContext], object]
    depends: tuple[str, ...] = ()
    applies: Callable[[SpaceInfo], bool] = lambda info: True


# --------------------------------------------------------------------------
# Space-scoped families (run once per probeable memory space)
# --------------------------------------------------------------------------
def _run_size(ctx: ProbeContext):
    info = ctx.info
    # Scratchpads are word-granular: probe them at 4 B steps, caches at the
    # 32 B default until the cold-pass granularity is known (§IV-D).
    step0 = 4 if info.kind == "scratchpad" else 32
    return find_size(ctx.runner, info.name, lo=1 * KIB, step=step0,
                     n_samples=ctx.n_samples, max_bytes=info.max_bytes,
                     batched=True, budget=ctx.budget,
                     robust=ctx.resilience)


def _run_fetch_granularity(ctx: ProbeContext):
    return find_fetch_granularity(ctx.runner, ctx.info.name,
                                  n_samples=ctx.n_samples, batched=True,
                                  budget=ctx.budget)


def _fetch_of(results: dict) -> int:
    gr = results.get("fetch_granularity")
    return gr.granularity if (gr is not None and gr.found) else 32


def _run_latency(ctx: ProbeContext):
    # Small caches: keep the fixed-size latency array inside capacity
    # (paper §IV-C uses 256 x granularity; a 2 KiB constant cache needs a
    # smaller factor).
    sr = ctx.results["size"]
    fetch = _fetch_of(ctx.results)
    factor = 256
    if sr.found:
        factor = max(min(256, sr.size // (2 * fetch)), 8)
    return measure_latency(ctx.runner, ctx.info.name, fetch_granularity=fetch,
                           n_samples=ctx.n_samples * 4 + 1,
                           array_factor=factor)


def _run_line_size(ctx: ProbeContext):
    sr = ctx.results["size"]
    if not (ctx.info.supports_cold and sr.found):
        return None
    return find_line_size(ctx.runner, ctx.info.name, sr.size,
                          _fetch_of(ctx.results), n_samples=ctx.n_samples,
                          batched=True, budget=ctx.budget)


def _run_amount(ctx: ProbeContext):
    info, sr = ctx.info, ctx.results["size"]
    if not sr.found:
        return None
    if info.supports_amount:
        return ("per_core", find_amount(ctx.runner, info.name, sr.size,
                                        ctx.runner.cores_per_sm,
                                        n_samples=ctx.n_samples,
                                        batched=True, budget=ctx.budget))
    if info.scope == "chip":
        # L2-style alignment happens at assembly time (needs the API total);
        # flag that the family applies so the driver runs align_segments.
        return ("aligned", sr.size)
    return None


def _run_bandwidth(ctx: ProbeContext):
    info = ctx.info
    if not (info.scope == "chip" or info.kind == "memory"):
        return None
    return measure_bandwidth(ctx.runner, info.name)


SPACE_FAMILIES: tuple[ProbeSpec, ...] = (
    ProbeSpec("size", _run_size),
    ProbeSpec("fetch_granularity", _run_fetch_granularity,
              applies=lambda info: info.supports_cold),
    ProbeSpec("latency", _run_latency,
              depends=("size", "fetch_granularity")),
    ProbeSpec("line_size", _run_line_size,
              depends=("size", "fetch_granularity"),
              applies=lambda info: info.supports_cold),
    ProbeSpec("amount", _run_amount, depends=("size",),
              applies=lambda info: info.supports_amount
              or info.scope == "chip"),
    ProbeSpec("bandwidth", _run_bandwidth,
              applies=lambda info: info.scope == "chip"
              or info.kind == "memory"),
)


# --------------------------------------------------------------------------
# Device-scoped families (run once per device, after the spaces they read)
# --------------------------------------------------------------------------
def _run_sharing(ctx: ProbeContext):
    """§IV-G pairwise physical sharing over core-scope cache spaces.

    Pair order matches the legacy nested loop (leader a, all partners after
    it), so the assembled ``shared_with`` lists come out identical.  With a
    ``SweepBudget`` on the context the whole leader list goes through the
    planner's partition-closure lattice (``find_sharing_planned``) — same
    pair order, inferred-then-spot-checked rows where transitivity allows.
    """
    spaces = [i.name for i in ctx.infos
              if i.supports_sharing and i.scope == "core"]
    leaders = []
    for i, a in enumerate(spaces):
        sr = ctx.all_results.get(a, {}).get("size")
        if sr is None or not sr.found:
            continue
        leaders.append((a, sr.size, spaces[i + 1:]))
    if ctx.budget is not None:
        from .planner import find_sharing_planned
        return find_sharing_planned(ctx.runner, leaders, ctx.n_samples,
                                    budget=ctx.budget)
    out = []
    for a, size, partners in leaders:
        out.extend(find_sharing_batch(ctx.runner, a, partners, size,
                                      n_samples=ctx.n_samples))
    return out


def _run_cu_sharing(ctx: ProbeContext):
    """§IV-H AMD-style CU<->sL1d sharing groups."""
    sl1d = ctx.all_results.get("sL1d", {}).get("size")
    if sl1d is None or not sl1d.found:
        return None
    cu_ids = ctx.runner.cu_ids()
    if not cu_ids:
        return None
    return find_cu_sharing(ctx.runner, cu_ids, sl1d.size,
                           n_samples=max(ctx.n_samples // 2, 9),
                           batched=True, budget=ctx.budget)


def _run_device_memory_latency(ctx: ProbeContext):
    return measure_latency(ctx.runner, "DeviceMemory", fetch_granularity=4096,
                           n_samples=ctx.n_samples * 4 + 1, array_factor=4096)


def _run_device_memory_bandwidth(ctx: ProbeContext):
    return measure_bandwidth(ctx.runner, "DeviceMemory")


DEVICE_FAMILIES: tuple[ProbeSpec, ...] = (
    ProbeSpec("sharing", _run_sharing),
    ProbeSpec("cu_sharing", _run_cu_sharing),
    ProbeSpec("device_memory_latency", _run_device_memory_latency),
    ProbeSpec("device_memory_bandwidth", _run_device_memory_bandwidth),
)


def space_probe_specs(info: SpaceInfo) -> list[ProbeSpec]:
    """The families applicable to one memory space, dependency-complete."""
    return [spec for spec in SPACE_FAMILIES if spec.applies(info)]


def device_probe_specs() -> tuple[ProbeSpec, ...]:
    return DEVICE_FAMILIES
