"""Dependency-aware work-item scheduler for the probe engine.

Discovery decomposes into (memory space × probe family) work items with a
small dependency DAG (line size needs size + fetch granularity; sharing
needs every partner's size; ...).  The scheduler runs all ready items
concurrently on a thread pool and releases dependents as their inputs
complete.

Correctness does not depend on scheduling: probe sample streams are keyed
by request (see ``simulate._KeyedSampler``), so any execution order — and
any ``max_workers`` — produces identical results.  The per-family wall
times are accumulated into the same ``DiscoveryTimings`` buckets the legacy
sequential loop reports (a sum of item durations, matching the paper's
§V-A per-family accounting).
"""
from __future__ import annotations

import threading
import time
from concurrent.futures import FIRST_COMPLETED, ThreadPoolExecutor, wait
from dataclasses import dataclass, field
from typing import Any, Callable, Hashable

from ..errors import TransientRunnerError

__all__ = ["WorkItem", "ScheduleResult", "run_work_items", "check_items"]


@dataclass(frozen=True)
class WorkItem:
    """One schedulable unit of discovery work.

    ``fn`` receives the results-so-far mapping (keyed like ``key``) and
    returns the item's result; it must only read keys listed in ``deps``.
    """

    key: Hashable
    fn: Callable[[dict], Any]
    deps: tuple = ()
    family: str = ""                # DiscoveryTimings bucket


@dataclass
class ScheduleResult:
    """Scheduler output: item results, completion order, wall time, and the
    fault-tolerance tallies (transient retries spent, items degraded)."""

    results: dict = field(default_factory=dict)
    order: list = field(default_factory=list)    # completion order
    wall_seconds: float = 0.0
    retries: int = 0                             # transient retries spent
    degraded: list = field(default_factory=list)  # keys past the budget


def check_items(items: list[WorkItem]) -> dict:
    """Validate keys/deps; returns the key->item map (shared with fusion)."""
    by_key = {it.key: it for it in items}
    if len(by_key) != len(items):
        raise ValueError("duplicate work-item keys")
    for it in items:
        unknown = [d for d in it.deps if d not in by_key]
        if unknown:
            raise ValueError(f"{it.key}: unknown deps {unknown}")
    return by_key


def run_work_items(items: list[WorkItem], *, max_workers: int | None = None,
                   timings=None, fuser=None, resilience=None,
                   on_exhausted=None, on_item_done=None,
                   parallel=None) -> ScheduleResult:
    """Execute ``items`` respecting dependencies; returns results + order.

    ``max_workers=0`` runs everything inline on the calling thread in
    topological order — no pool, no locks.  This is both the profiling mode
    and the fastest mode on GIL-bound runners with few cores; results are
    identical either way (request-keyed sampling).  ``max_workers=None``
    picks a pool size from the CPU count, staying inline on boxes where
    threads can only fight over the GIL.

    ``fuser`` (a ``fusion.FusionDispatcher``) switches to round-based
    cross-family batch fusion: ready items run concurrently but every
    probe dispatch is coalesced and executed serially by the coordinator —
    see ``engine/fusion.py``.  ``max_workers`` is ignored in that mode.

    Fault tolerance (``resilience``, an ``errors.Resilience``): an item
    raising ``TransientRunnerError`` is re-attempted up to
    ``resilience.max_retries`` times with capped exponential backoff.  Past
    the budget, if ``resilience.degrade`` and ``on_exhausted`` is given,
    ``on_exhausted(item, exc, attempts)`` supplies the item's stand-in
    result (recorded in ``ScheduleResult.degraded``) and scheduling
    continues; otherwise the error propagates as before.  Non-transient
    exceptions always propagate — a deterministic bug must not be retried
    into a topology.  ``on_item_done(key)`` fires after each item lands
    (the checkpoint write-through hook); it runs on the coordinating
    thread in every mode, so callbacks need no locking.

    ``parallel`` (an ``engine.parallel.ParallelConfig``) signals that the
    items' probe calls shard across the multiprocess pool (the engine
    wrapped the runner in a ``ParallelRunner`` before building the items).
    It replaces the GIL-bound thread mode: with ``max_workers=None`` the
    schedule then runs inline on the coordinator — real concurrency
    happens row-wise inside the worker processes, where numpy doesn't
    fight this process's GIL — and results are identical either way.

    Raises on unknown dependencies or cycles (both indicate a registry bug,
    not a runtime condition worth limping through).
    """
    if fuser is not None:
        from .fusion import run_fused

        return run_fused(items, fuser, timings=timings,
                         resilience=resilience, on_exhausted=on_exhausted,
                         on_item_done=on_item_done)

    by_key = check_items(items)

    out = ScheduleResult()
    t_start = time.perf_counter()
    pending = dict(by_key)
    lock = threading.Lock()

    def ready(it: WorkItem) -> bool:
        return all(d in out.results for d in it.deps)

    def run_one(it: WorkItem):
        t0 = time.perf_counter()
        value = it.fn(out.results)
        dt = time.perf_counter() - t0
        if timings is not None and it.family:
            with lock:
                timings.add(it.family, dt)
        return value

    def attempt(it: WorkItem):
        """``run_one`` under the resilience policy: retry transients with
        capped backoff, then degrade (via ``on_exhausted``) or re-raise."""
        attempts = 0
        while True:
            try:
                return run_one(it)
            except TransientRunnerError as exc:
                if resilience is None:
                    raise
                if attempts >= resilience.max_retries:
                    if resilience.degrade and on_exhausted is not None:
                        with lock:
                            out.degraded.append(it.key)
                        return on_exhausted(it, exc, attempts + 1)
                    raise
                resilience.sleep(resilience.backoff(attempts))
                attempts += 1
                with lock:
                    out.retries += 1

    if max_workers is None:
        if parallel is not None:
            # Pooled mode: batched probe calls already shard across worker
            # processes, so coordinator threads would only add GIL traffic.
            max_workers = 0
        else:
            from .parallel import effective_cpu_count

            # numpy probe work mostly holds the GIL: a pool only pays off
            # when there are spare cores for the pieces that do release it.
            # Effective cores, not os.cpu_count(): a cgroup CPU quota or
            # affinity mask must not be answered with 8 fighting threads.
            cores = effective_cpu_count()
            max_workers = min(8, cores - 2) if cores > 3 else 0

    if max_workers == 0:
        while pending:
            ready_now = [it for it in pending.values() if ready(it)]
            if not ready_now:
                raise ValueError("dependency cycle among work items: "
                                 f"{sorted(map(str, pending))}")
            for it in ready_now:
                out.results[it.key] = attempt(it)
                out.order.append(it.key)
                del pending[it.key]
                if on_item_done is not None:
                    on_item_done(it.key)
        out.wall_seconds = time.perf_counter() - t_start
        return out

    with ThreadPoolExecutor(max_workers=max_workers) as pool:
        futures = {}
        for it in list(pending.values()):
            if ready(it):
                futures[pool.submit(attempt, it)] = it
                del pending[it.key]
        while futures:
            done, _ = wait(list(futures), return_when=FIRST_COMPLETED)
            for fut in done:
                it = futures.pop(fut)
                out.results[it.key] = fut.result()   # re-raises item errors
                out.order.append(it.key)
                if on_item_done is not None:
                    on_item_done(it.key)
            for it in list(pending.values()):
                if ready(it):
                    futures[pool.submit(attempt, it)] = it
                    del pending[it.key]
        if pending:
            raise ValueError(
                f"dependency cycle among work items: {sorted(map(str, pending))}")

    out.wall_seconds = time.perf_counter() - t_start
    return out
