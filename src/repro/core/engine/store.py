"""Persistent, content-addressed topology store (the serving back end).

Discovery results become durable artifacts here: a finished ``Topology``
(plus request metadata and per-family timings) and the engine's
``SampleCache`` entries are persisted on disk, keyed by a hash of the
*discovery request* — the same signature the engine already uses to key
sample streams (``simulate._KeyedSampler``).  Because simulated runners draw
request-keyed samples, a stored topology is byte-for-byte what re-running
the request would produce, so repeated discovery of a known device is a pure
cache hit: ``discover_sim(store=...)`` returns the stored topology without
touching the runner at all.

Layout under the store root::

    topologies/<key>.json   # {"meta": {...}, "topology": Topology.to_json()}
    samples/<key>.npz       # SampleCache entries (manifest + row arrays)
    checkpoints/<key>.npz   # in-progress discovery state (resume path)
    corrupt/                # quarantined unreadable files (recovery path)

Writes are atomic (temp file + ``os.replace``); reads that hit corrupted
files quarantine them into ``corrupt/`` and report a miss, so a damaged
store degrades to re-discovery instead of failing the request.

Concurrent discoveries on one store additionally take an **advisory write
lock** (``fcntl.flock`` on ``<root>/.lock``; an exclusive-create lockfile
where ``fcntl`` is unavailable): atomic replace already keeps individual
files intact, but a discovery persists a topology *and* its sample archive
as a pair, and two processes interleaving those writes could leave a
topology from one run next to samples from another.  ``lock()`` is
re-entrant within a thread, so callers can span multi-file transactions
while the store's own writes stay safe when used bare.
"""
from __future__ import annotations

import contextlib
import hashlib
import io
import json
import os
import threading
import time
import zipfile
from dataclasses import dataclass, field

import numpy as np

from ..topology import Topology

__all__ = ["TopologyStore", "StoredTopology", "StoreLock", "GcPolicy",
           "request_key"]

SCHEMA_VERSION = 1

try:
    import fcntl
except ImportError:                                    # non-POSIX fallback
    fcntl = None


class StoreLock:
    """Advisory, re-entrant, cross-process write lock for one store root.

    POSIX: ``flock`` on a dedicated lock file — released automatically by
    the OS if the holder dies, so no stale-lock handling is needed.
    Fallback: an exclusive-create lockfile holding the owner pid, polled
    with a timeout; locks older than ``stale_seconds`` whose recorded
    holder pid is verifiably dead are broken (the holder crashed before
    unlinking).

    File locks only order *processes* reliably: ``flock`` semantics between
    two descriptors in one process are platform-dependent (fcntl-emulated
    flock — NFS mounts, some libcs — treats record locks as per-process, so
    a second thread "acquires" immediately).  A process-wide
    ``threading.Lock`` layered *under* the file lock serializes threads
    first, so the file lock only ever arbitrates between processes.

    The stale break is liveness-checked and atomic (``_break_stale``): a
    lock whose holder pid is still alive is never broken regardless of
    age, and the break renames the lockfile aside and verifies (by stat
    identity) that the renamed file is the one it sampled — so a breaker
    racing a fresh acquisition can never unlink a lockfile another holder
    just created, the race the pre-fix docstring documented.
    """

    def __init__(self, path: str, *, timeout: float = 30.0,
                 poll: float = 0.05, stale_seconds: float = 300.0):
        self.path = path
        self.timeout = timeout
        self.poll = poll
        self.stale_seconds = stale_seconds
        self._tls = threading.local()
        self._thread_gate = threading.Lock()

    @property
    def _depth(self) -> int:
        return getattr(self._tls, "depth", 0)

    def acquire(self) -> None:
        if self._depth:                                # re-entrant
            self._tls.depth += 1
            return
        self._thread_gate.acquire()                    # threads first...
        if fcntl is not None:                          # ...then processes
            fd = os.open(self.path, os.O_CREAT | os.O_RDWR, 0o644)
            fcntl.flock(fd, fcntl.LOCK_EX)
            self._tls.fd = fd
        else:
            deadline = time.monotonic() + self.timeout
            while True:
                try:
                    fd = os.open(self.path,
                                 os.O_CREAT | os.O_EXCL | os.O_WRONLY, 0o644)
                    os.write(fd, str(os.getpid()).encode())
                    self._tls.fd = fd
                    break
                except FileExistsError:
                    if self._break_stale():
                        continue
                    if time.monotonic() > deadline:
                        self._thread_gate.release()
                        raise TimeoutError(
                            f"store lock busy for >{self.timeout}s: "
                            f"{self.path}")
                    time.sleep(self.poll)
        self._tls.depth = 1

    def _break_stale(self) -> bool:
        """Safely break a stale fallback lockfile; True = retry the acquire.

        Guards (in order) against the documented race where an age-only
        break unlinks a lockfile another holder just created:

        1. a lock younger than ``stale_seconds`` is never touched;
        2. a lock whose recorded holder pid is still alive is never
           touched, regardless of age (a long critical section is not a
           crash);
        3. the break renames the lockfile aside and verifies by stat
           identity (inode + mtime) that the renamed file is the one it
           sampled — a mismatch means a fresh sibling lock was displaced,
           and it is restored via ``os.link`` (which cannot clobber a
           newer lockfile) instead of being destroyed.
        """
        try:
            st = os.stat(self.path)
        except OSError:
            return True                    # holder released: retry at once
        if time.time() - st.st_mtime <= self.stale_seconds:
            return False
        pid = None
        try:
            with open(self.path) as f:
                pid = int(f.read().strip() or "0")
        except (OSError, ValueError):
            pid = None                     # unreadable pid: treat as dead
        if pid:
            try:
                os.kill(pid, 0)
                return False               # holder alive: never break
            except ProcessLookupError:
                pass                       # verifiably dead: break below
            except OSError:
                return False               # alive under another uid, etc.
        trash = f"{self.path}.stale.{os.getpid()}.{threading.get_ident()}"
        try:
            os.rename(self.path, trash)
        except OSError:
            return True                    # lost the break race: retry
        restored = False
        try:
            st2 = os.stat(trash)
            if (st2.st_ino, st2.st_mtime_ns) != (st.st_ino, st.st_mtime_ns):
                # We displaced a FRESH lock created after our stat: put it
                # back (link fails harmlessly if yet another lock appeared
                # meanwhile — it never overwrites).
                with contextlib.suppress(OSError):
                    os.link(trash, self.path)
                restored = True
        except OSError:
            pass
        with contextlib.suppress(OSError):
            os.unlink(trash)
        return not restored

    def release(self) -> None:
        depth = self._depth
        if depth > 1:
            self._tls.depth = depth - 1
            return
        fd = getattr(self._tls, "fd", None)
        self._tls.depth = 0
        self._tls.fd = None
        if fd is None:
            return
        try:
            if fcntl is not None:
                fcntl.flock(fd, fcntl.LOCK_UN)
                os.close(fd)
            else:
                os.close(fd)
                with contextlib.suppress(OSError):
                    os.unlink(self.path)
        finally:
            self._thread_gate.release()

    @property
    def held(self) -> bool:
        return self._depth > 0

    def __enter__(self) -> "StoreLock":
        self.acquire()
        return self

    def __exit__(self, *exc) -> bool:
        self.release()
        return False


def request_key(descriptor: dict) -> str:
    """Content address of a discovery request.

    The descriptor must contain everything that determines the result
    (device identity + seed, sample count, element restriction) and nothing
    that does not (worker counts, engine vs legacy — both produce
    bit-identical topologies).  The store's schema version is folded in
    here, so a schema bump invalidates every old key instead of serving
    old-layout documents.
    """
    blob = json.dumps({"_schema": SCHEMA_VERSION, **descriptor},
                      sort_keys=True, separators=(",", ":"),
                      default=str).encode()
    return hashlib.blake2b(blob, digest_size=16).hexdigest()


@dataclass(frozen=True)
class GcPolicy:
    """Retention policy for ``TopologyStore.gc`` / ``discover(gc_policy=)``.

    ``max_entries`` keeps at most that many newest topologies;
    ``max_age_s`` evicts entries whose ``created_at`` is older than the
    horizon.  Both are opt-in (None = unlimited), and eviction always
    removes the topology *and* its sample archive as one pair.
    """

    max_entries: int | None = None
    max_age_s: float | None = None


@dataclass
class StoredTopology:
    """One store entry: the topology plus its request/provenance metadata."""

    key: str
    topology: Topology
    meta: dict = field(default_factory=dict)


class TopologyStore:
    """Content-addressed on-disk store for discovered topologies + samples."""

    def __init__(self, root: str):
        self.root = os.path.abspath(root)
        self._topo_dir = os.path.join(self.root, "topologies")
        self._samples_dir = os.path.join(self.root, "samples")
        self._ckpt_dir = os.path.join(self.root, "checkpoints")
        self._corrupt_dir = os.path.join(self.root, "corrupt")
        for d in (self._topo_dir, self._samples_dir, self._ckpt_dir):
            os.makedirs(d, exist_ok=True)
        self._lock = StoreLock(os.path.join(self.root, ".lock"))
        self.hits = 0
        self.misses = 0
        self.corrupt = 0

    def lock(self) -> StoreLock:
        """The store's advisory write lock (re-entrant context manager).

        Individual ``put``/``put_samples``/``delete`` calls take it on
        their own; wrap multi-file transactions — a topology plus its
        sample archive — in one ``with store.lock():`` block so concurrent
        discoveries cannot interleave the pair.
        """
        return self._lock

    # ------------------------------------------------------------- paths
    def _topo_path(self, key: str) -> str:
        return os.path.join(self._topo_dir, f"{key}.json")

    def _samples_path(self, key: str) -> str:
        return os.path.join(self._samples_dir, f"{key}.npz")

    def _ckpt_path(self, key: str) -> str:
        return os.path.join(self._ckpt_dir, f"{key}.npz")

    @staticmethod
    def _atomic_write(path: str, data: bytes) -> None:
        tmp = f"{path}.tmp.{os.getpid()}"
        with open(tmp, "wb") as f:
            f.write(data)
        os.replace(tmp, path)

    def _quarantine(self, path: str) -> None:
        """Move an unreadable file aside so the key reads as a miss."""
        os.makedirs(self._corrupt_dir, exist_ok=True)
        dest = os.path.join(self._corrupt_dir,
                            f"{os.path.basename(path)}.{int(time.time())}")
        try:
            os.replace(path, dest)
        except OSError:
            pass
        self.corrupt += 1

    # --------------------------------------------------------- topologies
    def put(self, key: str, topo: Topology, meta: dict | None = None) -> str:
        """Persist a topology under ``key``; returns the key.

        ``meta`` is merged over the defaults derived from the topology
        (model/vendor/backend identity, creation time, schema version) —
        the query service filters and ranks entries on these fields.
        """
        doc_meta = {
            "schema": SCHEMA_VERSION,
            "model": topo.model,
            "vendor": topo.vendor,
            "backend": topo.backend,
            "created_at": time.time(),
        }
        if meta:
            doc_meta.update(meta)
        doc = {"meta": doc_meta, "topology": topo.to_json()}
        with self._lock:
            self._atomic_write(self._topo_path(key),
                               json.dumps(doc, sort_keys=True).encode())
        return key

    def _read_doc(self, key: str) -> dict | None:
        """Raw store document, quarantining unreadable files; no counters."""
        path = self._topo_path(key)
        if not os.path.exists(path):
            return None
        try:
            with open(path, "rb") as f:
                doc = json.loads(f.read())
            if not isinstance(doc, dict) or "topology" not in doc:
                raise KeyError("topology")
            return doc
        except (json.JSONDecodeError, KeyError, TypeError, UnicodeDecodeError,
                OSError):
            self._quarantine(path)
            return None

    def get(self, key: str) -> StoredTopology | None:
        """Load a stored topology; corrupted entries quarantine + miss.

        The hit/miss counters track this key-addressed serving path only —
        meta scans (``index``/``find``) do not inflate them.
        """
        doc = self._read_doc(key)
        if doc is not None:
            try:
                topo = Topology.from_json(doc["topology"])
            except (KeyError, TypeError, AttributeError):
                self._quarantine(self._topo_path(key))
                doc = None
        if doc is None:
            self.misses += 1
            return None
        self.hits += 1
        return StoredTopology(key=key, topology=topo, meta=doc.get("meta", {}))

    def has(self, key: str) -> bool:
        return os.path.exists(self._topo_path(key))

    def generation(self, key: str) -> tuple | None:
        """Opaque freshness token for ``key``'s on-disk document, or None
        when the key has no document (never stored, GC'd, or quarantined).

        Derived from the file's stat identity (mtime_ns + size + inode), so
        it changes on every ``put`` — including cross-process writers the
        in-process service never saw — and disappears on eviction.  Callers
        caching deserialized topologies (``TopologyService``'s LRU) compare
        tokens to decide whether a cached object may still be served.
        """
        try:
            st = os.stat(self._topo_path(key))
        except OSError:
            return None
        return (st.st_mtime_ns, st.st_size, st.st_ino)

    def is_quarantined(self, key: str) -> bool:
        """True when ``key``'s topology document was moved to ``corrupt/``
        (and no fresh document has replaced it) — the serving layer maps
        this to 503-retry-later rather than 404-unknown."""
        if self.has(key):
            return False
        prefix = f"{key}.json."
        try:
            names = os.listdir(self._corrupt_dir)
        except OSError:
            return False
        return any(n.startswith(prefix) for n in names)

    def delete(self, key: str) -> None:
        """Remove every artifact of ``key``: topology, samples, checkpoint."""
        with self._lock:
            for path in (self._topo_path(key), self._samples_path(key),
                         self._ckpt_path(key)):
                try:
                    os.remove(path)
                except FileNotFoundError:
                    pass

    def keys(self) -> list[str]:
        return sorted(os.path.splitext(f)[0]
                      for f in os.listdir(self._topo_dir)
                      if f.endswith(".json"))

    def index(self) -> list[tuple[str, dict]]:
        """``(key, meta)`` for every readable entry — a meta-only scan that
        skips topology deserialization and leaves the serving counters
        untouched (corrupted files still quarantine)."""
        out = []
        for key in self.keys():
            doc = self._read_doc(key)
            if doc is not None:
                out.append((key, doc.get("meta", {})))
        return out

    def entries(self) -> list[StoredTopology]:
        """All readable entries (corrupted files are quarantined, not raised)."""
        out = []
        for key in self.keys():
            entry = self.get(key)
            if entry is not None:
                out.append(entry)
        return out

    def find(self, *, model: str | None = None, vendor: str | None = None,
             backend: str | None = None) -> list[StoredTopology]:
        """Entries matching the given identity fields, newest first.

        Filters on the meta index, then loads only the matching topologies.
        """
        matches = [(key, meta) for key, meta in self.index()
                   if (model is None or meta.get("model") == model)
                   and (vendor is None or meta.get("vendor") == vendor)
                   and (backend is None or meta.get("backend") == backend)]
        matches.sort(key=lambda km: km[1].get("created_at", 0.0), reverse=True)
        out = []
        for key, _meta in matches:
            entry = self.get(key)
            if entry is not None:
                out.append(entry)
        return out

    # ------------------------------------------------------------ samples
    def put_samples(self, key: str, entries: dict) -> None:
        """Persist ``SampleCache`` entries: tuple keys -> sample arrays.

        Keys are flat tuples of str/int (the runner request signatures);
        they serialize through a JSON manifest, arrays positionally.
        """
        manifest = []
        arrays = {}
        for i, (k, arr) in enumerate(entries.items()):
            manifest.append(list(k))
            arrays[f"a{i}"] = np.asarray(arr)
        buf = io.BytesIO()
        np.savez_compressed(buf, manifest=json.dumps(manifest), **arrays)
        with self._lock:
            self._atomic_write(self._samples_path(key), buf.getvalue())

    def load_samples(self, key: str) -> dict | None:
        """Load persisted sample entries; corrupted archives miss (+quarantine)."""
        path = self._samples_path(key)
        if not os.path.exists(path):
            return None
        try:
            with np.load(path, allow_pickle=False) as data:
                manifest = json.loads(str(data["manifest"]))
                return {tuple(k): data[f"a{i}"]
                        for i, k in enumerate(manifest)}
        except (ValueError, KeyError, OSError, json.JSONDecodeError,
                zipfile.BadZipFile):
            self._quarantine(path)
            return None

    # -------------------------------------------------------- checkpoints
    def put_checkpoint(self, key: str, entries: dict,
                       families: list | None = None) -> None:
        """Persist an in-progress discovery's state under ``key``.

        ``entries`` is the live ``SampleCache`` snapshot (tuple keys ->
        sample arrays) and ``families`` the completed work-item keys, so an
        interrupted ``discover()`` resumes by preloading the rows and — via
        the request-keyed cache — re-probes zero of them.  Written
        atomically under the store lock, same as the sample archive it
        will become.
        """
        manifest = []
        arrays = {}
        for i, (k, arr) in enumerate(entries.items()):
            manifest.append(list(k))
            arrays[f"a{i}"] = np.asarray(arr)
        buf = io.BytesIO()
        np.savez_compressed(buf, manifest=json.dumps(manifest),
                            families=json.dumps([list(f) if isinstance(f, (list, tuple)) else f
                                                 for f in (families or [])]),
                            **arrays)
        with self._lock:
            self._atomic_write(self._ckpt_path(key), buf.getvalue())

    def load_checkpoint(self, key: str) -> tuple[dict, list] | None:
        """``(entries, completed families)`` for ``key``, or None.

        Corrupted checkpoints quarantine and miss — a damaged checkpoint
        degrades to a from-scratch run, never a crash.
        """
        path = self._ckpt_path(key)
        if not os.path.exists(path):
            return None
        try:
            with np.load(path, allow_pickle=False) as data:
                manifest = json.loads(str(data["manifest"]))
                families = json.loads(str(data["families"]))
                entries = {tuple(k): data[f"a{i}"]
                           for i, k in enumerate(manifest)}
            return entries, [tuple(f) if isinstance(f, list) else f
                             for f in families]
        except (ValueError, KeyError, OSError, json.JSONDecodeError,
                zipfile.BadZipFile):
            self._quarantine(path)
            return None

    def clear_checkpoint(self, key: str) -> None:
        """Drop ``key``'s checkpoint (called after a successful persist)."""
        with self._lock:
            try:
                os.remove(self._ckpt_path(key))
            except FileNotFoundError:
                pass

    def has_checkpoint(self, key: str) -> bool:
        """True while an interrupted discovery's checkpoint exists."""
        return os.path.exists(self._ckpt_path(key))

    # ----------------------------------------------------------------- gc
    def gc(self, *, max_entries: int | None = None,
           max_age_s: float | None = None,
           now: float | None = None) -> dict:
        """Retention sweep: evict oldest entries beyond the given ceilings.

        Ranking is oldest-``created_at``-first (entries without a readable
        timestamp rank oldest, so damaged metadata cannot pin an entry
        forever).  Each eviction removes the topology document and its
        sample archive as one pair; orphaned sample archives (samples whose
        topology is gone — e.g. after a quarantine) are swept as well.
        Checkpoints are deliberately NOT swept as orphans: they exist
        precisely for keys that have no topology yet (an interrupted
        discovery awaiting resume); they are removed by ``delete`` /
        ``clear_checkpoint``.  The
        whole sweep runs under the store's advisory write lock so a
        concurrent discovery cannot interleave a persist with the unlink
        pair.  Returns ``{"evicted": [keys...], "kept": n, "orphans": n}``.
        """
        now = time.time() if now is None else now
        with self._lock:
            aged = sorted(self.index(),
                          key=lambda km: km[1].get("created_at", 0.0))
            evict: list[str] = []
            if max_age_s is not None:
                horizon = now - max_age_s
                evict.extend(k for k, meta in aged
                             if meta.get("created_at", 0.0) < horizon)
            if max_entries is not None and len(aged) - len(evict) > max_entries:
                overflow = len(aged) - len(evict) - max_entries
                remaining = [k for k, _ in aged if k not in set(evict)]
                evict.extend(remaining[:overflow])
            for key in evict:
                self.delete(key)
            # orphaned sample archives: samples/<key>.npz without a topology
            orphans = 0
            live = set(self.keys())
            for f in os.listdir(self._samples_dir):
                if not f.endswith(".npz"):
                    continue
                key = os.path.splitext(f)[0]
                if key not in live:
                    try:
                        os.remove(os.path.join(self._samples_dir, f))
                        orphans += 1
                    except FileNotFoundError:
                        pass
            return {"evicted": evict, "kept": len(live), "orphans": orphans}

    # -------------------------------------------------------------- stats
    def stats(self) -> dict:
        return {"hits": self.hits, "misses": self.misses,
                "corrupt": self.corrupt, "entries": len(self.keys())}
