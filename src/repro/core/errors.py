"""Shared fault-tolerance vocabulary for the probe and engine layers.

``TransientRunnerError`` started life in ``serve/jobs.py`` as the job
engine's retry trigger; promoting it here lets the *discovery engine*
retry individual work items on the same taxonomy without the core layers
importing from ``serve`` (the dependency arrow must point serve -> core,
never back).  ``serve/jobs.py`` keeps a compat re-export.

The module also defines the two small value types the resilience path is
built from:

* ``Resilience`` — the per-discovery fault-tolerance policy: how many
  retries a work item gets, how backoff grows, whether exhausted items
  degrade or abort, and the opt-in statistical hardening knobs (MAD
  outlier gating, ambiguity-driven resampling) threaded into the K-S
  adjudication path.
* ``DegradedResult`` — the sentinel an exhausted work item leaves in the
  engine results.  It ducks as "probe found nothing" (``found=False``)
  through every downstream family, so dependents skip it instead of
  crashing, and assembly maps it to an ``unknown`` attribute with
  ``provenance="degraded"`` plus diagnostics.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable

__all__ = ["TransientRunnerError", "Resilience", "DegradedResult"]


class TransientRunnerError(Exception):
    """A runner failure worth retrying: drift spikes, device contention,
    a flaky interconnect — anything where re-running the same request has
    a real chance of succeeding.  Deterministic errors must NOT subclass
    this; the engine fails them on the first attempt."""


@dataclass(frozen=True)
class Resilience:
    """Fault-tolerance policy for one discovery run.

    Retry semantics (scheduler + fusion dispatcher): a work item that
    raises ``TransientRunnerError`` is re-attempted up to ``max_retries``
    times, sleeping ``min(backoff_cap_s, backoff_base_s * 2**attempt)``
    between attempts (``backoff_base_s`` defaults to 0 so simulated runs
    and tests never sleep).  When the budget is exhausted: if ``degrade``
    is True the item lands as a ``DegradedResult`` and discovery
    continues; otherwise the error propagates (the pre-resilience
    behavior).

    Statistical hardening (opt-in, default off — defaults preserve
    bit-identical topologies): ``mad_k`` enables MAD-based outlier gating
    of probe sample rows before K-S adjudication; ``resample_band`` and
    ``resample_extra`` enable confidence-driven adaptive resampling —
    when the K-S statistic lands within ``resample_band`` of the critical
    value, ``resample_extra`` additional samples are drawn before the
    verdict.  Only these knobs affect results, so only they fold into
    the store request descriptor (``descriptor_entry``).
    """

    max_retries: int = 2
    backoff_base_s: float = 0.0
    backoff_cap_s: float = 2.0
    degrade: bool = True
    sleep: Callable[[float], None] = field(default=time.sleep, repr=False,
                                           compare=False)
    mad_k: float | None = None
    resample_band: float = 0.0
    resample_extra: int = 0

    def backoff(self, attempt: int) -> float:
        """Seconds to sleep before retry number ``attempt`` (0-based)."""
        return min(self.backoff_cap_s, self.backoff_base_s * (2 ** attempt))

    def descriptor_entry(self) -> dict | None:
        """The result-affecting knobs as a descriptor fragment, or None.

        Retry/backoff settings never change *what* a probe measures, only
        whether it survives faults — so they stay out of the store key and
        a resilient rerun of a clean request is a pure store hit.  The
        statistical knobs do change the sample stream; when any is active
        the fragment makes the request key distinct.
        """
        if self.mad_k is None and not self.resample_extra:
            return None
        return {"mad_k": self.mad_k, "resample_band": self.resample_band,
                "resample_extra": self.resample_extra}


@dataclass(frozen=True)
class DegradedResult:
    """What an attribute's slot holds after its probes exhausted retries.

    ``found=False`` makes it duck-type as a no-result through dependent
    probe families (they all check ``.found`` before consuming), and the
    assembly layer turns it into an ``unknown`` attribute with
    ``provenance="degraded"`` carrying ``error``/``attempts`` diagnostics.
    """

    family: str
    key: str
    error: str
    attempts: int
    found: bool = False
