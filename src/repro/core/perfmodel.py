"""Hong & Kim warp-parallelism performance model (paper §VI-A, eqs. 3-4).

MT4G's first integration scenario: the GPU-specific parameters of the
CWP/MWP analytical model (mem_latency, mem_bandwidth, mem_freq, active
warps, ...) are supplied by topology discovery instead of datasheets. We
implement the model faithfully and parameterize it from either a
``HardwareSpec`` (catalog) or a discovered ``Topology``.

On TPU, "warps" map to the per-core vector-lane pipeline; we keep the paper's
vocabulary since the model itself is vendor-agnostic arithmetic. The verdict
(CWP > MWP -> memory-bound) is the same quantity the roofline analyzer
cross-checks via HLO byte/FLOP counts.
"""
from __future__ import annotations

from dataclasses import dataclass

__all__ = ["AppParams", "GpuParams", "PerfModelResult", "evaluate",
           "gpu_params_from_topology"]


@dataclass(frozen=True)
class AppParams:
    """Application-specific parameters (profiling side)."""

    comp_cycles: float            # compute cycles per warp between mem ops
    mem_cycles: float             # memory waiting cycles per warp
    loads_per_warp: float         # memory insts issued per warp
    active_warps_per_sm: float    # occupancy


@dataclass(frozen=True)
class GpuParams:
    """GPU/TPU-specific parameters — the MT4G-supplied side."""

    mem_latency: float            # cycles (discovered: load_latency)
    mem_bandwidth: float          # bytes/s (discovered: read_bw)
    mem_freq: float               # Hz
    departure_delay: float        # cycles between consecutive mem requests
    bytes_per_load: float = 128.0


@dataclass(frozen=True)
class PerfModelResult:
    cwp: float
    mwp: float
    mwp_prime: float
    mwp_bw_bound: float
    memory_bound: bool
    est_cycles_per_warp_batch: float


def evaluate(app: AppParams, gpu: GpuParams) -> PerfModelResult:
    """Paper eqs. 3-4 plus the Hong&Kim cycle estimate."""
    n = max(app.active_warps_per_sm, 1.0)

    cwp_prime = (app.mem_cycles + app.comp_cycles) / max(app.comp_cycles, 1e-9)
    cwp = min(cwp_prime, n)

    mwp_prime = gpu.mem_latency / max(gpu.departure_delay, 1e-9)
    # MWP'' — bandwidth ceiling: how many warps the memory system can feed.
    per_warp_bw = (gpu.mem_freq * app.loads_per_warp * gpu.bytes_per_load
                   / max(gpu.mem_latency, 1e-9))
    mwp_bw = gpu.mem_bandwidth / max(per_warp_bw * n, 1e-9) * n
    mwp = min(mwp_prime, mwp_bw, n)

    # Hong & Kim case analysis: CWP > MWP -> memory bound; the saturated
    # case CWP == MWP == N is also the memory-limited regime (their Eq. 24),
    # hence >= rather than > .
    memory_bound = cwp >= mwp
    # Hong & Kim total-cycle estimates (simplified two-regime form).
    if memory_bound:
        est = app.mem_cycles * n / max(mwp, 1e-9)
    else:
        est = app.mem_cycles + app.comp_cycles * n
    return PerfModelResult(cwp=cwp, mwp=mwp, mwp_prime=mwp_prime,
                           mwp_bw_bound=mwp_bw, memory_bound=memory_bound,
                           est_cycles_per_warp_batch=est)


def gpu_params_from_topology(topo, mem_element: str = "DeviceMemory",
                             clock_hz: float = 1.0e9,
                             departure_delay: float = 4.0) -> GpuParams:
    """Build the GPU-side parameters from a discovered ``Topology`` —
    the paper's 'obtain GPU-specific parameters via MT4G' step."""
    me = topo.find_memory(mem_element)
    if me is None:
        raise KeyError(f"topology has no memory element '{mem_element}'")
    lat = float(me.get("load_latency", 500.0))
    bw = float(me.get("read_bw", 100.0)) * 1e9  # stored in GB/s
    return GpuParams(mem_latency=lat, mem_bandwidth=bw, mem_freq=clock_hz,
                     departure_delay=departure_delay)
