"""Microbenchmark probes (paper contribution C2)."""
from .runners import (HostRunner, ProbeRunner, SimRunner, SpaceInfo,
                      random_cycle, sattolo_cycle)
from .chaos import ChaosRunner, FaultSchedule
from .pallas_runner import PallasRunner, make_pallas_model
from .size import SizeResult, find_size
from .latency import LatencyResult, measure_latency
from .linesize import (GranularityResult, LineSizeResult,
                       find_fetch_granularity, find_line_size, snap_pow2)
from .amount import (AmountResult, CuSharingResult, SharingResult,
                     align_segments, find_amount, find_cu_sharing, find_sharing)
from .bandwidth import (BandwidthResult, CollectiveEstimate, all_to_all_time,
                        measure_bandwidth, measure_collective,
                        ring_all_gather_time, ring_all_reduce_time)
from .adjacency import AdjacencyResult, SimPod, find_link_adjacency

__all__ = [
    "ChaosRunner", "FaultSchedule",
    "HostRunner", "PallasRunner", "ProbeRunner", "SimRunner", "SpaceInfo",
    "make_pallas_model", "random_cycle", "sattolo_cycle",
    "SizeResult", "find_size", "LatencyResult", "measure_latency",
    "GranularityResult", "LineSizeResult", "find_fetch_granularity",
    "find_line_size", "snap_pow2",
    "AmountResult", "CuSharingResult", "SharingResult", "align_segments",
    "find_amount", "find_cu_sharing", "find_sharing",
    "BandwidthResult", "CollectiveEstimate", "all_to_all_time",
    "measure_bandwidth", "measure_collective", "ring_all_gather_time",
    "ring_all_reduce_time",
    "AdjacencyResult", "SimPod", "find_link_adjacency",
]
