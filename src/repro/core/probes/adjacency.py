"""ICI link-adjacency discovery — the pod-level analogue of paper §IV-H.

MT4G answers "which CU ids share one sL1d" by pairwise eviction probes. On a
TPU pod the corresponding topological unknown is "which chips share a direct
ICI link" (vs. multi-hop routed paths): the same pairwise measurement shape,
with ``collective_permute`` latency as the signal instead of cache eviction.

Workflow (mirrors find_cu_sharing):
  1. measure the pairwise one-hop permute latency for chip pairs;
  2. the sorted pairwise latencies form a stepped series (1 hop, 2 hops, ...);
     the K-S change point on that series separates direct links from routed
     paths — no assumptions about the torus shape are made;
  3. report the adjacency list; the mesh builder can verify its axes map
     onto physical neighbors (mis-wired "model" axes show up immediately).

Runners: ``SimPod`` (ground-truth torus with latency noise/outliers — the
validation path in this container) or a live backend that times
``jax.lax.ppermute`` pairs (the measurement is wall-clock around a jitted
permute, per DESIGN.md adaptation note 1).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from ..stats import ks_change_point, pelt_segments

__all__ = ["SimPod", "AdjacencyResult", "find_link_adjacency"]


@dataclass
class SimPod:
    """Virtual pod: chips on a (rows, cols) 2-D torus with per-hop latency."""

    rows: int
    cols: int
    hop_latency_us: float = 2.0
    routing_overhead_us: float = 1.0     # per extra hop
    noise_us: float = 0.15
    outlier_prob: float = 0.005
    outlier_scale: float = 20.0
    seed: int = 0

    def __post_init__(self):
        self._rng = np.random.default_rng(self.seed)

    @property
    def n_chips(self) -> int:
        return self.rows * self.cols

    def _coords(self, chip: int) -> tuple[int, int]:
        return divmod(chip, self.cols)

    def hops(self, a: int, b: int) -> int:
        (ra, ca), (rb, cb) = self._coords(a), self._coords(b)
        dr = min(abs(ra - rb), self.rows - abs(ra - rb))   # torus wraparound
        dc = min(abs(ca - cb), self.cols - abs(ca - cb))
        return dr + dc

    def neighbors(self, chip: int) -> list[int]:
        return sorted(b for b in range(self.n_chips)
                      if b != chip and self.hops(chip, b) == 1)

    def permute_latency(self, a: int, b: int, n_samples: int) -> np.ndarray:
        h = self.hops(a, b)
        mean = h * self.hop_latency_us + max(h - 1, 0) * self.routing_overhead_us
        lat = self._rng.normal(mean, self.noise_us, n_samples)
        mask = self._rng.random(n_samples) < self.outlier_prob
        lat[mask] *= self.outlier_scale
        return np.maximum(lat, 0.05)


@dataclass
class AdjacencyResult:
    neighbors: dict[int, list[int]]          # chip -> direct-link peers
    threshold_us: float                      # detected 1-hop/2-hop boundary
    found: bool
    pair_latency: dict[tuple[int, int], float] = field(default_factory=dict)

    def degree(self, chip: int) -> int:
        return len(self.neighbors.get(chip, []))


def find_link_adjacency(pod, chips: list[int] | None = None,
                        n_samples: int = 9, alpha: float = 0.01
                        ) -> AdjacencyResult:
    """Pairwise permute sweep -> K-S change point on sorted medians ->
    direct-link adjacency (no torus-shape assumptions, like §IV-H makes no
    CU-layout assumptions)."""
    chips = chips if chips is not None else list(range(pod.n_chips))
    med: dict[tuple[int, int], float] = {}
    for i, a in enumerate(chips):
        for b in chips[i + 1:]:
            lat = pod.permute_latency(a, b, n_samples)
            med[(a, b)] = float(np.median(lat))   # outlier-robust per §IV-C

    values = np.array(sorted(med.values()))
    # The sorted series is MULTI-step (1/2/3... hop groups): PELT segments
    # all of them; the FIRST boundary separates direct links from routed
    # paths. (A single K-S change point finds the most significant split,
    # which on a large torus is a mid-hop boundary — measured and rejected
    # in development; PELT is one of the paper's 'other algorithms'.)
    # Log space: hop latencies are multiplicative groups; in linear space
    # the BIC penalty (global variance) can swallow the small 1-hop group on
    # skewed tori (2xN), merging it with 2-hop.
    cps = pelt_segments(np.log(values))
    if cps:
        idx = cps[0]
    else:
        cp = ks_change_point(values, alpha=alpha, min_segment=2)
        if not cp.found or cp.index <= 0:
            return AdjacencyResult({}, -1.0, False, med)
        idx = cp.index
    threshold = float((values[idx - 1] + values[idx]) / 2.0)

    neighbors: dict[int, list[int]] = {c: [] for c in chips}
    for (a, b), m in med.items():
        if m < threshold:
            neighbors[a].append(b)
            neighbors[b].append(a)
    return AdjacencyResult({c: sorted(v) for c, v in neighbors.items()},
                           threshold, True, med)
