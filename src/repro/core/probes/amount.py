"""Amount (paper §IV-F), L2-segment alignment (§IV-F.1), physical-sharing
(§IV-G NVIDIA-style, §IV-H AMD-style) probes.

All three share the warm-A / warm-B / probe-A eviction pattern of paper
Fig. 3; hit-vs-miss classification reuses the K-S test against hit/miss
reference distributions rather than ad-hoc thresholds.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from ..stats import classify_miss_rows, ks_2samp

__all__ = ["AmountResult", "find_amount", "amount_ladder", "align_segments",
           "SharingResult", "find_sharing", "find_sharing_batch",
           "CuSharingResult", "find_cu_sharing"]


def _hit_miss_refs(runner, space: str, arr: int, cache_size: int,
                   n_samples: int) -> tuple[np.ndarray, np.ndarray]:
    """The Fig. 3 step-3 reference pair: a warm chase that surely hits and a
    beyond-capacity chase that surely misses.

    Issued as ONE ``pchase_many`` call on runners with the fused-batch
    capability (one dispatch — and one fusion round — instead of two), with
    per-row request keys identical to the two sequential ``pchase`` calls,
    so results are unchanged everywhere."""
    if hasattr(runner, "pchase_many"):
        rows = np.asarray(runner.pchase_many(
            [(space, arr // 4, 32), (space, cache_size * 4, 32)], n_samples))
        return rows[0], rows[1]
    return (runner.pchase(space, arr // 4, 32, n_samples),
            runner.pchase(space, cache_size * 4, 32, n_samples))


def _is_miss(probe: np.ndarray, hit_ref: np.ndarray, miss_ref: np.ndarray,
             alpha: float = 0.01) -> bool:
    """Classify a step-3 distribution: closer to the miss or the hit regime."""
    differs_from_hit = ks_2samp(probe, hit_ref, alpha=alpha).reject
    differs_from_miss = ks_2samp(probe, miss_ref, alpha=alpha).reject
    if differs_from_hit and not differs_from_miss:
        return True
    if differs_from_miss and not differs_from_hit:
        return False
    # Ambiguous -> fall back to median proximity, in LOG space: drift on
    # measuring backends scales a whole row multiplicatively, and the log
    # distance keeps the hit/miss midpoint drift-symmetric (a linear
    # midpoint sits nearer the miss side and misreads deflated miss rows).
    pm, hm, mm = (max(float(np.median(x)), 1e-12)
                  for x in (probe, hit_ref, miss_ref))
    return abs(np.log(pm / mm)) < abs(np.log(pm / hm))


@dataclass(frozen=True)
class AmountResult:
    amount: int
    found: bool
    first_disjoint_core: int    # first core-B index that did NOT evict core A
    tested_cores: list[int] = field(default_factory=list)


def amount_ladder(cores_per_sm: int) -> list[int]:
    """The §IV-F core-B doubling ladder: 1, 2, 4, ... below cores_per_sm."""
    bs = []
    b = 1
    while b < cores_per_sm:
        bs.append(b)
        b *= 2
    return bs


def find_amount(runner, space: str, cache_size: int, cores_per_sm: int,
                n_samples: int = 65, batched: bool = False,
                budget=None) -> AmountResult:
    """Paper §IV-F: pin core A at 0, double core B's index; the first B index
    on a different segment leaves A's data resident -> amount = cores/B.

    ``batched=True`` probes every B doubling up front and classifies the
    whole matrix with one vectorized K-S pass; the sequential early-exit
    semantics are replayed on the classification vector, so results are
    identical (request-keyed sampling makes the extra probes side-effect
    free).  The whole ladder goes out as ONE ``eviction_many`` grid call on
    runners with the eviction capability — one dispatch (and one fusion
    round) instead of one per doubling.

    ``budget`` (a ``SweepBudget``) routes to the adaptive planner's
    bisected ladder (``find_amount_planned``) — same discrete answer,
    fewer probed rows, dense fallback on non-monotonicity.
    """
    if budget is not None:
        from ..engine.planner import find_amount_planned
        return find_amount_planned(runner, space, cache_size, cores_per_sm,
                                   n_samples=n_samples, budget=budget)
    arr = int(cache_size * 0.9)  # "close to the cache size"
    hit_ref, miss_ref = _hit_miss_refs(runner, space, arr, cache_size,
                                       n_samples)

    if batched:
        bs = amount_ladder(cores_per_sm)
        if not bs:
            return AmountResult(1, True, -1, [])
        if hasattr(runner, "eviction_many"):
            rows = np.asarray(runner.eviction_many(
                [("amount", space, 0, b, arr) for b in bs], n_samples))
        else:
            rows = np.stack([runner.amount_probe(space, 0, b, arr, n_samples)
                             for b in bs])
        miss = classify_miss_rows(rows, hit_ref, miss_ref)
        tested = []
        for b, m in zip(bs, miss):
            tested.append(b)
            if not m:
                return AmountResult(max(cores_per_sm // b, 1), True, b, tested)
        return AmountResult(1, True, -1, tested)

    tested = []
    b = 1
    while b < cores_per_sm:
        tested.append(b)
        probe = runner.amount_probe(space, 0, b, arr, n_samples)
        if not _is_miss(probe, hit_ref, miss_ref):
            return AmountResult(max(cores_per_sm // b, 1), True, b, tested)
        b *= 2
    return AmountResult(1, True, -1, tested)


def align_segments(api_total: int, measured_segment: int) -> tuple[int, int, float]:
    """Paper §IV-F.1: align the measured L2 segment size to the nearest
    integer fraction of the API-reported total.

    Returns (num_segments, aligned_segment_size, confidence in [0,1]) where
    confidence reflects the distance from the nearest integer fraction.
    """
    if measured_segment <= 0 or api_total <= 0:
        return 1, api_total, 0.0
    ratio = api_total / measured_segment
    k = max(int(round(ratio)), 1)
    err = abs(ratio - k) / max(ratio, 1e-9)
    return k, api_total // k, max(0.0, 1.0 - 2.0 * err)


@dataclass(frozen=True)
class SharingResult:
    shared: bool
    space_a: str
    space_b: str


def find_sharing(runner, space_a: str, space_b: str, cache_size: int,
                 n_samples: int = 65) -> SharingResult:
    """Paper §IV-G: warm A, warm B, probe A on one core — misses mean the two
    logical spaces occupy the same physical cache."""
    arr = int(cache_size * 0.9)
    hit_ref = runner.pchase(space_a, arr // 4, 32, n_samples)
    miss_ref = runner.pchase(space_a, cache_size * 4, 32, n_samples)
    probe = runner.sharing_probe(space_a, space_b, arr, n_samples)
    return SharingResult(_is_miss(probe, hit_ref, miss_ref), space_a, space_b)


def find_sharing_batch(runner, space_a: str, space_bs: list[str],
                       cache_size: int,
                       n_samples: int = 65) -> list[SharingResult]:
    """All §IV-G partners of ``space_a`` in one probe matrix + one vectorized
    classification.  Equivalent to ``[find_sharing(runner, space_a, b, ...)
    for b in space_bs]`` — same reference keys, same per-pair probe keys."""
    if not space_bs:
        return []
    arr = int(cache_size * 0.9)
    hit_ref, miss_ref = _hit_miss_refs(runner, space_a, arr, cache_size,
                                       n_samples)
    if hasattr(runner, "eviction_many"):
        rows = np.asarray(runner.eviction_many(
            [("sharing", space_a, b, arr) for b in space_bs], n_samples))
    else:
        rows = np.stack([runner.sharing_probe(space_a, b, arr, n_samples)
                         for b in space_bs])
    miss = classify_miss_rows(rows, hit_ref, miss_ref)
    return [SharingResult(bool(m), space_a, b)
            for m, b in zip(miss, space_bs)]


@dataclass(frozen=True)
class CuSharingResult:
    groups: list[list[int]]          # CU ids sharing one sL1d
    exclusive: list[int]             # CUs with a whole sL1d to themselves


def find_cu_sharing(runner, cu_ids: list[int], cache_size: int,
                    n_samples: int = 33, space: str = "sL1d",
                    batched: bool = False, budget=None) -> CuSharingResult:
    """Paper §IV-H: test CU pairs for sL1d sharing; no layout assumptions.

    The full pairwise sweep is O(n^2); like MT4G we test all pairs (the paper
    notes this explicitly) but short-circuit once a CU is already grouped.

    ``batched=True`` (probe-engine path) probes one leader's whole candidate
    row at once and classifies it with a single vectorized K-S pass — the
    dominant cost of MI210-style discovery drops from ~2 K-S tests per pair
    to 2 matrix operations per group.  The candidate set a leader sees is
    the same as in the sequential scan (CUs grouped during a leader's own
    scan are exactly the ones that probe as sharing), so the grouping is
    identical.

    ``budget`` (a ``SweepBudget``) routes to the adaptive planner's
    hypothesis-first pairwise lattice (``find_cu_sharing_planned``) — spot
    checked per group, dense candidate row on any disagreement.
    """
    if budget is not None:
        from ..engine.planner import find_cu_sharing_planned
        return find_cu_sharing_planned(runner, cu_ids, cache_size,
                                       n_samples=n_samples, space=space,
                                       budget=budget)
    arr = int(cache_size * 0.9)
    hit_ref, miss_ref = _hit_miss_refs(runner, space, arr, cache_size,
                                       n_samples)

    assigned: dict[int, int] = {}
    groups: list[list[int]] = []
    for i, cu_a in enumerate(cu_ids):
        if cu_a in assigned:
            continue
        group = [cu_a]
        assigned[cu_a] = len(groups)
        candidates = [cu_b for cu_b in cu_ids[i + 1:] if cu_b not in assigned]
        if batched and candidates:
            if hasattr(runner, "cu_sharing_probe_batch"):
                rows = np.asarray(runner.cu_sharing_probe_batch(
                    cu_a, candidates, arr, n_samples, space=space))
            else:
                rows = np.stack([runner.cu_sharing_probe(cu_a, cu_b, arr,
                                                         n_samples,
                                                         space=space)
                                 for cu_b in candidates])
            miss = classify_miss_rows(rows, hit_ref, miss_ref)
            for cu_b, m in zip(candidates, miss):
                if m:
                    group.append(cu_b)
                    assigned[cu_b] = assigned[cu_a]
        else:
            for cu_b in candidates:
                probe = runner.cu_sharing_probe(cu_a, cu_b, arr, n_samples,
                                                space=space)
                if _is_miss(probe, hit_ref, miss_ref):
                    group.append(cu_b)
                    assigned[cu_b] = assigned[cu_a]
        groups.append(group)
    exclusive = [g[0] for g in groups if len(g) == 1]
    return CuSharingResult(groups, exclusive)
