"""Bandwidth benchmarks (paper §IV-I) and collective probes (TPU extension).

The stream-pattern bandwidth probe is delegated to the runner (SimDevice
returns its configured value with noise; HostRunner times a jitted reduction/
copy; the TPU-target Pallas version lives in ``repro.kernels.stream_probe``).

``collective.py``-style probes are included here: on a real pod they time
``jax.lax`` collectives per mesh axis; without hardware they evaluate the
standard ring/bidirectional-torus analytic models against catalog constants —
the same numbers the roofline's collective term uses.
"""
from __future__ import annotations

import time
from dataclasses import dataclass

import numpy as np

__all__ = ["BandwidthResult", "measure_bandwidth",
           "CollectiveEstimate", "ring_all_reduce_time", "ring_all_gather_time",
           "all_to_all_time", "measure_collective"]


@dataclass(frozen=True)
class BandwidthResult:
    read_bw: float      # bytes/s
    write_bw: float     # bytes/s


def measure_bandwidth(runner, space: str) -> BandwidthResult:
    return BandwidthResult(
        read_bw=float(runner.bandwidth(space, "read")),
        write_bw=float(runner.bandwidth(space, "write")),
    )


# --------------------------------------------------------------------------
# Collective probes / analytic models
# --------------------------------------------------------------------------
@dataclass(frozen=True)
class CollectiveEstimate:
    op: str
    bytes_moved: int
    n_devices: int
    seconds: float
    effective_bw: float     # bytes/s seen by one device


def ring_all_reduce_time(nbytes: int, n: int, link_bw: float) -> float:
    """Ring all-reduce: 2(n-1)/n * bytes across the slowest link."""
    if n <= 1:
        return 0.0
    return 2.0 * (n - 1) / n * nbytes / link_bw


def ring_all_gather_time(nbytes_per_shard: int, n: int, link_bw: float) -> float:
    """Ring all-gather of n shards of ``nbytes_per_shard``."""
    if n <= 1:
        return 0.0
    return (n - 1) * nbytes_per_shard / link_bw


def all_to_all_time(nbytes_total: int, n: int, link_bw: float) -> float:
    """All-to-all where each device exchanges 1/n of its data with each peer;
    on a ring/torus the bisection constrains it to ~bytes*(n-1)/n / bw."""
    if n <= 1:
        return 0.0
    return (n - 1) / n * nbytes_total / link_bw


def measure_collective(op: str, nbytes: int, axis_size: int,
                       link_bw: float, repeats: int = 3) -> CollectiveEstimate:
    """Measure a collective across the live devices if >1 exist, otherwise
    fall back to the analytic torus model (documented provenance)."""
    import jax
    import jax.numpy as jnp

    devs = jax.devices()
    if len(devs) >= axis_size > 1:
        from jax.sharding import NamedSharding, PartitionSpec as P

        from repro.compat import make_mesh, shard_map

        mesh = make_mesh((axis_size,), ("x",))
        x = jnp.ones((axis_size, max(nbytes // 4 // axis_size, 1)), jnp.float32)
        x = jax.device_put(x, NamedSharding(mesh, P("x")))

        if op == "all_reduce":
            body = lambda v: jax.lax.psum(v, "x")
            out_spec = P("x")
        elif op == "all_gather":
            body = lambda v: jax.lax.all_gather(v, "x")
            out_spec = P("x")
        else:
            raise ValueError(f"unsupported live collective '{op}'")
        mapped = jax.jit(shard_map(body, mesh=mesh, in_specs=P("x"),
                                   out_specs=out_spec))
        mapped(x).block_until_ready()
        best = np.inf
        for _ in range(repeats):
            t0 = time.perf_counter_ns()
            mapped(x).block_until_ready()
            best = min(best, time.perf_counter_ns() - t0)
        secs = best * 1e-9
    else:
        if op == "all_reduce":
            secs = ring_all_reduce_time(nbytes, axis_size, link_bw)
        elif op == "all_gather":
            secs = ring_all_gather_time(nbytes // max(axis_size, 1), axis_size,
                                        link_bw)
        else:
            secs = all_to_all_time(nbytes, axis_size, link_bw)
    secs = max(secs, 1e-12)
    return CollectiveEstimate(op, nbytes, axis_size, secs, nbytes / secs)
