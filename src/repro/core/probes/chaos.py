"""Chaos-injected probe runner: deterministic fault schedules over any backend.

The paper's claim is *reliable* discovery on noisy hardware; the resilience
machinery that backs it (engine retry, fused-round splitting, graceful
degradation, checkpoint/resume) needs faults on demand to be testable
without a flaky GPU.  ``ChaosRunner`` wraps any ``ProbeRunner`` and injects
a seeded, replayable fault schedule:

* **transient raises** — ``TransientRunnerError`` on single probes, with a
  per-request fault budget so a retried request eventually succeeds;
* **batch faults** — the same, on ``pchase_many``/``cold_chase_many``/
  ``eviction_many``/``*_batch`` fused dispatches, exercising the fusion
  dispatcher's split-and-retry path;
* **permanent faults** — call kinds listed in ``permanent_kinds`` raise on
  *every* attempt, driving an attribute past the retry budget into the
  ``provenance="degraded"`` path;
* **value perturbations** — per-sample multiplicative jitter, outlier
  spikes, and a sustained throttle ramp, feeding the MAD gating and
  adaptive-resampling hardening;
* **a kill switch** — ``kill_after=N`` raises a non-transient error once
  ``N`` probes have run, simulating a mid-discovery crash for the
  checkpoint/resume path.

Every decision is a pure function of ``(schedule.seed, call signature,
per-signature attempt index)`` — never of wall time or global RNG state —
so two runners with the same schedule replay the same faults on the same
call sequence, and perturbations are keyed per row signature so
batch == loop equivalence survives jitter.  A default (zero-fault)
schedule is a bit-exact passthrough.
"""
from __future__ import annotations

import hashlib
import os
from dataclasses import dataclass

import numpy as np

from ..errors import TransientRunnerError

__all__ = ["ChaosRunner", "FaultSchedule", "build_chaos_runner"]


def build_chaos_runner(base_spec, schedule) -> "ChaosRunner":
    """Rebuild a ``ChaosRunner`` over its base's spec (pool-worker side)."""
    return ChaosRunner(base_spec.build(), schedule)


@dataclass(frozen=True)
class FaultSchedule:
    """A seeded, replayable description of what goes wrong and when.

    All rates are probabilities in ``[0, 1]`` evaluated per call (or per
    sample for ``outlier_rate``) from a hash of the call signature — not
    from mutable RNG state — so replay is exact.  The default instance
    injects nothing and perturbs nothing.
    """

    seed: int = 0
    #: probability a single probe call raises ``TransientRunnerError``
    transient_rate: float = 0.0
    #: per-request-signature cap on injected transient faults (a retried
    #: request passes once its budget is spent)
    max_faults_per_request: int = 1
    #: probability a fused batch dispatch raises ``TransientRunnerError``
    batch_fault_rate: float = 0.0
    #: lognormal per-sample timing noise (sigma of ``exp(jitter * N(0,1))``)
    jitter: float = 0.0
    #: probability an individual sample is an outlier spike
    outlier_rate: float = 0.0
    #: multiplier applied to outlier samples
    outlier_scale: float = 8.0
    #: probe-call count after which a throttle ramp starts (None = never)
    throttle_after: int | None = None
    #: fractional slowdown added per call past ``throttle_after``
    throttle_slope: float = 0.0
    #: call kinds ("pchase", "cold", "amount", "sharing", "cu",
    #: "bandwidth") that fault on EVERY attempt — the degradation driver
    permanent_kinds: tuple = ()
    #: global probe-call count after which every call raises a
    #: non-transient ``RuntimeError`` — the mid-discovery kill switch
    kill_after: int | None = None
    #: probe-call count after which the runner hard-exits the *process* —
    #: but only inside a parallel-pool worker (``MT4G_POOL_WORKER`` env),
    #: simulating a crashed worker mid-shard.  The coordinator-side twin
    #: of the same schedule ignores it, so the pool's crash containment
    #: (respawn + ``TransientRunnerError`` + resilience retry) is what
    #: gets exercised, and the retry — served by a fresh worker whose
    #: call count restarts — converges.
    kill_worker_after: int | None = None

    @property
    def value_preserving(self) -> bool:
        """True when the schedule never alters sample values (it may still
        raise) — the condition under which a wrapped deterministic runner
        stays deterministic."""
        return (self.jitter == 0.0 and self.outlier_rate == 0.0
                and self.throttle_after is None)


class ChaosRunner:
    """``ProbeRunner`` wrapper injecting a ``FaultSchedule`` over any base.

    Implements the full protocol surface (including the fused
    ``pchase_many``/``eviction_many`` capabilities and the SimRunner
    extras ``cu_sharing_probe``/``api_size``/``cu_ids``) by gating each
    call through the schedule and delegating to the base runner.
    Counters (``calls``, ``faults_injected``, ``batch_faults``,
    ``base_calls``) make fault/recovery behavior assertable in tests and
    benches.
    """

    def __init__(self, base, schedule: FaultSchedule | None = None):
        self.base = base
        self.schedule = schedule or FaultSchedule()
        self.calls = 0
        self.faults_injected = 0
        self.batch_faults = 0
        self.base_calls: dict[str, int] = {}
        self._attempts: dict[str, int] = {}
        self._faulted: dict[str, int] = {}

    @property
    def deterministic(self) -> bool:
        """Bit-identical replay: requires a deterministic base AND a
        value-preserving schedule (faults may raise, never skew)."""
        return (bool(getattr(self.base, "deterministic", False))
                and self.schedule.value_preserving)

    # ------------------------------------------------------------ schedule
    def _uniform(self, *parts) -> float:
        """Deterministic uniform draw in [0, 1) keyed by the call parts."""
        blob = repr((self.schedule.seed,) + parts).encode()
        h = hashlib.blake2b(blob, digest_size=8).digest()
        return int.from_bytes(h, "big") / 2.0 ** 64

    def _rng(self, *parts) -> np.random.Generator:
        blob = repr((self.schedule.seed,) + parts).encode()
        h = hashlib.blake2b(blob, digest_size=8).digest()
        return np.random.default_rng(int.from_bytes(h, "big"))

    def _count(self, kind: str) -> None:
        self.calls += 1
        self.base_calls[kind] = self.base_calls.get(kind, 0) + 1
        sch = self.schedule
        if sch.kill_after is not None and self.calls > sch.kill_after:
            raise RuntimeError(
                f"chaos kill: probe call {self.calls} is past the "
                f"kill_after={sch.kill_after} horizon")
        if (sch.kill_worker_after is not None
                and os.environ.get("MT4G_POOL_WORKER")
                and self.calls > sch.kill_worker_after):
            # Hard process death, not an exception: the pool must detect
            # the broken pipe, respawn, and surface a transient fault.
            os._exit(17)

    def _gate(self, kind: str, sig: tuple) -> None:
        """Count one single-probe call; raise per the schedule."""
        self._count(kind)
        sch = self.schedule
        if kind in sch.permanent_kinds:
            self.faults_injected += 1
            raise TransientRunnerError(f"chaos permanent fault: {kind} {sig}")
        key = repr(sig)
        attempt = self._attempts[key] = self._attempts.get(key, 0) + 1
        if (self._faulted.get(key, 0) < sch.max_faults_per_request
                and self._uniform("fault", sig, attempt - 1)
                < sch.transient_rate):
            self._faulted[key] = self._faulted.get(key, 0) + 1
            self.faults_injected += 1
            raise TransientRunnerError(
                f"chaos transient fault: {sig} (attempt {attempt})")

    def _gate_batch(self, kind: str, sig: tuple, row_kinds=()) -> None:
        """Count one fused dispatch; raise per the batch schedule."""
        self._count(kind)
        sch = self.schedule
        # Permanent faults fire on the batch capability itself OR on any
        # row kind it carries (a fused grid with one doomed family fails
        # as a whole — the dispatcher's split path sorts out the rows).
        for rk in (kind, *row_kinds):
            if rk in sch.permanent_kinds:
                self.batch_faults += 1
                self.faults_injected += 1
                raise TransientRunnerError(
                    f"chaos permanent fault in fused batch: {rk}")
        key = repr(sig)
        attempt = self._attempts[key] = self._attempts.get(key, 0) + 1
        if (self._faulted.get(key, 0) < sch.max_faults_per_request
                and self._uniform("batch-fault", sig, attempt - 1)
                < sch.batch_fault_rate):
            self._faulted[key] = self._faulted.get(key, 0) + 1
            self.batch_faults += 1
            self.faults_injected += 1
            raise TransientRunnerError(
                f"chaos batch fault: {kind} (attempt {attempt})")

    def _perturb(self, arr, sig: tuple):
        """Apply jitter/outliers/throttle to one row, keyed by its request
        signature so identical requests (and fused rows vs. single calls)
        perturb identically."""
        sch = self.schedule
        throttled = (sch.throttle_after is not None
                     and self.calls > sch.throttle_after)
        if sch.jitter == 0.0 and sch.outlier_rate == 0.0 and not throttled:
            return arr
        out = np.asarray(arr, dtype=float).copy()
        rng = self._rng("perturb", sig)
        if sch.jitter:
            out *= np.exp(sch.jitter * rng.standard_normal(out.shape))
        if sch.outlier_rate:
            mask = rng.random(out.shape) < sch.outlier_rate
            out[mask] *= sch.outlier_scale
        if throttled:
            out *= 1.0 + sch.throttle_slope * (self.calls - sch.throttle_after)
        return out

    # ------------------------------------------------------------ protocol
    def spaces(self):
        """Structural query — never gated, never perturbed."""
        return self.base.spaces()

    def pchase(self, space, array_bytes, stride, n_samples):
        """Warm p-chase with chaos gating + per-row perturbation."""
        sig = ("pchase", space, int(array_bytes), int(stride), int(n_samples))
        self._gate("pchase", sig)
        out = self.base.pchase(space, array_bytes, stride, n_samples)
        return self._perturb(out, sig)

    def pchase_batch(self, space, array_bytes_list, stride, n_samples):
        """Size-sweep batch; faults via the batch schedule, rows perturbed
        under their single-call signatures (batch == loop holds)."""
        sig = ("pchase_batch", space, tuple(int(a) for a in array_bytes_list),
               int(stride), int(n_samples))
        self._gate_batch("pchase_batch", sig)
        out = np.asarray(self.base.pchase_batch(space, array_bytes_list,
                                                stride, n_samples))
        rows = [self._perturb(out[i], ("pchase", space, int(ab), int(stride),
                                       int(n_samples)))
                for i, ab in enumerate(array_bytes_list)]
        return np.stack(rows)

    def cold_chase(self, space, array_bytes, stride, n_samples):
        """Cold-pass chase with chaos gating + perturbation."""
        sig = ("cold", space, int(array_bytes), int(stride), int(n_samples))
        self._gate("cold", sig)
        out = self.base.cold_chase(space, array_bytes, stride, n_samples)
        return self._perturb(out, sig)

    def cold_chase_batch(self, space, array_bytes_list, stride_list,
                         n_samples):
        """Granularity stride-sweep batch under the batch schedule."""
        sig = ("cold_batch", space, tuple(int(a) for a in array_bytes_list),
               tuple(int(s) for s in stride_list), int(n_samples))
        self._gate_batch("cold_batch", sig)
        out = self.base.cold_chase_batch(space, array_bytes_list, stride_list,
                                         n_samples)
        rows = [self._perturb(np.asarray(out[i]),
                              ("cold", space, int(ab), int(st),
                               int(n_samples)))
                for i, (ab, st) in enumerate(zip(array_bytes_list,
                                                 stride_list))]
        return rows if isinstance(out, list) else np.stack(rows)

    def pchase_many(self, requests, n_samples):
        """Cross-family fused batch — the fusion dispatcher's main target."""
        reqs = [(sp, int(ab), int(st)) for sp, ab, st in requests]
        sig = ("pchase_many", tuple(reqs), int(n_samples))
        self._gate_batch("pchase_many", sig)
        out = np.asarray(self.base.pchase_many(reqs, n_samples))
        rows = [self._perturb(out[i], ("pchase", sp, ab, st, int(n_samples)))
                for i, (sp, ab, st) in enumerate(reqs)]
        return np.stack(rows)

    def cold_chase_many(self, requests, n_samples):
        """Fused heterogeneous cold-pass batch."""
        reqs = [(sp, int(ab), int(st)) for sp, ab, st in requests]
        sig = ("cold_many", tuple(reqs), int(n_samples))
        self._gate_batch("cold_many", sig)
        out = self.base.cold_chase_many(reqs, n_samples)
        rows = [self._perturb(np.asarray(out[i]),
                              ("cold", sp, ab, st, int(n_samples)))
                for i, (sp, ab, st) in enumerate(reqs)]
        return rows if isinstance(out, list) else np.stack(rows)

    def amount_probe(self, space, core_a, core_b, array_bytes, n_samples):
        """§IV-F amount probe with chaos gating."""
        sig = ("amount", space, int(core_a), int(core_b), int(array_bytes),
               int(n_samples))
        self._gate("amount", sig)
        out = self.base.amount_probe(space, core_a, core_b, array_bytes,
                                     n_samples)
        return self._perturb(out, sig)

    def sharing_probe(self, space_a, space_b, array_bytes, n_samples):
        """§IV-G sharing probe with chaos gating."""
        sig = ("sharing", space_a, space_b, int(array_bytes), int(n_samples))
        self._gate("sharing", sig)
        out = self.base.sharing_probe(space_a, space_b, array_bytes,
                                      n_samples)
        return self._perturb(out, sig)

    def cu_sharing_probe(self, cu_a, cu_b, array_bytes, n_samples,
                         space="sL1d"):
        """§IV-H CU sharing probe (delegates; raises if the base lacks it)."""
        sig = ("cu", space, int(cu_a), int(cu_b), int(array_bytes),
               int(n_samples))
        self._gate("cu", sig)
        out = self.base.cu_sharing_probe(cu_a, cu_b, array_bytes, n_samples,
                                         space=space)
        return self._perturb(out, sig)

    def cu_sharing_probe_batch(self, cu_a, cu_bs, array_bytes, n_samples,
                               space="sL1d"):
        """Batched CU sharing probe under the batch schedule."""
        sig = ("cu_batch", space, int(cu_a), tuple(int(b) for b in cu_bs),
               int(array_bytes), int(n_samples))
        self._gate_batch("cu_batch", sig, row_kinds=("cu",))
        out = np.asarray(self.base.cu_sharing_probe_batch(
            cu_a, cu_bs, array_bytes, n_samples, space=space))
        rows = [self._perturb(out[i], ("cu", space, int(cu_a), int(b),
                                       int(array_bytes), int(n_samples)))
                for i, b in enumerate(cu_bs)]
        return np.stack(rows)

    def eviction_many(self, requests, n_samples):
        """Mixed amount/sharing/cu eviction grid under the batch schedule.

        A permanent-kind row faults the whole dispatch (transiently), which
        is exactly what drives the dispatcher's split-into-singles path —
        where the offending row keeps faulting and the rest succeed.
        """
        reqs = [tuple(v if isinstance(v, str) else int(v) for v in r)
                for r in requests]
        sig = ("eviction_many", tuple(reqs), int(n_samples))
        self._gate_batch("eviction_many", sig,
                         row_kinds=tuple({r[0] for r in reqs}))
        out = np.asarray(self.base.eviction_many(reqs, n_samples))
        rows = []
        for i, r in enumerate(reqs):
            row_sig = tuple(r) + (int(n_samples),)
            rows.append(self._perturb(out[i], row_sig))
        return np.stack(rows)

    def bandwidth(self, space, mode="read"):
        """Streaming bandwidth with chaos gating (scalar perturbation)."""
        sig = ("bandwidth", space, mode)
        self._gate("bandwidth", sig)
        out = float(self.base.bandwidth(space, mode))
        return float(np.asarray(self._perturb(np.asarray([out]), sig))[0])

    # ----------------------------------------------------- optional extras
    def api_size(self, space):
        """API-reported capacity, when the base exposes it (else None)."""
        fn = getattr(self.base, "api_size", None)
        return fn(space) if fn is not None else None

    def cu_ids(self):
        """CU ids participating in sharing groups ([] for single-actor
        bases, which keeps the engine from scheduling cu probes)."""
        fn = getattr(self.base, "cu_ids", None)
        return fn() if fn is not None else []

    @property
    def cores_per_sm(self):
        """Delegated; AttributeError propagates when the base lacks it, so
        ``hasattr`` checks see the base's true capability."""
        return self.base.cores_per_sm

    def runner_spec(self):
        """Rebuild recipe for pool workers: the base's spec wrapped with
        this schedule (``FaultSchedule`` is frozen and picklable), or None
        when the base publishes none.  Fault *gating* counters are
        per-process, so worker-side fault timing differs from inline —
        sample values never do (perturbations are signature-keyed)."""
        fn = getattr(self.base, "runner_spec", None)
        base_spec = fn() if fn is not None else None
        if base_spec is None:
            return None
        from ..engine.parallel import RunnerSpec

        return RunnerSpec(build_chaos_runner, (base_spec, self.schedule))
