"""Load-latency benchmark (paper §IV-C).

A p-chase with one fixed, small array (256 x fetch granularity — guaranteed to
fit the target element after warm-up) whose per-load times *are* the result.
We report the mean plus the statistics set the paper lists (p50, p95, stddev).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

__all__ = ["LatencyResult", "measure_latency"]


@dataclass(frozen=True)
class LatencyResult:
    mean: float
    p50: float
    p95: float
    std: float
    n: int


def measure_latency(runner, space: str, fetch_granularity: int = 32,
                    n_samples: int = 257, array_factor: int = 256) -> LatencyResult:
    arr = int(array_factor * fetch_granularity)
    lats = np.asarray(runner.pchase(space, arr, fetch_granularity, n_samples),
                      dtype=np.float64)
    return LatencyResult(
        mean=float(np.mean(lats)),
        p50=float(np.percentile(lats, 50)),
        p95=float(np.percentile(lats, 95)),
        std=float(np.std(lats)),
        n=lats.size,
    )
