"""Fetch-granularity (paper §IV-D) and cache-line-size (paper §IV-E) probes.

Fetch granularity: cold-pass p-chase with strides growing by 4 B. While the
stride is below the granularity some loads land in the segment fetched by
their predecessor (hits + misses mixed); once every load opens a new fetch
transaction, only misses remain — that stride is the granularity. We detect
"mixed vs all-miss" by K-S-comparing each stride's distribution against an
all-miss reference (a stride far above any plausible granularity), using the
same statistical machinery as everywhere else.

Cache line size: once the capacity C is known, p-chase an array slightly
above C with growing step sizes. While step <= line size the footprint still
exceeds C (misses); once step > line the touched-line footprint shrinks below
C "as if the cache was larger" (hits). Per the paper's heuristics we compare
each step's distribution to a certain-miss pivot and a certain-hit MAX
reference, and snap the estimate to a power of two.

Both searches admit the adaptive planner (``budget=`` routes to
``engine/planner.py``): their discrete answers are *local* predicates of the
stride/step grid — the start of the first ``confirm``-long all-miss run, the
first hit-classified step — so a bisection that probes O(log n) grid rows
returns the identical answer whenever classification is locally monotone,
and the planner falls back to this dense implementation when it is not.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from ..stats import ks_statistic_rows

__all__ = ["GranularityResult", "find_fetch_granularity",
           "LineSizeResult", "find_line_size", "snap_pow2", "hit_scores"]


def snap_pow2(x: float) -> int:
    """Snap to the nearest power of two (paper §IV-E final heuristic)."""
    if x <= 1:
        return 1
    lo = 1 << int(np.floor(np.log2(x)))
    hi = lo * 2
    return lo if (x / lo) <= (hi / x) else hi


@dataclass(frozen=True)
class GranularityResult:
    granularity: int
    found: bool
    strides: np.ndarray
    mixed: np.ndarray          # bool per stride: hits+misses mixed?


def granularity_refs(runner, space: str, array_bytes: int, max_stride: int,
                     n_samples: int, stride_step: int):
    """Warm-hit / all-miss reference distributions + their threshold.

    Shared by the dense sweep and the planner so both classify per-load
    hit/miss against identical references (identical keys -> identical rows
    on request-keyed runners).

    The threshold is the *geometric* midpoint of the two medians: drift on
    measuring backends is multiplicative (a whole launch scales by its
    calibration ratio), and the geometric midpoint keeps the hit/miss
    margin symmetric under that scaling — an arithmetic midpoint sits
    closer to the miss side and lets a modestly inflated miss reference
    poison every subsequent classification.  A threshold only separates
    anything when the references themselves are separated, so the medians
    are returned too and ``find_fetch_granularity`` refuses to classify
    (returns not-found) when the miss median is not >=1.5x the hit median
    — the same practical-significance line the size classifier draws.
    """
    hit_ref = runner.pchase(space, array_bytes // 4, stride_step * 8,
                            n_samples)
    ref_stride = max_stride * 8
    miss_ref = runner.cold_chase(space, ref_stride * (n_samples + 1),
                                 ref_stride, n_samples)
    hit_med = max(float(np.median(hit_ref)), 1e-12)
    miss_med = max(float(np.median(miss_ref)), 1e-12)
    thresh = float(np.sqrt(hit_med * miss_med))
    return hit_ref, miss_ref, thresh, hit_med, miss_med


def find_fetch_granularity(
    runner, space: str,
    max_stride: int = 512,
    array_bytes: int = 64 * 1024,
    n_samples: int = 65,
    stride_step: int = 4,
    confirm: int = 2,
    batched: bool = False,
    budget=None,
) -> GranularityResult:
    """Paper §IV-D: grow the stride by 4 B until only misses remain.

    A load is classified hit/miss against warm-hit and all-miss reference
    distributions (their medians are far apart by construction); a stride is
    "mixed" while any statistically meaningful hit fraction remains. The
    granularity is the first stride with ``confirm`` all-miss successors —
    single-stride flukes at low sample counts must not end the search early.

    ``batched=True`` (probe-engine path) issues the sweep in
    ``cold_chase_batch`` chunks — both array size and stride vary per row,
    which is why this needed its own runner API next to ``pchase_batch``.
    The sequential early-stop is replayed on the classified chunk, so the
    returned result is bit-identical (request-keyed streams make the at most
    one chunk of extra probes side-effect free).

    ``budget`` routes to the adaptive planner: a bisection for the first
    all-miss stride plus a local run verification, falling back to this
    dense sweep when the stride classifications are not locally monotone.
    """
    if budget is not None:
        from ..engine.planner import find_granularity_planned

        return find_granularity_planned(
            runner, space, budget=budget, max_stride=max_stride,
            array_bytes=array_bytes, n_samples=n_samples,
            stride_step=stride_step, confirm=confirm)
    hit_ref, miss_ref, thresh, hit_med, miss_med = granularity_refs(
        runner, space, array_bytes, max_stride, n_samples, stride_step)
    strides = np.arange(stride_step, max_stride + stride_step, stride_step)
    if miss_med < hit_med * 1.5:
        # Degenerate references (e.g. a tiny cache whose warm reference
        # already misses to the same next level the cold pass does):
        # hit/miss classification cannot separate anything, so don't
        # sweep 100+ strides to discover that — §IV-D is inapplicable.
        return GranularityResult(-1, False, strides[:0],
                                 np.zeros(0, dtype=bool))
    mixed = np.zeros(strides.size, dtype=bool)
    # Hit/miss is classified per load, so use a long cold pass: near the
    # granularity the hit fraction approaches stride_step/G and needs enough
    # loads to be observable above the fluke floor (256 B granularities
    # produce only ~1.6% hits at the last mixed stride).
    n_loads = 16 * n_samples
    min_frac = max(0.005, 2.0 / n_loads)

    def rows_for(part: np.ndarray) -> np.ndarray:
        arrs = [max(array_bytes, int(s) * (n_loads + 1)) for s in part]
        if batched:
            return np.asarray(runner.cold_chase_batch(
                space, arrs, [int(s) for s in part], n_loads))
        return np.stack([runner.cold_chase(space, arrs[j], int(s), n_loads)
                         for j, s in enumerate(part)])

    chunk = 16 if batched else 1
    candidate_i = -1
    for lo in range(0, strides.size, chunk):
        part = strides[lo: lo + chunk]
        hit_fracs = np.mean(rows_for(part) < thresh, axis=1)
        for i in range(lo, lo + part.size):
            mixed[i] = float(hit_fracs[i - lo]) > min_frac
            if not mixed[i] and candidate_i < 0:
                candidate_i = i
            elif mixed[i]:
                candidate_i = -1  # fluke: hits reappeared, keep searching
            if candidate_i >= 0 and i - candidate_i >= confirm:
                g = int(strides[candidate_i])
                return GranularityResult(g, True, strides[: i + 1],
                                         mixed[: i + 1])
    if candidate_i >= 0:
        return GranularityResult(int(strides[candidate_i]), True, strides, mixed)
    return GranularityResult(-1, False, strides, mixed)


@dataclass(frozen=True)
class LineSizeResult:
    line_size: int
    found: bool
    raw_estimate: float
    steps: np.ndarray
    hit_score: np.ndarray      # similarity-to-hit-reference per step


def hit_scores(rows: np.ndarray, pivot: np.ndarray,
               hit_ref: np.ndarray) -> np.ndarray:
    """Per-step §IV-E classification score: >0 means closer to the certain-
    hit reference than to the certain-miss pivot.

    Primary signal is the K-S distance difference the paper's heuristic
    prescribes.  On measuring backends, per-launch drift can push a row
    *away from both references at once* — both distances saturate toward 1
    and their difference becomes sample noise.  Those saturated rows are
    adjudicated by median log-proximity instead (drift shifts a whole row,
    so which reference's median is closer in log space survives it), the
    same fallback the amount/sharing classifier uses when K-S is
    uninformative.  Shared by the dense sweep and the planner, so both
    paths score identically.
    """
    rows = np.asarray(rows, dtype=np.float64)
    if rows.ndim == 1:
        rows = rows[None, :]
    d_pivot = ks_statistic_rows(rows, pivot)
    d_hit = ks_statistic_rows(rows, hit_ref)
    score = d_pivot - d_hit
    n = rows.shape[1]
    saturated = np.minimum(d_pivot, d_hit) >= (n - 1) / n
    if np.any(saturated):
        med = np.median(rows[saturated], axis=1)
        lp = np.abs(np.log(np.maximum(med, 1e-12)
                           / max(float(np.median(pivot)), 1e-12)))
        lh = np.abs(np.log(np.maximum(med, 1e-12)
                           / max(float(np.median(hit_ref)), 1e-12)))
        score[saturated] = lp - lh
    return score


def line_size_from_first_hit(first_hit_step: int, over_factor: float,
                             g2: int) -> tuple[int, float]:
    """§IV-E final heuristic: (snapped line size, raw estimate).

    The transition step satisfies step ~= line * over_factor; a step equal
    to the line size still touches every line, so the first *hitting* step
    is one granularity notch above — bias the raw estimate down by half a
    notch before snapping to a power of two.  Shared by the dense sweep and
    the planner so the discrete answer is one formula."""
    raw = first_hit_step / over_factor
    raw_adj = max(raw - g2 / 2, g2)
    return snap_pow2(raw_adj), raw


def find_line_size(
    runner, space: str,
    cache_size: int,
    fetch_granularity: int,
    n_samples: int = 65,
    over_factor: float = 1.0625,
    max_line: int = 1024,
    batched: bool = False,
    budget=None,
) -> LineSizeResult:
    """Paper §IV-E with the pivot/MAX heuristic.

    ``batched=True`` (probe-engine path) issues the step sweep in chunks of
    16 (array, step) pairs — one ``pchase_many`` call per chunk on runners
    that support it (per-row strides; a single kernel launch on the Pallas
    backend), per-step ``pchase`` calls otherwise — scored by one vectorized
    K-S pass per chunk.  The early-stop truncation of the sequential loop is
    applied post-hoc, so the returned result is bit-identical.

    ``budget`` routes to the adaptive planner: bisection for the first
    hit-classified step, with dense fallback when the scores are not
    locally monotone.
    """
    if budget is not None:
        from ..engine.planner import find_line_size_planned

        return find_line_size_planned(
            runner, space, cache_size, fetch_granularity, budget=budget,
            n_samples=n_samples, over_factor=over_factor, max_line=max_line)
    g2 = max(fetch_granularity // 2, 4)
    arr = int(cache_size * over_factor)

    # Pivot: certain miss (tiny step, array beyond capacity).
    pivot = runner.pchase(space, arr, g2, n_samples)
    # MAX: certain hit (huge step shrinks the footprint far below capacity).
    hit_ref = runner.pchase(space, arr, max_line * 8, n_samples)

    steps = np.arange(g2, max_line * 2 + g2, g2, dtype=np.int64)
    if batched:
        # Chunked vector sweep: classify 16 steps per K-S pass, applying the
        # sequential early-stop between chunks so no more than one chunk of
        # extra probes is issued past the stop point.
        chunk = 16
        scores: list[np.ndarray] = []
        first_hit_step = -1
        cut = steps.size
        for lo in range(0, steps.size, chunk):
            part = steps[lo: lo + chunk]
            if hasattr(runner, "pchase_many"):
                rows = np.asarray(runner.pchase_many(
                    [(space, arr, int(s)) for s in part], n_samples))
            else:
                rows = np.stack([runner.pchase(space, arr, int(s), n_samples)
                                 for s in part])
            scores.append(hit_scores(rows, pivot, hit_ref))
            done = False
            for i, s in enumerate(part, start=lo):
                if scores[-1][i - lo] > 0 and first_hit_step < 0:
                    first_hit_step = int(s)
                if first_hit_step > 0 and s >= 4 * first_hit_step:
                    cut = i + 1
                    done = True
                    break
            if done:
                break
        hit_score_full = np.concatenate(scores)
        steps, hit_score = steps[:cut], hit_score_full[:cut]
    else:
        hit_score = np.zeros(steps.size)
        first_hit_step = -1
        for i, s in enumerate(steps):
            cur = runner.pchase(space, arr, int(s), n_samples)
            hit_score[i] = hit_scores(cur, pivot, hit_ref)[0]
            if hit_score[i] > 0 and first_hit_step < 0:
                first_hit_step = int(s)
            if first_hit_step > 0 and s >= 4 * first_hit_step:
                steps, hit_score = steps[: i + 1], hit_score[: i + 1]
                break

    if first_hit_step < 0:
        return LineSizeResult(-1, False, -1.0, steps, hit_score)
    line, raw = line_size_from_first_hit(first_hit_step, over_factor, g2)
    return LineSizeResult(line, True, raw, steps, hit_score)
