"""Fetch-granularity (paper §IV-D) and cache-line-size (paper §IV-E) probes.

Fetch granularity: cold-pass p-chase with strides growing by 4 B. While the
stride is below the granularity some loads land in the segment fetched by
their predecessor (hits + misses mixed); once every load opens a new fetch
transaction, only misses remain — that stride is the granularity. We detect
"mixed vs all-miss" by K-S-comparing each stride's distribution against an
all-miss reference (a stride far above any plausible granularity), using the
same statistical machinery as everywhere else.

Cache line size: once the capacity C is known, p-chase an array slightly
above C with growing step sizes. While step <= line size the footprint still
exceeds C (misses); once step > line the touched-line footprint shrinks below
C "as if the cache was larger" (hits). Per the paper's heuristics we compare
each step's distribution to a certain-miss pivot and a certain-hit MAX
reference, and snap the estimate to a power of two.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from ..stats import ks_statistic, ks_statistic_rows

__all__ = ["GranularityResult", "find_fetch_granularity",
           "LineSizeResult", "find_line_size", "snap_pow2"]


def snap_pow2(x: float) -> int:
    """Snap to the nearest power of two (paper §IV-E final heuristic)."""
    if x <= 1:
        return 1
    lo = 1 << int(np.floor(np.log2(x)))
    hi = lo * 2
    return lo if (x / lo) <= (hi / x) else hi


@dataclass(frozen=True)
class GranularityResult:
    granularity: int
    found: bool
    strides: np.ndarray
    mixed: np.ndarray          # bool per stride: hits+misses mixed?


def find_fetch_granularity(
    runner, space: str,
    max_stride: int = 512,
    array_bytes: int = 64 * 1024,
    n_samples: int = 65,
    stride_step: int = 4,
    confirm: int = 2,
    batched: bool = False,
) -> GranularityResult:
    """Paper §IV-D: grow the stride by 4 B until only misses remain.

    A load is classified hit/miss against warm-hit and all-miss reference
    distributions (their medians are far apart by construction); a stride is
    "mixed" while any statistically meaningful hit fraction remains. The
    granularity is the first stride with ``confirm`` all-miss successors —
    single-stride flukes at low sample counts must not end the search early.

    ``batched=True`` (probe-engine path) issues the sweep in
    ``cold_chase_batch`` chunks — both array size and stride vary per row,
    which is why this needed its own runner API next to ``pchase_batch``.
    The sequential early-stop is replayed on the classified chunk, so the
    returned result is bit-identical (request-keyed streams make the at most
    one chunk of extra probes side-effect free).
    """
    # References: a warm chase that surely hits, and a cold chase whose
    # stride is far beyond any plausible granularity (every load misses).
    hit_ref = runner.pchase(space, array_bytes // 4, stride_step * 8, n_samples)
    ref_stride = max_stride * 8
    miss_ref = runner.cold_chase(space, ref_stride * (n_samples + 1),
                                 ref_stride, n_samples)
    thresh = (float(np.median(hit_ref)) + float(np.median(miss_ref))) / 2.0

    strides = np.arange(stride_step, max_stride + stride_step, stride_step)
    mixed = np.zeros(strides.size, dtype=bool)
    # Hit/miss is classified per load, so use a long cold pass: near the
    # granularity the hit fraction approaches stride_step/G and needs enough
    # loads to be observable above the fluke floor (256 B granularities
    # produce only ~1.6% hits at the last mixed stride).
    n_loads = 16 * n_samples
    min_frac = max(0.005, 2.0 / n_loads)

    def rows_for(part: np.ndarray) -> np.ndarray:
        arrs = [max(array_bytes, int(s) * (n_loads + 1)) for s in part]
        if batched:
            return np.asarray(runner.cold_chase_batch(
                space, arrs, [int(s) for s in part], n_loads))
        return np.stack([runner.cold_chase(space, arrs[j], int(s), n_loads)
                         for j, s in enumerate(part)])

    chunk = 16 if batched else 1
    candidate_i = -1
    for lo in range(0, strides.size, chunk):
        part = strides[lo: lo + chunk]
        hit_fracs = np.mean(rows_for(part) < thresh, axis=1)
        for i in range(lo, lo + part.size):
            mixed[i] = float(hit_fracs[i - lo]) > min_frac
            if not mixed[i] and candidate_i < 0:
                candidate_i = i
            elif mixed[i]:
                candidate_i = -1  # fluke: hits reappeared, keep searching
            if candidate_i >= 0 and i - candidate_i >= confirm:
                g = int(strides[candidate_i])
                return GranularityResult(g, True, strides[: i + 1],
                                         mixed[: i + 1])
    if candidate_i >= 0:
        return GranularityResult(int(strides[candidate_i]), True, strides, mixed)
    return GranularityResult(-1, False, strides, mixed)


@dataclass(frozen=True)
class LineSizeResult:
    line_size: int
    found: bool
    raw_estimate: float
    steps: np.ndarray
    hit_score: np.ndarray      # similarity-to-hit-reference per step


def find_line_size(
    runner, space: str,
    cache_size: int,
    fetch_granularity: int,
    n_samples: int = 65,
    over_factor: float = 1.0625,
    max_line: int = 1024,
    batched: bool = False,
) -> LineSizeResult:
    """Paper §IV-E with the pivot/MAX heuristic.

    ``batched=True`` (probe-engine path) issues the whole step sweep as one
    ``pchase_batch`` call — the strides vary, not the array size, so the
    batch is over (array, step) pairs via per-step calls folded into one
    vectorized K-S scoring pass.  The early-stop truncation of the
    sequential loop is applied post-hoc, so the returned result is
    bit-identical.
    """
    g2 = max(fetch_granularity // 2, 4)
    arr = int(cache_size * over_factor)

    # Pivot: certain miss (tiny step, array beyond capacity).
    pivot = runner.pchase(space, arr, g2, n_samples)
    # MAX: certain hit (huge step shrinks the footprint far below capacity).
    hit_ref = runner.pchase(space, arr, max_line * 8, n_samples)

    steps = np.arange(g2, max_line * 2 + g2, g2, dtype=np.int64)
    if batched:
        # Chunked vector sweep: classify 16 steps per K-S pass, applying the
        # sequential early-stop between chunks so no more than one chunk of
        # extra probes is issued past the stop point.
        chunk = 16
        scores: list[np.ndarray] = []
        first_hit_step = -1
        cut = steps.size
        for lo in range(0, steps.size, chunk):
            part = steps[lo: lo + chunk]
            rows = np.stack([runner.pchase(space, arr, int(s), n_samples)
                             for s in part])
            scores.append(ks_statistic_rows(rows, pivot)
                          - ks_statistic_rows(rows, hit_ref))
            done = False
            for i, s in enumerate(part, start=lo):
                if scores[-1][i - lo] > 0 and first_hit_step < 0:
                    first_hit_step = int(s)
                if first_hit_step > 0 and s >= 4 * first_hit_step:
                    cut = i + 1
                    done = True
                    break
            if done:
                break
        hit_score_full = np.concatenate(scores)
        steps, hit_score = steps[:cut], hit_score_full[:cut]
    else:
        hit_score = np.zeros(steps.size)
        first_hit_step = -1
        for i, s in enumerate(steps):
            cur = runner.pchase(space, arr, int(s), n_samples)
            d_pivot = ks_statistic(cur, pivot)
            d_hit = ks_statistic(cur, hit_ref)
            hit_score[i] = d_pivot - d_hit      # >0 -> closer to the hit side
            if hit_score[i] > 0 and first_hit_step < 0:
                first_hit_step = int(s)
            if first_hit_step > 0 and s >= 4 * first_hit_step:
                steps, hit_score = steps[: i + 1], hit_score[: i + 1]
                break

    if first_hit_step < 0:
        return LineSizeResult(-1, False, -1.0, steps, hit_score)
    # The transition step satisfies step ~= line * over_factor.
    raw = first_hit_step / over_factor
    # A step equal to the line size still touches every line; the first
    # *hitting* step is one granularity notch above -> bias the raw estimate
    # down by half a notch before snapping to a power of two.
    raw_adj = max(raw - g2 / 2, g2)
    return LineSizeResult(snap_pow2(raw_adj), True, raw, steps, hit_score)
