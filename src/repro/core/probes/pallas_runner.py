"""PallasRunner: the ProbeRunner backend over the real Pallas probe kernels.

This is the third discovery backend (after Sim and Host) and the one the
ROADMAP's "wire the engine into a PallasRunner" item asked for: every probe
request executes the TPU-target kernels from ``repro.kernels`` —
``pchase_kernel_batch`` for dependent-load chains, ``stream_read_kernel`` /
``stream_write_kernel`` for bandwidth — in Pallas interpret mode, and the
*caller times the whole call* (DESIGN.md adaptation note 1: no in-kernel
clock on TPU).

Interpret mode runs on a CPU with no TPU memory system behind it, so the
hit/miss behavior comes from a configured ground-truth hierarchy (a
``SimDevice`` model, default ``make_pallas_model``): the modeled level an
access hits sets the *length of the dependent chain the kernel actually
executes* — a modeled miss literally serializes more loads, exactly as a
real miss serializes more cycles — and the reported per-load value comes
from timing that execution.  Locations of the latency distributions
therefore track the configured hierarchy (sizes, line size, fetch
granularity are discoverable and checkable against
``model.ground_truth()``), while the distributions themselves carry real
end-to-end timing noise, which is what the K-S machinery is built to
absorb.  On hardware the same runner drops the model and keeps the timing
loop.

Shared-box drift calibration: the probe workflows compare distributions
*across* requests (a doubling step against its baseline, an eviction probe
against hit/miss references), and on a time-shared CPU the interpreter's
per-step cost drifts by tens of percent between calls — enough to fake a
regime change.  Every timed execution is therefore normalized by a
back-to-back **shape-matched calibration chain**: a separate buffer of the
*same grid shape and the same per-row chain lengths*, executed adjacent in
time, so a sample is ``modeled_cycles x (request wall / calibration
wall)``.  Matching the full launch shape — not just the buffer bucket —
matters because the interpreter charges a per-grid-row overhead: a 100-row
sweep launch has a very different wall-per-step than a single-row chase,
and only a calibration with the identical (rows x bucket, steps) profile
cancels both that overhead and temporal drift.  The result: reported
latencies land in model-cycle units comparable across requests *and
across launch shapes* — the property the planner's row classification
(every row judged against one baseline distribution) depends on.

Implementation notes:

* chase buffers are Sattolo-style single-cycle permutations sized per
  request from the probed ``SpaceInfo`` (slot i stands for byte offset
  ``i * stride``, so the resident footprint matches ``array_bytes``),
  generated vectorized (``random_cycle``) and padded to power-of-two
  buckets so the jit cache stays small;
* the chain length is passed to the kernel as data, not a static arg —
  sweeps over hundreds of sizes reuse a handful of compiled kernels;
* ``pchase_batch`` maps a whole §IV-B sweep onto the kernel grid in ONE
  launch; ``cold_chase_batch`` does the same for the §IV-D stride sweep
  with per-row chain lengths;
* the eviction-pattern probes (§IV-F/G/H) ride the same grid trick:
  ``eviction_many`` maps mixed amount/sharing/cu rows onto
  ``eviction_kernel_batch`` — each row executes a real warm-B/probe-A
  two-phase chain (Fig. 3) with both phase lengths as per-row data, and the
  calibration chain matches the full two-phase launch profile;
* scratchpad spaces (VMEM/SMEM-like) advertise ``supports_cold=False``:
  end-to-end timing cannot classify individual loads of a cold pass there,
  and the engine registry honors the capability flag by never scheduling
  the family.  Cache-kind spaces support the cold pass through the modeled
  per-load pattern scaled by the measured per-step cost.
"""
from __future__ import annotations

import time

import numpy as np

from ..simulate import SimDevice, SimLevel
from .runners import SpaceInfo, random_cycle

__all__ = ["PallasRunner", "make_pallas_model"]

KIB = 1024


def make_pallas_model(seed: int = 0) -> SimDevice:
    """Default ground-truth hierarchy for the interpret-mode backend.

    Deliberately small (16 KiB / 64 KiB / 256 KiB) so a full discovery stays
    in seconds: interpret-mode chains cost ~70 ns per executed load, and the
    size sweeps scale with capacity.  The shape mirrors a TPU-flavored
    hierarchy: one cache-kind space in front of global loads, a
    compiler-managed VMEM scratchpad (no cold pass — capability flag), and a
    chip-level L2 ahead of device memory.
    """
    levels = [
        SimLevel("L1", 16 * KIB, 40.0, 128, 32, noise=0.0),
        SimLevel("VMEM", 64 * KIB, 12.0, 4, 4, noise=0.0, kind="scratchpad"),
        SimLevel("L2", 256 * KIB, 150.0, 128, 64, amount=1, scope="chip",
                 noise=0.0),
    ]
    return SimDevice(
        name="pallas-interp", vendor="Google", levels=levels,
        mem_latency=800.0, mem_noise=0.0,
        read_bw={}, write_bw={},        # bandwidth is measured, not modeled
        cores_per_sm=8,
        space_of_level={"global": "L1", "DeviceMemory": "L2"},
        outlier_prob=0.0,
        seed=seed,
    )


def _pow2_at_least(n: int) -> int:
    return 1 << max(int(n - 1).bit_length(), 2)


class PallasRunner:
    """ProbeRunner over ``repro.kernels`` p-chase/stream kernels.

    ``base_steps`` is the minimum executed chain length per timed call: the
    jit dispatch overhead (~20-30 us on this container) must stay small
    against the kernel's compute time for the wall-clock division to carry
    signal.  ``reps``/``cold_reps`` control how many timed executions back
    each scalar the cold-pass and bandwidth probes report.
    """

    ELEM_BYTES = 4               # int32 chase indices
    deterministic = False        # samples are real wall-time measurements

    def __init__(self, model: SimDevice | None = None, *,
                 base_steps: int = 6144, cold_reps: int = 3,
                 bandwidth_bytes: int = 1 << 21, seed: int = 0,
                 interpret: bool = True):
        self.model = model if model is not None else make_pallas_model()
        self.base_steps = int(base_steps)
        self.cold_reps = int(cold_reps)
        self.bandwidth_bytes = int(bandwidth_bytes)
        self.interpret = bool(interpret)
        self._rng = np.random.default_rng(seed)
        self._perm_cache: dict[int, np.ndarray] = {}
        self._evictor_cache: dict[int, np.ndarray] = {}
        self._cal_cache: dict[tuple, np.ndarray] = {}  # (shape, tag) -> perms
        self._cal_cache_cap = 16
        self._warmed: set[tuple] = set()               # launch-shape keys
        self.kernel_calls = 0
        # Eviction-grid utilization (§IV-F/G/H): dispatches vs rows carried.
        # rows > calls means heterogeneous rows actually coalesced onto
        # shared grids — the bench's ``eviction_fusion`` gate reads these.
        self.eviction_grid_calls = 0
        self.eviction_grid_rows = 0

    # ------------------------------------------------------------- spaces
    def spaces(self) -> list[SpaceInfo]:
        out = []
        for lvl in self.model.levels:
            out.append(SpaceInfo(
                name=lvl.name, scope=lvl.scope, kind=lvl.kind,
                max_bytes=lvl.size * 8,
                # Scratchpads: end-to-end timing cannot classify individual
                # cold-pass loads; the registry honors the flag.
                supports_cold=lvl.kind == "cache",
                supports_amount=lvl.kind == "cache" and lvl.scope == "core",
                supports_sharing=lvl.kind == "cache",
            ))
        return out

    # ------------------------------------------------------- chase plumbing
    def _slots(self, array_bytes: int, stride: int) -> int:
        stride_elems = max(int(stride) // self.ELEM_BYTES, 1)
        return max(int(array_bytes) // self.ELEM_BYTES // stride_elems, 4)

    def _perm(self, n: int) -> np.ndarray:
        """Single-cycle chase buffer over ``n`` slots (memoized per size)."""
        perm = self._perm_cache.get(n)
        if perm is None:
            perm = random_cycle(n, self._rng)
            self._perm_cache[n] = perm
        return perm

    def _chain_factor(self, lat_cycles: float) -> int:
        """Repetitions of the modeled latency needed to beat dispatch."""
        return max(int(np.ceil(self.base_steps / max(lat_cycles, 1.0))), 1)

    def _run_batch(self, perms: np.ndarray, steps: np.ndarray) -> float:
        """One timed launch of the grid kernel; returns wall seconds."""
        import jax.numpy as jnp

        from repro.kernels.pchase_probe import pchase_kernel_batch

        perms_j = jnp.asarray(perms)
        steps_j = jnp.asarray(steps, dtype=jnp.int32)
        t0 = time.perf_counter_ns()
        pchase_kernel_batch(perms_j, steps_j,
                            interpret=self.interpret).block_until_ready()
        self.kernel_calls += 1
        return (time.perf_counter_ns() - t0) * 1e-9

    def _stacked_perms(self, slot_counts: list[int]) -> np.ndarray:
        """(R, bucket) padded permutation matrix for a sweep's rows."""
        bucket = _pow2_at_least(max(slot_counts))
        out = np.zeros((len(slot_counts), bucket), dtype=np.int32)
        for i, n in enumerate(slot_counts):
            out[i, :n] = self._perm(n)
        return out

    def _cal_perms(self, shape: tuple[int, int], tag: str = "") -> np.ndarray:
        """Calibration buffers of the given (rows, bucket) launch shape.

        Independent random cycles (never the request's own buffers), small
        LRU so sweep-sized grids do not accumulate.  The kernel shape is
        identical to the request's, so the jit cache the request warmed up
        serves the calibration launch too — no extra warm-up dispatch.
        ``tag`` separates calibration roles that must use distinct buffers
        at the same shape (e.g. the eviction kernel's probe vs warm side).
        """
        key = (shape, tag)
        cal = self._cal_cache.pop(key, None)
        if cal is None:
            rows, bucket = shape
            cal = np.stack([random_cycle(bucket, self._rng)
                            for _ in range(rows)]).astype(np.int32)
            while len(self._cal_cache) >= self._cal_cache_cap:
                self._cal_cache.pop(next(iter(self._cal_cache)))
        self._cal_cache[key] = cal                      # LRU: re-insert last
        return cal

    def _cal_wall(self, shape: tuple[int, int], steps: np.ndarray) -> float:
        """ONE wall measurement of the shape-matched calibration chain.

        Same grid shape, same per-row chain lengths, adjacent in time: the
        request/calibration wall ratio cancels temporal drift AND the
        interpreter's per-grid-row overhead, leaving model-cycle units
        comparable across launch shapes (see module docstring).

        Callers combine multiple calibrations *spread across* their sample
        loops (min of a before/after pair, median of adjacent pairs):
        back-to-back calibration repetitions are covered by a single
        steal-time burst together and would be no more robust than one.
        """
        return self._run_batch(self._cal_perms(shape), steps)

    def _maybe_warm(self, perms: np.ndarray, steps: np.ndarray) -> None:
        """Warm-up launch (paper §IV-A) once per (rows, bucket) grid shape.

        Chain lengths travel as data, so every launch of a seen shape hits
        the same compiled/traced kernel — re-warming would only burn a
        dispatch."""
        shape = perms.shape
        if shape not in self._warmed:
            self._run_batch(perms, steps)
            self._warmed.add(shape)

    # ------------------------------------------------------------- pchase
    def pchase(self, space, array_bytes, stride, n_samples):
        lat = self.model.hit_latency(space, array_bytes, stride)
        return self._timed_chase(array_bytes, stride, lat, int(n_samples))

    def _timed_chase(self, array_bytes, stride, lat_cycles,
                     n_samples) -> np.ndarray:
        """n_samples timed kernel executions of one modeled-latency chain.

        Each sample is the calibration-normalized per-load value
        ``lat_cycles x (c_request / c_calibration)`` — model-cycle units
        with real adjacent-in-time measurement noise.
        """
        n = self._slots(array_bytes, stride)
        m = self._chain_factor(lat_cycles)
        bucket = _pow2_at_least(n)
        perms = np.zeros((1, bucket), dtype=np.int32)
        perms[0, :n] = self._perm(n)
        steps = np.array([max(int(round(m * lat_cycles)), 1)], dtype=np.int32)
        self._maybe_warm(perms, steps)
        walls, cal = self._timed_loop(perms, steps, n_samples)
        return lat_cycles * walls / cal

    def pchase_batch(self, space, array_bytes_list, stride, n_samples):
        """A whole size sweep on the kernel grid: ONE launch per repetition.

        Row i's chain length encodes its own modeled hit latency; each timed
        launch yields one per-step cost estimate ``c`` (wall over total
        executed steps), and row i's sample for that repetition is
        ``c * lat_i`` — the same quantity ``pchase`` measures one row at a
        time, amortizing the launch overhead over the grid.
        """
        sizes = [int(ab) for ab in array_bytes_list]
        return self._timed_grid(
            [(space, ab, int(stride)) for ab in sizes], int(n_samples))

    def pchase_many(self, requests, n_samples):
        """Heterogeneous fused batch — per-row (space, array_bytes, stride)
        on ONE kernel grid (the cross-family fusion capability).

        This is what collapses the per-family kernel launches: a fusion
        round containing a size-search bisection probe, a line-size step,
        and a latency chase costs a single grid launch per repetition
        instead of one launch per family.  Row semantics are identical to
        ``pchase`` — row i's chain length encodes its own modeled hit
        latency and every repetition is calibration-normalized.
        """
        reqs = [(space, int(ab), int(stride))
                for space, ab, stride in requests]
        return self._timed_grid(reqs, int(n_samples))

    def _timed_grid(self, reqs: list[tuple], n_samples: int) -> np.ndarray:
        """Shared grid-launch timing loop behind pchase_batch/pchase_many."""
        lats = np.array([self.model.hit_latency(space, ab, stride)
                         for space, ab, stride in reqs])
        slot_counts = [self._slots(ab, stride) for _, ab, stride in reqs]
        perms = self._stacked_perms(slot_counts)
        # Spread the dispatch-beating budget over the grid: per-row chains
        # can be shorter because one launch times all of them.
        per_row = max(self.base_steps // max(len(reqs), 1), 512)
        ms = np.maximum(np.ceil(per_row / np.maximum(lats, 1.0)), 1.0)
        steps = np.asarray(np.round(ms * lats), dtype=np.int32)
        self._maybe_warm(perms, steps)
        walls, cal = self._timed_loop(perms, steps, n_samples)
        return lats[:, None] * (walls[None, :] / cal)

    def _timed_loop(self, perms: np.ndarray, steps: np.ndarray,
                    n_samples: int) -> tuple[np.ndarray, float]:
        """``n_samples`` timed request walls + a burst-resistant calibration.

        Three calibration launches INTERLEAVED with the sample loop
        (before / middle / after), combined by median: per-sample request
        noise is the distribution the statistics consume, but the
        calibration divisor scales the whole row, so no single steal
        burst may own it.  A spike on one calibration is outvoted; a
        burst long enough to cover two of the three spread-out
        calibrations covers most of the request walls as well, and then
        the ratio stays self-consistent.
        """
        cal_a = self._cal_wall(perms.shape, steps)
        half = max(n_samples // 2, 1)
        walls = [self._run_batch(perms, steps) for _ in range(half)]
        cal_b = self._cal_wall(perms.shape, steps)
        walls += [self._run_batch(perms, steps)
                  for _ in range(n_samples - half)]
        cal_c = self._cal_wall(perms.shape, steps)
        return np.asarray(walls), float(np.median([cal_a, cal_b, cal_c]))

    # --------------------------------------------------------- cold chase
    def _cold_cycles(self, space, array_bytes, stride, n_loads) -> np.ndarray:
        """Modeled per-load cycle costs of a cold pass (§IV-D pattern)."""
        info = self.model.level(space)
        if info.kind != "cache":
            raise NotImplementedError(
                f"pallas runner: no cold-pass control over scratchpad "
                f"space '{space}'")
        miss = self.model.cold_miss_pattern(space, array_bytes, stride,
                                            n_loads)
        hit_lat = info.latency
        miss_lat = self.model.next_level_latency(space)
        return np.where(miss, miss_lat, hit_lat)

    def cold_chase(self, space, array_bytes, stride, n_samples):
        """Per-load cold-pass values: modeled hit/miss pattern x measured
        per-step cost of a real chain executing the modeled total work."""
        cycles = self._cold_cycles(space, array_bytes, stride, n_samples)
        return self._cold_rows([cycles])[0]

    def _cold_rows(self, cycles_rows: list[np.ndarray]) -> np.ndarray:
        """Execute + time the chains behind one or many cold rows.

        One grid launch covers every row; the per-step cost is best-of-reps
        (steal-time spikes only ever slow a run down), normalized by the
        matching best-of-reps calibration cost.  Per-load values are the
        modeled hit/miss cycle pattern scaled by that measured ratio, which
        is what the §IV-D threshold classification consumes.
        """
        totals = np.array([float(c.sum()) for c in cycles_rows])
        reps = np.maximum(np.ceil(self.base_steps / totals), 1.0)
        steps = np.asarray(np.round(reps * totals), dtype=np.int32)
        slot_counts = [max(c.size, 4) for c in cycles_rows]
        perms = self._stacked_perms(slot_counts)
        self._maybe_warm(perms, steps)
        # Cold rows are classified against an *absolute* hit/miss
        # threshold, so the whole-row scale must survive steal bursts:
        # measure ``cold_reps`` ADJACENT (request, calibration) pairs and
        # take the median per-pair ratio — a burst spanning one pair
        # inflates both walls and cancels; a spike hitting a single launch
        # is outvoted.  (min-of-requests over min-of-calibrations, by
        # contrast, lets one lucky/unlucky side skew the ratio 2x+.)
        ratios = []
        for _ in range(self.cold_reps):
            w_req = self._run_batch(perms, steps)
            ratios.append(w_req / self._cal_wall(perms.shape, steps))
        ratio = float(np.median(ratios))
        return np.stack([ratio * cyc for cyc in cycles_rows])

    def cold_chase_batch(self, space, array_bytes_list, stride_list,
                         n_samples):
        """The §IV-D stride sweep as one grid launch (per-row strides AND
        array sizes, like the Sim backend's batch API)."""
        cycles_rows = [self._cold_cycles(space, int(ab), int(s), n_samples)
                       for ab, s in zip(array_bytes_list, stride_list)]
        return self._cold_rows(cycles_rows)

    def cold_chase_many(self, requests, n_samples):
        """Heterogeneous cold-pass fusion: per-row spaces AND strides AND
        array sizes, one grid launch for the whole round."""
        cycles_rows = [self._cold_cycles(space, int(ab), int(s), n_samples)
                       for space, ab, s in requests]
        return self._cold_rows(cycles_rows)

    # ----------------------------------------------- eviction-pattern probes
    def amount_probe(self, space, core_a, core_b, array_bytes, n_samples):
        lvl = self.model.level(space)
        lat = (self.model.next_level_latency(space)
               if self.model.amount_evicted(space, core_a, core_b,
                                            array_bytes)
               else lvl.latency)
        return self._timed_chase(array_bytes, 64, lat, int(n_samples))

    def sharing_probe(self, space_a, space_b, array_bytes, n_samples):
        lvl = self.model.level(space_a)
        lat = (self.model.next_level_latency(space_a)
               if self.model.sharing_evicted(space_a, space_b, array_bytes)
               else lvl.latency)
        return self._timed_chase(array_bytes, 64, lat, int(n_samples))

    def cu_sharing_probe(self, cu_a, cu_b, array_bytes, n_samples,
                         space="sL1d"):
        """Single §IV-H pair probe (grid path: ``eviction_many``)."""
        return self.eviction_many(
            [("cu", space, cu_a, cu_b, array_bytes)], n_samples)[0]

    def _evict_row_latency(self, req) -> tuple[float, int]:
        """(modeled post-warm probe latency, probe array bytes) of one row."""
        kind = req[0]
        if kind == "amount":
            _, space, core_a, core_b, ab = req
            evicted = self.model.amount_evicted(space, core_a, core_b, ab)
        elif kind == "sharing":
            _, space, space_b, ab = req
            evicted = self.model.sharing_evicted(space, space_b, ab)
        elif kind == "cu":
            _, space, cu_a, cu_b, ab = req
            evicted = self.model.cu_sharing_evicted(cu_a, cu_b, ab, space)
        else:
            raise ValueError(f"unknown eviction request kind: {kind!r}")
        lat = (self.model.next_level_latency(space) if evicted
               else self.model.level(space).latency)
        return lat, int(ab)

    def _evictor_perm(self, n: int) -> np.ndarray:
        """Evictor-side chase buffer: independent of the probe buffer of the
        same size (warm phase must walk a *conflicting* working set, never
        the probe array itself)."""
        perm = self._evictor_cache.get(n)
        if perm is None:
            perm = random_cycle(n, self._rng)
            self._evictor_cache[n] = perm
        return perm

    def _stacked_evictors(self, slot_counts: list[int]) -> np.ndarray:
        """(R, bucket) padded evictor matrix for an eviction grid's rows."""
        bucket = _pow2_at_least(max(slot_counts))
        out = np.zeros((len(slot_counts), bucket), dtype=np.int32)
        for i, n in enumerate(slot_counts):
            out[i, :n] = self._evictor_perm(n)
        return out

    def _run_evict(self, perms, evictors, warm, probe) -> float:
        """One timed launch of the eviction grid kernel; wall seconds."""
        import jax.numpy as jnp

        from repro.kernels.pchase_probe import eviction_kernel_batch

        t0 = time.perf_counter_ns()
        eviction_kernel_batch(
            jnp.asarray(perms), jnp.asarray(evictors),
            jnp.asarray(warm, dtype=jnp.int32),
            jnp.asarray(probe, dtype=jnp.int32),
            interpret=self.interpret).block_until_ready()
        self.kernel_calls += 1
        return (time.perf_counter_ns() - t0) * 1e-9

    def eviction_many(self, requests, n_samples):
        """Mixed §IV-F/G/H rows on ONE eviction-kernel grid per repetition.

        Each row executes the Fig. 3 pattern for real: a warm phase walks
        the row's evictor cycle once end-to-end (conflicting working set of
        the probe's footprint), then the timed probe phase walks the probe
        cycle with a chain length encoding the *modeled* post-warm hit
        level — evicted rows literally serialize more loads.  The
        calibration chain matches the full two-phase (rows x bucket,
        warm+probe steps) launch profile, so the wall ratio cancels both
        drift and the per-row interpreter overhead, exactly as in
        ``_timed_grid``.  Replaces one ``_timed_chase`` dispatch (~12
        launches) per amount/sharing/cu request with a single fused grid.
        """
        self.eviction_grid_calls += 1
        self.eviction_grid_rows += len(requests)
        params = [self._evict_row_latency(r) for r in requests]
        lats = np.array([lat for lat, _ in params])
        slot_counts = [self._slots(ab, 64) for _, ab in params]
        perms = self._stacked_perms(slot_counts)
        evictors = self._stacked_evictors(slot_counts)
        # One full pass over the evictor cycle: the minimal walk that
        # touches the whole conflicting footprint (and ends back at slot 0).
        warm = np.asarray(slot_counts, dtype=np.int32)
        per_row = max(self.base_steps // max(len(requests), 1), 512)
        ms = np.maximum(np.ceil(per_row / np.maximum(lats, 1.0)), 1.0)
        probe = np.asarray(np.round(ms * lats), dtype=np.int32)
        shape_key = ("evict", perms.shape, evictors.shape)
        if shape_key not in self._warmed:
            self._run_evict(perms, evictors, warm, probe)
            self._warmed.add(shape_key)
        cal_args = (self._cal_perms(perms.shape, "evict-probe"),
                    self._cal_perms(evictors.shape, "evict-warm"),
                    warm, probe)
        cal_a = self._run_evict(*cal_args)
        half = max(int(n_samples) // 2, 1)
        walls = [self._run_evict(perms, evictors, warm, probe)
                 for _ in range(half)]
        cal_b = self._run_evict(*cal_args)
        walls += [self._run_evict(perms, evictors, warm, probe)
                  for _ in range(int(n_samples) - half)]
        cal_c = self._run_evict(*cal_args)
        cal = float(np.median([cal_a, cal_b, cal_c]))
        return lats[:, None] * (np.asarray(walls)[None, :] / cal)

    # ---------------------------------------------------------- bandwidth
    def bandwidth(self, space, mode="read"):
        """Stream-kernel bandwidth: bytes moved over best-of-reps wall time.

        Interpret-mode numbers characterize this container, not a TPU — the
        value is that the measurement loop and kernels are the ones a
        hardware backend reuses unchanged.
        """
        import jax.numpy as jnp

        from repro.kernels.stream_probe import (stream_read_kernel,
                                                stream_write_kernel)

        del space  # one DMA path in interpret mode
        n = self.bandwidth_bytes // 4
        block = min(64 * KIB, n)
        n = (n // block) * block
        x = jnp.arange(n, dtype=jnp.float32)
        fn = stream_read_kernel if mode == "read" else stream_write_kernel
        fn(x, block=block, interpret=self.interpret).block_until_ready()
        best = np.inf
        for _ in range(self.cold_reps):
            t0 = time.perf_counter_ns()
            fn(x, block=block, interpret=self.interpret).block_until_ready()
            best = min(best, time.perf_counter_ns() - t0)
            self.kernel_calls += 1
        moved = n * 4 * (2 if mode == "write" else 1)
        return moved / (best * 1e-9)

    # ------------------------------------------------------------- hooks
    def api_size(self, space: str) -> int | None:
        try:
            return self.model.level(space).size
        except KeyError:
            return None

    def cu_ids(self) -> list[int]:
        return sorted(cu for grp in self.model.cu_share_groups for cu in grp)

    @property
    def cores_per_sm(self) -> int:
        return self.model.cores_per_sm

    def ground_truth(self) -> dict[str, dict]:
        return self.model.ground_truth()
