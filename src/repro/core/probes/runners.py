"""Probe runners: who actually executes a memory-access pattern.

The probe *workflows* (size, latency, line size, amount, ...) are runner-
agnostic — the same code drives:

* ``SimRunner``   — virtual devices with ground truth (validation tables);
* ``HostRunner``  — real measurements on this machine's CPU hierarchy using
                    jit-compiled dependent-load chases (the live-hardware
                    sanity check; TPU/GPU-free analogue of paper §V);
* ``PallasRunner``— the TPU-target kernels in ``repro.kernels``
                    (``pchase_probe``/``pchase_kernel_batch``,
                    ``stream_probe``), executed in Pallas interpret mode and
                    timed end-to-end against a configured ground-truth
                    hierarchy; lives in ``pallas_runner.py`` and is the
                    third backend of the unified ``discover()`` driver.

Per DESIGN.md adaptation note 1, runners without an in-kernel clock time a
short dependent chain end-to-end and report the distribution across
repetitions; the K-S evaluation is identical either way.

``deterministic`` (class attribute) tells callers whether repeating a
request returns bit-identical samples: true for the request-keyed simulated
runners, false for runners whose samples are real wall-time measurements
(Host, Pallas).  The engine's caches are correctness-neutral only for
deterministic runners; for measuring runners they are a documented
trade-off (serve the first measurement) that discovery relies on anyway.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Protocol, runtime_checkable

import numpy as np

__all__ = ["ProbeRunner", "SpaceInfo", "SimRunner", "HostRunner",
           "sattolo_cycle", "random_cycle", "build_sim_runner",
           "build_host_runner"]


def build_sim_runner(device) -> "SimRunner":
    """Rebuild a ``SimRunner`` from its device model (pool-worker side)."""
    return SimRunner(device)


def build_host_runner(max_bytes: int, iters: int, seed: int) -> "HostRunner":
    """Rebuild a ``HostRunner`` from its config scalars (pool-worker side)."""
    return HostRunner(max_bytes=max_bytes, iters=iters, seed=seed)


@dataclass(frozen=True)
class SpaceInfo:
    """Search hints for one probeable memory space."""

    name: str
    scope: str                    # "core" | "chip" | "host"
    kind: str                     # "cache" | "scratchpad" | "memory"
    max_bytes: int                # upper bound for the size search
    supports_cold: bool = True    # cold-pass (fetch granularity) available?
    supports_amount: bool = True
    supports_sharing: bool = True


@runtime_checkable
class ProbeRunner(Protocol):
    """The capability surface the probe workflows rely on."""

    def spaces(self) -> list[SpaceInfo]: ...

    def pchase(self, space: str, array_bytes: int, stride: int,
               n_samples: int) -> np.ndarray: ...

    def pchase_batch(self, space: str, array_bytes_list, stride: int,
                     n_samples: int) -> np.ndarray: ...

    def cold_chase(self, space: str, array_bytes: int, stride: int,
                   n_samples: int) -> np.ndarray: ...

    def cold_chase_batch(self, space: str, array_bytes_list, stride_list,
                         n_samples: int) -> np.ndarray: ...

    # Heterogeneous fused batches — per-row (space, array_bytes, stride)
    # triples, the capability the cross-family fusion dispatcher coalesces
    # ready work items onto (one dispatch per round instead of one per
    # family).  Optional: the engine falls back to per-row calls when a
    # runner lacks them.
    def pchase_many(self, requests, n_samples: int) -> np.ndarray: ...

    def cold_chase_many(self, requests, n_samples: int) -> np.ndarray: ...

    def amount_probe(self, space: str, core_a: int, core_b: int,
                     array_bytes: int, n_samples: int) -> np.ndarray: ...

    def sharing_probe(self, space_a: str, space_b: str, array_bytes: int,
                      n_samples: int) -> np.ndarray: ...

    # Heterogeneous eviction-grid capability (§IV-F/G/H): requests mixes
    # ("amount", space, core_a, core_b, ab), ("sharing", space_a, space_b,
    # ab) and ("cu", space, cu_a, cu_b, ab) rows; returns (R, n_samples)
    # with row i bit-identical to the matching single-probe call.  Runners
    # without multi-actor control raise NotImplementedError.
    def eviction_many(self, requests, n_samples: int) -> np.ndarray: ...

    def bandwidth(self, space: str, mode: str = "read") -> float: ...


def sattolo_cycle(n: int, rng: np.random.Generator) -> np.ndarray:
    """Random single-cycle permutation (defeats stride prefetchers; the
    standard p-chase array construction, cf. Mei & Chu [39])."""
    perm = np.arange(n, dtype=np.int32)
    for i in range(n - 1, 0, -1):
        j = rng.integers(0, i)
        perm[i], perm[j] = perm[j], perm[i]
    return perm


def random_cycle(n: int, rng: np.random.Generator) -> np.ndarray:
    """Vectorized Sattolo equivalent: a uniform random single-cycle
    permutation built from one ``rng.permutation`` call.

    ``sattolo_cycle`` walks an O(n) Python loop — fine for host-probe slot
    counts, too slow for the Pallas sweeps that need fresh million-slot
    buffers.  Visiting a random ordering ``sigma`` cyclically
    (``perm[sigma[i]] = sigma[i+1]``) yields exactly the Sattolo
    distribution (every n-cycle equally likely), in numpy time.
    """
    if n <= 1:
        return np.zeros(max(n, 1), dtype=np.int32)
    sigma = rng.permutation(n).astype(np.int32)
    perm = np.empty(n, dtype=np.int32)
    perm[sigma] = np.roll(sigma, -1)
    return perm


# --------------------------------------------------------------------------
# Simulated runner
# --------------------------------------------------------------------------
class SimRunner:
    """Adapts a ``SimDevice`` to the ProbeRunner protocol."""

    deterministic = True     # request-keyed sample streams

    def __init__(self, device):
        self.device = device

    def spaces(self) -> list[SpaceInfo]:
        out = []
        for lvl in self.device.levels:
            out.append(SpaceInfo(
                name=lvl.name, scope=lvl.scope, kind=lvl.kind,
                max_bytes=lvl.size * 8,
                supports_cold=lvl.kind == "cache",
                supports_amount=lvl.kind == "cache" and lvl.scope == "core",
                supports_sharing=lvl.kind == "cache",
            ))
        return out

    def pchase(self, space, array_bytes, stride, n_samples):
        return self.device.pchase(space, array_bytes, stride, n_samples)

    def pchase_batch(self, space, array_bytes_list, stride, n_samples):
        """One vectorized call for a whole size sweep (engine fast path)."""
        return self.device.pchase_batch(space, array_bytes_list, stride,
                                        n_samples)

    def cold_chase(self, space, array_bytes, stride, n_samples):
        return self.device.cold_chase(space, array_bytes, stride, n_samples)

    def cold_chase_batch(self, space, array_bytes_list, stride_list,
                         n_samples):
        """One vectorized call for a whole granularity stride sweep."""
        return self.device.cold_chase_batch(space, array_bytes_list,
                                            stride_list, n_samples)

    def pchase_many(self, requests, n_samples):
        """Cross-family fused batch: per-row (space, array_bytes, stride)."""
        return self.device.pchase_many(requests, n_samples)

    def cold_chase_many(self, requests, n_samples):
        return self.device.cold_chase_many(requests, n_samples)

    def amount_probe(self, space, core_a, core_b, array_bytes, n_samples):
        return self.device.amount_probe(space, core_a, core_b, array_bytes, n_samples)

    def sharing_probe(self, space_a, space_b, array_bytes, n_samples):
        return self.device.sharing_probe(space_a, space_b, array_bytes, n_samples)

    def cu_sharing_probe(self, cu_a, cu_b, array_bytes, n_samples,
                         space="sL1d"):
        return self.device.cu_sharing_probe(cu_a, cu_b, array_bytes,
                                            n_samples, space=space)

    def cu_sharing_probe_batch(self, cu_a, cu_bs, array_bytes, n_samples,
                               space="sL1d"):
        return self.device.cu_sharing_probe_batch(cu_a, cu_bs, array_bytes,
                                                  n_samples, space=space)

    def eviction_many(self, requests, n_samples):
        """Mixed amount/sharing/cu eviction rows in one fused dispatch."""
        return self.device.eviction_many(requests, n_samples)

    def bandwidth(self, space, mode="read"):
        return self.device.bandwidth(space, mode)

    def api_size(self, space: str) -> int | None:
        """API-reported capacity (paper Table I: chip-scope totals come from
        the driver API, not the benchmark)."""
        try:
            return self.device.level(space).size
        except KeyError:
            return None

    def cu_ids(self) -> list[int]:
        """All CU ids participating in sL1d sharing groups (AMD, §IV-H)."""
        return sorted(cu for grp in self.device.cu_share_groups for cu in grp)

    @property
    def cores_per_sm(self) -> int:
        return self.device.cores_per_sm

    def runner_spec(self):
        """Rebuild recipe for pool workers: the device model is the whole
        state (request-keyed streams live in the device seed), so a worker
        rebuilt from it is bit-identical to this runner."""
        from ..engine.parallel import RunnerSpec

        return RunnerSpec(build_sim_runner, (self.device,))


# --------------------------------------------------------------------------
# Host (real CPU) runner
# --------------------------------------------------------------------------
class HostRunner:
    """Real p-chase measurements against this machine's cache hierarchy.

    Per-load timing at ns resolution is not available from Python, so — per
    DESIGN.md adaptation note 1 — each "sample" is the mean ns/load of a
    jit-compiled dependent-load loop (warm, single cycle), and the probe
    distribution is built across ``n_samples`` repetitions.
    """

    ELEM_BYTES = 4  # int32 chase indices
    deterministic = False    # samples are real wall-time measurements

    def __init__(self, max_bytes: int = 256 * 1024**2, iters: int = 1 << 15,
                 seed: int = 0):
        import jax  # local import: keep module import cheap

        self._jax = jax
        self.max_bytes = max_bytes
        self.iters = iters
        self.seed = seed
        self._rng = np.random.default_rng(seed)
        self._chase_cache: dict[int, object] = {}

    def spaces(self) -> list[SpaceInfo]:
        return [SpaceInfo(
            name="host-cache", scope="host", kind="cache",
            max_bytes=self.max_bytes,
            supports_cold=False, supports_amount=False, supports_sharing=False,
        )]

    # ------------------------------------------------------------- chase
    def _chase_fn(self):
        import jax
        import jax.numpy as jnp

        @jax.jit
        def run(perm, iters):
            def body(_, x):
                return perm[x]
            return jax.lax.fori_loop(0, iters, body, jnp.int32(0))

        return run

    def pchase(self, space, array_bytes, stride, n_samples):
        del space
        import jax.numpy as jnp

        stride_elems = max(stride // self.ELEM_BYTES, 1)
        n = max(array_bytes // self.ELEM_BYTES // stride_elems, 4)
        # Random single cycle over n slots; slot i stands for byte offset
        # i*stride, so the resident footprint matches ``array_bytes``.
        perm_np = sattolo_cycle(n, self._rng)
        perm = jnp.asarray(perm_np)
        run = self._chase_cache.setdefault(0, self._chase_fn())
        iters = max(self.iters, n)
        run(perm, iters).block_until_ready()  # warm-up pass (paper §IV-A)
        out = np.empty(n_samples)
        for s in range(n_samples):
            t0 = time.perf_counter_ns()
            run(perm, iters).block_until_ready()
            out[s] = (time.perf_counter_ns() - t0) / iters
        return out

    def pchase_batch(self, space, array_bytes_list, stride, n_samples):
        """Batched sweep over array sizes sharing one jitted chase.

        Real hardware cannot overlap dependent chases, so this is a loop —
        but it amortizes the jit-function lookup and gives the engine one
        call site to schedule/cache, same as the simulator's vector path.
        """
        rows = [self.pchase(space, int(ab), stride, n_samples)
                for ab in array_bytes_list]
        return np.stack(rows)

    def pchase_many(self, requests, n_samples):
        """Fused heterogeneous batch: dependent chases cannot overlap on
        real hardware, so this is a loop — but it gives the fusion
        dispatcher one call site, same as the simulator's vector path."""
        return np.stack([self.pchase(space, int(ab), int(stride), n_samples)
                         for space, ab, stride in requests])

    def runner_spec(self):
        """Rebuild recipe for pool workers.  Host samples are real wall
        time, so shards are *statistically* interchangeable with inline
        rows, never bit-identical — same contract as ``deterministic``."""
        from ..engine.parallel import RunnerSpec

        return RunnerSpec(build_host_runner,
                          (self.max_bytes, self.iters, self.seed))

    def cold_chase(self, space, array_bytes, stride, n_samples):
        raise NotImplementedError("host runner has no cold-pass control")

    def cold_chase_batch(self, space, array_bytes_list, stride_list,
                         n_samples):
        raise NotImplementedError("host runner has no cold-pass control")

    def cold_chase_many(self, requests, n_samples):
        raise NotImplementedError("host runner has no cold-pass control")

    def amount_probe(self, *a, **k):
        raise NotImplementedError("host runner is single-actor")

    def sharing_probe(self, *a, **k):
        raise NotImplementedError("host runner has a unified cache path")

    def eviction_many(self, *a, **k):
        raise NotImplementedError("host runner is single-actor")

    # --------------------------------------------------------- bandwidth
    def bandwidth(self, space, mode="read", nbytes: int = 128 * 1024**2,
                  repeats: int = 5):
        del space
        import jax
        import jax.numpy as jnp

        n = nbytes // 4
        x = jnp.arange(n, dtype=jnp.float32)

        if mode == "read":
            fn = jax.jit(lambda v: jnp.sum(v))
            moved = nbytes
        else:  # write (copy: read + write -> count written bytes)
            fn = jax.jit(lambda v: v + 1.0)
            moved = nbytes
        fn(x).block_until_ready()
        best = np.inf
        for _ in range(repeats):
            t0 = time.perf_counter_ns()
            fn(x).block_until_ready()
            best = min(best, time.perf_counter_ns() - t0)
        return moved / (best * 1e-9)
