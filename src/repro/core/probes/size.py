"""Size benchmark (paper §IV-B) — the fundamental MT4G probe.

Workflow (paper §IV-B.1):
  1. identify a narrower search interval (exponential doubling from the lower
     bound until the latency distribution departs from the baseline, then
     binary search to re-narrow);
  2. run p-chase with array sizes swept across the interval, step = fetch
     granularity (coarsened only if the interval would need too many points);
  3. check for outliers; widen the interval and repeat (2) if found;
  4. reduce (eq. 2) and detect the change point with the K-S test; report the
     size and the confidence metric.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from ..stats import (boundary_suspect, cusum_change_point,
                     geometric_reduction, ks_2samp, ks_change_point,
                     ks_change_point_scan, winsorize)

__all__ = ["SizeResult", "find_size", "sweep_rows"]

KIB = 1024


@dataclass(frozen=True)
class SizeResult:
    size: int                # bytes; -1 if not found
    found: bool
    confidence: float        # K-S confidence at the change point
    pvalue: float
    sizes_swept: np.ndarray  # the final sweep grid
    reduced: np.ndarray      # eq. 2 series over the grid (for Fig. 2 plots)
    widenings: int           # how many times step (3) widened the interval
    samples_per_size: int
    cusum_agrees: bool = True  # parametric cross-check (paper: 'other
                               # algorithms'); False flags a suspect result


def _distribution_shifted(base: np.ndarray, cur: np.ndarray, alpha: float,
                          min_jump: float = 0.15) -> bool:
    """Statistical (K-S) AND practical significance: a real next-level miss
    raises the median by >=1.5x on every hierarchy in the paper's tables;
    requiring a modest +15% median jump suppresses the ~alpha-rate false
    positives that small samples produce on identical distributions."""
    if not ks_2samp(base, cur, alpha=alpha).reject:
        return False
    return float(np.median(cur)) > float(np.median(base)) * (1.0 + min_jump)


def sweep_rows(runner, space: str, sizes, stride: int, n_samples: int,
               batched: bool = False) -> np.ndarray:
    """Sample a whole size grid: one ``pchase_batch`` call on the engine path,
    N sequential ``pchase`` calls on the legacy path.  Identical rows either
    way — simulated runners key their sample streams by request, so batching
    only changes how the work is issued, never what comes back."""
    if batched and hasattr(runner, "pchase_batch"):
        return np.asarray(runner.pchase_batch(
            space, [int(s) for s in sizes], stride, n_samples))
    return np.stack([runner.pchase(space, int(s), stride, n_samples)
                     for s in sizes])


def find_size(
    runner,
    space: str,
    lo: int = 1 * KIB,
    hi: int = 1024 * KIB,
    step: int = 32,
    n_samples: int = 33,
    alpha: float = 0.01,
    max_points: int = 96,
    max_widenings: int = 3,
    max_bytes: int | None = None,
    batched: bool = False,
) -> SizeResult:
    """Run the full §IV-B workflow against ``runner``/``space``.

    ``batched=True`` is the probe-engine fast path: the linear sweep (2) is
    issued as one vectorized ``pchase_batch`` call and the change-point scan
    (4) runs the vectorized K-S over the whole reduced series at once.  The
    result is bit-identical to the sequential path.
    """
    max_bytes = max_bytes or 64 * 1024 * KIB

    # -- (1a) exponential doubling until the distribution departs from baseline
    base = runner.pchase(space, lo, step, n_samples)
    size = lo
    first_bad = None
    while size <= max_bytes:
        size *= 2
        cur = runner.pchase(space, size, step, n_samples)
        if _distribution_shifted(base, cur, alpha):
            first_bad = size
            break
    if first_bad is None:
        return SizeResult(-1, False, 0.0, 1.0, np.zeros(0), np.zeros(0), 0, n_samples)

    # -- (1b) binary search to narrow [last_good, first_bad]
    last_good, bad = first_bad // 2, first_bad
    while bad - last_good > max(8 * step, (bad + last_good) // 64):
        mid = (last_good + bad) // 2
        cur = runner.pchase(space, mid, step, n_samples)
        if _distribution_shifted(base, cur, alpha):
            bad = mid
        else:
            last_good = mid
    sweep_lo, sweep_hi = last_good, bad

    widenings = 0
    while True:
        # -- (2) linear sweep, step = fetch granularity (coarsen if too wide)
        span = sweep_hi - sweep_lo
        eff_step = step
        if span // step > max_points:
            eff_step = max(step, (span // max_points) // step * step)
        sizes = np.arange(sweep_lo, sweep_hi + eff_step, eff_step, dtype=np.int64)
        rows = sweep_rows(runner, space, sizes, step, n_samples,
                          batched=batched)

        # -- (4) reduce + K-S change point
        reduced = geometric_reduction(rows)
        cp_scan = ks_change_point_scan if batched else ks_change_point
        cp = cp_scan(reduced, alpha=alpha, min_segment=3)

        # -- (3) outlier / boundary check -> widen interval and re-sweep
        need_widen = (not cp.found) or boundary_suspect(reduced) or \
                     cp.index <= 2 or cp.index >= sizes.size - 2
        if need_widen and widenings < max_widenings:
            widenings += 1
            span = max(span, eff_step * 8)
            sweep_lo = max(lo, sweep_lo - span // 2)
            sweep_hi = min(max_bytes, sweep_hi + span // 2)
            continue

        if not cp.found:
            return SizeResult(-1, False, 0.0, cp.pvalue, sizes, reduced,
                              widenings, n_samples)
        # cp.index is the first size in the *miss* regime; the capacity is the
        # last size that still fits.
        detected = int(sizes[max(cp.index - 1, 0)])
        # Parametric cross-check (CUSUM on the winsorized reduction): the two
        # detectors agreeing within a few grid steps raises confidence in the
        # non-parametric result; disagreement is surfaced to the caller.
        cc = cusum_change_point(winsorize(reduced, pct=2.0))
        agrees = bool(cc.found and abs(cc.index - cp.index)
                      <= max(3, sizes.size // 10))
        return SizeResult(detected, True, cp.confidence, cp.pvalue, sizes,
                          reduced, widenings, n_samples, cusum_agrees=agrees)
