"""Size benchmark (paper §IV-B) — the fundamental MT4G probe.

Workflow (paper §IV-B.1):
  1. identify a narrower search interval (exponential doubling from the lower
     bound until the latency distribution departs from the baseline, then
     binary search to re-narrow);
  2. run p-chase with array sizes swept across the interval, step = fetch
     granularity (coarsened only if the interval would need too many points);
  3. check the boundary position; widen the interval and repeat (2) when the
     change sits at the interval edge;
  4. locate the change point with the K-S machinery and report the size and
     the confidence metric.

Boundary rule (shared with the adaptive planner): the discrete capacity is
the *classification flip* of the sweep grid — the first grid size whose
latency distribution departs from the in-capacity baseline (two-sample K-S
rejection plus a practical median jump), located by a deterministic
bisection over grid indices (``descend_first_shifted``).  Because the rule
is a local function of individual grid rows, the adaptive coarse-to-fine
planner (``engine/planner.py``) can reproduce it exactly while sampling
only O(log n) of the grid: dense and planned sweeps return *identical*
discrete sizes by construction whenever the underlying rows agree (always,
for request-keyed simulated runners; whenever rows are shared, for
measuring runners).  The K-S split at the flip still provides the paper's
confidence metric, and CUSUM remains the parametric cross-check.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import numpy as np

from ..stats import (cusum_change_point, geometric_reduction, ks_2samp,
                     ks_change_point_scan, mad_gate, winsorize)
from ..stats.ks import ks_critical_value

__all__ = ["SizeResult", "find_size", "sweep_rows", "descend_first_shifted",
           "sweep_grid", "bisect_interval", "ShiftClassifier",
           "boundary_window", "BOUNDARY_WINDOW"]

KIB = 1024


@dataclass(frozen=True)
class SizeResult:
    size: int                # bytes; -1 if not found
    found: bool
    confidence: float        # K-S confidence at the change point
    pvalue: float
    sizes_swept: np.ndarray  # the final sweep grid (confidence window when planned)
    reduced: np.ndarray      # eq. 2 series over the grid (for Fig. 2 plots)
    widenings: int           # how many times step (3) widened the interval
    samples_per_size: int
    cusum_agrees: bool = True  # parametric cross-check (paper: 'other
                               # algorithms'); False flags a suspect result


def _fast_median(x: np.ndarray) -> float:
    """np.median without its dispatch overhead (equal values, ~3x faster
    on the tiny per-row sample vectors classification works over)."""
    n = x.size
    h = n // 2
    if n % 2:
        return float(np.partition(x, h)[h])
    p = np.partition(x, (h - 1, h))
    return 0.5 * (float(p[h - 1]) + float(p[h]))


def classification_jump(runner) -> float:
    """The practical-significance median-jump guard for ``runner``.

    Request-keyed (deterministic) runners carry no cross-launch drift, so
    a modest +15% jump suffices to suppress the ~alpha-rate K-S false
    positives — and preserves sensitivity to subtle real steps (a
    scratchpad spilling into an only-slightly-slower cache).  Measuring
    runners need +50%: their calibration drift can offset same-regime
    medians by tens of percent between launches, and every hierarchy in
    the paper's tables jumps >=1.5x at a true boundary.  Dense sweep and
    planner derive the guard from the same runner, so it cannot split
    their decisions.
    """
    return 0.15 if getattr(runner, "deterministic", False) else 0.5


class ShiftClassifier:
    """Memoized "has this row departed from the baseline?" decision.

    Statistical (K-S) AND practical significance (a median jump of at
    least ``min_jump`` — see ``classification_jump`` for how the guard is
    chosen per runner).

    Classification sits on the hot path of every search (the descent, the
    ladder, the bisection — hundreds of decisions per discovery), so the
    baseline side is computed once: sorted samples, jump threshold, and the
    critical value for the (n, m) pair.  Decisions are identical to a
    fresh ``ks_2samp`` + median-jump evaluation per row.
    """

    def __init__(self, base: np.ndarray, alpha: float,
                 min_jump: float = 0.5, *, mad_k: float | None = None,
                 resample_band: float = 0.0):
        self.base = np.asarray(base, dtype=np.float64).ravel()
        if mad_k is not None:
            self.base = mad_gate(self.base, mad_k)
        self.alpha = alpha
        self.mad_k = mad_k
        self.resample_band = resample_band
        self._sorted = np.sort(self.base)
        self._jump_med = _fast_median(self.base) * (1.0 + min_jump)
        self._crit: dict[int, float] = {}

    def _departure(self, cur: np.ndarray) -> tuple[float, float]:
        """(K-S D, critical value) of ``cur`` against the baseline."""
        b = np.sort(cur)
        n, m = self._sorted.size, b.size
        pooled = np.concatenate([self._sorted, b])
        d = float(np.max(np.abs(
            np.searchsorted(self._sorted, pooled, side="right") / n
            - np.searchsorted(b, pooled, side="right") / m)))
        crit = self._crit.get(m)
        if crit is None:
            crit = self._crit[m] = ks_critical_value(n, m, self.alpha)
        return d, crit

    def shifted(self, cur: np.ndarray, resample=None) -> bool:
        """Classify one row; defaults are bit-identical to the historical
        decision (no gating, no resampling).

        With ``mad_k`` set (resilience hardening), both sides are MAD-gated
        before the test so an injected outlier spike cannot fake or mask a
        boundary.  With ``resample`` (a zero-arg callable drawing extra
        samples) and a positive ``resample_band``, an *ambiguous* verdict —
        K-S D within the band of the critical value — triggers one
        confidence-driven resample: the extra rows concatenate onto ``cur``
        and the larger-sample test decides.
        """
        cur = np.asarray(cur, dtype=np.float64).ravel()
        if self.mad_k is not None:
            cur = mad_gate(cur, self.mad_k)
        d, crit = self._departure(cur)
        if (resample is not None and self.resample_band > 0.0
                and abs(d - crit) <= self.resample_band):
            extra = np.asarray(resample(), dtype=np.float64).ravel()
            if self.mad_k is not None:
                extra = mad_gate(extra, self.mad_k)
            cur = np.concatenate([cur, extra])
            d, crit = self._departure(cur)
        if d <= crit:
            return False
        return _fast_median(cur) > self._jump_med


def sweep_rows(runner, space: str, sizes, stride: int, n_samples: int,
               batched: bool = False) -> np.ndarray:
    """Sample a whole size grid: one ``pchase_batch`` call on the engine path,
    N sequential ``pchase`` calls on the legacy path.  Identical rows either
    way — simulated runners key their sample streams by request, so batching
    only changes how the work is issued, never what comes back."""
    if batched and hasattr(runner, "pchase_batch"):
        return np.asarray(runner.pchase_batch(
            space, [int(s) for s in sizes], stride, n_samples))
    return np.stack([runner.pchase(space, int(s), stride, n_samples)
                     for s in sizes])


def descend_first_shifted(classify: Callable[[int], bool], n: int,
                          confirm: int = 1) -> int:
    """First *confirmed* shifted grid index in [0, n) by bisection.

    ``classify(i)`` answers "has row i's distribution departed from the
    baseline?" and must be memoized by the caller (each index is asked at
    most once; re-asking must return the same answer).  The boundary is
    the first index opening a run of ``1 + confirm`` consecutive shifted
    rows: on measuring runners a steal burst can scale one row across the
    classification threshold, and requiring an independent successor
    prevents a lone fluke from both steering the bisection and confirming
    itself.  When confirmation fails, the disconfirming row is *known
    in-capacity evidence* and the descent resumes above it — the rule
    stays a deterministic local function of the rows, so the dense sweep
    (classifying in-memory rows) and the adaptive planner (fetching rows
    on demand) agree index-for-index whenever their rows agree.

    Returns ``0`` when the grid starts inside a confirmed run and ``n``
    when the last row is not shifted — both mean the boundary escaped the
    grid.
    """
    if n <= 0 or not classify(n - 1):
        return n
    lo_known = -1                    # highest index known in-capacity
    while True:
        a, b = lo_known, n - 1
        while b - a > 1:
            mid = (a + b) // 2
            if classify(mid):
                b = mid
            else:
                a = mid
        f = b
        disconfirmed = False
        for k in range(1, confirm + 1):
            if f + k >= n:
                break                # run reaches the grid end: accept
            if not classify(f + k):
                lo_known = f + k
                disconfirmed = True
                break
        if not disconfirmed:
            return f


def sweep_grid(sweep_lo: int, sweep_hi: int, step: int,
               max_points: int) -> tuple[np.ndarray, int]:
    """The §IV-B.2 linear sweep grid with its coarsening rule.

    Step = fetch granularity, coarsened (in multiples of ``step``) only when
    the interval would need more than ``max_points`` rows.  Shared by the
    dense sweep and the planner so both operate on the *same lattice*.
    """
    span = sweep_hi - sweep_lo
    eff_step = step
    if span // step > max_points:
        eff_step = max(step, (span // max_points) // step * step)
    sizes = np.arange(sweep_lo, sweep_hi + eff_step, eff_step, dtype=np.int64)
    return sizes, eff_step


def bisect_interval(shifted_at: Callable[[int], bool], first_bad: int,
                    step: int) -> tuple[int, int]:
    """§IV-B.1b binary search narrowing [first_bad/2, first_bad].

    ``shifted_at(size)`` probes one size and classifies it against the
    baseline.  Deterministic given the classifications, so the planner
    replays it bit-for-bit (the probes fuse across families instead of
    running back-to-back, but the sizes visited are identical).
    """
    last_good, bad = first_bad // 2, first_bad
    while bad - last_good > max(8 * step, (bad + last_good) // 64):
        mid = (last_good + bad) // 2
        if shifted_at(mid):
            bad = mid
        else:
            last_good = mid
    return last_good, bad


# Half-width (grid rows) of the boundary-detection window.  A shared
# constant — NOT a knob — because the dense sweep and the planner must
# evaluate the identical window for their answers to be identical.
BOUNDARY_WINDOW = 6


def _clamp_tails(reduced: np.ndarray) -> np.ndarray:
    """Winsorize ~one point per tail before a change-point scan.

    The two-sample K-S test has little power on short segments: on a
    12-row boundary window the critical value approaches 1.0, so a single
    injected outlier on the wrong side erases an otherwise perfect
    rejection.  Clamping one point per tail restores the decision the
    long-series scan would have made while leaving the series order — and
    hence the detected index — untouched.  Deterministic and shared by
    dense/planner, so it cannot break their identity."""
    pct = min(100.0 / max(reduced.size, 1), 25.0)
    return winsorize(reduced, pct=pct)


def boundary_window(flip: int, n: int) -> tuple[int, int]:
    """The [wa, wb) grid-index window the final detection runs over."""
    return max(flip - BOUNDARY_WINDOW, 0), min(flip + BOUNDARY_WINDOW, n)


def finalize_size(G: np.ndarray, wa: int, window_rows: np.ndarray,
                  flip: int, widenings: int, n_samples: int,
                  alpha: float) -> SizeResult | None:
    """Build the SizeResult from the boundary window around the flip.

    The classification descent *locates* the boundary window; the final
    index comes from the paper's K-S change-point scan over the window's
    reduced series.  Rationale: per-row classification compares rows
    against a baseline from another launch, but the scan compares the
    window's rows against each other — on measuring backends that makes
    the final decision immune to whole-row scale drift (the window is
    fetched as one launch), while on request-keyed runners dense and
    planner see the identical window rows and therefore return the
    identical size.  Returns ``None`` when the scan finds no change inside
    the window (a mispositioned flip) — callers escalate to
    ``rescue_change_point`` over the whole grid.
    """
    reduced = geometric_reduction(window_rows)
    cp = ks_change_point_scan(_clamp_tails(reduced), alpha=alpha,
                              min_segment=3)
    if not (cp.found and 0 < cp.index < reduced.size):
        # No change inside the window: the flip that positioned it is
        # suspect — escalate to the full-grid rescue scan.
        return None
    cut = cp.index
    confidence, pvalue = cp.confidence, cp.pvalue
    cc = cusum_change_point(winsorize(reduced, pct=2.0))
    # The parametric cross-check disagrees only when it *affirmatively*
    # places the change elsewhere — CUSUM has limited power on a short
    # window, and "found nothing" is absence of evidence, not a conflict.
    agrees = (not cc.found) or abs(cc.index - cut) \
        <= max(3, reduced.size // 10)
    return SizeResult(int(G[wa + cut - 1]), True, confidence, pvalue,
                      G[wa:wa + reduced.size], reduced, widenings, n_samples,
                      cusum_agrees=bool(agrees))


def widen_interval(sweep_lo: int, sweep_hi: int, eff_step: int, lo: int,
                   max_bytes: int) -> tuple[int, int]:
    """§IV-B.3: symmetric interval widening around the current sweep."""
    span = max(sweep_hi - sweep_lo, eff_step * 8)
    return (max(lo, sweep_lo - span // 2),
            min(max_bytes, sweep_hi + span // 2))


def ladder_rescue(ladder: list[int], rows: np.ndarray,
                  alpha: float) -> int | None:
    """Boundary octave from the doubling ladder's own rows (§IV-B.1a rescue).

    When per-row classification finds no shifted rung — on measuring
    backends, usually a poisoned baseline rather than a truly boundary-free
    range — the ladder rows still contain the boundary as a step *between
    rungs*, which the change-point scan detects without consulting the
    baseline at all.  Returns the first-bad ladder size, or None when the
    ladder genuinely shows no regime change.  Shared by the dense sweep and
    the planner (same rows in, same octave out)."""
    if len(ladder) < 4:
        return None
    reduced = geometric_reduction(np.stack(rows))
    cp = ks_change_point_scan(_clamp_tails(reduced), alpha=alpha,
                              min_segment=2)
    if cp.found and 0 < cp.index < len(ladder):
        return int(ladder[cp.index])
    return None


def rescue_change_point(G: np.ndarray, rows: np.ndarray, widenings: int,
                        n_samples: int, alpha: float) -> SizeResult:
    """Scale-immune rescue when the classification flip escapes the grid.

    Per-row classification compares each row against a baseline measured in
    a different launch; on measuring backends a sustained steal burst can
    scale EVERY row of a search relative to that baseline and walk the flip
    off the grid edge.  The paper's own change-point scan is immune to
    exactly that failure (it compares the sweep's rows against each other,
    and a batched sweep shares one launch), so it is kept as the last-resort
    detector.  Shared by the dense sweep and the planner over the same grid
    rows — identical inputs, identical rescue."""
    reduced = geometric_reduction(rows)
    cp = ks_change_point_scan(_clamp_tails(reduced), alpha=alpha,
                              min_segment=3)
    if not cp.found or cp.index <= 0:
        return SizeResult(-1, False, 0.0, cp.pvalue, G, reduced,
                          widenings, n_samples)
    cc = cusum_change_point(winsorize(reduced, pct=2.0))
    agrees = bool(cc.found and abs(cc.index - cp.index)
                  <= max(3, reduced.size // 10))
    return SizeResult(int(G[cp.index - 1]), True, cp.confidence, cp.pvalue,
                      G, reduced, widenings, n_samples, cusum_agrees=agrees)


def find_size(
    runner,
    space: str,
    lo: int = 1 * KIB,
    hi: int = 1024 * KIB,
    step: int = 32,
    n_samples: int = 33,
    alpha: float = 0.01,
    max_points: int = 96,
    max_widenings: int = 3,
    max_bytes: int | None = None,
    batched: bool = False,
    budget=None,
    robust=None,
) -> SizeResult:
    """Run the full §IV-B workflow against ``runner``/``space``.

    ``batched=True`` is the probe-engine fast path: the linear sweep (2) is
    issued as one vectorized ``pchase_batch`` call.  The result is
    bit-identical to the sequential path (request-keyed sample streams).

    ``budget`` (a ``SweepBudget``) switches to the adaptive coarse-to-fine
    planner: the sweep lattice is *subsampled* instead of fully measured —
    a chunked doubling ladder, the same binary bisection, then the
    deterministic classification descent over the grid — cutting probed
    rows ~4-8x while returning the identical discrete size (the dense sweep
    stays available as the equivalence oracle behind ``budget=None``).

    ``robust`` (an ``errors.Resilience``) opts the *dense* path into the
    statistical hardening knobs: MAD outlier gating of every classified
    row, and confidence-driven resampling of grid rows whose K-S verdict is
    ambiguous (extra samples drawn under a distinct request key).  The
    planner path ignores ``robust`` — its row-sharing identity guarantees
    are calibrated against the unhardened classifier.  Defaults (all knobs
    off) are bit-identical to the historical behavior.
    """
    if budget is not None:
        from ..engine.planner import find_size_planned

        return find_size_planned(runner, space, budget=budget, lo=lo,
                                 step=step, n_samples=n_samples, alpha=alpha,
                                 max_points=max_points,
                                 max_widenings=max_widenings,
                                 max_bytes=max_bytes)
    max_bytes = max_bytes or 64 * 1024 * KIB

    mad_k = getattr(robust, "mad_k", None)
    resample_band = getattr(robust, "resample_band", 0.0)
    resample_extra = getattr(robust, "resample_extra", 0)

    # -- (1a) exponential doubling until the distribution departs from baseline
    base = runner.pchase(space, lo, step, n_samples)
    clf = ShiftClassifier(base, alpha, classification_jump(runner),
                          mad_k=mad_k, resample_band=resample_band)
    size = lo
    first_bad = None
    ladder: list[int] = []
    ladder_rows: list[np.ndarray] = []
    while size <= max_bytes:
        size *= 2
        cur = runner.pchase(space, size, step, n_samples)
        ladder.append(size)
        ladder_rows.append(cur)
        if clf.shifted(cur):
            first_bad = size
            break
    if first_bad is None:
        first_bad = ladder_rescue(ladder, ladder_rows, alpha)
    if first_bad is None:
        return SizeResult(-1, False, 0.0, 1.0, np.zeros(0), np.zeros(0), 0, n_samples)

    # -- (1b) binary search to narrow [last_good, first_bad]
    def shifted_at(size: int) -> bool:
        return clf.shifted(runner.pchase(space, int(size), step, n_samples))

    sweep_lo, sweep_hi = bisect_interval(shifted_at, first_bad, step)

    widenings = 0
    while True:
        # -- (2) linear sweep, step = fetch granularity (coarsen if too wide)
        sizes, eff_step = sweep_grid(sweep_lo, sweep_hi, step, max_points)
        rows = sweep_rows(runner, space, sizes, step, n_samples,
                          batched=batched)

        # -- (4) the classification flip over the grid (see module docstring)
        memo: dict[int, bool] = {}

        def classify(i: int) -> bool:
            if i not in memo:
                resample = None
                if resample_extra:
                    # A distinct n_samples keys an independent sample
                    # stream on request-keyed runners — genuinely new
                    # evidence, not a replay of the ambiguous row.
                    resample = (lambda s=int(sizes[i]):
                                runner.pchase(space, s, step,
                                              int(resample_extra)))
                memo[i] = clf.shifted(rows[i], resample=resample)
            return memo[i]

        flip = descend_first_shifted(classify, sizes.size)

        # -- (3) boundary near the interval edge -> widen and re-sweep
        if (flip <= 2 or flip >= sizes.size - 2) and widenings < max_widenings:
            widenings += 1
            sweep_lo, sweep_hi = widen_interval(sweep_lo, sweep_hi, eff_step,
                                                lo, max_bytes)
            continue
        if 0 < flip < sizes.size:
            wa, wb = boundary_window(flip, sizes.size)
            result = finalize_size(sizes, wa, rows[wa:wb], flip, widenings,
                                   n_samples, alpha)
        else:
            result = None
        if result is None:
            result = rescue_change_point(sizes, rows, widenings, n_samples,
                                         alpha)
        if not result.found and widenings < max_widenings:
            # No statistically significant change anywhere: a wider grid
            # gives the K-S scan more points per segment (its power on
            # short series is poor — paper §IV-B step 3's re-measure loop).
            widenings += 1
            sweep_lo, sweep_hi = widen_interval(sweep_lo, sweep_hi, eff_step,
                                                lo, max_bytes)
            continue
        return result
