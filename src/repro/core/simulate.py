"""Simulated memory hierarchies with known ground truth.

The paper validates MT4G against 10 physical GPUs (Tables II/III). This
container has no GPU/TPU, so we reproduce that validation loop against
*simulated devices*: parameterized hierarchies that generate per-load latency
distributions (with realistic noise and injected outliers) for the same probe
requests the real backends would serve. The probe + K-S machinery under test
is byte-for-byte the code that runs against real hardware runners.

The simulation model is deliberately behavioral, not cycle-accurate:

* capacity: a cyclic p-chase over ``A`` bytes with step ``s`` touches
  ``ceil(A / max(s, L)) * L`` resident bytes of a cache with line size ``L``;
  it hits iff that footprint fits (paper Fig. 1).
* fetch granularity: on a cold pass, a load misses iff it lands in a new
  ``G``-byte fetched segment (paper §IV-D).
* amount/sharing: two actors evict each other iff they map to the same
  physical segment and their combined footprint exceeds it (paper Fig. 3).

Noise is drawn from *request-keyed* streams (``_KeyedSampler``): a probe
request's samples depend only on (device seed, request signature), never on
how many probes ran before it.  This is the property the probe engine's
scheduler/cache/batching builds on — engine and legacy discovery are
bit-identical for a fixed seed.
"""
from __future__ import annotations

import hashlib
import math
from dataclasses import dataclass, field

import numpy as np

__all__ = [
    "SimLevel", "SimDevice",
    "make_h100_like", "make_mi210_like", "make_v5e_like",
    "SIM_DEVICES",
]


@dataclass(frozen=True)
class SimLevel:
    """Ground truth for one cache/memory level of a simulated device."""

    name: str                  # "L1", "L2", "Texture", "vL1", "sL1d", "VMEM"...
    size: int                  # bytes
    latency: float             # cycles, mean on hit
    line_size: int             # bytes
    fetch_granularity: int     # bytes
    amount: int = 1            # independent segments within its scope
    noise: float = 1.0         # latency stddev
    scope: str = "core"        # "core" | "chip"
    physical_group: str = ""   # caches in the same group share silicon
    kind: str = "cache"
    path: str = "global"       # miss path: e.g. NVIDIA constant caches form
                               # their own ConstL1 -> ConstL1.5 hierarchy

    @property
    def group(self) -> str:
        return self.physical_group or self.name


_U64 = np.uint64
_SM_GAMMA = _U64(0x9E3779B97F4A7C15)          # SplitMix64 increment
_SM_M1 = _U64(0xBF58476D1CE4E5B9)
_SM_M2 = _U64(0x94D049BB133111EB)
_INV_2_53 = 1.0 / (1 << 53)


def _splitmix64(z: np.ndarray) -> np.ndarray:
    """SplitMix64 finalizer, vectorized over a uint64 counter array."""
    z = (z ^ (z >> _U64(30))) * _SM_M1
    z = (z ^ (z >> _U64(27))) * _SM_M2
    return z ^ (z >> _U64(31))


class _KeyedSampler:
    """Deterministic, *vectorizable* per-request sampling for the probes.

    Every probe request draws from a counter-based stream keyed by
    ``(device seed, request signature)`` instead of one shared stateful
    stream: a 64-bit blake2b of the request signature (keyed by the device
    seed) seeds the row, and sample j of that row is the SplitMix64
    finalizer applied to ``row_seed + (j + 1) * gamma`` — normals come from
    Box–Muller over consecutive uniform pairs.  Consequences the engine
    relies on:

    * identical requests return identical samples — a keyed sample cache is
      exactly equivalent to re-running the probe;
    * results are independent of execution order, so the engine's concurrent
      scheduler and batched sweeps are bit-identical to the legacy
      sequential loop;
    * distinct requests get independent streams, preserving the statistical
      independence the K-S machinery assumes.

    The counter-based construction (unlike the stateful-generator design it
    replaced) is embarrassingly parallel ACROSS rows: a whole sweep's — or
    a whole fused round's — sample matrix is a handful of numpy ops plus
    one 8-byte hash per row, which is what drops the per-row sampling floor
    from ~13 µs to ~2 µs on batched paths (the O(n²) CU-sharing sweep was
    the single largest engine cost before it).  Stateless, hence trivially
    thread-safe.
    """

    def __init__(self, seed: int):
        self.seed = (seed & 0xFFFFFFFFFFFFFFFF).to_bytes(8, "big")
        self._j_memo: dict[tuple[int, int], np.ndarray] = {}

    def row_seeds(self, keys: list[tuple]) -> np.ndarray:
        """(R,) uint64 stream seeds, one blake2b per request signature."""
        out = np.empty(len(keys), dtype=np.uint64)
        for i, key in enumerate(keys):
            digest = hashlib.blake2b(repr(key).encode(), digest_size=8,
                                     key=self.seed).digest()
            out[i] = int.from_bytes(digest, "big")
        return out

    def uniforms(self, row_seeds: np.ndarray, count: int,
                 offset: int = 0) -> np.ndarray:
        """(R, count) uniforms in [0, 1) from counters offset+1..offset+count."""
        j = self._j_memo.get((offset, count))
        if j is None:
            j = ((np.arange(offset + 1, offset + count + 1, dtype=np.uint64))
                 * _SM_GAMMA)
            if len(self._j_memo) > 64:
                self._j_memo.clear()
            self._j_memo[(offset, count)] = j
        z = _splitmix64(row_seeds[:, None] + j[None, :])
        return (z >> _U64(11)).astype(np.float64) * _INV_2_53

    def normals(self, row_seeds: np.ndarray, count: int) -> np.ndarray:
        """(R, count) standard normals (Box–Muller; counters 1..2*count)."""
        u = self.uniforms(row_seeds, 2 * count)
        r = np.sqrt(-2.0 * np.log1p(-u[:, :count]))
        return r * np.cos((2.0 * np.pi) * u[:, count:])


@dataclass
class SimDevice:
    """A virtual device serving probe requests against a known hierarchy."""

    name: str
    vendor: str
    levels: list[SimLevel]                      # ordered smallest..largest
    mem_latency: float                          # device/host memory latency
    mem_noise: float = 8.0
    read_bw: dict[str, float] = field(default_factory=dict)   # space -> B/s
    write_bw: dict[str, float] = field(default_factory=dict)
    cores_per_sm: int = 32
    cu_share_groups: list[list[int]] = field(default_factory=list)  # AMD sL1d
    space_of_level: dict[str, str] = field(default_factory=dict)    # space -> level name
    outlier_prob: float = 0.002
    outlier_scale: float = 30.0
    seed: int = 0

    def __post_init__(self):
        self._sampler = _KeyedSampler(self.seed)
        self._by_name = {l.name: l for l in self.levels}
        self._chain_cache: dict[str, list[SimLevel]] = {}
        self._cu_group_of = {cu: gi
                             for gi, grp in enumerate(self.cu_share_groups)
                             for cu in grp}

    # ------------------------------------------------------------ helpers
    def level(self, space: str) -> SimLevel:
        name = self.space_of_level.get(space, space)
        try:
            return self._by_name[name]
        except KeyError as e:
            raise KeyError(f"{self.name}: unknown memory space '{space}'") from e

    def _chain(self, space: str) -> list[SimLevel]:
        """Levels an access targeted at ``space`` passes through, small->large:
        larger caches on the SAME path (constant path on NVIDIA), then the
        chip-level caches.  Memoized: probe loops walk it millions of times."""
        cached = self._chain_cache.get(space)
        if cached is not None:
            return cached
        lvl = self.level(space)
        higher = [l for l in self.levels if l.kind == "cache"
                  and l.size > lvl.size
                  and (l.scope == "chip" or l.path == lvl.path)]
        chain = [lvl] + sorted(higher, key=lambda l: l.size)
        self._chain_cache[space] = chain
        return chain

    def _lat_rows(self, means: np.ndarray, noises: np.ndarray, n: int,
                  keys: list[tuple]) -> np.ndarray:
        """(R, n) latency draws, one request-keyed stream per row.

        The whole matrix is one vectorized pass (see ``_KeyedSampler``):
        row i is bit-identical to ``_lat(means[i], noises[i], n, keys[i])``,
        so batch APIs built on this are result-invisible relative to their
        sequential per-row twins.  Normals use counters 1..2n of each
        stream, outlier uniforms counters 2n+1..3n."""
        outliers = self.outlier_prob > 0.0
        seeds = self._sampler.row_seeds(keys)
        # One uniform pass covers both the Box-Muller pairs (counters
        # 1..2n) and the outlier draws (2n+1..3n) — same values as separate
        # normals()/uniforms() calls, half the counter-hashing work.
        u = self._sampler.uniforms(seeds, 3 * n if outliers else 2 * n)
        z = np.sqrt(-2.0 * np.log1p(-u[:, :n])) \
            * np.cos((2.0 * np.pi) * u[:, n:2 * n])
        lats = means[:, None] + noises[:, None] * z
        if outliers:
            # Injected measurement outliers (disturbances the K-S absorbs)
            mask = u[:, 2 * n:] < self.outlier_prob
            if mask.any():
                lats[mask] *= self.outlier_scale
        return np.maximum(lats, 1.0, out=lats)

    def _lat(self, mean: float, noise: float, n: int, key: tuple) -> np.ndarray:
        """Latency draw from the request-keyed stream (see _KeyedSampler)."""
        return self._lat_rows(np.array([float(mean)]),
                              np.array([float(noise)]), n, [key])[0]

    @staticmethod
    def _footprint(array_bytes: int, stride: int, line: int) -> int:
        touched = math.ceil(array_bytes / max(stride, line))
        return touched * line

    # ----------------------------------------------------- model hooks
    # Public, noise-free views of the behavioral model.  ``SimDevice``'s own
    # probe API draws sampled latencies around them; the ``PallasRunner``
    # reuses them as its configured ground truth — the modeled level an
    # access hits sets the executed chain length of a *real* Pallas kernel,
    # and the caller times that kernel end-to-end.
    def hit_latency(self, space: str, array_bytes: int, stride: int) -> float:
        """Mean latency (cycles) of the level a warm strided chase hits."""
        return self._hit_level(space, int(array_bytes), int(stride))[0]

    def next_level_latency(self, space: str) -> float:
        """Mean latency of the next level behind ``space`` (miss cost)."""
        return self._next_latency(self.level(space))

    def cold_miss_pattern(self, space: str, array_bytes: int, stride: int,
                          n_loads: int) -> np.ndarray:
        """Per-load miss mask of a cold pass (§IV-D): load i misses iff it
        opens a new ``fetch_granularity``-byte segment."""
        g = self.level(space).fetch_granularity
        n = max(min(int(array_bytes) // max(int(stride), 1), int(n_loads)), 1)
        seg = (np.arange(n) * int(stride)) // g
        prev_seg = np.concatenate([[-1], seg[:-1]])
        return seg != prev_seg

    def amount_evicted(self, space: str, core_a: int, core_b: int,
                       array_bytes: int) -> bool:
        """§IV-F eviction model: same segment AND 2x footprint > segment."""
        lvl = self.level(space)
        seg_size = lvl.size // max(lvl.amount, 1)
        per_seg_cores = max(self.cores_per_sm // max(lvl.amount, 1), 1)
        same_segment = (core_a // per_seg_cores) == (core_b // per_seg_cores)
        return same_segment and 2 * int(array_bytes) > seg_size

    def sharing_evicted(self, space_a: str, space_b: str,
                        array_bytes: int) -> bool:
        """§IV-G eviction model: same physical group AND over capacity."""
        la, lb = self.level(space_a), self.level(space_b)
        return la.group == lb.group and 2 * int(array_bytes) > la.size

    def cu_sharing_evicted(self, cu_a: int, cu_b: int, array_bytes: int,
                           space: str = "sL1d") -> bool:
        """§IV-H eviction model: distinct CUs in one sL1d group, over
        capacity.  Noise-free twin of ``cu_sharing_probe`` (same predicate),
        exposed so real runners can reuse it as configured ground truth."""
        lvl = self.level(space)
        group_of = self._cu_group_of
        shared = (cu_a in group_of and cu_b in group_of
                  and group_of[cu_a] == group_of[cu_b] and cu_a != cu_b)
        return shared and 2 * int(array_bytes) > lvl.size

    # -------------------------------------------------------- probe API
    def _hit_level(self, space: str, array_bytes: int,
                   stride: int) -> tuple[float, float]:
        """(latency mean, noise) of the level a warm strided chase hits."""
        if space == "DeviceMemory":
            # Cache-bypassing load (paper §IV-C: `.cg` / GLC-bit semantics).
            return self.mem_latency, self.mem_noise
        chain = self._chain(space)
        for lvl in chain:
            fp = self._footprint(array_bytes, stride, lvl.line_size)
            # One core only reaches one of the level's segments (paper §IV-F.1:
            # e.g. an SM sees a single 25 MB half of H100's 50 MB L2).
            usable = lvl.size // max(lvl.amount, 1)
            if fp <= usable:
                return lvl.latency, lvl.noise
        return self.mem_latency, self.mem_noise

    def pchase(self, space: str, array_bytes: int, stride: int,
               n_samples: int, warmup: bool = True) -> np.ndarray:
        """Warm p-chase latencies (paper §IV-A/B): hit level determined by
        whether the strided footprint fits each level of the chain."""
        del warmup  # warm pass is implied; cold behavior via cold_chase()
        mean, noise = self._hit_level(space, array_bytes, stride)
        key = ("pchase", space, int(array_bytes), int(stride), int(n_samples))
        return self._lat(mean, noise, n_samples, key)

    def pchase_batch(self, space: str, array_bytes_list, stride: int,
                     n_samples: int) -> np.ndarray:
        """Batched §IV-B sweep: one call for a whole size grid.

        Row i is bit-identical to ``pchase(space, array_bytes_list[i], ...)``
        because each row draws from its own request-keyed stream; the batch
        only amortizes the probe-dispatch overhead of N sequential calls.
        """
        means = np.empty(len(array_bytes_list))
        noises = np.empty(len(array_bytes_list))
        keys = []
        for i, ab in enumerate(array_bytes_list):
            means[i], noises[i] = self._hit_level(space, int(ab), stride)
            keys.append(("pchase", space, int(ab), int(stride),
                         int(n_samples)))
        return self._lat_rows(means, noises, int(n_samples), keys)

    def pchase_many(self, requests, n_samples: int) -> np.ndarray:
        """Heterogeneous warm-chase batch: per-row (space, array_bytes,
        stride) triples in one call — the cross-family fusion capability.

        Row i is bit-identical to ``pchase(*requests[i], n_samples)``
        (request-keyed streams), so fusing refinement rounds from several
        probe families into one dispatch is result-invisible.
        """
        means = np.empty(len(requests))
        noises = np.empty(len(requests))
        keys = []
        for i, (space, ab, stride) in enumerate(requests):
            means[i], noises[i] = self._hit_level(space, int(ab), int(stride))
            keys.append(("pchase", space, int(ab), int(stride),
                         int(n_samples)))
        return self._lat_rows(means, noises, int(n_samples), keys)

    def cold_chase(self, space: str, array_bytes: int, stride: int,
                   n_samples: int) -> np.ndarray:
        """Cold-pass latencies for the fetch-granularity probe (§IV-D):
        a load hits iff it falls into the segment fetched by its predecessor."""
        lvl = self.level(space)
        g = lvl.fetch_granularity
        n_loads = max(array_bytes // max(stride, 1), 1)
        idx = np.arange(min(n_loads, n_samples))
        seg = (idx * stride) // g
        prev_seg = np.concatenate([[-1], seg[:-1]])
        miss = seg != prev_seg
        chain = self._chain(lvl.name)
        next_lat = chain[1].latency if len(chain) > 1 else self.mem_latency
        next_noise = chain[1].noise if len(chain) > 1 else self.mem_noise
        key = ("cold", space, int(array_bytes), int(stride), int(n_samples))
        lats = np.where(miss,
                        self._lat(next_lat, next_noise, idx.size, key + ("m",)),
                        self._lat(lvl.latency, lvl.noise, idx.size, key + ("h",)))
        return lats

    def cold_chase_batch(self, space: str, array_bytes_list, stride_list,
                         n_samples: int) -> np.ndarray:
        """One call for a whole §IV-D stride sweep (engine fast path).

        Unlike ``pchase_batch`` both the array size AND the stride vary per
        row.  Row i is bit-identical to
        ``cold_chase(space, array_bytes_list[i], stride_list[i], n_samples)``
        — request-keyed streams — so batching only removes the per-stride
        dispatch overhead of the granularity sweep's sequential calls.
        """
        return np.stack([
            self.cold_chase(space, int(ab), int(s), int(n_samples))
            for ab, s in zip(array_bytes_list, stride_list)])

    def cold_chase_many(self, requests, n_samples: int) -> np.ndarray:
        """Heterogeneous cold-pass batch: per-row (space, array_bytes,
        stride) — the cold-capability twin of ``pchase_many``.  Row i is
        bit-identical to ``cold_chase(*requests[i], n_samples)``."""
        return np.stack([
            self.cold_chase(space, int(ab), int(s), int(n_samples))
            for space, ab, s in requests])

    def _next_latency(self, lvl: SimLevel) -> float:
        chain = self._chain(lvl.name)
        return chain[1].latency if len(chain) > 1 else self.mem_latency

    def amount_probe(self, space: str, core_a: int, core_b: int,
                     array_bytes: int, n_samples: int) -> np.ndarray:
        """Step-3 latencies of the Amount workflow (paper Fig. 3).

        Cores are spread evenly over the level's segments; eviction occurs iff
        both cores map to the same segment and 2x footprint exceeds it."""
        lvl = self.level(space)
        seg_size = lvl.size // max(lvl.amount, 1)
        per_seg_cores = max(self.cores_per_sm // max(lvl.amount, 1), 1)
        same_segment = (core_a // per_seg_cores) == (core_b // per_seg_cores)
        evicted = same_segment and 2 * array_bytes > seg_size
        key = ("amount", space, int(core_a), int(core_b), int(array_bytes),
               int(n_samples))
        if evicted:
            return self._lat(self._next_latency(lvl), self.mem_noise,
                             n_samples, key)
        return self._lat(lvl.latency, lvl.noise, n_samples, key)

    def sharing_probe(self, space_a: str, space_b: str, array_bytes: int,
                      n_samples: int) -> np.ndarray:
        """Step-3 latencies of the Physical Sharing workflow (§IV-G):
        spaces on the same physical cache evict each other."""
        la, lb = self.level(space_a), self.level(space_b)
        shared = la.group == lb.group
        evicted = shared and 2 * array_bytes > la.size
        key = ("sharing", space_a, space_b, int(array_bytes), int(n_samples))
        if evicted:
            return self._lat(self._next_latency(la), self.mem_noise,
                             n_samples, key)
        return self._lat(la.latency, la.noise, n_samples, key)

    def cu_sharing_probe(self, cu_a: int, cu_b: int, array_bytes: int,
                         n_samples: int, space: str = "sL1d") -> np.ndarray:
        """AMD-style sL1d sharing across CU ids (§IV-H)."""
        lvl = self.level(space)
        group_of = self._cu_group_of
        shared = (cu_a in group_of and cu_b in group_of
                  and group_of[cu_a] == group_of[cu_b] and cu_a != cu_b)
        evicted = shared and 2 * array_bytes > lvl.size
        key = ("cu", space, int(cu_a), int(cu_b), int(array_bytes),
               int(n_samples))
        if evicted:
            return self._lat(self._next_latency(lvl), self.mem_noise,
                             n_samples, key)
        return self._lat(lvl.latency, lvl.noise, n_samples, key)

    def cu_sharing_probe_batch(self, cu_a: int, cu_bs, array_bytes: int,
                               n_samples: int,
                               space: str = "sL1d") -> np.ndarray:
        """One leader's whole §IV-H candidate row in a single call.

        Row i is bit-identical to ``cu_sharing_probe(cu_a, cu_bs[i], ...)``
        (request-keyed streams); batching removes the per-pair dispatch of
        the O(n²) pairwise sweep — the dominant cost on MI210-style devices.
        """
        lvl = self.level(space)
        group_of = self._cu_group_of
        ga = group_of.get(cu_a)
        next_lat = self._next_latency(lvl)
        over = 2 * array_bytes > lvl.size
        means = np.empty(len(cu_bs))
        noises = np.empty(len(cu_bs))
        keys = []
        for i, cu_b in enumerate(cu_bs):
            shared = (ga is not None and group_of.get(cu_b) == ga
                      and cu_a != cu_b)
            if shared and over:
                means[i], noises[i] = next_lat, self.mem_noise
            else:
                means[i], noises[i] = lvl.latency, lvl.noise
            keys.append(("cu", space, int(cu_a), int(cu_b),
                         int(array_bytes), int(n_samples)))
        return self._lat_rows(means, noises, int(n_samples), keys)

    def eviction_many(self, requests, n_samples: int) -> np.ndarray:
        """Heterogeneous eviction-pattern batch (§IV-F/G/H in one call).

        ``requests`` mixes rows of three kinds::

            ("amount",  space, core_a, core_b, array_bytes)
            ("sharing", space_a, space_b, array_bytes)
            ("cu",      space, cu_a, cu_b, array_bytes)

        Row i is bit-identical to the matching single-probe call
        (``amount_probe`` / ``sharing_probe`` / ``cu_sharing_probe``): each
        row reuses that probe's request-keyed stream, so fusing mixed
        eviction families into one dispatch is result-invisible — the
        eviction twin of ``pchase_many``.
        """
        means = np.empty(len(requests))
        noises = np.empty(len(requests))
        keys = []
        for i, req in enumerate(requests):
            kind = req[0]
            if kind == "amount":
                _, space, core_a, core_b, ab = req
                lvl = self.level(space)
                evicted = self.amount_evicted(space, core_a, core_b, ab)
                keys.append(("amount", space, int(core_a), int(core_b),
                             int(ab), int(n_samples)))
            elif kind == "sharing":
                _, space_a, space_b, ab = req
                lvl = self.level(space_a)
                evicted = self.sharing_evicted(space_a, space_b, ab)
                keys.append(("sharing", space_a, space_b, int(ab),
                             int(n_samples)))
            elif kind == "cu":
                _, space, cu_a, cu_b, ab = req
                lvl = self.level(space)
                evicted = self.cu_sharing_evicted(cu_a, cu_b, ab, space)
                keys.append(("cu", space, int(cu_a), int(cu_b), int(ab),
                             int(n_samples)))
            else:
                raise ValueError(f"unknown eviction request kind: {kind!r}")
            if evicted:
                means[i], noises[i] = self._next_latency(lvl), self.mem_noise
            else:
                means[i], noises[i] = lvl.latency, lvl.noise
        return self._lat_rows(means, noises, int(n_samples), keys)

    def bandwidth(self, space: str, mode: str = "read") -> float:
        table = self.read_bw if mode == "read" else self.write_bw
        base = table.get(space)
        if base is None:
            raise KeyError(f"{self.name}: no {mode} bandwidth for '{space}'")
        seeds = self._sampler.row_seeds([("bw", space, mode)])
        return float(base * (1.0 + 0.02 * self._sampler.normals(seeds, 1)[0, 0]))

    # ------------------------------------------------------ ground truth
    def ground_truth(self) -> dict[str, dict]:
        gt = {}
        for l in self.levels:
            gt[l.name] = {
                "size": l.size, "latency": l.latency, "line_size": l.line_size,
                "fetch_granularity": l.fetch_granularity, "amount": l.amount,
                "physical_group": l.group, "scope": l.scope,
            }
        gt["DeviceMemory"] = {"latency": self.mem_latency}
        return gt


# --------------------------------------------------------------------------
# Virtual devices mirroring paper Table III ground truth.
# --------------------------------------------------------------------------

def make_h100_like(seed: int = 0) -> SimDevice:
    """NVIDIA H100-like hierarchy (paper Table III, top)."""
    kib, mib, gib = 1024, 1024**2, 1024**3
    levels = [
        SimLevel("ConstL1", 2 * kib, 21.0, 64, 64, noise=0.8,
                 physical_group="const-path", path="const"),
        SimLevel("ConstL1.5", 64 * kib, 105.0, 256, 256, noise=2.0,
                 physical_group="const-path15", path="const"),
        SimLevel("L1", 238 * kib, 38.0, 128, 32, noise=1.5,
                 physical_group="unified-l1"),
        SimLevel("Texture", 238 * kib, 39.0, 128, 32, noise=1.5,
                 physical_group="unified-l1"),
        SimLevel("Readonly", 238 * kib, 35.0, 128, 32, noise=1.5,
                 physical_group="unified-l1"),
        SimLevel("SharedMem", 228 * kib, 30.0, 4, 4, noise=0.6,
                 kind="scratchpad"),
        SimLevel("L2", 50 * mib, 220.0, 128, 32, amount=2, scope="chip",
                 noise=6.0),
    ]
    return SimDevice(
        name="sim-h100", vendor="NVIDIA", levels=levels,
        mem_latency=843.0, mem_noise=25.0,
        read_bw={"L2": 4.4e12, "DeviceMemory": 2.5e12},
        write_bw={"L2": 3.4e12, "DeviceMemory": 2.7e12},
        cores_per_sm=128,
        space_of_level={"global": "L1", "DeviceMemory": "L2"},
        seed=seed,
    )


def make_mi210_like(seed: int = 0) -> SimDevice:
    """AMD MI210-like hierarchy (paper Table III, bottom). 104 active CUs out
    of 128 physical ids -> some CUs have exclusive sL1d (paper §IV-H)."""
    kib, mib = 1024, 1024**2
    levels = [
        SimLevel("vL1", 16 * kib, 125.0, 64, 64, noise=2.0),
        SimLevel("sL1d", 16 * kib, 50.0, 64, 64, noise=1.0),
        SimLevel("LDS", 64 * kib, 55.0, 4, 4, noise=0.8, kind="scratchpad"),
        SimLevel("L2", 8 * mib, 310.0, 128, 64, amount=1, scope="chip",
                 noise=8.0),
    ]
    # Physical CU ids 0..127 in pairs sharing sL1d; ids >= 104 inactive, and a
    # few odd ids disabled so their partner has exclusive sL1d.
    groups, disabled = [], {9, 33, 57, 81}
    for base in range(0, 104, 2):
        pair = [cu for cu in (base, base + 1) if cu not in disabled]
        groups.append(pair)
    return SimDevice(
        name="sim-mi210", vendor="AMD", levels=levels,
        mem_latency=748.0, mem_noise=20.0,
        read_bw={"L2": 4.19e12, "DeviceMemory": 1.0e12},
        write_bw={"L2": 2.4e12, "DeviceMemory": 0.9e12},
        cores_per_sm=64,
        cu_share_groups=groups,
        space_of_level={"global": "vL1", "DeviceMemory": "L2"},
        seed=seed,
    )


def make_v5e_like(seed: int = 0) -> SimDevice:
    """TPU v5e-like hierarchy: compiler-managed VMEM + CMEM-less HBM path.

    TPUs have no hardware-managed data cache between VMEM and HBM; the "size
    cliff" the probes detect is the VMEM working-set limit (DESIGN.md §2,
    adaptation note 2)."""
    mib = 1024**2
    levels = [
        SimLevel("SMEM", 1 * mib // 8, 8.0, 4, 4, noise=0.3, kind="scratchpad"),
        SimLevel("VMEM", 16 * mib, 20.0, 512, 512, noise=0.8,
                 kind="scratchpad"),
    ]
    return SimDevice(
        name="sim-v5e", vendor="Google", levels=levels,
        mem_latency=500.0, mem_noise=15.0,
        read_bw={"VMEM": 20e12, "DeviceMemory": 0.819e12},
        write_bw={"VMEM": 20e12, "DeviceMemory": 0.78e12},
        cores_per_sm=1,
        space_of_level={"global": "VMEM", "DeviceMemory": "VMEM"},
        seed=seed,
    )


SIM_DEVICES = {
    "sim-h100": make_h100_like,
    "sim-mi210": make_mi210_like,
    "sim-v5e": make_v5e_like,
}
