"""Statistical auto-evaluation machinery (paper contribution C3)."""
from .ks import KSResult, ks_2samp, ks_critical_value, ks_pvalue, ks_statistic
from .reduction import geometric_reduction, reduce_rows
from .cpd import ChangePoint, cusum_change_point, ks_change_point, pelt_segments
from .outliers import (OutlierReport, boundary_suspect, detect_outliers,
                       mad_gate, winsorize)
from .batch import (classify_miss_rows, ks_2samp_rows, ks_change_point_scan,
                    ks_scan, ks_statistic_rows)

__all__ = [
    "KSResult", "ks_2samp", "ks_critical_value", "ks_pvalue", "ks_statistic",
    "geometric_reduction", "reduce_rows",
    "ChangePoint", "cusum_change_point", "ks_change_point", "pelt_segments",
    "OutlierReport", "boundary_suspect", "detect_outliers", "mad_gate",
    "winsorize",
    "classify_miss_rows", "ks_2samp_rows", "ks_change_point_scan", "ks_scan",
    "ks_statistic_rows",
]
