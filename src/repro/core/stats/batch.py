"""Vectorized sample-set statistics for the probe engine.

The probe engine runs the same statistics the paper describes — the K-S
change-point scan (§IV-B step 4) and K-S hit/miss classification (§IV-F/G/H)
— but over whole sample matrices at once instead of one Python-level
``ks_2samp`` call per candidate/probe:

* ``ks_scan``            — every candidate split of a reduced series in one
                           broadcasted ECDF pass (the legacy scan makes ~N
                           ``ks_2samp`` calls, the dominant cost of
                           ``find_size``);
* ``ks_change_point_scan`` — drop-in for ``ks_change_point`` built on it,
                           bit-identical decisions;
* ``ks_statistic_rows``  — per-row K-S statistic of a probe matrix against a
                           shared reference distribution;
* ``classify_miss_rows`` — the §IV-F/G/H hit-vs-miss classifier, vectorized
                           over many probes (the O(n²) CU-sharing sweep).

Exactness matters: the engine must produce the same topology as the legacy
sequential loop, so every function here reproduces its scalar counterpart's
arithmetic (integer ECDF counts divided by segment sizes, tie handling via
right-continuous ECDFs) rather than approximating it.
"""
from __future__ import annotations

import numpy as np

from .cpd import ChangePoint, _l1_refine
from .ks import ks_2samp, ks_statistic

__all__ = ["ks_scan", "ks_change_point_scan", "ks_statistic_rows",
           "ks_2samp_rows", "classify_miss_rows"]


def ks_scan(series: np.ndarray, alpha: float = 0.01,
            min_segment: int = 3) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """K-S statistic of every admissible split of ``series`` in one pass.

    Returns ``(idxs, d, crit)`` where ``d[i]`` equals
    ``ks_statistic(series[:idxs[i]], series[idxs[i]:])`` exactly and ``crit``
    is the per-split critical value (eq. 1).

    Method: sort the series once; for split index k, the left segment's ECDF
    evaluated at the j-th smallest element is ``|{sorted[:j+1]} ∩ left| / k``
    — a cumulative sum of a boolean membership matrix, broadcast over all
    candidate splits at once.  Ties are handled by only evaluating at the
    right edge of each tie group, which is where a right-continuous ECDF
    difference is attained.
    """
    s = np.asarray(series, dtype=np.float64).ravel()
    n = s.size
    idxs = np.arange(min_segment, n - min_segment + 1)
    if n < 2 * min_segment or idxs.size == 0:
        return np.zeros(0, np.int64), np.zeros(0), np.zeros(0)

    order = np.argsort(s, kind="stable")
    sorted_s = s[order]
    # membership[i, j]: does the j-th smallest element belong to the left
    # segment of split idxs[i]?  (left segment = original indices < idxs[i])
    membership = order[None, :] < idxs[:, None]
    left_counts = np.cumsum(membership, axis=1)
    pos = np.arange(1, n + 1)[None, :]
    cdf_l = left_counts / idxs[:, None].astype(np.float64)
    cdf_r = (pos - left_counts) / (n - idxs)[:, None].astype(np.float64)
    diff = np.abs(cdf_l - cdf_r)
    # Right-continuous ECDF: within a tie group only the last position holds
    # the full count both sides agree on; mask the rest.
    tie_edge = np.concatenate([sorted_s[:-1] < sorted_s[1:], [True]])
    diff[:, ~tie_edge] = 0.0
    d = diff.max(axis=1)
    crit = np.sqrt(-0.5 * (n / (idxs * (n - idxs))) * np.log(alpha / 2.0))
    return idxs, d, crit


def ks_change_point_scan(series: np.ndarray, alpha: float = 0.01,
                         min_segment: int = 3,
                         mode: str = "best") -> ChangePoint:
    """Vectorized drop-in for ``ks_change_point`` (same decisions).

    The scan produces the full (D, d_alpha) vectors; the decision logic —
    best-score selection, the L1 boundary refinement, and the final
    ``ks_2samp`` at the chosen index — is identical to the sequential
    implementation, so a fixed input yields a bit-identical ``ChangePoint``.
    """
    s = np.asarray(series, dtype=np.float64).ravel()
    n = s.size
    if n < 2 * min_segment:
        return ChangePoint(-1, False, 0.0, 1.0, 0.0, alpha)

    idxs, d, crit = ks_scan(s, alpha=alpha, min_segment=min_segment)
    reject = d > crit
    rejected = [int(i) for i in idxs[reject]]

    if mode == "first" and rejected:
        first = rejected[0]
        res = ks_2samp(s[:first], s[first:], alpha=alpha)
        upto = [r for r in rejected if r <= first]
        return ChangePoint(first, True, res.statistic, res.pvalue,
                           res.confidence, alpha, upto)

    score = d / np.maximum(crit, 1e-12)
    best_i = int(np.argmax(score))        # first max, like the scalar loop
    best_idx = int(idxs[best_i])

    if reject[best_i]:
        refined = _l1_refine(s, best_idx, window=max(3, n // 10),
                             min_segment=min_segment)
        best = ks_2samp(s[:refined], s[refined:], alpha=alpha)
        return ChangePoint(refined, True, best.statistic, best.pvalue,
                           best.confidence, alpha, rejected)
    best = ks_2samp(s[:best_idx], s[best_idx:], alpha=alpha)
    return ChangePoint(-1, False, best.statistic, best.pvalue, 0.0, alpha,
                       rejected)


def ks_statistic_rows(rows: np.ndarray, ref: np.ndarray) -> np.ndarray:
    """Per-row two-sample K-S statistic against one shared reference.

    ``out[i] == ks_statistic(rows[i], ref)`` exactly, for a (k, n) probe
    matrix and an m-sample reference, via one argsort over the pooled
    (k, n+m) matrix.
    """
    rows = np.asarray(rows, dtype=np.float64)
    if rows.ndim == 1:
        rows = rows[None, :]
    ref = np.asarray(ref, dtype=np.float64).ravel()
    k, n = rows.shape
    m = ref.size
    if n == 0 or m == 0:
        raise ValueError("ks_statistic_rows needs non-empty samples")

    pooled = np.concatenate([rows, np.broadcast_to(ref, (k, m))], axis=1)
    order = np.argsort(pooled, axis=1, kind="stable")
    sorted_pool = np.take_along_axis(pooled, order, axis=1)
    row_counts = np.cumsum(order < n, axis=1)
    pos = np.arange(1, n + m + 1)[None, :]
    diff = np.abs(row_counts / n - (pos - row_counts) / m)
    tie_edge = np.concatenate(
        [sorted_pool[:, :-1] < sorted_pool[:, 1:], np.ones((k, 1), bool)],
        axis=1)
    diff[~tie_edge] = 0.0
    return diff.max(axis=1)


def ks_2samp_rows(rows: np.ndarray, ref: np.ndarray,
                  alpha: float = 0.05) -> tuple[np.ndarray, np.ndarray]:
    """(statistic, reject) arrays of per-row K-S tests vs a shared reference."""
    rows = np.asarray(rows, dtype=np.float64)
    if rows.ndim == 1:
        rows = rows[None, :]
    ref = np.asarray(ref, dtype=np.float64).ravel()
    d = ks_statistic_rows(rows, ref)
    n, m = rows.shape[1], ref.size
    crit = np.sqrt(-0.5 * ((n + m) / (n * m)) * np.log(alpha / 2.0))
    return d, d > crit


def classify_miss_rows(rows: np.ndarray, hit_ref: np.ndarray,
                       miss_ref: np.ndarray,
                       alpha: float = 0.01) -> np.ndarray:
    """Vectorized §IV-F/G/H hit-vs-miss classification.

    ``out[i]`` reproduces ``probes.amount._is_miss(rows[i], hit_ref,
    miss_ref, alpha)``: K-S against both references; when both or neither
    reject, fall back to median proximity.
    """
    rows = np.asarray(rows, dtype=np.float64)
    if rows.ndim == 1:
        rows = rows[None, :]
    _, differs_hit = ks_2samp_rows(rows, hit_ref, alpha=alpha)
    _, differs_miss = ks_2samp_rows(rows, miss_ref, alpha=alpha)

    is_miss = differs_hit & ~differs_miss
    ambiguous = ~(differs_hit ^ differs_miss)
    if np.any(ambiguous):
        # Median proximity in LOG space, matching ``amount._is_miss``:
        # multiplicative drift on measuring backends scales whole rows, and
        # the log distance keeps the hit/miss midpoint drift-symmetric.
        pm = np.maximum(np.median(rows[ambiguous], axis=1), 1e-12)
        hm = max(float(np.median(hit_ref)), 1e-12)
        mm = max(float(np.median(miss_ref)), 1e-12)
        is_miss[ambiguous] = np.abs(np.log(pm / mm)) < np.abs(np.log(pm / hm))
    return is_miss
