"""Change-point detection (paper §II-C, §IV-B step 4).

The primary detector is the K-S scan the paper describes: every index of the
reduced series S is a candidate change point; the two-sample K-S test compares
the sub-series left and right of the candidate; the candidate with the most
significant rejection wins, and its significance is reported as a confidence
metric.

Two "other algorithms" the paper cites are provided for cross-checks and for
distributions where they are better suited:

* ``cusum``  — parametric mean-shift detector (Page's cumulative sum).
* ``pelt``   — Pruned Exact Linear Time segmentation with an L2 cost, for
               multi-change-point segmentation (Killick et al.).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from .ks import KSResult, ks_2samp

__all__ = ["ChangePoint", "ks_change_point", "cusum_change_point", "pelt_segments"]


@dataclass(frozen=True)
class ChangePoint:
    """A detected change point in a 1-D series.

    ``index`` is the first index belonging to the *new* regime, i.e. the
    series is segmented as ``s[:index] | s[index:]``.
    """

    index: int
    found: bool
    statistic: float
    pvalue: float
    confidence: float
    alpha: float
    candidates: list[int] = field(default_factory=list)  # all rejected indices


def _l1_refine(s: np.ndarray, idx: int, window: int, min_segment: int) -> int:
    """Refine a candidate change point within +-window using a robust L1 cost.

    The K-S scan locates the regime change; minimizing the sum of absolute
    deviations from per-segment medians pinpoints the boundary and is immune
    to lone outliers (unlike an L2 refinement).

    Vectorized over the whole candidate window (one masked median + one
    masked reduction per side instead of a Python loop per candidate — the
    last per-candidate loop in the change-point path).  Ties resolve to the
    first (lowest) candidate index, like the sequential scan did; float
    summation order differs from the old per-candidate loop, so results can
    flip on exact cost ties — which is why the engine==legacy contract is
    discrete attributes + rel-tol floats, not bit equality.
    """
    n = s.size
    lo = max(min_segment, idx - window)
    hi = min(n - min_segment, idx + window)
    if hi < lo:
        return idx
    idxs = np.arange(lo, hi + 1)
    left_mask = np.arange(n)[None, :] < idxs[:, None]     # (W, n)

    def masked_medians(mask: np.ndarray, sizes: np.ndarray) -> np.ndarray:
        """Per-row median of the masked elements: pad the complement with
        +inf, sort, and average the two middle positions of each row."""
        padded = np.where(mask, s[None, :], np.inf)
        padded.sort(axis=1)
        lo_mid = (sizes - 1) // 2
        hi_mid = sizes // 2
        rows = np.arange(sizes.size)
        return 0.5 * (padded[rows, lo_mid] + padded[rows, hi_mid])

    left_med = masked_medians(left_mask, idxs)
    right_med = masked_medians(~left_mask, n - idxs)
    cost = (np.where(left_mask, np.abs(s[None, :] - left_med[:, None]),
                     0.0).sum(axis=1)
            + np.where(left_mask, 0.0,
                       np.abs(s[None, :] - right_med[:, None])).sum(axis=1))
    return int(idxs[np.argmin(cost)])


def ks_change_point(
    series: np.ndarray,
    alpha: float = 0.01,
    min_segment: int = 3,
    mode: str = "best",
) -> ChangePoint:
    """Scan every admissible index with the two-sample K-S test.

    Args:
      series: 1-D reduced series (eq. 2 output) or raw scalar measurements.
      alpha: significance level for rejecting H0 (same distribution).
      min_segment: minimum samples required on each side of a candidate.
      mode: "best" returns the most significant rejected candidate (max
        D/d_alpha ratio); "first" returns the first rejected index, matching
        the paper's "denies the null hypothesis when reaching the index of the
        actual change point" phrasing. Both are exposed; "best" is the default
        because it is strictly more outlier-robust.
    """
    s = np.asarray(series, dtype=np.float64).ravel()
    n = s.size
    if n < 2 * min_segment:
        return ChangePoint(-1, False, 0.0, 1.0, 0.0, alpha)

    best: KSResult | None = None
    best_idx = -1
    rejected: list[int] = []
    for idx in range(min_segment, n - min_segment + 1):
        res = ks_2samp(s[:idx], s[idx:], alpha=alpha)
        if res.reject:
            rejected.append(idx)
            if mode == "first":
                return ChangePoint(idx, True, res.statistic, res.pvalue,
                                   res.confidence, alpha, rejected)
        score = res.statistic / max(res.critical_value, 1e-12)
        if best is None or score > best.statistic / max(best.critical_value, 1e-12):
            best, best_idx = res, idx

    if best is not None and best.reject:
        refined = _l1_refine(s, best_idx, window=max(3, n // 10), min_segment=min_segment)
        if refined != best_idx:
            best = ks_2samp(s[:refined], s[refined:], alpha=alpha)
            best_idx = refined
        return ChangePoint(best_idx, True, best.statistic, best.pvalue,
                           best.confidence, alpha, rejected)
    stat = best.statistic if best else 0.0
    pval = best.pvalue if best else 1.0
    return ChangePoint(-1, False, stat, pval, 0.0, alpha, rejected)


def cusum_change_point(series: np.ndarray, threshold_sigmas: float = 5.0) -> ChangePoint:
    """Page's CUSUM for a mean shift; parametric cross-check for the K-S scan."""
    s = np.asarray(series, dtype=np.float64).ravel()
    n = s.size
    if n < 4:
        return ChangePoint(-1, False, 0.0, 1.0, 0.0, 0.0)
    mu = float(np.mean(s))
    sigma = float(np.std(s)) or 1e-12
    # Cumulative sums of deviations; the change point is where |C| peaks.
    c = np.cumsum(s - mu)
    idx = int(np.argmax(np.abs(c)))
    # Bootstrap-free significance proxy: peak magnitude in sigma units,
    # normalized by the random-walk expectation sqrt(n)/2.
    stat = float(np.abs(c[idx]) / (sigma * max(np.sqrt(n) / 2.0, 1.0)))
    found = stat > threshold_sigmas / np.sqrt(n) * np.sqrt(n)  # == threshold
    found = stat > threshold_sigmas
    cp = idx + 1  # first index of the new regime
    conf = max(0.0, stat / threshold_sigmas - 1.0)
    return ChangePoint(cp if found else -1, bool(found), stat, 0.0 if found else 1.0,
                       conf, 0.0)


def _l2_cost(prefix: np.ndarray, prefix_sq: np.ndarray, lo: int, hi: int) -> float:
    """Sum of squared deviations of s[lo:hi] from its own mean (O(1))."""
    n = hi - lo
    if n <= 0:
        return 0.0
    seg_sum = prefix[hi] - prefix[lo]
    seg_sq = prefix_sq[hi] - prefix_sq[lo]
    return float(seg_sq - seg_sum * seg_sum / n)


def pelt_segments(series: np.ndarray, penalty: float | None = None) -> list[int]:
    """PELT multi-change-point segmentation with an L2 (mean-shift) cost.

    Returns the sorted list of change-point indices (first index of each new
    segment), excluding 0 and n. ``penalty`` defaults to the BIC-style
    ``2 * var * log(n)``.
    """
    s = np.asarray(series, dtype=np.float64).ravel()
    n = s.size
    if n < 4:
        return []
    if penalty is None:
        penalty = 2.0 * float(np.var(s)) * np.log(n) + 1e-12
    prefix = np.concatenate([[0.0], np.cumsum(s)])
    prefix_sq = np.concatenate([[0.0], np.cumsum(s * s)])

    f = np.full(n + 1, np.inf)
    f[0] = -penalty
    last = np.zeros(n + 1, dtype=np.int64)
    candidates = [0]
    for t in range(1, n + 1):
        best_cost, best_tau = np.inf, 0
        for tau in candidates:
            c = f[tau] + _l2_cost(prefix, prefix_sq, tau, t) + penalty
            if c < best_cost:
                best_cost, best_tau = c, tau
        f[t] = best_cost
        last[t] = best_tau
        # PELT pruning: drop candidates that can never be optimal again.
        candidates = [
            tau for tau in candidates
            if f[tau] + _l2_cost(prefix, prefix_sq, tau, t) <= f[t]
        ] + [t]

    # Backtrack.
    cps: list[int] = []
    t = n
    while t > 0:
        tau = int(last[t])
        if tau > 0:
            cps.append(tau)
        t = tau
    return sorted(cps)
