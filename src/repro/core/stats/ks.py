"""Two-sample Kolmogorov-Smirnov test (paper §II-C.1, eq. 1).

MT4G uses the K-S test as its primary change-point detector because it is
non-parametric: no assumption is made about the latency distributions
produced by the probes. We implement the exact two-sample statistic

    D = max_x |F(x) - G(x)|

and the critical-value approximation the paper cites from Wilcox (eq. 1):

    d_alpha = sqrt( -1/2 * (n+m)/(n*m) * ln(alpha/2) )

(the paper prints ``log(alpha/2)`` — for alpha < 1 this is negative, so the
minus sign is implied by taking the magnitude; we make it explicit).

An asymptotic p-value is provided through the Kolmogorov distribution

    Q(lam) = 2 * sum_{k>=1} (-1)^(k-1) exp(-2 k^2 lam^2).
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import numpy as np

__all__ = ["KSResult", "ks_statistic", "ks_critical_value", "ks_pvalue", "ks_2samp"]


@dataclass(frozen=True)
class KSResult:
    """Outcome of a two-sample K-S test."""

    statistic: float          # D = sup |F - G|
    critical_value: float     # d_alpha for the requested alpha
    pvalue: float             # asymptotic p-value
    alpha: float              # significance level used for the decision
    reject: bool              # True -> distributions differ (H0 rejected)
    n: int                    # size of the first sample
    m: int                    # size of the second sample

    @property
    def confidence(self) -> float:
        """MT4G-style confidence metric: how far D exceeds d_alpha (>=0)."""
        if self.critical_value <= 0:
            return 0.0
        return max(0.0, (self.statistic - self.critical_value) / self.critical_value)


def ks_statistic(a: np.ndarray, b: np.ndarray) -> float:
    """Exact two-sample K-S statistic D = max|F_a - F_b| (O((n+m) log(n+m)))."""
    a = np.asarray(a, dtype=np.float64).ravel()
    b = np.asarray(b, dtype=np.float64).ravel()
    n, m = a.size, b.size
    if n == 0 or m == 0:
        raise ValueError("ks_statistic needs non-empty samples")
    a = np.sort(a)
    b = np.sort(b)
    # Evaluate both ECDFs on the pooled support.
    pooled = np.concatenate([a, b])
    cdf_a = np.searchsorted(a, pooled, side="right") / n
    cdf_b = np.searchsorted(b, pooled, side="right") / m
    return float(np.max(np.abs(cdf_a - cdf_b)))


def ks_critical_value(n: int, m: int, alpha: float = 0.05) -> float:
    """Critical value d_alpha per paper eq. 1 (Wilcox approximation)."""
    if not 0.0 < alpha < 1.0:
        raise ValueError(f"alpha must be in (0,1), got {alpha}")
    if n <= 0 or m <= 0:
        raise ValueError("sample sizes must be positive")
    return math.sqrt(-0.5 * (n + m) / (n * m) * math.log(alpha / 2.0))


def ks_pvalue(d: float, n: int, m: int, _terms: int = 100) -> float:
    """Asymptotic two-sample p-value via the Kolmogorov distribution."""
    if d <= 0.0:
        return 1.0
    en = math.sqrt(n * m / (n + m))
    lam = (en + 0.12 + 0.11 / en) * d  # Stephens' small-sample correction
    total = 0.0
    for k in range(1, _terms + 1):
        term = 2.0 * (-1.0) ** (k - 1) * math.exp(-2.0 * k * k * lam * lam)
        total += term
        if abs(term) < 1e-12:
            break
    return float(min(max(total, 0.0), 1.0))


def ks_2samp(a: np.ndarray, b: np.ndarray, alpha: float = 0.05) -> KSResult:
    """Full two-sample K-S test: statistic, critical value, p, decision."""
    a = np.asarray(a, dtype=np.float64).ravel()
    b = np.asarray(b, dtype=np.float64).ravel()
    d = ks_statistic(a, b)
    crit = ks_critical_value(a.size, b.size, alpha)
    p = ks_pvalue(d, a.size, b.size)
    return KSResult(
        statistic=d,
        critical_value=crit,
        pvalue=p,
        alpha=alpha,
        reject=d > crit,
        n=a.size,
        m=b.size,
    )
