"""Outlier handling for probe sweeps (paper §IV-B workflow step 3).

MT4G checks raw sweep results for outliers — e.g. a cache boundary sitting at
the edge of the searched interval, or a disturbance spike — and widens the
search interval / re-measures when they are found. These helpers implement the
decision logic; the re-measurement loop lives in ``core.probes.size``.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

__all__ = ["OutlierReport", "detect_outliers", "boundary_suspect", "winsorize",
           "mad_gate"]


@dataclass(frozen=True)
class OutlierReport:
    indices: np.ndarray        # indices flagged as outliers
    fraction: float            # |outliers| / n
    lo_fence: float
    hi_fence: float

    @property
    def any(self) -> bool:
        return self.indices.size > 0


def detect_outliers(series: np.ndarray, k: float = 3.0) -> OutlierReport:
    """Tukey-fence outlier detection on a 1-D series (k=3 -> 'far out')."""
    s = np.asarray(series, dtype=np.float64).ravel()
    if s.size < 4:
        return OutlierReport(np.zeros(0, np.int64), 0.0, -np.inf, np.inf)
    q1, q3 = np.percentile(s, [25, 75])
    iqr = max(q3 - q1, 1e-12)
    lo, hi = q1 - k * iqr, q3 + k * iqr
    idx = np.where((s < lo) | (s > hi))[0]
    return OutlierReport(idx, idx.size / s.size, float(lo), float(hi))


def boundary_suspect(series: np.ndarray, edge: int = 2, k: float = 3.0) -> bool:
    """True if a distribution change sits suspiciously close to the interval
    edge (paper: 'outliers, especially ones caused by cache sizes close to one
    of the boundaries') — signals the caller to widen the interval."""
    s = np.asarray(series, dtype=np.float64).ravel()
    if s.size < 2 * edge + 2:
        return False
    rep = detect_outliers(s, k=k)
    if not rep.any:
        return False
    n = s.size
    return bool(np.any(rep.indices < edge) or np.any(rep.indices >= n - edge))


def winsorize(series: np.ndarray, pct: float = 1.0) -> np.ndarray:
    """Clamp the extreme ``pct`` percent on each tail (used before CUSUM,
    which unlike K-S is not outlier-robust)."""
    s = np.asarray(series, dtype=np.float64).ravel()
    lo, hi = np.percentile(s, [pct, 100.0 - pct])
    return np.clip(s, lo, hi)


def mad_gate(series: np.ndarray, k: float = 5.0) -> np.ndarray:
    """Drop samples beyond ``k`` robust standard deviations from the median
    (MAD scaled by 1.4826), the resilience layer's pre-adjudication gate
    against chaos-style outlier spikes.

    Unlike ``winsorize`` this *removes* rows instead of clamping, so a
    single 8x throttle spike cannot drag a K-S verdict; the series is
    returned unchanged when it is too short to judge (< 4), when the MAD is
    zero (constant samples), or when the gate would drop everything.
    """
    s = np.asarray(series, dtype=np.float64).ravel()
    if s.size < 4:
        return s
    med = np.median(s)
    mad = np.median(np.abs(s - med))
    if mad <= 0:
        return s
    keep = np.abs(s - med) <= k * 1.4826 * mad
    if not np.any(keep):
        return s
    return s[keep]
