"""Dimensionality reduction for multi-dimensional probe results (paper eq. 2).

Each probe configuration i (e.g. one p-chase array size) yields a vector of N
per-load latencies r_{i,0..N-1}. MT4G reduces each vector to one scalar with
the geometrically inspired mapping of Grundy et al.:

    S_i = sqrt( sum_j (r_ij - min(r))^2 )

where min(r) is the *global* minimum over the whole 2-D result array. The
reduced 1-D series S is what the K-S change-point detector consumes. Compared
to mean/max, the mapping amplifies distribution-shape changes while staying
robust to single outliers (paper Fig. 2).
"""
from __future__ import annotations

import numpy as np

__all__ = ["geometric_reduction", "reduce_rows"]


def geometric_reduction(results: np.ndarray, global_min: float | None = None) -> np.ndarray:
    """Reduce a (num_configs, N) latency array to a (num_configs,) series.

    ``global_min`` can be supplied when reducing incrementally (e.g. while the
    search interval is being widened) so all chunks share one reference.
    """
    r = np.asarray(results, dtype=np.float64)
    if r.ndim == 1:
        r = r[None, :]
    if r.ndim != 2:
        raise ValueError(f"expected 2-D (configs, samples), got shape {r.shape}")
    gmin = float(np.min(r)) if global_min is None else float(global_min)
    return np.sqrt(np.sum((r - gmin) ** 2, axis=1))


def reduce_rows(rows: list[np.ndarray]) -> np.ndarray:
    """Reduce ragged per-config latency vectors (lengths may differ).

    Rows are normalized by sqrt(N) so configs measured with different sample
    counts remain comparable; with equal lengths this is a monotone rescale of
    eq. 2 and leaves the K-S change point unchanged.
    """
    if not rows:
        return np.zeros((0,))
    gmin = min(float(np.min(np.asarray(r))) for r in rows if np.asarray(r).size)
    out = np.empty(len(rows))
    for i, row in enumerate(rows):
        row = np.asarray(row, dtype=np.float64)
        out[i] = np.sqrt(np.sum((row - gmin) ** 2) / max(row.size, 1))
    return out
