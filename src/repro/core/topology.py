"""Backend-agnostic topology data model (paper contribution C1).

MT4G unifies NVIDIA and AMD reports into one schema covering general,
compute, and memory information (paper §III). We keep that schema and extend
it with interconnect links, because on a TPU pod the ICI/DCN fabric is the
dominant "memory element" between chips.

Every attribute records its *provenance* — ``api`` (read from an interface),
``benchmark`` (reverse-engineered via probes), ``catalog`` (vendor datasheet)
— and benchmark-derived attributes carry the confidence metric emitted by the
K-S change-point machinery, mirroring the paper's reporting.
"""
from __future__ import annotations

import json
from dataclasses import asdict, dataclass, field
from typing import Any

__all__ = [
    "Attribute", "MemoryElement", "ComputeElement", "Link", "Topology",
    "topology_equivalent",
    "PROVENANCE_API", "PROVENANCE_BENCHMARK", "PROVENANCE_CATALOG",
    "PROVENANCE_DEGRADED",
]

PROVENANCE_API = "api"
PROVENANCE_BENCHMARK = "benchmark"
PROVENANCE_CATALOG = "catalog"
# An attribute whose probes exhausted the retry budget: value is "unknown",
# diagnostics ride in the element notes, discovery completes anyway.
PROVENANCE_DEGRADED = "degraded"


def _plain(value: Any) -> Any:
    """Canonicalize a value for JSON: numpy scalars/arrays -> native types,
    tuples -> lists, recursively.  Serialization must round-trip bit-equal
    (the topology store keys on it), so everything that reaches disk goes
    through here."""
    if isinstance(value, (list, tuple)):
        return [_plain(v) for v in value]
    if hasattr(value, "tolist"):           # numpy array
        return _plain(value.tolist())
    if hasattr(value, "item"):             # numpy scalar
        return value.item()
    return value


@dataclass
class Attribute:
    """One measured/reported attribute with provenance + confidence."""

    value: Any
    unit: str = ""
    provenance: str = PROVENANCE_BENCHMARK
    confidence: float | None = None  # None for API/catalog values

    def to_json(self) -> dict:
        d = {"value": _plain(self.value), "unit": self.unit,
             "provenance": self.provenance}
        if self.confidence is not None:
            # Full precision: the store's round-trip guarantee is bit-equal,
            # including confidence (display rounding happens in to_markdown).
            d["confidence"] = float(self.confidence)
        return d

    @classmethod
    def from_json(cls, d: dict) -> "Attribute":
        return cls(d["value"], d.get("unit", ""), d.get("provenance", PROVENANCE_BENCHMARK),
                   d.get("confidence"))


@dataclass
class MemoryElement:
    """A cache/scratchpad/memory level (paper Table I rows)."""

    name: str                       # e.g. "L1", "HBM", "VMEM", "sL1d"
    kind: str                       # "cache" | "scratchpad" | "memory"
    scope: str                      # "core" | "chip" | "host" | "pod"
    attrs: dict[str, Attribute] = field(default_factory=dict)
    # Paper: "Physically Shared With" — names of logical spaces / peer ids
    shared_with: list[str] = field(default_factory=list)

    def set(self, key: str, value: Any, unit: str = "",
            provenance: str = PROVENANCE_BENCHMARK,
            confidence: float | None = None) -> None:
        self.attrs[key] = Attribute(value, unit, provenance, confidence)

    def get(self, key: str, default: Any = None) -> Any:
        a = self.attrs.get(key)
        return default if a is None else a.value


@dataclass
class ComputeElement:
    """A compute grouping (chip, core, MXU; SM/CU on the GPU side)."""

    name: str
    count: int
    attrs: dict[str, Attribute] = field(default_factory=dict)

    def set(self, key: str, value: Any, unit: str = "",
            provenance: str = PROVENANCE_API,
            confidence: float | None = None) -> None:
        self.attrs[key] = Attribute(value, unit, provenance, confidence)

    def get(self, key: str, default: Any = None) -> Any:
        a = self.attrs.get(key)
        return default if a is None else a.value


@dataclass
class Link:
    """An interconnect edge (ICI link, DCN path, PCIe, or on-chip bus)."""

    name: str                       # "ici", "dcn", "pcie"
    endpoints: tuple[str, str]      # logical endpoint ids
    attrs: dict[str, Attribute] = field(default_factory=dict)

    def set(self, key: str, value: Any, unit: str = "",
            provenance: str = PROVENANCE_BENCHMARK,
            confidence: float | None = None) -> None:
        self.attrs[key] = Attribute(value, unit, provenance, confidence)


@dataclass
class Topology:
    """Full device topology report (the MT4G JSON equivalent)."""

    vendor: str = ""
    model: str = ""
    backend: str = ""               # "cpu" | "tpu" | "simulated:<name>"
    general: dict[str, Attribute] = field(default_factory=dict)
    compute: list[ComputeElement] = field(default_factory=list)
    memory: list[MemoryElement] = field(default_factory=list)
    links: list[Link] = field(default_factory=list)
    notes: list[str] = field(default_factory=list)

    # ------------------------------------------------------------- access
    def set_general(self, key: str, value: Any, unit: str = "",
                    provenance: str = PROVENANCE_API) -> None:
        self.general[key] = Attribute(value, unit, provenance)

    def find_memory(self, name: str) -> MemoryElement | None:
        for m in self.memory:
            if m.name == name:
                return m
        return None

    def find_compute(self, name: str) -> ComputeElement | None:
        for c in self.compute:
            if c.name == name:
                return c
        return None

    # ------------------------------------------------------ serialization
    def to_json(self) -> dict:
        return {
            "vendor": self.vendor,
            "model": self.model,
            "backend": self.backend,
            "general": {k: v.to_json() for k, v in self.general.items()},
            "compute": [
                {"name": c.name, "count": c.count,
                 "attrs": {k: v.to_json() for k, v in c.attrs.items()}}
                for c in self.compute
            ],
            "memory": [
                {"name": m.name, "kind": m.kind, "scope": m.scope,
                 "shared_with": m.shared_with,
                 "attrs": {k: v.to_json() for k, v in m.attrs.items()}}
                for m in self.memory
            ],
            "links": [
                {"name": l.name, "endpoints": list(l.endpoints),
                 "attrs": {k: v.to_json() for k, v in l.attrs.items()}}
                for l in self.links
            ],
            "notes": self.notes,
        }

    @classmethod
    def from_json(cls, d: dict) -> "Topology":
        topo = cls(vendor=d.get("vendor", ""), model=d.get("model", ""),
                   backend=d.get("backend", ""))
        topo.general = {k: Attribute.from_json(v) for k, v in d.get("general", {}).items()}
        for c in d.get("compute", []):
            ce = ComputeElement(c["name"], c["count"])
            ce.attrs = {k: Attribute.from_json(v) for k, v in c.get("attrs", {}).items()}
            topo.compute.append(ce)
        for m in d.get("memory", []):
            me = MemoryElement(m["name"], m["kind"], m["scope"],
                               shared_with=list(m.get("shared_with", [])))
            me.attrs = {k: Attribute.from_json(v) for k, v in m.get("attrs", {}).items()}
            topo.memory.append(me)
        for l in d.get("links", []):
            le = Link(l["name"], tuple(l["endpoints"]))
            le.attrs = {k: Attribute.from_json(v) for k, v in l.get("attrs", {}).items()}
            topo.links.append(le)
        topo.notes = list(d.get("notes", []))
        return topo

    def dumps(self, indent: int = 2) -> str:
        return json.dumps(self.to_json(), indent=indent)

    @classmethod
    def loads(cls, s: str) -> "Topology":
        return cls.from_json(json.loads(s))

    # --------------------------------------------------- human-readable md
    def to_markdown(self) -> str:
        lines = [f"# Topology report: {self.vendor} {self.model} ({self.backend})", ""]
        if self.general:
            lines += ["## General", ""]
            for k, v in self.general.items():
                lines.append(f"- **{k}**: {v.value} {v.unit} _[{v.provenance}]_")
            lines.append("")
        if self.compute:
            lines += ["## Compute", ""]
            for c in self.compute:
                lines.append(f"- **{c.name}** ×{c.count}")
                for k, v in c.attrs.items():
                    lines.append(f"  - {k}: {v.value} {v.unit} _[{v.provenance}]_")
            lines.append("")
        if self.memory:
            lines += ["## Memory", "",
                      "| element | kind | scope | " +
                      " | ".join(["size", "load_latency", "read_bw", "write_bw",
                                  "line_size", "fetch_granularity", "amount"]) +
                      " | shared_with |",
                      "|---|---|---|---|---|---|---|---|---|---|"]
            for m in self.memory:
                cells = []
                for key in ("size", "load_latency", "read_bw", "write_bw",
                            "line_size", "fetch_granularity", "amount"):
                    a = m.attrs.get(key)
                    if a is None:
                        cells.append("–")
                    else:
                        conf = f" (c={a.confidence:.2f})" if a.confidence is not None else ""
                        cells.append(f"{a.value}{a.unit}{conf}")
                shared = ",".join(m.shared_with) or "n/a"
                lines.append(f"| {m.name} | {m.kind} | {m.scope} | " +
                             " | ".join(cells) + f" | {shared} |")
            lines.append("")
        if self.links:
            lines += ["## Links", ""]
            for l in self.links:
                attrs = ", ".join(f"{k}={v.value}{v.unit}" for k, v in l.attrs.items())
                lines.append(f"- {l.name} {l.endpoints[0]}↔{l.endpoints[1]}: {attrs}")
            lines.append("")
        if self.notes:
            lines += ["## Notes", ""] + [f"- {n}" for n in self.notes]
        return "\n".join(lines)


def _values_equivalent(a: Any, b: Any, rel_tol: float) -> bool:
    """Discrete values exactly equal; floats within ``rel_tol`` relative."""
    import math

    if isinstance(a, bool) or isinstance(b, bool):
        return a == b
    if isinstance(a, float) or isinstance(b, float):
        if not (isinstance(a, (int, float)) and isinstance(b, (int, float))):
            return False
        return math.isclose(float(a), float(b), rel_tol=rel_tol, abs_tol=0.0)
    return a == b


def topology_equivalent(a: "Topology", b: "Topology", *,
                        rel_tol: float = 1e-6,
                        compare_confidence: bool = True) -> bool:
    """Equality contract between two discovery paths over the same device.

    Discrete attributes — sizes, line sizes, granularities, amounts,
    element names/order, shared_with lists, provenance — must match
    *exactly*; float-valued attributes (latencies, bandwidths, confidences)
    match within ``rel_tol`` relative tolerance.  This is the engine==legacy
    identity the ROADMAP prescribes: vectorized statistics cannot promise
    bit-equal float summation order, only equal decisions and near-equal
    metrics.  Notes (free-text wall-time diagnostics) are ignored.

    ``compare_confidence=False`` is the planner-vs-dense contract: the
    adaptive planner computes the K-S confidence metric from a window
    around the boundary instead of the full sweep series, so confidence
    *presence* must still match attribute-for-attribute but its value is
    excluded.  Every other field — including every discrete attribute and
    every measured float — is still enforced.
    """
    if (a.vendor, a.model, a.backend) != (b.vendor, b.model, b.backend):
        return False
    if [m.name for m in a.memory] != [m.name for m in b.memory]:
        return False
    if [(c.name, c.count) for c in a.compute] != \
            [(c.name, c.count) for c in b.compute]:
        return False
    if sorted(a.general) != sorted(b.general):
        return False
    for key, ga in a.general.items():
        gb = b.general[key]
        if (ga.unit, ga.provenance) != (gb.unit, gb.provenance):
            return False
        if not _values_equivalent(ga.value, gb.value, rel_tol):
            return False
    if [(l.name, l.endpoints) for l in a.links] != \
            [(l.name, l.endpoints) for l in b.links]:
        return False
    for ma, mb in zip(a.memory, b.memory):
        if (ma.kind, ma.scope) != (mb.kind, mb.scope):
            return False
        if ma.shared_with != mb.shared_with:
            return False
        if sorted(ma.attrs) != sorted(mb.attrs):
            return False
        for key, aa in ma.attrs.items():
            ab = mb.attrs[key]
            if (aa.unit, aa.provenance) != (ab.unit, ab.provenance):
                return False
            if not _values_equivalent(aa.value, ab.value, rel_tol):
                return False
            ca, cb = aa.confidence, ab.confidence
            if (ca is None) != (cb is None):
                return False
            if (compare_confidence and ca is not None
                    and not _values_equivalent(float(ca), float(cb), rel_tol)):
                return False
    return True
