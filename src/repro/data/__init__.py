from .pipeline import ByteCorpus, DataConfig, SyntheticLM

__all__ = ["ByteCorpus", "DataConfig", "SyntheticLM"]
