"""Deterministic, restartable, host-sharded data pipeline.

Fault tolerance starts at the data layer: after a crash/restart (or an
elastic resize) the pipeline must reproduce exactly the batches the failed
run would have seen. Batches are therefore a pure function of
(seed, step, host_id) — no iterator state to lose. Tokens come from a
counter-mode PRNG (synthetic LM data) or a bundled byte corpus.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

__all__ = ["DataConfig", "SyntheticLM", "ByteCorpus"]

_TEXT = (
    "MT4G discovers GPU compute and memory topologies with over fifty "
    "microbenchmarks and a Kolmogorov-Smirnov change point detector. "
    "Understanding which memory elements exist, their sizes, latencies and "
    "bandwidths, and where they sit in the chip topology is the first step "
    "of every serious performance engineering effort. "
) * 64


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_hosts: int = 1
    host_id: int = 0
    n_codebooks: int = 0          # audio family
    n_patches: int = 0            # vlm family
    vision_embed_dim: int = 0

    @property
    def host_batch(self) -> int:
        assert self.global_batch % self.n_hosts == 0
        return self.global_batch // self.n_hosts


class SyntheticLM:
    """Counter-mode synthetic next-token data: batch_at(step) is pure."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence([self.cfg.seed, step, self.cfg.host_id]))

    def batch_at(self, step: int) -> dict:
        c = self.cfg
        rng = self._rng(step)
        b = c.host_batch
        if c.n_codebooks:
            toks = rng.integers(0, c.vocab_size,
                                (b, c.n_codebooks, c.seq_len + 1))
            return {"tokens": toks[..., :-1].astype(np.int32),
                    "targets": toks[..., 1:].astype(np.int32)}
        toks = rng.integers(0, c.vocab_size, (b, c.seq_len + 1))
        out = {"tokens": toks[:, :-1].astype(np.int32),
               "targets": toks[:, 1:].astype(np.int32)}
        if c.n_patches:
            out["patches"] = rng.normal(
                0, 1, (b, c.n_patches, c.vision_embed_dim)).astype(np.float32)
        return out


class ByteCorpus:
    """Byte-level LM over a bundled corpus — a learnable task for the
    end-to-end training example (loss should drop well below ln(256))."""

    def __init__(self, cfg: DataConfig, text: str = _TEXT):
        self.cfg = cfg
        data = np.frombuffer(text.encode(), dtype=np.uint8)
        reps = int(np.ceil((cfg.seq_len + 1) * cfg.global_batch * 4
                           / data.size)) + 1
        self.data = np.tile(data, reps)

    def batch_at(self, step: int) -> dict:
        c = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([c.seed, step, c.host_id, 7]))
        b = c.host_batch
        starts = rng.integers(0, self.data.size - c.seq_len - 1, b)
        rows = np.stack([self.data[s: s + c.seq_len + 1] for s in starts])
        return {"tokens": rows[:, :-1].astype(np.int32),
                "targets": rows[:, 1:].astype(np.int32)}
