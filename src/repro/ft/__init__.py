from .supervisor import (FailureInjector, RestartExhausted, StragglerDetector,
                         Supervisor)

__all__ = ["FailureInjector", "RestartExhausted", "StragglerDetector",
           "Supervisor"]
