"""Fault tolerance: supervised restart, straggler detection, elastic resize.

On a 1000+-node fleet the framework assumes (a) any step can throw (XLA
errors surface as exceptions; preemptions kill processes — the supervisor
pattern covers the single-controller view, the external scheduler re-execs
the binary which lands in ``Supervisor.run`` again and restores), (b) per-
step wall times expose stragglers, and (c) after losing capacity, training
resumes on a smaller mesh from the same sharded checkpoint
(``Checkpointer.restore`` takes new shardings — see checkpointer.py).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import numpy as np

__all__ = ["Supervisor", "StragglerDetector", "FailureInjector",
           "RestartExhausted"]


class RestartExhausted(RuntimeError):
    pass


class FailureInjector:
    """Deterministic failure injection for FT tests: raises at given steps."""

    def __init__(self, fail_at: set[int]):
        self.fail_at = set(fail_at)
        self.fired: set[int] = set()

    def __call__(self, step: int, metrics: dict) -> None:
        if step in self.fail_at and step not in self.fired:
            self.fired.add(step)
            raise RuntimeError(f"injected failure at step {step}")


@dataclass
class StragglerDetector:
    """Per-step wall-time z-score detector (straggler mitigation hook).

    On real fleets the reaction is to flag the slow host for replacement /
    trigger elastic resize; here we record and expose the verdicts.
    """

    threshold_sigmas: float = 4.0
    window: int = 50
    durations: list = field(default_factory=list)
    flagged: list = field(default_factory=list)

    def record(self, step: int, seconds: float) -> bool:
        self.durations.append(seconds)
        hist = np.asarray(self.durations[-self.window:-1] or [seconds])
        mu, sd = float(np.median(hist)), float(np.std(hist))
        is_straggler = (len(self.durations) > 5
                        and seconds > mu + self.threshold_sigmas * max(sd, 1e-6)
                        and seconds > 1.5 * mu)
        if is_straggler:
            self.flagged.append((step, seconds, mu))
        return is_straggler


class Supervisor:
    """Run a (restartable) train function, restoring from checkpoints on
    failure. The train function must accept (state, start_step) and honor
    them — the deterministic data pipeline guarantees bitwise-identical
    continuation (tested in tests/test_ft.py)."""

    def __init__(self, checkpointer, max_restarts: int = 3,
                 backoff_s: float = 0.0):
        self.ckpt = checkpointer
        self.max_restarts = max_restarts
        self.backoff_s = backoff_s
        self.restarts = 0
        self.log: list[str] = []

    def run(self, train_fn, init_state, state_template=None):
        """train_fn(state, start_step) -> (state, history)."""
        state, start = init_state, 0
        while True:
            try:
                return train_fn(state, start)
            except Exception as e:  # noqa: BLE001 — any step failure
                self.restarts += 1
                self.log.append(f"failure: {e!r}")
                if self.restarts > self.max_restarts:
                    raise RestartExhausted(
                        f"gave up after {self.max_restarts} restarts") from e
                if self.backoff_s:
                    time.sleep(self.backoff_s)
                template = state_template if state_template is not None else state
                # A step failure propagates without draining the async
                # writer; join it first so an in-flight save is visible as a
                # restore point instead of being raced past.
                self.ckpt.wait()
                last = self.ckpt.latest_step()
                if last is None:
                    state, start = init_state, 0
                    self.log.append("restart from scratch (no checkpoint)")
                else:
                    state, _ = self.ckpt.restore(template, step=last)
                    start = last
                    self.log.append(f"restart from step {last}")
