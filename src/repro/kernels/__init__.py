"""Pallas TPU kernels for the perf-critical compute paths + probe kernels.

Each kernel: <name>.py (pl.pallas_call + explicit BlockSpec VMEM tiling),
with jit'd wrappers in ops.py and pure-jnp oracles in ref.py.
"""
from . import ops, ref

__all__ = ["ops", "ref"]
