"""Blockwise (flash) causal GQA attention — Pallas TPU kernel.

TPU-native adaptation (DESIGN.md): online-softmax accumulation in VMEM f32
scratch, MXU-aligned block shapes (multiples of 128 on the contracting дims),
grid = (batch, q_heads, q_blocks, kv_blocks) with the kv dimension marked
"arbitrary" (sequential) so the running (m, l, acc) carry lives across kv
steps. GQA is expressed in the k/v BlockSpec index maps (q head h reads kv
head h // group). Causality skips fully-masked kv blocks via pl.when.

Used on real TPUs for train/prefill attention; validated here in interpret
mode against ref.py's dense oracle across shape/dtype sweeps.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention"]

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            causal: bool, block_q: int, block_k: int, kv_len: int):
    iq, ik = pl.program_id(2), pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = iq * block_q
    k_start = ik * block_k
    needed = (not causal) or (k_start <= q_start + block_q - 1)

    @pl.when(needed)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)              # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)              # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)              # (bk, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))
        s *= 1.0 / math.sqrt(q.shape[-1])

        if causal:
            rows = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                      (block_q, block_k), 0)
            cols = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                      (block_q, block_k), 1)
            s = jnp.where(rows >= cols, s, NEG_INF)

        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_cur = jnp.max(s, axis=1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + \
            jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())))
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(ik == nk - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, block_q: int = 128,
                    block_k: int = 128, interpret: bool = True) -> jax.Array:
    """q (B, Hq, Sq, d); k/v (B, Hkv, Sk, d) -> (B, Hq, Sq, d).

    Sq % block_q == 0 and Sk % block_k == 0 are required (production path
    pads the ragged tail); Hq % Hkv == 0 (GQA).
    """
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    assert hq % hkv == 0 and sq % block_q == 0 and sk % block_k == 0
    g = hq // hkv
    grid = (b, hq, sq // block_q, sk // block_k)

    kernel = functools.partial(_kernel, causal=causal, block_q=block_q,
                               block_k=block_k, kv_len=sk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda b_, h, iq, ik: (b_, h, iq, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b_, h, iq, ik, g=g: (b_, h // g, ik, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b_, h, iq, ik, g=g: (b_, h // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda b_, h, iq, ik: (b_, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),      # running max m
            pltpu.VMEM((block_q,), jnp.float32),      # running sum l
            pltpu.VMEM((block_q, d), jnp.float32),    # accumulator
        ],
        interpret=interpret,
    )(q, k, v)
