"""Jit'd public wrappers around the Pallas kernels.

``interpret`` defaults to True in this CPU container; on a TPU fleet the
launcher flips it to False (the kernels carry explicit BlockSpec tilings and
MXU-aligned block shapes for that path).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .flash_attention import flash_attention
from .pchase_probe import pchase_kernel, pchase_kernel_batch
from .rwkv6_scan import wkv6_chunked_kernel
from .stream_probe import stream_read_kernel, stream_write_kernel

__all__ = ["mha", "wkv6", "stream_read", "stream_write", "pchase",
           "pchase_batch"]


def mha(q, k, v, *, causal=True, block_q=128, block_k=128, interpret=True):
    """Flash attention over (B, S, H, d) activations (model layout)."""
    qt = jnp.swapaxes(q, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    out = flash_attention(qt, kt, vt, causal=causal, block_q=block_q,
                          block_k=block_k, interpret=interpret)
    return jnp.swapaxes(out, 1, 2)


def wkv6(r, k, v, w, u, *, chunk=32, interpret=True):
    """Chunked WKV6 over (B, T, H, K) activations; returns (y, state)."""
    return wkv6_chunked_kernel(r, k, v, w, u, chunk=chunk,
                               interpret=interpret)


def stream_read(x, *, block=64 * 1024, interpret=True):
    return stream_read_kernel(x, block=block, interpret=interpret)


def stream_write(x, *, block=64 * 1024, interpret=True):
    return stream_write_kernel(x, block=block, interpret=interpret)


def pchase(perm, *, iters, interpret=True):
    return pchase_kernel(perm, iters=iters, interpret=interpret)


def pchase_batch(perms, steps, *, interpret=True):
    """Grid-batched p-chase: (R, N) padded cycles + (R,) per-row chain
    lengths -> (R, 2) [cursor, checksum] rows (one launch per sweep)."""
    return pchase_kernel_batch(perms, jnp.asarray(steps, jnp.int32),
                               interpret=interpret)
