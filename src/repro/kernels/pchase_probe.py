"""Pointer-chase probe — Pallas TPU kernel (paper §IV-A, TPU-native).

The GPU p-chase reads a per-load cycle counter; TPU Pallas has no in-kernel
clock (DESIGN.md adaptation note 1), so the kernel executes a dependent-load
chain of known length and the *caller* times the whole call: ns/load =
wall / iters, and the latency distribution is built across repetitions.

The chase array is a random single cycle (Sattolo) so hardware prefetchers
cannot run ahead; the chain is serialized by construction (each load's
address is the previous load's value). Output returns the final cursor and
a visit checksum so the chain cannot be dead-code-eliminated; both are also
the correctness contract checked against ref.py.

``pchase_kernel_batch`` is the probe-engine variant: a whole §IV-B size
sweep maps onto the grid dimension — row i carries its own single-cycle
permutation (padded to a shared width) and its own chain length, read from
a per-row scalar so sweeps with different step counts reuse one compiled
kernel.  This is the runner API ``PallasRunner.pchase_batch`` is built on.

``eviction_kernel_batch`` extends the same trick to the eviction-pattern
probes (paper §IV-F/§IV-G/§IV-H, Fig. 3): each grid row first walks an
*evictor* chain (warm phase over buffer B) and then a *probe* chain
(buffer A), with both phase lengths carried as per-row kernel data.  A row
with ``warm_steps == 0`` degenerates to a plain p-chase row, which is the
bit-identity anchor the tests pin.  This is the runner API
``PallasRunner.eviction_many`` is built on.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["pchase_kernel", "pchase_kernel_batch", "pchase_reference",
           "eviction_kernel_batch", "eviction_reference"]


def _kernel(perm_ref, out_ref, *, iters: int):
    def body(_, carry):
        cursor, checksum = carry
        nxt = perm_ref[cursor]
        return nxt, checksum + nxt

    cursor, checksum = jax.lax.fori_loop(
        0, iters, body, (jnp.int32(0), jnp.int32(0)))
    out_ref[0] = cursor
    out_ref[1] = checksum


@functools.partial(jax.jit, static_argnames=("iters", "interpret"))
def pchase_kernel(perm: jax.Array, *, iters: int,
                  interpret: bool = True) -> jax.Array:
    """perm (N,) int32 single-cycle permutation -> [final_cursor, checksum]."""
    return pl.pallas_call(
        functools.partial(_kernel, iters=iters),
        grid=(1,),
        in_specs=[pl.BlockSpec(perm.shape, lambda i: (0,))],
        out_specs=pl.BlockSpec((2,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((2,), jnp.int32),
        interpret=interpret,
    )(perm)


def _batch_kernel(steps_ref, perm_ref, out_ref):
    steps = steps_ref[0]

    def body(_, carry):
        cursor, checksum = carry
        nxt = perm_ref[0, cursor]
        return nxt, checksum + nxt

    cursor, checksum = jax.lax.fori_loop(
        0, steps, body, (jnp.int32(0), jnp.int32(0)))
    out_ref[0, 0] = cursor
    out_ref[0, 1] = checksum


@functools.partial(jax.jit, static_argnames=("interpret",))
def pchase_kernel_batch(perms: jax.Array, steps: jax.Array, *,
                        interpret: bool = True) -> jax.Array:
    """Grid-batched p-chase: one kernel launch for a whole size sweep.

    ``perms`` (R, N) int32 — row i is a single-cycle permutation over its
    first ``n_i <= N`` slots, zero-padded to the shared width (the chain
    starts at 0 and never leaves its cycle, so padding is never read).

    **Chain-lengths-as-data contract**: ``steps`` (R,) int32 carries each
    row's dependent-chain length as kernel *data*, loaded inside the kernel
    body per grid row — never baked in as a static/compile-time argument.
    This is what lets one compiled kernel serve every row of a sweep (and
    every sweep with the same (R, N) shape): rows with different chain
    lengths differ only in the value read from ``steps``, so no row forces
    a recompile.  Consequence for callers: changing a row's chain length
    must never change the kernel's shape signature — resize ``perms``
    padding, not the grid.

    Returns (R, 2) int32 ``[final_cursor, checksum]`` rows, the same
    correctness contract as ``pchase_kernel``.
    """
    r, n = perms.shape
    return pl.pallas_call(
        _batch_kernel,
        grid=(r,),
        in_specs=[pl.BlockSpec((1,), lambda i: (i,)),
                  pl.BlockSpec((1, n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, 2), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, 2), jnp.int32),
        interpret=interpret,
    )(steps, perms)


def _evict_kernel(warm_ref, probe_ref, evictor_ref, perm_ref, out_ref):
    warm = warm_ref[0]
    probe = probe_ref[0]

    def body_warm(_, carry):
        cursor, checksum = carry
        nxt = evictor_ref[0, cursor]
        return nxt, checksum + nxt

    _, warm_sum = jax.lax.fori_loop(
        0, warm, body_warm, (jnp.int32(0), jnp.int32(0)))

    def body_probe(_, carry):
        cursor, checksum = carry
        nxt = perm_ref[0, cursor]
        return nxt, checksum + nxt

    cursor, checksum = jax.lax.fori_loop(
        0, probe, body_probe, (jnp.int32(0), warm_sum))
    out_ref[0, 0] = cursor
    out_ref[0, 1] = checksum


@functools.partial(jax.jit, static_argnames=("interpret",))
def eviction_kernel_batch(perms: jax.Array, evictors: jax.Array,
                          warm_steps: jax.Array, probe_steps: jax.Array, *,
                          interpret: bool = True) -> jax.Array:
    """Grid-batched eviction-pattern probe (Fig. 3 warm-B / probe-A).

    Row i walks its *evictor* cycle ``evictors[i]`` for ``warm_steps[i]``
    dependent loads (warming the conflicting working set), then walks its
    *probe* cycle ``perms[i]`` for ``probe_steps[i]`` loads — the phase the
    caller times to see whether the warm phase evicted the probe array.
    Both phase lengths follow the chain-lengths-as-data contract of
    ``pchase_kernel_batch``: they are per-row kernel *data*, so one compiled
    kernel serves heterogeneous amount/sharing/cu-sharing rows of any mix,
    and changing a row's phase lengths never forces a recompile.

    ``perms`` (R, N) and ``evictors`` (R, M) are zero-padded single-cycle
    permutations; both chains start at slot 0 and never leave their cycle.
    Returns (R, 2) int32 ``[final_probe_cursor, checksum]`` where the
    checksum covers both phases.  A row with ``warm_steps == 0`` is
    bit-identical to the same ``pchase_kernel_batch`` row.
    """
    r, n = perms.shape
    _, m = evictors.shape
    return pl.pallas_call(
        _evict_kernel,
        grid=(r,),
        in_specs=[pl.BlockSpec((1,), lambda i: (i,)),
                  pl.BlockSpec((1,), lambda i: (i,)),
                  pl.BlockSpec((1, m), lambda i: (i, 0)),
                  pl.BlockSpec((1, n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, 2), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, 2), jnp.int32),
        interpret=interpret,
    )(warm_steps, probe_steps, evictors, perms)


def eviction_reference(perm, evictor, warm_steps: int,
                       probe_steps: int) -> tuple[int, int]:
    """Pure-Python two-phase walk: the contract for ``eviction_kernel_batch``."""
    import numpy as np

    checksum = np.int32(0)
    cursor = 0
    ev = np.asarray(evictor)
    for _ in range(int(warm_steps)):
        cursor = int(ev[cursor])
        checksum = np.int32(checksum + np.int32(cursor))
    p = np.asarray(perm)
    cursor = 0
    for _ in range(int(probe_steps)):
        cursor = int(p[cursor])
        checksum = np.int32(checksum + np.int32(cursor))
    return cursor, int(checksum)


def pchase_reference(perm, steps: int) -> tuple[int, int]:
    """Pure-Python chain walk: the correctness contract for both kernels.

    int32 wrap-around on the checksum matches the kernel's arithmetic.
    """
    import numpy as np

    p = np.asarray(perm)
    cursor = 0
    checksum = np.int32(0)
    for _ in range(int(steps)):
        cursor = int(p[cursor])
        checksum = np.int32(checksum + np.int32(cursor))
    return cursor, int(checksum)
