"""Pointer-chase probe — Pallas TPU kernel (paper §IV-A, TPU-native).

The GPU p-chase reads a per-load cycle counter; TPU Pallas has no in-kernel
clock (DESIGN.md adaptation note 1), so the kernel executes a dependent-load
chain of known length and the *caller* times the whole call: ns/load =
wall / iters, and the latency distribution is built across repetitions.

The chase array is a random single cycle (Sattolo) so hardware prefetchers
cannot run ahead; the chain is serialized by construction (each load's
address is the previous load's value). Output returns the final cursor and
a visit checksum so the chain cannot be dead-code-eliminated; both are also
the correctness contract checked against ref.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["pchase_kernel"]


def _kernel(perm_ref, out_ref, *, iters: int):
    def body(_, carry):
        cursor, checksum = carry
        nxt = perm_ref[cursor]
        return nxt, checksum + nxt

    cursor, checksum = jax.lax.fori_loop(
        0, iters, body, (jnp.int32(0), jnp.int32(0)))
    out_ref[0] = cursor
    out_ref[1] = checksum


@functools.partial(jax.jit, static_argnames=("iters", "interpret"))
def pchase_kernel(perm: jax.Array, *, iters: int,
                  interpret: bool = True) -> jax.Array:
    """perm (N,) int32 single-cycle permutation -> [final_cursor, checksum]."""
    return pl.pallas_call(
        functools.partial(_kernel, iters=iters),
        grid=(1,),
        in_specs=[pl.BlockSpec(perm.shape, lambda i: (0,))],
        out_specs=pl.BlockSpec((2,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((2,), jnp.int32),
        interpret=interpret,
    )(perm)
