"""Pure-jnp (and pure-python) oracles for every Pallas kernel.

Kept dependency-free of the kernel modules: these are the ground truth the
shape/dtype sweep tests assert against.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["attention_ref", "wkv6_ref", "stream_read_ref", "stream_write_ref",
           "pchase_ref"]


def attention_ref(q, k, v, causal: bool = True):
    """Dense softmax attention with GQA head repetition. Shapes as kernel."""
    b, hq, sq, d = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    g = hq // hkv
    kk = jnp.repeat(k, g, axis=1)
    vv = jnp.repeat(v, g, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kk.astype(jnp.float32)) / math.sqrt(d)
    if causal:
        mask = jnp.tril(jnp.ones((sq, sk), bool))
        s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, vv.astype(jnp.float32))
    return out.astype(q.dtype)


def wkv6_ref(r, k, v, w, u):
    """Sequential WKV6 recurrence (zero init state), f32 outputs.

    y_t = (S_{t-1} + diag(u) k_t v_t^T)^T r_t ;  S_t = diag(w_t) S + k v^T
    """
    b, t, h, kk = r.shape
    vv = v.shape[-1]
    r, k, v, w = (x.astype(jnp.float32) for x in (r, k, v, w))
    u = u.astype(jnp.float32)

    def step(s, xs):
        rt, kt, vt, wt = xs
        kv = kt[..., :, None] * vt[..., None, :]
        y = jnp.einsum("bhk,bhkv->bhv", rt, s + u[None, :, :, None] * kv)
        return wt[..., None] * s + kv, y

    xs = tuple(jnp.moveaxis(x, 1, 0) for x in (r, k, v, w))
    state, ys = jax.lax.scan(step, jnp.zeros((b, h, kk, vv), jnp.float32), xs)
    return jnp.moveaxis(ys, 0, 1), state


def stream_read_ref(x, block: int):
    return jnp.sum(x.reshape(-1, block).astype(jnp.float32), axis=1)


def stream_write_ref(x):
    return x + jnp.asarray(1, x.dtype)


def pchase_ref(perm: np.ndarray, iters: int) -> tuple[int, int]:
    """Python chase oracle: (final cursor, int32-wrapped visit checksum)."""
    cursor, checksum = 0, 0
    p = np.asarray(perm)
    for _ in range(iters):
        cursor = int(p[cursor])
        checksum = (checksum + cursor) & 0xFFFFFFFF
    if checksum >= 2**31:
        checksum -= 2**32
    return cursor, checksum
