"""Chunked RWKV6 (Finch) WKV recurrence — Pallas TPU kernel.

Grid = (batch, heads, n_chunks) with the chunk dimension sequential
("arbitrary"): the (K, V) wkv state lives in f32 VMEM scratch across chunk
steps. Within a chunk the per-channel pairwise decay tensor (C, C, K) is
materialized in VMEM — C=32, K<=128 keeps it under 2 MB, comfortably inside
the ~16 MB v5e VMEM together with the r/k/v/w blocks.

This is the TPU-native schedule of ``models.rwkv6.wkv_chunked`` (same math;
cross-checked in tests) and the optimized training path for rwkv6-3b.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["wkv6_chunked_kernel"]


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, y_ref, state_out_ref, s_scr,
            *, chunk: int):
    ic = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(ic == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    r = r_ref[0, 0].astype(jnp.float32)          # (C, K)
    k = k_ref[0, 0].astype(jnp.float32)          # (C, K)
    v = v_ref[0, 0].astype(jnp.float32)          # (C, V)
    w = w_ref[0, 0].astype(jnp.float32)          # (C, K)
    u = u_ref[0, 0].astype(jnp.float32)          # (1, K) broadcast row

    lw = jnp.log(w)
    cs = jnp.cumsum(lw, axis=0)                  # L_j inclusive, (C, K)
    d_in = jnp.exp(cs - lw)                      # exp(L_{j-1}), (C, K)
    s = s_scr[...]                               # (K, V)

    # inter-chunk
    y = jax.lax.dot_general(r * d_in, s, (((1,), (0,)), ((), ())))  # (C, V)

    # intra-chunk: att[j, i] = sum_k r_j k_i exp(L_{j-1}[k] - L_i[k]), i < j
    dec = jnp.exp((cs - lw)[:, None, :] - cs[None, :, :])   # (C, C, K)
    att = jnp.sum(r[:, None, :] * k[None, :, :] * dec, axis=-1)
    rows = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    att = jnp.where(rows > cols, att, 0.0)
    y += jax.lax.dot_general(att, v, (((1,), (0,)), ((), ())))

    # diagonal bonus u
    diag = jnp.sum(r * u * k, axis=-1)           # (C,)
    y += diag[:, None] * v

    # state carry
    total = cs[-1:, :]                           # (1, K)
    kdec = k * jnp.exp(total - cs)               # (C, K)
    s_scr[...] = jnp.exp(total[0])[:, None] * s + \
        jax.lax.dot_general(kdec, v, (((0,), (0,)), ((), ())))

    y_ref[0, 0] = y.astype(y_ref.dtype)

    @pl.when(ic == nc - 1)
    def _emit_state():
        state_out_ref[0, 0] = s_scr[...]


@functools.partial(jax.jit,
                   static_argnames=("chunk", "interpret"))
def wkv6_chunked_kernel(r, k, v, w, u, *, chunk: int = 32,
                        interpret: bool = True):
    """r,k,w: (B,T,H,K); v: (B,T,H,V); u: (H,K) -> (y (B,T,H,V) f32,
    state (B,H,K,V) f32). Zero initial state (prefill semantics)."""
    b, t, h, kk = r.shape
    vv = v.shape[-1]
    assert t % chunk == 0, (t, chunk)
    nc = t // chunk

    # (B,T,H,*) -> (B,H,T,*) for chunk-contiguous blocks.
    tr = lambda x: jnp.swapaxes(x, 1, 2)
    rq, kq, vq, wq = tr(r), tr(k), tr(v), tr(w)
    u2 = u[:, None, :]                           # (H, 1, K)

    grid = (b, h, nc)
    blk = lambda d: pl.BlockSpec((1, 1, chunk, d),
                                 lambda b_, h_, c: (b_, h_, c, 0))
    y, state = pl.pallas_call(
        functools.partial(_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            blk(kk), blk(kk), blk(vv), blk(kk),
            pl.BlockSpec((1, 1, kk), lambda b_, h_, c: (h_, 0, 0)),
        ],
        out_specs=[
            blk(vv),
            pl.BlockSpec((1, 1, kk, vv), lambda b_, h_, c: (b_, h_, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, t, vv), jnp.float32),
            jax.ShapeDtypeStruct((b, h, kk, vv), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((kk, vv), jnp.float32)],
        interpret=interpret,
    )(rq, kq, vq, wq, u2)
    return jnp.swapaxes(y, 1, 2), state
