"""Stream bandwidth probe — Pallas TPU kernel (paper §IV-I, TPU-native).

MT4G's bandwidth benchmark issues wide vector loads from many threads; the
TPU-native equivalent streams HBM->VMEM tiles across a grid sized to keep
the DMA engines saturated (DESIGN.md adaptation note 4). Two modes:

  * read  — per-tile reduction (one f32 out per tile: bytes in, ~0 out);
  * write — tile copy (bytes in == bytes out), measuring write bandwidth
            together with read.

On hardware the wall clock around ``ops.stream_read/write`` divided into
bytes gives GB/s; in this container the kernels are validated for
correctness in interpret mode and the HostRunner measures real bandwidth
with jitted XLA ops instead.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["stream_read_kernel", "stream_write_kernel"]


def _read_kernel(x_ref, out_ref):
    out_ref[0] = jnp.sum(x_ref[...].astype(jnp.float32))


def _write_kernel(x_ref, y_ref):
    y_ref[...] = x_ref[...] + jnp.asarray(1, x_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def stream_read_kernel(x: jax.Array, *, block: int = 64 * 1024,
                       interpret: bool = True) -> jax.Array:
    """x (N,) -> per-block partial sums (N // block,). N % block == 0."""
    n = x.shape[0]
    assert n % block == 0
    grid = (n // block,)
    return pl.pallas_call(
        _read_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=pl.BlockSpec((1,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n // block,), jnp.float32),
        interpret=interpret,
    )(x)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def stream_write_kernel(x: jax.Array, *, block: int = 64 * 1024,
                        interpret: bool = True) -> jax.Array:
    """x (N,) -> x + 1, streamed tile-by-tile (read+write bytes)."""
    n = x.shape[0]
    assert n % block == 0
    grid = (n // block,)
    return pl.pallas_call(
        _write_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), x.dtype),
        interpret=interpret,
    )(x)
