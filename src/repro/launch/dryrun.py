import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count at first init). The CI small-mesh test overrides the count via
# REPRO_DRYRUN_DEVICES before jax is imported; still prior to any import.
if os.environ.get("REPRO_DRYRUN_DEVICES"):
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                               + os.environ["REPRO_DRYRUN_DEVICES"])

"""Multi-pod dry-run (assignment deliverable (e)+(f)+(g) input).

For every (architecture x input shape) cell and mesh:
  * build the step function the shape implies (train_step / prefill /
    serve_step) with production runtime knobs (remat, microbatching,
    chunked attention);
  * attach NamedShardings from the divisibility-aware rules to every input
    ShapeDtypeStruct (params, optimizer state, batch, caches);
  * ``jit(...).lower(...).compile()`` — success proves the distribution
    config is coherent; failures are bugs;
  * record memory_analysis / cost_analysis / collective bytes into
    ``artifacts/dryrun/<mesh>/<arch>__<shape>.json`` for §Dry-run and the
    roofline analyzer.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --mesh single --arch qwen3-14b
  PYTHONPATH=src python -m repro.launch.dryrun --all --skip-existing
"""
import argparse
import json
import time
import traceback

__all__ = ["run_cell", "cells_for", "pick_microbatches", "main"]

SKIP_LONG_FULL_ATTN = "long_500k needs sub-quadratic attention; pure " \
    "full-attention arch — skipped per assignment (see DESIGN.md §4)"


def cells_for(arch_names, shape_names):
    """Yield runnable (arch, shape) cells, honoring the long_500k rule."""
    from ..configs import get_config, shape_for

    for a in arch_names:
        cfg = get_config(a)
        for s in shape_names:
            shape = shape_for(s)
            if s == "long_500k" and not cfg.subquadratic:
                yield (a, s, SKIP_LONG_FULL_ATTN)
                continue
            yield (a, s, None)


def pick_microbatches(cfg, shape, mesh) -> int:
    """Gradient-accumulation factor for the train cells: target ~1 sequence
    per data shard per microbatch for wide models (bounds activation + MoE
    dispatch memory), 4 for narrow ones."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    data_shards = sizes.get("data", 1) * sizes.get("pod", 1)
    per_dev = max(shape.global_batch // data_shards, 1)
    target = 1 if (cfg.d_model >= 4096 or cfg.family == "moe") else 4
    mb = max(per_dev // target, 1)
    while shape.global_batch % mb != 0:
        mb -= 1
    return max(mb, 1)


def _with_shardings(shape_tree, logical_tree, rules, mesh):
    import jax
    from ..sharding import tree_shardings

    sh = tree_shardings(shape_tree, logical_tree, rules, mesh)
    return jax.tree.map(
        lambda s, d: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=d),
        shape_tree, sh)


def _batch_logical(cfg, batch_shapes):
    """Logical axes for each input tensor of the batch."""
    out = {}
    for name, sds in batch_shapes.items():
        nd = len(sds.shape)
        out[name] = ("batch",) + ("",) * (nd - 1)
    return out


def build_cell(arch: str, shape_name: str, mesh, runtime=None,
               overrides: dict | None = None):
    """Returns (step_fn, example_args_with_shardings, meta).

    ``overrides`` (perf-iteration knobs, see EXPERIMENTS.md §Perf):
      rules         — replace the Rules object (sharding layout variants)
      microbatches  — grad-accumulation factor for train cells
      runtime       — models.Runtime (remat / q_chunk / kernels)
    """
    overrides = overrides or {}
    import jax
    from ..configs import get_config, shape_for
    from ..models import Runtime, get_model
    from ..sharding import SERVE_RULES, TRAIN_RULES
    from ..train.optimizer import OptConfig, init_opt_state, opt_state_specs
    from ..train.train_loop import TrainConfig, make_train_step

    from ..sharding.context import activation_sharding

    cfg = get_config(arch)
    shape = shape_for(shape_name)
    model = get_model(cfg)
    meta = {"arch": arch, "shape": shape_name, "kind": shape.kind}

    def _ctx(fn, rules):
        """Trace the step under the activation-sharding context so the
        models' constrain() calls anchor batch/vocab/expert layouts."""
        def wrapped(*a, **k):
            with activation_sharding(mesh, rules):
                return fn(*a, **k)
        return wrapped

    if shape.kind == "train":
        rules = overrides.get("rules", TRAIN_RULES)
        mb = overrides.get("microbatches") or pick_microbatches(cfg, shape, mesh)
        meta["microbatches"] = mb
        rt = overrides.get("runtime") or runtime or Runtime(q_chunk=1024,
                                                            remat="full")
        oc = OptConfig(master_f32=True)
        tc = TrainConfig(opt=oc, microbatches=mb, runtime=rt)
        step = _ctx(make_train_step(model, tc), rules)

        pshapes = model.param_shapes()
        plogical = model.param_specs()
        oshapes = jax.eval_shape(lambda p: init_opt_state(p, oc), pshapes)
        ological = opt_state_specs(plogical, oc,
                                   has_master="master" in oshapes)
        state_shapes = {"params": pshapes, "opt": oshapes}
        state_logical = {"params": plogical, "opt": ological}
        state_in = _with_shardings(state_shapes, state_logical, rules, mesh)

        bshapes = model.input_specs(shape)
        batch_in = _with_shardings(bshapes, _batch_logical(cfg, bshapes),
                                   rules, mesh)
        state_sh = jax.tree.map(lambda x: x.sharding, state_in)
        meta["jit"] = {"out_shardings": (state_sh, None),
                       "donate_argnums": (0,)}
        return step, (state_in, batch_in), meta

    rules = overrides.get("rules", SERVE_RULES)
    rt = overrides.get("runtime") or runtime or Runtime(q_chunk=1024)
    pshapes = model.param_shapes()
    plogical = model.param_specs()
    params_in = _with_shardings(pshapes, plogical, rules, mesh)
    bshapes = model.input_specs(shape)
    batch_in = _with_shardings(bshapes, _batch_logical(cfg, bshapes),
                               rules, mesh)

    cache_shapes = model.cache_input_specs(shape)
    if overrides.get("cache_dtype"):
        import jax.numpy as jnp
        dt = getattr(jnp, overrides["cache_dtype"])
        cache_shapes = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(
                x.shape, dt if x.dtype == jnp.bfloat16 else x.dtype),
            cache_shapes)
    cache_in = _with_shardings(cache_shapes, model.cache_specs(), rules, mesh)
    cache_sh = jax.tree.map(lambda x: x.sharding, cache_in)

    if shape.kind == "prefill":
        step = _ctx(
            lambda p, b: model.prefill(p, b, max_len=shape.seq_len, rt=rt),
            rules)
        # Pin the returned KV cache to the serve layout (seq over "model"),
        # otherwise the compiler replicates the 100+GB cache output.
        meta["jit"] = {"out_shardings": (None, cache_sh)}
        return step, (params_in, batch_in), meta

    # decode / long-context decode: one new token vs a filled cache.
    step = _ctx(lambda p, b, c: model.decode_step(p, b, c, rt=rt), rules)
    meta["jit"] = {"out_shardings": (None, cache_sh)}
    if not overrides.get("no_donate"):
        meta["jit"]["donate_argnums"] = (2,)
    return step, (params_in, batch_in, cache_in), meta


def run_cell(arch: str, shape_name: str, mesh, mesh_name: str,
             hlo_dir: str | None = None, overrides: dict | None = None) -> dict:
    """Lower + compile one cell; return the artifact dict."""
    import jax
    from ..analysis.hlo import parse_collectives
    from ..analysis.hlo_cost import analyze_hlo

    t0 = time.time()
    record = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
              "devices": int(mesh.devices.size), "ok": False}
    try:
        step, args, meta = build_cell(arch, shape_name, mesh,
                                      overrides=overrides)
        jit_kw = meta.pop("jit", {})
        record.update(meta)
        with mesh:
            lowered = jax.jit(step, **jit_kw).lower(*args)
            t_lower = time.time()
            compiled = lowered.compile()
            t_compile = time.time()
        ma = compiled.memory_analysis()
        record["memory"] = {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
            "peak_estimate_bytes": int(ma.argument_size_in_bytes
                                       + ma.output_size_in_bytes
                                       + ma.temp_size_in_bytes
                                       - ma.alias_size_in_bytes),
        }
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):  # jax<=0.4.x: one dict per program
            cost = cost[0] if cost else {}
        record["cost"] = {k: float(v) for k, v in cost.items()
                          if isinstance(v, (int, float))}
        txt = compiled.as_text()
        record["collectives"] = parse_collectives(txt).to_json()
        # Trip-count-aware accounting (scan bodies x their trip counts):
        # the roofline reads these, not raw cost_analysis (see hlo_cost.py).
        record["hlo_cost"] = analyze_hlo(txt).to_json()
        record["hlo_bytes"] = len(txt)
        record["timings"] = {"lower_s": round(t_lower - t0, 2),
                             "compile_s": round(t_compile - t_lower, 2)}
        record["ok"] = True
    except Exception as e:  # noqa: BLE001 — record the failure, don't die
        record["error"] = f"{type(e).__name__}: {e}"
        record["traceback"] = traceback.format_exc()[-4000:]
    record["total_s"] = round(time.time() - t0, 2)
    return record


def main(argv=None) -> int:
    from ..configs import ARCHS, SHAPES
    from .mesh import make_production_mesh

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", action="append", default=None,
                    help="arch id (repeatable); default: all")
    ap.add_argument("--shape", action="append", default=None,
                    help="shape name (repeatable); default: all")
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="all archs x shapes x both meshes")
    args = ap.parse_args(argv)

    archs = args.arch or sorted(ARCHS)
    shapes = args.shape or list(SHAPES)
    meshes = ["single", "multi"] if (args.mesh == "both" or args.all) \
        else [args.mesh]

    failures = 0
    for mesh_name in meshes:
        mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
        out_dir = os.path.join(args.out, mesh_name)
        os.makedirs(out_dir, exist_ok=True)
        for arch, shape, skip in cells_for(archs, shapes):
            path = os.path.join(out_dir, f"{arch}__{shape}.json")
            if args.skip_existing and os.path.exists(path):
                print(f"[skip-existing] {mesh_name}/{arch}/{shape}")
                continue
            if skip is not None:
                json.dump({"arch": arch, "shape": shape, "mesh": mesh_name,
                           "skipped": skip, "ok": True},
                          open(path, "w"), indent=1)
                print(f"[skipped] {mesh_name}/{arch}/{shape}: long_500k rule")
                continue
            print(f"[run] {mesh_name}/{arch}/{shape} ...", flush=True)
            rec = run_cell(arch, shape, mesh, mesh_name)
            json.dump(rec, open(path, "w"), indent=1)
            status = "OK" if rec["ok"] else f"FAIL ({rec.get('error')})"
            print(f"  -> {status} in {rec['total_s']}s "
                  f"(compile {rec.get('timings', {}).get('compile_s', '-')}s)",
                  flush=True)
            failures += 0 if rec["ok"] else 1
    print(f"done; failures={failures}")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
