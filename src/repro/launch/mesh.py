"""Production mesh construction (assignment MULTI-POD DRY-RUN step 1).

Defined as functions so importing this module never touches jax device
state. The "pod" axis is the cross-DCN data-parallel dimension; "data" is
the intra-pod FSDP/DP axis; "model" the tensor/expert-parallel axis kept on
ICI. ``make_subslice_mesh`` is the MIG-analogue used by the elastic-resize
path (paper §VI-C: topology-aware dynamic partitioning).
"""
from __future__ import annotations

import numpy as np

__all__ = ["make_production_mesh", "make_subslice_mesh", "make_debug_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    from repro.compat import make_mesh

    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_debug_mesh(shape=(2, 4), axes=("data", "model")):
    """Small mesh for the in-CI dry-run test (8 forced host devices)."""
    from repro.compat import make_mesh

    return make_mesh(shape, axes)


def make_subslice_mesh(base_shape=(16, 16), drop_data_rows: int = 8,
                       axes=("data", "model")):
    """Elastic resize: rebuild a mesh after losing ``drop_data_rows`` of the
    data axis (the checkpointer reshards state onto it)."""
    import jax

    from repro.compat import mesh_from_devices

    new_shape = (base_shape[0] - drop_data_rows, base_shape[1])
    n = int(np.prod(new_shape))
    devices = np.asarray(jax.devices()[:n]).reshape(new_shape)
    return mesh_from_devices(devices, axes)
