"""Serving driver: load (or init) a model, shard with SERVE_RULES, serve a
synthetic request stream through the continuous-batching engine.

    PYTHONPATH=src python -m repro.launch.serve --arch internlm2-1.8b-smoke \
        --requests 8 --max-new 16
"""
import argparse

__all__ = ["main"]


def main(argv=None) -> int:
    import time

    import jax
    import numpy as np

    from ..checkpoint import Checkpointer
    from ..configs import get_config
    from ..models import get_model
    from ..serve import Engine, ServeConfig
    from ..sharding import SERVE_RULES, tree_shardings

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b-smoke")
    ap.add_argument("--mesh", default="1x1")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--ckpt-dir", default=None,
                    help="restore params from a training checkpoint")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if cfg.name.endswith("-smoke"):
        cfg = cfg.replace(dtype="float32")
    model = get_model(cfg)
    d, m = (int(x) for x in args.mesh.split("x"))
    from repro.compat import make_mesh
    mesh = make_mesh((d, m), ("data", "model"))

    params, pspecs = model.init(jax.random.PRNGKey(0))
    if args.ckpt_dir:
        ck = Checkpointer(args.ckpt_dir)
        state, _ = ck.restore({"params": params})
        params = state["params"]
    shardings = tree_shardings(jax.eval_shape(lambda: params), pspecs,
                               SERVE_RULES, mesh)
    params = jax.tree.map(jax.device_put, params, shardings)

    eng = Engine(model, params, ServeConfig(max_len=args.max_len,
                                            slots=args.slots))
    rng = np.random.default_rng(0)
    reqs = [rng.integers(0, cfg.vocab_size, args.prompt_len).astype(np.int32)
            for _ in range(args.requests)]
    t0 = time.perf_counter()
    outs = eng.serve(reqs, max_new=args.max_new)
    dt = time.perf_counter() - t0
    toks = sum(o.size for o in outs)
    print(f"[serve] arch={cfg.name} mesh={args.mesh} requests={len(reqs)} "
          f"new_tokens={toks} wall={dt:.2f}s throughput={toks/dt:.1f} tok/s")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
