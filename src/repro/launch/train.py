"""Distributed training driver.

    PYTHONPATH=src python -m repro.launch.train --arch internlm2-1.8b-smoke \
        --steps 20 --mesh 1x1

Builds the mesh, resolves TRAIN_RULES shardings for state and batch, applies
the activation-sharding context, and runs the fault-tolerant loop
(checkpointer + supervisor + straggler detector). On a real fleet this is
the per-process entry point (jax.distributed.initialize is invoked when the
standard cluster env vars are present); in this container it runs the smoke
configs on one device.

Compute/communication overlap: the XLA flags below enable the latency-hiding
scheduler + async collectives on TPU; they are no-ops on CPU.
"""
import os

_OVERLAP_FLAGS = (
    " --xla_tpu_enable_async_collective_fusion=true"
    " --xla_tpu_enable_async_collective_fusion_fuse_all_gather=true"
    " --xla_tpu_overlap_compute_collective_tc=true"
    " --xla_enable_async_all_gather=true"
)
# TPU-only flags: the CPU PJRT plugin hard-fails on unknown flags, so they
# are applied only when a TPU runtime is actually present/requested.
if (os.environ.get("REPRO_TPU") or "tpu" in os.environ.get("JAX_PLATFORMS", "")) \
        and "--xla_tpu" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = os.environ.get("XLA_FLAGS", "") + _OVERLAP_FLAGS

import argparse

__all__ = ["main"]


def main(argv=None) -> int:
    import jax

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b-smoke")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--mesh", default="1x1",
                    help="DxM data x model mesh shape, e.g. 16x16")
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="artifacts/train_ckpt")
    ap.add_argument("--remat", default="none")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args(argv)

    if "COORDINATOR_ADDRESS" in os.environ:       # multi-host fleet
        jax.distributed.initialize()

    from ..checkpoint import Checkpointer
    from ..configs import get_config
    from ..data import ByteCorpus, DataConfig
    from ..ft import StragglerDetector, Supervisor
    from ..models import Runtime, get_model
    from ..sharding import TRAIN_RULES, activation_sharding, tree_shardings
    from ..train import (OptConfig, TrainConfig, init_train_state,
                         make_train_step, train_loop)
    from ..train.optimizer import init_opt_state, opt_state_specs

    cfg = get_config(args.arch)
    model = get_model(cfg)
    d, m = (int(x) for x in args.mesh.split("x"))
    from repro.compat import make_mesh
    mesh = make_mesh((d, m), ("data", "model"))

    tc = TrainConfig(opt=OptConfig(lr=args.lr, warmup_steps=10,
                                   total_steps=args.steps),
                     microbatches=args.microbatches,
                     runtime=Runtime(remat=args.remat), ckpt_every=50)
    data = ByteCorpus(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq,
        global_batch=args.global_batch,
        n_hosts=jax.process_count(), host_id=jax.process_index()))

    # Sharded state: resolve TRAIN_RULES onto the mesh for params + opt.
    state, pspecs = init_train_state(model, jax.random.PRNGKey(0), tc)
    ospecs = opt_state_specs(pspecs, tc.opt, has_master="master" in state["opt"])
    shardings = tree_shardings(
        jax.eval_shape(lambda: state), {"params": pspecs, "opt": ospecs},
        TRAIN_RULES, mesh)
    state = jax.tree.map(jax.device_put, state, shardings)

    def step_with_ctx(st, batch):
        with activation_sharding(mesh, TRAIN_RULES):
            return make_train_step(model, tc)(st, batch)

    step_fn = jax.jit(step_with_ctx, donate_argnums=0)
    ck = Checkpointer(args.ckpt_dir)
    straggler = StragglerDetector()

    start = 0
    if args.resume and ck.latest_step() is not None:
        state, _ = ck.restore(state)
        start = ck.latest_step()
        print(f"resumed from step {start}")

    def train_fn(st, st_step):
        return train_loop(model, tc, data, steps=args.steps, state=st,
                          start_step=st_step, checkpointer=ck,
                          step_fn=step_fn, straggler=straggler)

    sup = Supervisor(ck, max_restarts=3)
    state, hist = sup.run(lambda st, s0: train_fn(st, s0), state)

    losses = [mtr["loss"] for _, mtr in hist]
    print(f"[train] arch={cfg.name} mesh={args.mesh} steps={len(hist)} "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"restarts={sup.restarts} stragglers={len(straggler.flagged)}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
