"""Model zoo: 10 assigned architectures behind one functional API."""
from .model import Model, Runtime, get_model

__all__ = ["Model", "Runtime", "get_model"]
