"""Shared model building blocks (pure JAX, no flax).

Parameters are plain pytrees. Every initializer builds the parameter tree
*and* a parallel tree of logical-axis tuples (MaxText-style) in lockstep via
``ParamBuilder``; ``repro.sharding`` later maps logical axes onto mesh axes
with divisibility-aware fallbacks.

Logical axes used across the zoo:
  "embed" (d_model), "heads", "kv_heads", "head_dim", "ff", "vocab",
  "experts", "layers" (scan stack — never sharded), "state", "conv",
  "vision" — plus "batch"/"seq" on activations.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["ParamBuilder", "rms_norm", "rope_angles", "apply_rope",
           "attention", "swiglu", "cross_entropy", "stack_layers", "DTYPES"]

DTYPES = {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
          "float16": jnp.float16}


class ParamBuilder:
    """Builds a params pytree and its logical-axis spec pytree in lockstep."""

    def __init__(self, key: jax.Array, dtype=jnp.bfloat16):
        self.key = key
        self.dtype = dtype
        self.params: dict[str, Any] = {}
        self.specs: dict[str, Any] = {}

    def _next(self, name: str) -> jax.Array:
        return jax.random.fold_in(self.key, abs(hash(name)) % (2**31 - 1))

    def add(self, name: str, shape: tuple[int, ...], axes: tuple[str, ...],
            init: str = "normal", scale: float | None = None) -> None:
        assert len(shape) == len(axes), (name, shape, axes)
        if init == "zeros":
            p = jnp.zeros(shape, self.dtype)
        elif init == "ones":
            p = jnp.ones(shape, self.dtype)
        else:
            fan_in = shape[0] if len(shape) > 1 else shape[-1]
            s = scale if scale is not None else 1.0 / math.sqrt(max(fan_in, 1))
            p = (jax.random.normal(self._next(name), shape, jnp.float32) * s
                 ).astype(self.dtype)
        self.params[name] = p
        self.specs[name] = axes

    def sub(self, name: str) -> "ParamBuilder":
        b = ParamBuilder(self._next(name), self.dtype)
        self.params[name] = b.params
        self.specs[name] = b.specs
        return b

    def build(self) -> tuple[dict, dict]:
        return self.params, self.specs


def stack_layers(key: jax.Array, n_layers: int, make_one, dtype=jnp.bfloat16):
    """Initialize a homogeneous layer stack with a leading 'layers' axis.

    The stacked representation keeps the traced HLO O(1) in depth via
    ``jax.lax.scan`` — essential for compiling 94-layer configs in the
    512-device dry-run.
    """
    def init_at(k):
        b = ParamBuilder(k, dtype)
        make_one(b)
        return b.params

    keys = jax.random.split(key, n_layers)
    params = jax.vmap(init_at)(keys)
    b = ParamBuilder(key, dtype)
    make_one(b)
    specs = jax.tree.map(lambda a: ("layers",) + a, b.specs,
                         is_leaf=lambda x: isinstance(x, tuple))
    return params, specs


# ------------------------------------------------------------------ layers
def rms_norm(x: jax.Array, gamma: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps) * gamma.astype(jnp.float32)).astype(dt)


def rope_angles(positions: jax.Array, head_dim: int, theta: float) -> tuple:
    """NeoX-style rotary angles for given absolute positions (any shape)."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (..., half)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (..., n_heads, head_dim); cos/sin broadcastable (..., half)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    cos = cos[..., None, :] if cos.ndim == x.ndim - 1 else cos
    sin = sin[..., None, :] if sin.ndim == x.ndim - 1 else sin
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin],
                           axis=-1).astype(x.dtype)


def _attend(q, k, v, mask, scale):
    """q (B,Tq,Hkv,G,hd), k/v (B,Tk,Hkv,hd), mask (B,1,1,Tq,Tk) or None."""
    scores = jnp.einsum("btkgh,bskh->bkgts", q, k).astype(jnp.float32) * scale
    if mask is not None:
        scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    return jnp.einsum("bkgts,bskh->btkgh", probs, v)


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              causal: bool, q_offset: Any = 0,
              prefix_len: Any = None,
              q_chunk: int = 0) -> jax.Array:
    """GQA attention. q (B,Tq,Hq,hd), k/v (B,Tk,Hkv,hd) -> (B,Tq,Hq,hd).

    ``q_offset``: absolute position of q[0] (decode: cache length).
    ``prefix_len``: PaliGemma-style prefix-LM — positions < prefix_len attend
    bidirectionally, the rest causally.
    ``q_chunk``: if >0 and Tq >= 2*q_chunk, scan over query chunks so the
    score matrix never materializes at (Tq, Tk) — the XLA-level analogue of
    flash attention used for 32k prefill shapes.
    """
    b, tq, hq, hd = q.shape
    tk, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    qg = q.reshape(b, tq, hkv, g, hd)
    scale = 1.0 / math.sqrt(hd)

    def mask_for(q_pos):
        if not causal:
            return None
        k_pos = jnp.arange(tk)[None, :]
        m = q_pos[:, None] >= k_pos
        if prefix_len is not None:
            both_prefix = (q_pos[:, None] < prefix_len) & (k_pos < prefix_len)
            m = m | both_prefix
        return m[None, None, None]           # (1,1,1,Tq,Tk)

    if q_chunk and tq >= 2 * q_chunk and tq % q_chunk == 0:
        n_chunks = tq // q_chunk
        qs = qg.reshape(b, n_chunks, q_chunk, hkv, g, hd).transpose(1, 0, 2, 3, 4, 5)

        def body(carry, args):
            i, qc = args
            q_pos = q_offset + i * q_chunk + jnp.arange(q_chunk)
            out = _attend(qc, k, v, mask_for(q_pos), scale)
            return carry, out

        _, outs = jax.lax.scan(body, 0, (jnp.arange(n_chunks), qs))
        out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(b, tq, hq, hd)
        return out

    q_pos = q_offset + jnp.arange(tq)
    out = _attend(qg, k, v, mask_for(q_pos), scale)
    return out.reshape(b, tq, hq, hd)


def swiglu(x: jax.Array, w1: jax.Array, w3: jax.Array, w2: jax.Array) -> jax.Array:
    h = jax.nn.silu(x @ w1) * (x @ w3)
    return h @ w2


def cross_entropy(logits: jax.Array, targets: jax.Array,
                  weights: jax.Array | None = None) -> jax.Array:
    """Mean token CE. logits (..., V) any dtype; targets int (...)."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if weights is None:
        return jnp.mean(nll)
    return jnp.sum(nll * weights) / jnp.maximum(jnp.sum(weights), 1.0)
