"""Mamba2 (SSD) block — the state-space mixer inside zamba2-2.7b.

Scalar-per-head decay a_t = exp(-softplus(dt_t) * exp(A_log)); state
(B, H, P, N). Chunked SSD evaluation for sequences (decay algebra in f32),
exact recurrent step for decode. Both are cross-checked in tests.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["init_mamba2", "mamba2_seq", "mamba2_step", "mamba2_state_shape"]


def _dims(cfg):
    d_in = cfg.ssm_expand * cfg.d_model
    p = cfg.ssm_head_dim
    h = d_in // p
    n = cfg.ssm_state
    return d_in, h, p, n


def init_mamba2(b, cfg) -> None:
    d = cfg.d_model
    d_in, h, p, n = _dims(cfg)
    conv_dim = d_in + 2 * n
    b.add("ln", (d,), ("embed",), init="ones")
    # in_proj emits [z (d_in), x (d_in), B (n), C (n), dt (h)]
    b.add("in_proj", (d, 2 * d_in + 2 * n + h), ("embed", "inner"))
    b.add("conv_w", (cfg.ssm_conv_width, conv_dim), ("conv", "inner"))
    b.add("conv_b", (conv_dim,), ("inner",), init="zeros")
    b.add("a_log", (h,), ("state_heads",), init="zeros")
    b.add("d_skip", (h,), ("state_heads",), init="ones")
    b.add("dt_bias", (h,), ("state_heads",), init="zeros")
    b.add("out_norm", (d_in,), ("inner",), init="ones")
    b.add("out_proj", (d_in, d), ("inner", "embed"))


def mamba2_state_shape(cfg, batch: int):
    _, h, p, n = _dims(cfg)
    return {"ssm": (batch, h, p, n), "conv": (batch, cfg.ssm_conv_width - 1,
                                              None)}  # conv dim filled below


def _split(cfg, zxbcdt):
    d_in, h, p, n = _dims(cfg)
    z = zxbcdt[..., :d_in]
    xbc = zxbcdt[..., d_in: 2 * d_in + 2 * n]
    dt = zxbcdt[..., 2 * d_in + 2 * n:]
    return z, xbc, dt


def _rms(x, gamma, eps=1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * gamma.astype(jnp.float32)
            ).astype(x.dtype)


def mamba2_seq(p_, x, cfg, state=None, conv_state=None, chunk: int = 64):
    """Full-sequence SSD. x (B,T,d) -> (y (B,T,d), ssm_state, conv_state)."""
    b, t, d = x.shape
    d_in, h, pp, n = _dims(cfg)
    cw = cfg.ssm_conv_width

    hin = _rms(x, p_["ln"], cfg.norm_eps)
    zxbcdt = hin @ p_["in_proj"]
    z, xbc, dt = _split(cfg, zxbcdt)

    # Depthwise causal conv over [x; B; C], width cw.
    if conv_state is None:
        conv_state = jnp.zeros((b, cw - 1, xbc.shape[-1]), xbc.dtype)
    xbc_pad = jnp.concatenate([conv_state, xbc], axis=1)
    new_conv_state = xbc_pad[:, -(cw - 1):]
    conv = sum(xbc_pad[:, i: i + t] * p_["conv_w"][i] for i in range(cw))
    xbc = jax.nn.silu(conv + p_["conv_b"])
    xs = xbc[..., :d_in].reshape(b, t, h, pp)
    bmat = xbc[..., d_in: d_in + n]                 # (B,T,N)
    cmat = xbc[..., d_in + n:]                      # (B,T,N)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p_["dt_bias"])  # (B,T,H)
    neg_a = -jnp.exp(p_["a_log"].astype(jnp.float32))             # (H,)
    la = dt * neg_a                                               # log decay

    if state is None:
        state = jnp.zeros((b, h, pp, n), jnp.float32)
    if t % chunk != 0:
        chunk = t                                    # single chunk fallback
    nc = t // chunk

    def per_chunk(s, xs_c):
        xc, bc, cc, dtc, lac = xs_c
        cs = jnp.cumsum(lac, axis=1)                 # (B,C,H) inclusive
        # inter-chunk: y_j += exp(L_j) * C_j . S
        y_inter = jnp.einsum("bjn,bhpn,bjh->bjhp", cc, s, jnp.exp(cs))
        # intra-chunk: att[j,i] = C_j.B_i * exp(L_j - L_i) for i <= j
        att = jnp.einsum("bjn,bin->bji", cc, bc)[:, :, :, None] * \
            jnp.exp(cs[:, :, None] - cs[:, None])    # (B,j,i,H)
        mask = jnp.tril(jnp.ones((xc.shape[1], xc.shape[1]), bool))
        att = jnp.where(mask[None, :, :, None], att, 0.0)
        xdt = xc * dtc[..., None]                    # (B,C,H,P)
        y = y_inter + jnp.einsum("bjih,bihp->bjhp", att, xdt)
        # state carry
        total = cs[:, -1]                            # (B,H)
        bdec = bc[:, :, None, :] * jnp.exp(total[:, None] - cs)[..., None]
        s = jnp.exp(total)[..., None, None] * s + \
            jnp.einsum("bihn,bihp->bhpn", bdec, xdt)
        return s, y

    resh = lambda a: jnp.moveaxis(
        a.reshape((b, nc, chunk) + a.shape[2:]), 1, 0)
    xs_f32 = xs.astype(jnp.float32)
    state, ys = jax.lax.scan(
        per_chunk, state,
        (resh(xs_f32), resh(bmat.astype(jnp.float32)),
         resh(cmat.astype(jnp.float32)), resh(dt), resh(la)))
    y = jnp.moveaxis(ys, 0, 1).reshape(b, t, h, pp)

    y = y + xs_f32.reshape(b, t, h, pp) * p_["d_skip"].astype(jnp.float32)[
        None, None, :, None]
    y = y.reshape(b, t, d_in).astype(x.dtype)
    y = _rms(y * jax.nn.silu(z), p_["out_norm"], cfg.norm_eps)
    return y @ p_["out_proj"], state, new_conv_state


def mamba2_step(p_, x, cfg, state, conv_state):
    """Single-token recurrence. x (B,d) -> (y (B,d), state', conv_state')."""
    b, d = x.shape
    d_in, h, pp, n = _dims(cfg)
    cw = cfg.ssm_conv_width

    hin = _rms(x[:, None], p_["ln"], cfg.norm_eps)[:, 0]
    z, xbc, dt = _split(cfg, hin @ p_["in_proj"])
    window = jnp.concatenate([conv_state, xbc[:, None]], axis=1)  # (B,cw,D)
    new_conv_state = window[:, 1:]
    conv = jnp.einsum("bwd,wd->bd", window, p_["conv_w"])
    xbc = jax.nn.silu(conv + p_["conv_b"])
    xs = xbc[..., :d_in].reshape(b, h, pp).astype(jnp.float32)
    bvec = xbc[..., d_in: d_in + n].astype(jnp.float32)
    cvec = xbc[..., d_in + n:].astype(jnp.float32)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p_["dt_bias"])  # (B,H)
    decay = jnp.exp(dt * -jnp.exp(p_["a_log"].astype(jnp.float32)))
    xdt = xs * dt[..., None]                                       # (B,H,P)
    state = decay[..., None, None] * state + \
        jnp.einsum("bhp,bn->bhpn", xdt, bvec)
    y = jnp.einsum("bhpn,bn->bhp", state, cvec)
    y = y + xs * p_["d_skip"].astype(jnp.float32)[None, :, None]
    y = y.reshape(b, d_in).astype(x.dtype)
    y = _rms((y * jax.nn.silu(z))[:, None], p_["out_norm"], cfg.norm_eps)[:, 0]
    return y @ p_["out_proj"], state, new_conv_state
