"""Unified model API + registry.

``get_model(cfg)`` returns a ``Model`` facade with a family-appropriate
backend. All entry points are functional (params are explicit pytrees) so
they compose with jit/pjit, grad, and the checkpointing substrate.

``input_specs(shape)`` produces ShapeDtypeStruct stand-ins for every input of
the step the shape implies (train_step / prefill / serve_step) — the same
pattern the multi-pod dry-run lowers against, with no device allocation.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp

from . import rwkv6, transformer, zamba2
from .common import DTYPES
from .transformer import Runtime

__all__ = ["Model", "Runtime", "get_model"]

_BACKENDS = {
    "dense": transformer, "moe": transformer, "audio": transformer,
    "vlm": transformer,
    "ssm": rwkv6,
    "hybrid": zamba2,
}


@dataclass(frozen=True)
class Model:
    cfg: Any
    backend: Any

    # ------------------------------------------------------------ factory
    def init(self, key: jax.Array):
        """Returns (params, logical-axis specs)."""
        return self.backend.init(self.cfg, key)

    def param_specs(self):
        """Logical-axis spec tree WITHOUT allocating parameters.

        ``init`` is traced under ``eval_shape`` (no allocation even for the
        235B config); the spec tree — plain string tuples built at trace
        time — is captured as a side effect.
        """
        captured = {}

        def f(k):
            params, specs = self.backend.init(self.cfg, k)
            captured["specs"] = specs
            return params

        jax.eval_shape(f, jax.random.PRNGKey(0))
        return captured["specs"]

    def param_shapes(self):
        """ShapeDtypeStruct tree of the parameters (no allocation)."""
        return jax.eval_shape(
            lambda k: self.backend.init(self.cfg, k)[0], jax.random.PRNGKey(0))

    # ------------------------------------------------------------- steps
    def train_loss(self, params, batch, rt: Runtime = Runtime()):
        return self.backend.train_loss(self.cfg, params, batch, rt)

    def forward(self, params, batch, rt: Runtime = Runtime()):
        return self.backend.forward(self.cfg, params, batch, rt)

    def prefill(self, params, batch, max_len: int, rt: Runtime = Runtime()):
        return self.backend.prefill(self.cfg, params, batch, max_len, rt)

    def decode_step(self, params, batch, cache, rt: Runtime = Runtime()):
        return self.backend.decode_step(self.cfg, params, batch, cache, rt)

    def init_cache(self, batch_size: int, max_len: int):
        return self.backend.init_cache(self.cfg, batch_size, max_len)

    def cache_specs(self):
        return self.backend.cache_specs(self.cfg)

    # ------------------------------------------------------- shape specs
    def input_specs(self, shape) -> dict:
        """ShapeDtypeStructs for the batch of `shape` (see configs.SHAPES)."""
        cfg = self.cfg
        b, s = shape.global_batch, shape.seq_len
        i32 = jnp.int32
        sds = jax.ShapeDtypeStruct

        if cfg.family == "audio":
            k = cfg.n_codebooks
            if shape.kind == "decode":
                return {"tokens": sds((b, k, 1), i32)}
            d = {"tokens": sds((b, k, s), i32)}
            if shape.kind == "train":
                d["targets"] = sds((b, k, s), i32)
            return d

        if cfg.family == "vlm":
            p, vd = cfg.n_patches, cfg.vision_embed_dim
            text = s - p
            assert text > 0, "vlm sequence must exceed the patch prefix"
            if shape.kind == "decode":
                return {"tokens": sds((b, 1), i32)}
            d = {"patches": sds((b, p, vd), DTYPES[cfg.dtype]),
                 "tokens": sds((b, text), i32)}
            if shape.kind == "train":
                d["targets"] = sds((b, text), i32)
            return d

        if shape.kind == "decode":
            return {"tokens": sds((b, 1), i32)}
        d = {"tokens": sds((b, s), i32)}
        if shape.kind == "train":
            d["targets"] = sds((b, s), i32)
        return d

    def cache_input_specs(self, shape) -> dict:
        """ShapeDtypeStructs for a filled cache at ``shape`` (decode only)."""
        cache = jax.eval_shape(
            lambda: self.init_cache(shape.global_batch, shape.seq_len))
        return cache


def get_model(cfg) -> Model:
    try:
        backend = _BACKENDS[cfg.family]
    except KeyError as e:
        raise KeyError(f"no backend for family '{cfg.family}'") from e
    return Model(cfg, backend)
