"""Mixture-of-Experts FFN (qwen3-moe family: 128 experts, top-8).

Dropless-style sorted dispatch with static capacity:

  1. router top-k per token;
  2. flatten (token, k) pairs, sort by expert id;
  3. position-in-expert via sorted ranks -> dispatch index ``e*C + pos``
     (pairs beyond capacity C are dropped, standard GShard semantics);
  4. scatter-add tokens into an (E, C, d) buffer, batched expert matmuls,
     gather back, weight, combine.

Everything is O(T*k) memory — no (T, E, C) one-hot dispatch tensor — so the
compiled HLO FLOPs stay close to 6*N_active*D (checked in §Roofline as the
MODEL_FLOPS/HLO_FLOPs ratio). Expert weights carry the "experts" logical
axis; the sharding rules map it to the FSDP/data axis so the 235B config
fits (DESIGN.md §5).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..sharding.context import constrain

__all__ = ["init_moe", "moe_ffn", "load_balance_loss"]


def init_moe(b, cfg) -> None:
    d, ff, e = cfg.d_model, cfg.d_ff, cfg.moe_experts
    b.add("router", (d, e), ("embed", "experts"))
    b.add("w1", (e, d, ff), ("experts", "embed", "ff"))
    b.add("w3", (e, d, ff), ("experts", "embed", "ff"))
    b.add("w2", (e, ff, d), ("experts", "ff", "embed"))


def moe_ffn(p, x: jax.Array, cfg) -> tuple[jax.Array, jax.Array]:
    """x (B,S,d) -> (out (B,S,d), router probs (T,E) for the aux loss)."""
    bsz, seq, d = x.shape
    e, k = cfg.moe_experts, cfg.moe_top_k
    t = bsz * seq
    cap = max(int(t * k / e * cfg.moe_capacity_factor), k)

    xt = x.reshape(t, d)
    logits = (xt @ p["router"]).astype(jnp.float32)          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, k)                   # (T, k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    flat_e = top_e.reshape(t * k)
    flat_w = top_w.reshape(t * k)
    token_id = jnp.repeat(jnp.arange(t), k)

    # Sort (token, k) pairs by expert; rank within expert = index - group start.
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    group_start = jnp.searchsorted(sorted_e, sorted_e, side="left")
    pos_in_e = jnp.arange(t * k) - group_start
    keep = pos_in_e < cap
    dispatch = jnp.where(keep, sorted_e * cap + pos_in_e, e * cap)  # drop slot

    # Scatter tokens into the expert buffer (+1 trash row for drops).
    gathered = xt[token_id[order]]                           # (T*k, d)
    buf = jnp.zeros((e * cap + 1, d), x.dtype).at[dispatch].set(gathered)
    buf = buf[: e * cap].reshape(e, cap, d)
    buf = constrain(buf, ("experts", "moe_cap", "embed_act"))

    # Batched expert FFN (swiglu).
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["w1"])) \
        * jnp.einsum("ecd,edf->ecf", buf, p["w3"])
    h = constrain(h, ("experts", "moe_cap", "ff"))
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["w2"]).reshape(e * cap, d)
    out_buf = jnp.concatenate([out_buf, jnp.zeros((1, d), x.dtype)], axis=0)

    # Gather back, weight, combine over the k replicas of each token.
    y_sorted = out_buf[dispatch] * (flat_w[order] * keep).astype(x.dtype)[:, None]
    y = jnp.zeros((t, d), x.dtype).at[token_id[order]].add(y_sorted)
    return y.reshape(bsz, seq, d), probs


def load_balance_loss(probs: jax.Array, top_e: jax.Array | None, cfg) -> jax.Array:
    """Switch-style auxiliary loss: E * sum_e f_e * P_e (f from argmax)."""
    e = cfg.moe_experts
    p_mean = probs.mean(axis=0)                               # (E,)
    hard = jax.nn.one_hot(jnp.argmax(probs, axis=-1), e).mean(axis=0)
    return e * jnp.sum(hard * p_mean)
