"""Expert-parallel MoE FFN under shard_map (beyond-paper optimization).

The GSPMD lowering of the sorted-dispatch MoE (moe.py) falls back to
"scatter = materialize + all-reduce": the full (E*cap, d) buffer is
all-reduced across the data axis per layer, ~24 TB/device/step on the
qwen3-moe-30b train cell (EXPERIMENTS.md §Perf, hillclimb B).

This module routes tokens explicitly:

  1. per shard: top-k routing, destination shard = expert // E_local;
  2. pack tokens into per-destination slots (static capacity C_send);
  3. ``lax.all_to_all`` over the data axis (the EP axis — expert weights are
     sharded over it);
  4. local capacity dispatch to the shard's E_local experts, batched
     matmuls (ff dim sharded over "model" -> one psum at the end);
  5. reverse all-to-all (an involution: rows return to their send slots),
     weight and combine at the source.

Collective bytes per layer drop to 2 x (tokens x d) a2a + one d-sized psum —
the algorithmic minimum for EP — instead of E*cap*d all-reduces.
"""
from __future__ import annotations

import math

import jax
import jax.ad_checkpoint
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map

__all__ = ["moe_ffn_ep"]


def _positions_within_groups(group_ids: jax.Array, n_groups: int,
                             length: int) -> jax.Array:
    """Rank of each element within its group, computed via stable sort."""
    order = jnp.argsort(group_ids, stable=True)
    sorted_g = group_ids[order]
    start = jnp.searchsorted(sorted_g, sorted_g, side="left")
    rank_sorted = jnp.arange(length) - start
    ranks = jnp.zeros(length, jnp.int32).at[order].set(
        rank_sorted.astype(jnp.int32))
    return ranks


def _ep_body(x, router, w1, w3, w2, *, cfg, dp_axes, ep_axis, tp_axis, dsz):
    """shard_map body. x (B_loc, S, d); w* sharded: E over ep, ff over tp."""
    b_loc, s, d = x.shape
    e, k = cfg.moe_experts, cfg.moe_top_k
    e_loc = e // dsz
    t = b_loc * s
    cf = cfg.moe_capacity_factor

    xt = x.reshape(t, d)
    logits = (xt @ router).astype(jnp.float32)             # (T, E) full router
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    pairs = t * k
    flat_e = top_e.reshape(pairs)
    flat_w = top_w.reshape(pairs).astype(x.dtype)
    token_id = jnp.repeat(jnp.arange(t), k)

    # ---- pack into per-destination-shard slots
    dest = flat_e // e_loc                                  # (pairs,)
    c_send = max(int(math.ceil(pairs / dsz * cf)), k)
    pos = _positions_within_groups(dest, dsz, pairs)
    keep = pos < c_send
    slot = jnp.where(keep, dest * c_send + pos, dsz * c_send)

    send_x = jnp.zeros((dsz * c_send + 1, d), x.dtype).at[slot].set(xt[token_id])
    send_e = jnp.full((dsz * c_send + 1,), e, jnp.int32).at[slot].set(
        flat_e % e_loc)                                     # local expert id
    send_x = send_x[:-1].reshape(dsz, c_send, d)
    send_e = send_e[:-1].reshape(dsz, c_send)

    # ---- exchange: row block i goes to shard i
    recv_x = jax.lax.all_to_all(send_x, ep_axis, split_axis=0, concat_axis=0,
                                tiled=False)
    # Named for the save_a2a remat policy: saving the received activations
    # keeps the backward from replaying the forward exchange.
    recv_x = jax.ad_checkpoint.checkpoint_name(recv_x, "moe_a2a")
    recv_e = jax.lax.all_to_all(send_e, ep_axis, split_axis=0, concat_axis=0,
                                tiled=False)
    rt = dsz * c_send
    rx = recv_x.reshape(rt, d)
    re = recv_e.reshape(rt)                                 # in [0, e_loc] (e_loc==invalid)

    # ---- local capacity dispatch to my e_loc experts
    c_loc = max(int(math.ceil(rt / e_loc * cf)), 1)
    lpos = _positions_within_groups(re, e_loc + 1, rt)
    lkeep = (re < e_loc) & (lpos < c_loc)
    lslot = jnp.where(lkeep, re * c_loc + lpos, e_loc * c_loc)
    buf = jnp.zeros((e_loc * c_loc + 1, d), x.dtype).at[lslot].set(rx)
    buf = buf[:-1].reshape(e_loc, c_loc, d)

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, w1)) \
        * jnp.einsum("ecd,edf->ecf", buf, w3)
    out = jnp.einsum("ecf,efd->ecd", h, w2).reshape(e_loc * c_loc, d)
    out = jnp.concatenate([out, jnp.zeros((1, d), x.dtype)], axis=0)

    # ---- return rows to their send slots (a2a is an involution here)
    back = (out[lslot] * lkeep[:, None].astype(x.dtype)).reshape(
        dsz, c_send, d)
    ret = jax.lax.all_to_all(back, ep_axis, split_axis=0, concat_axis=0,
                             tiled=False).reshape(dsz * c_send, d)
    ret = jax.ad_checkpoint.checkpoint_name(ret, "moe_a2a")
    ret = jnp.concatenate([ret, jnp.zeros((1, d), x.dtype)], axis=0)

    # ---- weight + combine at the source
    y_pairs = ret[slot] * (flat_w * keep.astype(x.dtype))[:, None]
    y = jnp.zeros((t, d), x.dtype).at[token_id].add(y_pairs)
    # ff was sharded over the tensor-parallel axis -> partial sums.
    if tp_axis is not None:
        y = jax.lax.psum(y, tp_axis)
    return y.reshape(b_loc, s, d), probs


def moe_ffn_ep(p, x: jax.Array, cfg, mesh) -> tuple[jax.Array, jax.Array]:
    """Drop-in for moe.moe_ffn with explicit EP collectives (needs a mesh)."""
    names = mesh.axis_names
    ep_axis = "data" if "data" in names else names[-1]
    tp_axis = "model" if "model" in names else None
    dp_axes = tuple(a for a in ("pod", "data") if a in names)
    batch_entry = dp_axes if len(dp_axes) > 1 else \
        (dp_axes[0] if dp_axes else None)

    body = lambda xx, r, a, b, c: _ep_body(
        xx, r, a, b, c, cfg=cfg, dp_axes=dp_axes, ep_axis=ep_axis,
        tp_axis=tp_axis, dsz=int(mesh.shape[ep_axis]))
    y, probs = shard_map(
        body, mesh=mesh,
        in_specs=(P(batch_entry, None, None),         # x: batch over DP
                  P(None, None),                      # router: replicated
                  P(ep_axis, None, tp_axis),          # w1 (E, d, ff)
                  P(ep_axis, None, tp_axis),          # w3 (E, d, ff)
                  P(ep_axis, tp_axis, None)),         # w2 (E, ff, d)
        out_specs=(P(batch_entry, None, None),
                   P(batch_entry, None)),
        check_vma=False,
    )(x, p["router"], p["w1"], p["w3"], p["w2"])
    return y, probs.reshape(-1, probs.shape[-1])
