"""RWKV6 "Finch" — attention-free LM with data-dependent decay (rwkv6-3b).

Defining features implemented: token shift, LoRA-parameterized per-channel
data-dependent decay w_t = exp(-exp(w0 + tanh(x W_a) W_b)), bonus ``u``,
squared-ReLU channel mixing. (The paper-exact ddlerp on all five mixes is
simplified to static per-channel interpolation; the decay — the Finch
contribution — is fully data-dependent. Recorded in DESIGN.md.)

Two WKV evaluators with identical semantics (cross-checked in tests and by
``kernels/rwkv6_scan``):
  * ``wkv_scan``    — O(T) sequential recurrence (decode path; also the
                      simplest-possible training baseline);
  * ``wkv_chunked`` — chunk-parallel form: intra-chunk pairwise decays +
                      inter-chunk state carry; the training default, and the
                      basis of the Pallas kernel.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .common import DTYPES, ParamBuilder, cross_entropy, rms_norm, stack_layers
from ..sharding.context import constrain

__all__ = ["init", "train_loss", "prefill", "decode_step", "init_cache",
           "wkv_scan", "wkv_chunked"]


# ---------------------------------------------------------------- wkv core
def wkv_scan(r, k, v, w, u, state):
    """Sequential recurrence.

    r,k,w: (B,T,H,K); v: (B,T,H,V); u: (H,K); state: (B,H,K,V).
    Returns (y (B,T,H,V), final state).
      y_t  = (S_{t-1} + diag(u) k_t v_t^T)^T r_t
      S_t  = diag(w_t) S_{t-1} + k_t v_t^T
    """
    def step(s, xs):
        rt, kt, vt, wt = xs                          # (B,H,K) / (B,H,V)
        kv = kt[..., :, None] * vt[..., None, :]     # (B,H,K,V)
        y = jnp.einsum("bhk,bhkv->bhv", rt, s + u[None, :, :, None] * kv)
        s = wt[..., None] * s + kv
        return s, y

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (r, k, v, w))
    state, ys = jax.lax.scan(step, state, xs)
    return jnp.moveaxis(ys, 0, 1), state


def wkv_chunked(r, k, v, w, u, state, chunk: int = 64):
    """Chunk-parallel evaluation (identical math, different schedule).

    Within a chunk, pairwise per-channel decays form an (C, C, K) tensor per
    (batch, head); across chunks the (K, V) state is carried. f32 throughout
    the decay algebra for stability.
    """
    b, t, h, kk = r.shape
    vv = v.shape[-1]
    if t % chunk != 0:
        return wkv_scan(r, k, v, w, u, state)
    n = t // chunk

    def per_chunk(s, xs):
        rc, kc, vc, wc = xs                          # (B,C,H,*)
        lw = jnp.log(wc.astype(jnp.float32))         # (B,C,H,K)
        cs = jnp.cumsum(lw, axis=1)                  # L_j inclusive
        d_in = jnp.exp(cs - lw)                      # exp(L_{j-1}) from start
        # inter-chunk: y_j += (r_j * exp(L_{j-1})) . S
        y_inter = jnp.einsum("bjhk,bhkv->bjhv",
                             rc.astype(jnp.float32) * d_in, s)
        # intra-chunk: att[j,i] = sum_k r_j k_i exp(L_{j-1}-L_i)  (i < j)
        dec = jnp.exp((cs - lw)[:, :, None] - cs[:, None])   # (B,j,i,H,K)
        att = jnp.einsum("bjhk,bihk,bjihk->bjih",
                         rc.astype(jnp.float32), kc.astype(jnp.float32), dec)
        mask = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)
        att = jnp.where(mask[None, :, :, None], att, 0.0)
        # diagonal bonus term (i == j): sum_k r_j u k_j
        diag = jnp.einsum("bjhk,hk,bjhk->bjh",
                          rc.astype(jnp.float32), u.astype(jnp.float32),
                          kc.astype(jnp.float32))
        y = y_inter + jnp.einsum("bjih,bihv->bjhv", att,
                                 vc.astype(jnp.float32))
        y = y + diag[..., None] * vc.astype(jnp.float32)
        # state carry: S' = diag(exp(L_C)) S + sum_i k_i exp(L_C - L_i) v_i
        total = cs[:, -1][:, None]                   # (B,1,H,K)
        kdec = kc.astype(jnp.float32) * jnp.exp(total - cs)
        s = jnp.exp(total[:, 0])[..., None] * s + \
            jnp.einsum("bihk,bihv->bhkv", kdec, vc.astype(jnp.float32))
        return s, y

    resh = lambda x: jnp.moveaxis(
        x.reshape(b, n, chunk, h, x.shape[-1]), 1, 0)
    state = state.astype(jnp.float32)
    state, ys = jax.lax.scan(per_chunk, state,
                             tuple(resh(x) for x in (r, k, v, w)))
    y = jnp.moveaxis(ys, 0, 1).reshape(b, t, h, vv)
    return y.astype(v.dtype), state


# -------------------------------------------------------------------- init
def _init_layer(b: ParamBuilder, cfg) -> None:
    d, ff, lora = cfg.d_model, cfg.d_ff, cfg.rwkv_decay_lora
    b.add("ln1", (d,), ("embed",), init="ones")
    b.add("ln2", (d,), ("embed",), init="ones")
    for mu in ("mu_r", "mu_k", "mu_v", "mu_g", "mu_w", "mu_ffn_k", "mu_ffn_r"):
        b.add(mu, (d,), ("embed",), init="zeros")
    b.add("w0", (d,), ("embed",), init="zeros")
    b.add("w_lora_a", (d, lora), ("embed", "lora"))
    b.add("w_lora_b", (lora, d), ("lora", "embed"))
    b.add("u", (d,), ("embed",), init="zeros")
    for w in ("wr", "wk", "wv", "wg"):
        b.add(w, (d, d), ("embed", "inner"))
    b.add("wo", (d, d), ("inner", "embed"))
    b.add("ln_x", (d,), ("embed",), init="ones")
    b.add("ffn_k", (d, ff), ("embed", "ff"))
    b.add("ffn_v", (ff, d), ("ff", "embed"))
    b.add("ffn_r", (d, d), ("embed", "inner"))


def init(cfg, key: jax.Array):
    dtype = DTYPES[cfg.dtype]
    b = ParamBuilder(key, dtype)
    b.add("embed", (cfg.vocab_size, cfg.d_model), ("vocab", "embed"))
    b.add("head", (cfg.d_model, cfg.vocab_size), ("embed", "vocab"))
    b.add("final_norm", (cfg.d_model,), ("embed",), init="ones")
    layers, lspecs = stack_layers(b._next("layers"), cfg.n_layers,
                                  lambda lb: _init_layer(lb, cfg), dtype)
    params, specs = b.build()
    params["layers"], specs["layers"] = layers, lspecs
    return params, specs


# ------------------------------------------------------------------ layers
def _heads(cfg):
    hd = cfg.ssm_head_dim
    return cfg.d_model // hd, hd


def _time_mix(cfg, p, x, shifted, wkv_state, use_chunked: bool):
    """x, shifted: (B,T,d). Returns (out, new wkv_state)."""
    b, t, d = x.shape
    h, hd = _heads(cfg)
    lerp = lambda mu: x + (shifted - x) * p[mu]
    xr, xk, xv, xg, xw = (lerp(m) for m in ("mu_r", "mu_k", "mu_v", "mu_g",
                                            "mu_w"))
    r = (xr @ p["wr"]).reshape(b, t, h, hd)
    k = (xk @ p["wk"]).reshape(b, t, h, hd)
    v = (xv @ p["wv"]).reshape(b, t, h, hd)
    g = jax.nn.silu(xg @ p["wg"])
    # Finch data-dependent decay via LoRA, w in (0, 1).
    dd = p["w0"] + jnp.tanh(xw @ p["w_lora_a"]) @ p["w_lora_b"]
    w = jnp.exp(-jnp.exp(dd.astype(jnp.float32))).reshape(b, t, h, hd)
    u = p["u"].reshape(h, hd)

    fn = wkv_chunked if use_chunked else wkv_scan
    y, new_state = fn(r, k, v.astype(jnp.float32), w, u, wkv_state)
    y = y.reshape(b, t, d).astype(x.dtype)
    y = rms_norm(y, p["ln_x"], cfg.norm_eps)      # per-channel out-norm
    return (y * g) @ p["wo"], new_state


def _channel_mix(cfg, p, x, shifted):
    lerp = lambda mu: x + (shifted - x) * p[mu]
    xk, xr = lerp("mu_ffn_k"), lerp("mu_ffn_r")
    kk = jnp.square(jax.nn.relu(xk @ p["ffn_k"]))
    return (kk @ p["ffn_v"]) * jax.nn.sigmoid(xr @ p["ffn_r"])


def _shift_seq(x):
    """Token shift for full sequences: x_{t-1}, zeros at t=0."""
    return jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]


def _block_seq(cfg, p, x, wkv_state, use_chunked):
    """Returns (out, new wkv state, h1_last, h2_last) — the last-token normed
    activations are the token-shift state a later decode step continues from."""
    x = constrain(x, ("batch", "seq", "embed_act"))
    h1 = rms_norm(x, p["ln1"], cfg.norm_eps)
    att, new_state = _time_mix(cfg, p, h1, _shift_seq(h1), wkv_state,
                               use_chunked)
    x = x + att
    h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
    x = constrain(x + _channel_mix(cfg, p, h2, _shift_seq(h2)),
                  ("batch", "seq", "embed_act"))
    return x, new_state, h1[:, -1], h2[:, -1]


def _run_seq(cfg, params, x, use_chunked=True, remat=False):
    b = x.shape[0]
    h, hd = _heads(cfg)
    s0 = jnp.zeros((b, h, hd, hd), jnp.float32)

    def body(carry, lp):
        out, state, _, _ = _block_seq(cfg, lp, carry, s0, use_chunked)
        return out, state

    fn = jax.checkpoint(body) if remat else body
    x, states = jax.lax.scan(fn, x, params["layers"])
    return x, states


# -------------------------------------------------------------- entry pts
def forward(cfg, params, batch, rt=None):
    use_chunked = getattr(rt, "rwkv_chunked", True) if rt else True
    remat = (getattr(rt, "remat", "none") != "none") if rt else False
    x = params["embed"][batch["tokens"]]
    x = constrain(x, ("batch", "seq", "embed_act"))
    x, _ = _run_seq(cfg, params, x, use_chunked, remat)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return constrain(x @ params["head"], ("batch", "seq", "vocab")), None


def train_loss(cfg, params, batch, rt=None):
    logits, _ = forward(cfg, params, batch, rt)
    return cross_entropy(logits, batch["targets"])


def init_cache(cfg, batch_size: int, max_len: int, dtype=None):
    """RWKV decode state is O(1) in sequence length (DESIGN.md: the 'KV
    cache' of an attention-free arch is the per-layer wkv + shift state)."""
    del max_len
    h, hd = _heads(cfg)
    L, d = cfg.n_layers, cfg.d_model
    dtype = dtype or DTYPES[cfg.dtype]
    return {
        "wkv": jnp.zeros((L, batch_size, h, hd, hd), jnp.float32),
        "att_shift": jnp.zeros((L, batch_size, d), dtype),
        "ffn_shift": jnp.zeros((L, batch_size, d), dtype),
        "len": jnp.zeros((), jnp.int32),
    }


def cache_specs(cfg):
    return {
        "wkv": ("layers", "batch", "state_heads", "head_dim", "head_dim2"),
        "att_shift": ("layers", "batch", "embed"),
        "ffn_shift": ("layers", "batch", "embed"),
        "len": (),
    }


def prefill(cfg, params, batch, max_len: int, rt=None):
    use_chunked = getattr(rt, "rwkv_chunked", True) if rt else True
    x = params["embed"][batch["tokens"]]
    b, t, d = x.shape
    h, hd = _heads(cfg)
    s0 = jnp.zeros((b, h, hd, hd), jnp.float32)

    def body(carry, lp):
        out, state, h1_last, h2_last = _block_seq(cfg, lp, carry, s0,
                                                  use_chunked)
        return out, (state, h1_last, h2_last)

    x, (wkv, att_shift, ffn_shift) = jax.lax.scan(body, x, params["layers"])
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    cache = {"wkv": wkv, "att_shift": att_shift, "ffn_shift": ffn_shift,
             "len": jnp.int32(t)}
    return (x[:, -1] @ params["head"]), cache


def decode_step(cfg, params, batch, cache, rt=None):
    x = params["embed"][batch["tokens"]][:, 0]      # (B, d)
    h, hd = _heads(cfg)

    def body(carry, xs):
        xc = carry
        lp, wkv, att_sh, ffn_sh = xs
        h1 = rms_norm(xc[:, None], lp["ln1"], cfg.norm_eps)
        att, new_wkv = _time_mix(cfg, lp, h1, att_sh[:, None], wkv, False)
        xc = xc + att[:, 0]
        h2 = rms_norm(xc[:, None], lp["ln2"], cfg.norm_eps)
        ffn = _channel_mix(cfg, lp, h2, ffn_sh[:, None])
        xc = xc + ffn[:, 0]
        return xc, (new_wkv, h1[:, 0], h2[:, 0])

    x, (wkv, att_shift, ffn_shift) = jax.lax.scan(
        body, x, (params["layers"], cache["wkv"], cache["att_shift"],
                  cache["ffn_shift"]))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = x @ params["head"]
    new_cache = {"wkv": wkv, "att_shift": att_shift, "ffn_shift": ffn_shift,
                 "len": cache["len"] + 1}
    return logits, new_cache
