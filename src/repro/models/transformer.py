"""Decoder-only transformer driver for the dense / moe / audio / vlm families.

One implementation covers:
  * dense GQA (+ optional qk_norm) — qwen3-14b/32b, codeqwen1.5-7b, internlm2;
  * MoE FFN — qwen3-moe-30b/235b (see moe.py);
  * multi-codebook audio LM — musicgen (sum-of-codebook embeddings, K heads);
  * prefix-LM VLM — paligemma (stub patch embeddings + projector, MQA,
    logit soft-capping, sqrt(d) embedding scale).

Layers are stacked on a leading "layers" axis and executed with
``jax.lax.scan`` (optionally rematerialized) so trace/compile cost is O(1)
in depth.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .common import (DTYPES, ParamBuilder, apply_rope, attention,
                     cross_entropy, rms_norm, rope_angles, stack_layers,
                     swiglu)
from ..sharding.context import constrain
from .moe import init_moe, load_balance_loss, moe_ffn

__all__ = ["Runtime", "init", "forward", "train_loss", "prefill",
           "decode_step", "init_cache"]


@dataclass(frozen=True)
class Runtime:
    """Execution knobs (perf levers — see EXPERIMENTS.md §Perf)."""

    q_chunk: int = 1024          # query-chunked attention threshold
    remat: str = "none"          # none | full — scan-level rematerialization
    moe_aux_weight: float = 0.01
    moe_impl: str = "gspmd"      # gspmd (sorted dispatch) | ep (shard_map a2a)


# ------------------------------------------------------------------- init
def _init_layer(b: ParamBuilder, cfg) -> None:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    nq, nkv = cfg.n_heads, cfg.n_kv_heads
    b.add("ln1", (d,), ("embed",), init="ones")
    b.add("wq", (d, nq, hd), ("embed", "heads", "head_dim"))
    b.add("wk", (d, nkv, hd), ("embed", "kv_heads", "head_dim"))
    b.add("wv", (d, nkv, hd), ("embed", "kv_heads", "head_dim"))
    b.add("wo", (nq, hd, d), ("heads", "head_dim", "embed"))
    if cfg.qk_norm:
        b.add("q_norm", (hd,), ("head_dim",), init="ones")
        b.add("k_norm", (hd,), ("head_dim",), init="ones")
    b.add("ln2", (d,), ("embed",), init="ones")
    if cfg.family == "moe":
        init_moe(b.sub("moe"), cfg)
    else:
        b.add("w1", (d, cfg.d_ff), ("embed", "ff"))
        b.add("w3", (d, cfg.d_ff), ("embed", "ff"))
        b.add("w2", (cfg.d_ff, d), ("ff", "embed"))


def init(cfg, key: jax.Array):
    """Returns (params, logical-axis specs)."""
    dtype = DTYPES[cfg.dtype]
    b = ParamBuilder(key, dtype)
    d, v = cfg.d_model, cfg.vocab_size
    if cfg.family == "audio":
        b.add("embed", (cfg.n_codebooks, v, d), ("codebooks", "vocab", "embed"))
        b.add("head", (cfg.n_codebooks, d, v), ("codebooks", "embed", "vocab"))
    else:
        b.add("embed", (v, d), ("vocab", "embed"))
        if not cfg.tie_embeddings:
            b.add("head", (d, v), ("embed", "vocab"))
    if cfg.family == "vlm":
        b.add("vis_proj", (cfg.vision_embed_dim, d), ("vision", "embed"))
    b.add("final_norm", (d,), ("embed",), init="ones")

    layer_params, layer_specs = stack_layers(
        b._next("layers"), cfg.n_layers, lambda lb: _init_layer(lb, cfg), dtype)
    params, specs = b.build()
    params["layers"], specs["layers"] = layer_params, layer_specs
    return params, specs


# ------------------------------------------------------------------ layers
def _attn(cfg, p, x, *, cache_kv=None, cur_len=None, pos_offset=0,
          prefix_len=None, rt: Runtime = Runtime()):
    """One attention sub-block. Returns (out, new_cache_kv)."""
    bsz, tq, d = x.shape
    hd = cfg.resolved_head_dim
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    q = jnp.einsum("btd,dnh->btnh", h, p["wq"])
    k = jnp.einsum("btd,dnh->btnh", h, p["wk"])
    v = jnp.einsum("btd,dnh->btnh", h, p["wv"])
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)

    if cache_kv is None:
        pos = pos_offset + jnp.arange(tq)
        cos, sin = rope_angles(pos, hd, cfg.rope_theta)
        q = apply_rope(q, cos[None], sin[None])
        k = apply_rope(k, cos[None], sin[None])
        out = attention(q, k, v, causal=True, prefix_len=prefix_len,
                        q_chunk=rt.q_chunk)
        new_cache = (k, v)
    else:
        ck, cv = cache_kv                      # (B, Smax, Hkv, hd)
        pos = cur_len + jnp.arange(tq)         # decode: tq == 1
        cos, sin = rope_angles(pos, hd, cfg.rope_theta)
        q = apply_rope(q, cos[None], sin[None])
        k = apply_rope(k, cos[None], sin[None])
        ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype),
                                          (0, cur_len, 0, 0))
        cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype),
                                          (0, cur_len, 0, 0))
        smax = ck.shape[1]
        valid = (jnp.arange(smax) <= cur_len)[None, None, None, None, :]
        hq, hkv = cfg.n_heads, cfg.n_kv_heads
        qg = q.reshape(bsz, tq, hkv, hq // hkv, hd)
        scores = jnp.einsum("btkgh,bskh->bkgts", qg, ck).astype(jnp.float32)
        scores = scores / jnp.sqrt(jnp.float32(hd))
        scores = jnp.where(valid, scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1).astype(cv.dtype)
        out = jnp.einsum("bkgts,bskh->btkgh", probs, cv)
        out = out.reshape(bsz, tq, hq, hd).astype(x.dtype)
        new_cache = (ck, cv)
    return jnp.einsum("btnh,nhd->btd", out, p["wo"]).astype(x.dtype), new_cache


def _block(cfg, p, x, *, cache_kv=None, cur_len=None, pos_offset=0,
           prefix_len=None, rt: Runtime = Runtime()):
    x = constrain(x, ("batch", "seq", "embed_act"))
    attn_out, new_cache = _attn(cfg, p, x, cache_kv=cache_kv, cur_len=cur_len,
                                pos_offset=pos_offset, prefix_len=prefix_len,
                                rt=rt)
    x = x + attn_out
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    if cfg.family == "moe":
        from ..sharding.context import current_mesh
        mesh = current_mesh()
        if rt.moe_impl == "ep" and mesh is not None:
            from .moe_ep import moe_ffn_ep
            ffn_out, router_probs = moe_ffn_ep(p["moe"], h, cfg, mesh)
        else:
            ffn_out, router_probs = moe_ffn(p["moe"], h, cfg)
    else:
        ffn_out = swiglu(h, p["w1"], p["w3"], p["w2"])
        router_probs = jnp.zeros((1, 1), jnp.float32)
    out = constrain(x + ffn_out, ("batch", "seq", "embed_act"))
    return out, new_cache, router_probs


def _run_layers(cfg, layers, x, *, cache=None, cur_len=None, pos_offset=0,
                prefix_len=None, rt: Runtime = Runtime()):
    """scan over the stacked layer axis; threads KV caches through."""

    def body(carry, scanned):
        h = carry
        if cache is None:
            p = scanned
            h2, _, probs = _block(cfg, p, h, pos_offset=pos_offset,
                                  prefix_len=prefix_len, rt=rt)
            return h2, probs
        p, (ck, cv) = scanned
        h2, new_kv, probs = _block(cfg, p, h, cache_kv=(ck, cv),
                                   cur_len=cur_len, rt=rt)
        return h2, (new_kv[0], new_kv[1], probs)

    if rt.remat == "save_a2a":
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.save_only_these_names(
                "moe_a2a"))
    elif rt.remat != "none":
        body = jax.checkpoint(body)

    if cache is None:
        x, probs = jax.lax.scan(body, x, layers)
        return x, None, probs
    x, (ck, cv, probs) = jax.lax.scan(body, x, (layers, cache))
    return x, (ck, cv), probs


# ----------------------------------------------------------------- embeds
def _embed_tokens(cfg, params, batch):
    d = cfg.d_model
    if cfg.family == "audio":
        # (B, K, T) codebook ids -> sum over K codebook embeddings.
        toks = batch["tokens"]
        parts = [params["embed"][kb][toks[:, kb]] for kb in range(cfg.n_codebooks)]
        return sum(parts), None
    if cfg.family == "vlm":
        patches = batch["patches"].astype(params["vis_proj"].dtype)
        img = patches @ params["vis_proj"]                     # (B, P, d)
        txt = params["embed"][batch["tokens"]]                 # (B, Tt, d)
        x = jnp.concatenate([img, txt], axis=1)
        if cfg.embed_scale:
            x = x * jnp.sqrt(jnp.float32(d)).astype(x.dtype)
        return x, cfg.n_patches
    x = params["embed"][batch["tokens"]]
    if cfg.embed_scale:
        x = x * jnp.sqrt(jnp.float32(d)).astype(x.dtype)
    return x, None


def _logits(cfg, params, x):
    if cfg.family == "audio":
        out = jnp.einsum("btd,kdv->btkv", x, params["head"])
    elif cfg.tie_embeddings:
        out = jnp.einsum("btd,vd->btv", x, params["embed"])
    else:
        out = jnp.einsum("btd,dv->btv", x, params["head"])
    if cfg.logit_softcap:
        c = cfg.logit_softcap
        out = jnp.tanh(out.astype(jnp.float32) / c) * c
    if cfg.family == "audio":
        out = constrain(out, ("batch", "seq", "codebooks", "vocab"))
    else:
        out = constrain(out, ("batch", "seq", "vocab"))
    return out


# -------------------------------------------------------------- entry pts
def forward(cfg, params, batch, rt: Runtime = Runtime()):
    """Full-sequence forward -> logits (train/prefill share this path)."""
    x, prefix_len = _embed_tokens(cfg, params, batch)
    x = constrain(x, ("batch", "seq", "embed_act"))
    x, _, probs = _run_layers(cfg, params["layers"], x,
                              prefix_len=prefix_len, rt=rt)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if cfg.family == "vlm":
        x = x[:, cfg.n_patches:]            # loss only on text positions
    return _logits(cfg, params, x), probs


def train_loss(cfg, params, batch, rt: Runtime = Runtime()):
    logits, probs = forward(cfg, params, batch, rt)
    if cfg.family == "audio":
        tgt = batch["targets"]              # (B, K, T)
        loss = cross_entropy(logits.transpose(0, 2, 1, 3), tgt)
    else:
        loss = cross_entropy(logits, batch["targets"])
    if cfg.family == "moe":
        aux = load_balance_loss(probs.reshape(-1, probs.shape[-1]), None, cfg)
        loss = loss + rt.moe_aux_weight * aux
    return loss


def init_cache(cfg, batch_size: int, max_len: int, dtype=None):
    dtype = dtype or DTYPES[cfg.dtype]
    hd, nkv, L = cfg.resolved_head_dim, cfg.n_kv_heads, cfg.n_layers
    return {
        "k": jnp.zeros((L, batch_size, max_len, nkv, hd), dtype),
        "v": jnp.zeros((L, batch_size, max_len, nkv, hd), dtype),
        "len": jnp.zeros((), jnp.int32),
    }


def cache_specs(cfg):
    """Logical axes for the cache pytree (sequence is model-sharded for
    decode — flash-decoding style; DESIGN.md §5)."""
    kv = ("layers", "batch", "kv_seq", "kv_heads", "head_dim")
    return {"k": kv, "v": kv, "len": ()}


def prefill(cfg, params, batch, max_len: int, rt: Runtime = Runtime()):
    """Run the prompt, fill a KV cache, return (last-token logits, cache)."""
    x, prefix_len = _embed_tokens(cfg, params, batch)
    x = constrain(x, ("batch", "seq", "embed_act"))
    bsz, seq = x.shape[0], x.shape[1]
    cache = init_cache(cfg, bsz, max_len)

    def body(carry, scanned):
        h = carry
        p = scanned
        h2, kv, _ = _block(cfg, p, h, prefix_len=prefix_len, rt=rt)
        return h2, kv

    body_fn = jax.checkpoint(body) if rt.remat != "none" else body
    x, (ks, vs) = jax.lax.scan(body_fn, x, params["layers"])
    pad = max_len - seq
    ks = jnp.pad(ks, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    vs = jnp.pad(vs, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    cache = {"k": ks, "v": vs, "len": jnp.int32(seq)}
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if cfg.family == "vlm":
        x = x[:, cfg.n_patches:]
    logits = _logits(cfg, params, x[:, -1:])
    return logits[:, 0], cache


def decode_step(cfg, params, batch, cache, rt: Runtime = Runtime()):
    """One-token step against a filled KV cache (serve_step for decode_*)."""
    if cfg.family == "audio":
        toks = batch["tokens"]              # (B, K, 1)
        parts = [params["embed"][kb][toks[:, kb]] for kb in range(cfg.n_codebooks)]
        x = sum(parts)
    else:
        x = params["embed"][batch["tokens"]]   # (B, 1) -> (B, 1, d)
        if cfg.embed_scale:
            x = x * jnp.sqrt(jnp.float32(cfg.d_model)).astype(x.dtype)
    cur = cache["len"]
    x, new_kv, _ = _run_layers(cfg, params["layers"], x,
                               cache=(cache["k"], cache["v"]), cur_len=cur,
                               rt=rt)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = _logits(cfg, params, x)
    new_cache = {"k": new_kv[0], "v": new_kv[1], "len": cur + 1}
    return logits[:, 0], new_cache
