"""Zamba2 hybrid — Mamba2 backbone + one *shared* attention block
(zamba2-2.7b: 54 mamba layers; the shared block fires every 6 layers).

Faithful-to-family structure: the shared transformer block has ONE set of
attention+MLP weights; each application site concatenates the current hidden
state with the original embedding ([h; emb] -> 2d) and maps it through a
per-site input projector, per the Zamba2 design. KV caches exist only at the
shared-block sites, which is what makes long_500k viable for this family.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import (DTYPES, ParamBuilder, apply_rope, attention,
                     cross_entropy, rms_norm, rope_angles, stack_layers,
                     swiglu)
from .mamba2 import _dims, init_mamba2, mamba2_seq, mamba2_step
from ..sharding.context import constrain

__all__ = ["init", "train_loss", "prefill", "decode_step", "init_cache"]


def _n_sites(cfg) -> int:
    return (cfg.n_layers + cfg.shared_attn_every - 1) // cfg.shared_attn_every


def _init_shared_block(b: ParamBuilder, cfg) -> None:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    nq, nkv = cfg.n_heads, cfg.n_kv_heads
    b.add("ln1", (d,), ("embed",), init="ones")
    b.add("wq", (d, nq, hd), ("embed", "heads", "head_dim"))
    b.add("wk", (d, nkv, hd), ("embed", "kv_heads", "head_dim"))
    b.add("wv", (d, nkv, hd), ("embed", "kv_heads", "head_dim"))
    b.add("wo", (nq, hd, d), ("heads", "head_dim", "embed"))
    b.add("ln2", (d,), ("embed",), init="ones")
    b.add("w1", (d, cfg.d_ff), ("embed", "ff"))
    b.add("w3", (d, cfg.d_ff), ("embed", "ff"))
    b.add("w2", (cfg.d_ff, d), ("ff", "embed"))


def init(cfg, key: jax.Array):
    dtype = DTYPES[cfg.dtype]
    b = ParamBuilder(key, dtype)
    d = cfg.d_model
    b.add("embed", (cfg.vocab_size, d), ("vocab", "embed"))
    b.add("head", (d, cfg.vocab_size), ("embed", "vocab"))
    b.add("final_norm", (d,), ("embed",), init="ones")
    _init_shared_block(b.sub("shared"), cfg)

    n_sites = _n_sites(cfg)
    # Per-site [h; emb] -> d input projectors for the shared block.
    b.add("site_proj", (n_sites, 2 * d, d), ("sites", "embed2", "embed"))

    layers, lspecs = stack_layers(b._next("layers"), cfg.n_layers,
                                  lambda lb: init_mamba2(lb, cfg), dtype)
    params, specs = b.build()
    params["layers"], specs["layers"] = layers, lspecs
    return params, specs


# ---------------------------------------------------------------- shared
def _shared_attn(cfg, sp, site_proj, h, emb, *, cache_kv=None, cur_len=None,
                 q_chunk=1024):
    """One application of the shared block at a site."""
    x = jnp.concatenate([h, emb], axis=-1) @ site_proj       # (B,T,d)
    a = rms_norm(x, sp["ln1"], cfg.norm_eps)
    hd = cfg.resolved_head_dim
    q = jnp.einsum("btd,dnh->btnh", a, sp["wq"])
    k = jnp.einsum("btd,dnh->btnh", a, sp["wk"])
    v = jnp.einsum("btd,dnh->btnh", a, sp["wv"])
    if cache_kv is None:
        pos = jnp.arange(x.shape[1])
        cos, sin = rope_angles(pos, hd, cfg.rope_theta)
        q = apply_rope(q, cos[None], sin[None])
        k = apply_rope(k, cos[None], sin[None])
        out = attention(q, k, v, causal=True, q_chunk=q_chunk)
        new_kv = (k, v)
    else:
        ck, cv = cache_kv
        pos = cur_len + jnp.arange(1)
        cos, sin = rope_angles(pos, hd, cfg.rope_theta)
        q = apply_rope(q, cos[None], sin[None])
        k = apply_rope(k, cos[None], sin[None])
        ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype),
                                          (0, cur_len, 0, 0))
        cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype),
                                          (0, cur_len, 0, 0))
        smax = ck.shape[1]
        nq, nkv = cfg.n_heads, cfg.n_kv_heads
        qg = q.reshape(q.shape[0], 1, nkv, nq // nkv, hd)
        scores = jnp.einsum("btkgh,bskh->bkgts", qg, ck).astype(jnp.float32)
        scores = scores / jnp.sqrt(jnp.float32(hd))
        valid = (jnp.arange(smax) <= cur_len)[None, None, None, None, :]
        scores = jnp.where(valid, scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1).astype(cv.dtype)
        out = jnp.einsum("bkgts,bskh->btkgh", probs, cv)
        out = out.reshape(q.shape[0], 1, nq, hd).astype(x.dtype)
        new_kv = (ck, cv)
    x = x + jnp.einsum("btnh,nhd->btd", out, sp["wo"]).astype(x.dtype)
    m = rms_norm(x, sp["ln2"], cfg.norm_eps)
    x = x + swiglu(m, sp["w1"], sp["w3"], sp["w2"])
    return x, new_kv


# --------------------------------------------------------------- sequence
def _group_layers(cfg, layers):
    """Reshape the (L, ...) stack into (sites, every, ...) for a 2-level scan."""
    every = cfg.shared_attn_every
    n_sites = _n_sites(cfg)
    pad = n_sites * every - cfg.n_layers
    assert pad == 0, "n_layers must be divisible by shared_attn_every"
    return jax.tree.map(
        lambda a: a.reshape((n_sites, every) + a.shape[1:]), layers)


def _run_seq(cfg, params, x, remat=False, q_chunk=1024):
    emb = x
    grouped = _group_layers(cfg, params["layers"])

    def outer_body(h, xs):
        site_proj, group = xs
        h = constrain(h, ("batch", "seq", "embed_act"))
        shared_out, _ = _shared_attn(cfg, params["shared"], site_proj, h, emb,
                                     q_chunk=q_chunk)
        h = h + shared_out               # shared block feeds the residual

        def inner_body(hh, lp):
            y, _, _ = mamba2_seq(lp, hh, cfg)
            return constrain(hh + y, ("batch", "seq", "embed_act")), None

        fn = jax.checkpoint(inner_body) if remat else inner_body
        h, _ = jax.lax.scan(fn, h, group)
        return h, None

    h, _ = jax.lax.scan(outer_body, x, (params["site_proj"], grouped))
    return h, None


def forward(cfg, params, batch, rt=None):
    remat = (getattr(rt, "remat", "none") != "none") if rt else False
    q_chunk = getattr(rt, "q_chunk", 1024) if rt else 1024
    x = params["embed"][batch["tokens"]]
    x = constrain(x, ("batch", "seq", "embed_act"))
    h, _ = _run_seq(cfg, params, x, remat=remat, q_chunk=q_chunk)
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    return constrain(h @ params["head"], ("batch", "seq", "vocab")), None


def train_loss(cfg, params, batch, rt=None):
    logits, _ = forward(cfg, params, batch, rt)
    return cross_entropy(logits, batch["targets"])


# ------------------------------------------------------------------ serve
def init_cache(cfg, batch_size: int, max_len: int, dtype=None):
    dtype = dtype or DTYPES[cfg.dtype]
    d_in, h, pp, n = _dims(cfg)
    n_sites = _n_sites(cfg)
    hd, nkv = cfg.resolved_head_dim, cfg.n_kv_heads
    conv_dim = d_in + 2 * n
    return {
        "kv_k": jnp.zeros((n_sites, batch_size, max_len, nkv, hd), dtype),
        "kv_v": jnp.zeros((n_sites, batch_size, max_len, nkv, hd), dtype),
        "ssm": jnp.zeros((cfg.n_layers, batch_size, h, pp, n), jnp.float32),
        "conv": jnp.zeros((cfg.n_layers, batch_size, cfg.ssm_conv_width - 1,
                           conv_dim), dtype),
        "len": jnp.zeros((), jnp.int32),
    }


def cache_specs(cfg):
    kv = ("sites", "batch", "kv_seq", "kv_heads", "head_dim")
    return {"kv_k": kv, "kv_v": kv,
            "ssm": ("layers", "batch", "state_heads", "head_dim", "state"),
            "conv": ("layers", "batch", "conv", "inner"),
            "len": ()}


def prefill(cfg, params, batch, max_len: int, rt=None):
    q_chunk = getattr(rt, "q_chunk", 1024) if rt else 1024
    x = params["embed"][batch["tokens"]]
    emb = x
    b, t, d = x.shape
    grouped = _group_layers(cfg, params["layers"])

    def outer_body(h, xs):
        site_proj, group = xs
        shared_out, kv = _shared_attn(cfg, params["shared"], site_proj, h,
                                      emb, q_chunk=q_chunk)
        h = h + shared_out

        def inner_body(hh, lp):
            y, ssm, conv = mamba2_seq(lp, hh, cfg)
            return hh + y, (ssm, conv)

        h, (ssm, conv) = jax.lax.scan(inner_body, h, group)
        return h, (kv, ssm, conv)

    h, (kvs, ssm, conv) = jax.lax.scan(outer_body, x,
                                       (params["site_proj"], grouped))
    ks, vs = kvs
    pad = max_len - t
    ks = jnp.pad(ks, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    vs = jnp.pad(vs, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    L = cfg.n_layers
    cache = {
        "kv_k": ks, "kv_v": vs,
        "ssm": ssm.reshape((L,) + ssm.shape[2:]),
        "conv": conv.reshape((L,) + conv.shape[2:]),
        "len": jnp.int32(t),
    }
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    return h[:, -1] @ params["head"], cache


def decode_step(cfg, params, batch, cache, rt=None):
    x = params["embed"][batch["tokens"]][:, 0]          # (B,d)
    emb = x
    cur = cache["len"]
    every = cfg.shared_attn_every
    n_sites = _n_sites(cfg)
    grouped = _group_layers(cfg, params["layers"])
    ssm_g = cache["ssm"].reshape((n_sites, every) + cache["ssm"].shape[1:])
    conv_g = cache["conv"].reshape((n_sites, every) + cache["conv"].shape[1:])

    def outer_body(h, xs):
        site_proj, group, kv_k, kv_v, ssm, conv = xs
        h2, (ck, cv) = _shared_attn(cfg, params["shared"], site_proj,
                                    h[:, None], emb[:, None],
                                    cache_kv=(kv_k, kv_v), cur_len=cur)
        h = h + h2[:, 0]

        def inner_body(hh, xs2):
            lp, s, cs = xs2
            y, s2, cs2 = mamba2_step(lp, hh, cfg, s, cs)
            return hh + y, (s2, cs2)

        h, (ssm2, conv2) = jax.lax.scan(inner_body, h, (group, ssm, conv))
        return h, (ck, cv, ssm2, conv2)

    h, (ks, vs, ssm, conv) = jax.lax.scan(
        outer_body, x,
        (params["site_proj"], grouped, cache["kv_k"], cache["kv_v"],
         ssm_g, conv_g))
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = h @ params["head"]
    L = cfg.n_layers
    new_cache = {
        "kv_k": ks, "kv_v": vs,
        "ssm": ssm.reshape((L,) + ssm.shape[2:]),
        "conv": conv.reshape((L,) + conv.shape[2:]),
        "len": cur + 1,
    }
    return logits, new_cache
