"""Serving layer: topology query service, HTTP front end + client, the
remote-discovery job engine, and the token-serving engine used by the
latency benchmarks.  See ``docs/ARCHITECTURE.md`` for how these fit
together."""
from .client import TopologyClient, TopologyHTTPError
from .engine import Engine, ServeConfig
from .http import HttpError, ServerMetrics, TopologyHTTPServer
from .jobs import (Job, JobEngine, QueueFullError, TransientRunnerError,
                   resolve_discovery)
from .topology_service import (AttrDelta, QueryResult, TopologyDiff,
                               TopologyService)

__all__ = ["Engine", "ServeConfig",
           "AttrDelta", "QueryResult", "TopologyDiff", "TopologyService",
           "HttpError", "ServerMetrics", "TopologyHTTPServer",
           "TopologyClient", "TopologyHTTPError",
           "Job", "JobEngine", "QueueFullError", "TransientRunnerError",
           "resolve_discovery"]
