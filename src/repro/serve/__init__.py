from .engine import Engine, ServeConfig
from .topology_service import (AttrDelta, QueryResult, TopologyDiff,
                               TopologyService)

__all__ = ["Engine", "ServeConfig",
           "AttrDelta", "QueryResult", "TopologyDiff", "TopologyService"]
