from .client import TopologyClient, TopologyHTTPError
from .engine import Engine, ServeConfig
from .http import HttpError, ServerMetrics, TopologyHTTPServer
from .topology_service import (AttrDelta, QueryResult, TopologyDiff,
                               TopologyService)

__all__ = ["Engine", "ServeConfig",
           "AttrDelta", "QueryResult", "TopologyDiff", "TopologyService",
           "HttpError", "ServerMetrics", "TopologyHTTPServer",
           "TopologyClient", "TopologyHTTPError"]
