"""Small stdlib client for the topology HTTP front end (``serve/http.py``).

Mirrors the server's endpoint surface one method per endpoint, speaking the
same JSON shapes; non-2xx responses raise ``TopologyHTTPError`` carrying
the structured error payload (and the ``Retry-After`` hint on 503s), so
callers can distinguish retry-later from wrong-request without parsing
message strings.
"""
from __future__ import annotations

import json
import urllib.error
import urllib.request
from urllib.parse import quote, urlencode

__all__ = ["TopologyHTTPError", "TopologyClient"]


class TopologyHTTPError(Exception):
    """A non-2xx response from the topology server."""

    def __init__(self, status: int, payload: dict,
                 retry_after_s: float | None = None):
        super().__init__(f"HTTP {status}: {payload.get('error', payload)}")
        self.status = status
        self.payload = payload
        self.retry_after_s = retry_after_s


class TopologyClient:
    """Client for one topology server, e.g. ``TopologyClient(server.url)``."""

    def __init__(self, base_url: str, timeout_s: float = 10.0):
        self.base_url = base_url.rstrip("/")
        self.timeout_s = timeout_s

    # ------------------------------------------------------------ plumbing
    def _request(self, path: str, params: dict | None = None,
                 body: dict | None = None) -> dict:
        url = f"{self.base_url}{path}"
        if params:
            url += "?" + urlencode({k: v for k, v in params.items()
                                    if v is not None})
        data = None
        headers = {}
        if body is not None:
            data = json.dumps(body).encode()
            headers["Content-Type"] = "application/json"
        req = urllib.request.Request(url, data=data, headers=headers)
        try:
            with urllib.request.urlopen(req, timeout=self.timeout_s) as resp:
                return json.loads(resp.read())
        except urllib.error.HTTPError as e:
            try:
                payload = json.loads(e.read())
            except (json.JSONDecodeError, UnicodeDecodeError):
                payload = {"error": str(e)}
            retry_after = e.headers.get("Retry-After")
            raise TopologyHTTPError(
                e.code, payload,
                float(retry_after) if retry_after else None) from None

    @staticmethod
    def _k(key: str) -> str:
        return quote(key, safe="")

    # ----------------------------------------------------------- endpoints
    def healthz(self) -> dict:
        return self._request("/healthz")

    def metrics(self) -> dict:
        return self._request("/metrics")

    def topologies(self) -> list[dict]:
        return self._request("/topologies")["topologies"]

    def topology(self, key: str) -> dict:
        return self._request(f"/topologies/{self._k(key)}")

    def query(self, key: str, path: str) -> dict:
        return self._request(f"/topologies/{self._k(key)}/query",
                             params={"path": path})

    def query_batch(self, pairs) -> list[dict]:
        body = {"requests": [[k, p] for k, p in pairs]}
        return self._request("/query_batch", body=body)["results"]

    def attributes(self, key: str, *, provenance: str | None = None,
                   min_confidence: float | None = None) -> list[dict]:
        return self._request(
            f"/topologies/{self._k(key)}/attributes",
            params={"provenance": provenance,
                    "min_confidence": min_confidence})["attributes"]

    def adjacency(self, key: str) -> dict:
        return self._request(f"/adjacency/{self._k(key)}")["adjacency"]

    def diff(self, key_a: str, key_b: str, rel_tol: float = 0.0) -> dict:
        return self._request("/diff", params={"a": key_a, "b": key_b,
                                              "rel_tol": rel_tol})
