"""Small stdlib client for the topology HTTP front end (``serve/http.py``).

Mirrors the server's endpoint surface one method per endpoint, speaking the
same JSON shapes; non-2xx responses raise ``TopologyHTTPError`` carrying
the structured error payload (and the ``Retry-After`` hint on 503s), so
callers can distinguish retry-later from wrong-request without parsing
message strings.

Remote discovery (the write path) adds three verbs plus a poller:
``submit_discovery`` / ``discovery`` / ``cancel_discovery`` / ``wait``.
When the server requires auth, pass ``auth_token=`` and every request
carries ``Authorization: Bearer <token>``.

Client-side retry: ``max_retries > 0`` re-issues a request that failed
with **503** (quarantined entry, full job queue, overload) or a transport-
level ``URLError``, sleeping ``Retry-After`` seconds when the server said
so and otherwise ``min(backoff_cap_s, backoff_base_s * 2**attempt)`` —
bounded, capped, and off by default so the error-mapping tests (and any
caller that wants failures raw) see the first answer.  ``wait`` honors the
``Retry-After`` header unfinished job polls carry instead of hammering a
fixed interval.
"""
from __future__ import annotations

import json
import time
import urllib.error
import urllib.request
from urllib.parse import quote, urlencode

__all__ = ["TopologyHTTPError", "TopologyClient"]

TERMINAL_JOB_STATES = ("done", "failed", "cancelled")


class TopologyHTTPError(Exception):
    """A non-2xx response from the topology server."""

    def __init__(self, status: int, payload: dict,
                 retry_after_s: float | None = None):
        super().__init__(f"HTTP {status}: {payload.get('error', payload)}")
        self.status = status
        self.payload = payload
        self.retry_after_s = retry_after_s


class TopologyClient:
    """Client for one topology server, e.g. ``TopologyClient(server.url)``.

    ``max_retries`` bounds the 503/transport retry loop (0 = no retries);
    ``sleep`` is injectable so tests can assert the exact backoff schedule.
    """

    def __init__(self, base_url: str, timeout_s: float = 10.0, *,
                 auth_token: str | None = None, max_retries: int = 0,
                 backoff_base_s: float = 0.25, backoff_cap_s: float = 10.0,
                 sleep=time.sleep):
        self.base_url = base_url.rstrip("/")
        self.timeout_s = timeout_s
        self.auth_token = auth_token
        self.max_retries = int(max_retries)
        self.backoff_base_s = float(backoff_base_s)
        self.backoff_cap_s = float(backoff_cap_s)
        self._sleep = sleep

    # ------------------------------------------------------------ plumbing
    def _request_once(self, path: str, params: dict | None = None,
                      body: dict | None = None,
                      method: str | None = None) -> tuple[dict, dict]:
        """One HTTP round trip -> (parsed payload, response headers)."""
        url = f"{self.base_url}{path}"
        if params:
            url += "?" + urlencode({k: v for k, v in params.items()
                                    if v is not None})
        data = None
        headers = {}
        if body is not None:
            data = json.dumps(body).encode()
            headers["Content-Type"] = "application/json"
        if self.auth_token is not None:
            headers["Authorization"] = f"Bearer {self.auth_token}"
        req = urllib.request.Request(url, data=data, headers=headers,
                                     method=method)
        try:
            with urllib.request.urlopen(req, timeout=self.timeout_s) as resp:
                return json.loads(resp.read()), dict(resp.headers)
        except urllib.error.HTTPError as e:
            try:
                payload = json.loads(e.read())
            except (json.JSONDecodeError, UnicodeDecodeError):
                payload = {"error": str(e)}
            retry_after = e.headers.get("Retry-After")
            raise TopologyHTTPError(
                e.code, payload,
                float(retry_after) if retry_after else None) from None

    def _request_full(self, path: str, params: dict | None = None,
                      body: dict | None = None,
                      method: str | None = None) -> tuple[dict, dict]:
        """``_request_once`` wrapped in the bounded 503/transport retry
        loop.  The sleep before attempt ``i`` is the server's
        ``Retry-After`` when present, else capped exponential backoff."""
        for attempt in range(self.max_retries + 1):
            try:
                return self._request_once(path, params, body, method)
            except TopologyHTTPError as e:
                if e.status != 503 or attempt >= self.max_retries:
                    raise
                delay = e.retry_after_s
            except urllib.error.URLError:
                if attempt >= self.max_retries:
                    raise
                delay = None
            self._sleep(min(self.backoff_cap_s,
                            delay if delay is not None
                            else self.backoff_base_s * (2 ** attempt)))
        raise AssertionError("unreachable")          # loop returns or raises

    def _request(self, path: str, params: dict | None = None,
                 body: dict | None = None, method: str | None = None) -> dict:
        return self._request_full(path, params, body, method)[0]

    @staticmethod
    def _k(key: str) -> str:
        return quote(key, safe="")

    # ----------------------------------------------------------- endpoints
    def healthz(self) -> dict:
        """``GET /healthz`` — liveness + store size + job-queue depth."""
        return self._request("/healthz")

    def metrics(self) -> dict:
        """``GET /metrics`` — per-endpoint counters, service stats, and
        the job engine's counter/histogram snapshot."""
        return self._request("/metrics")

    def topologies(self) -> list[dict]:
        """``GET /topologies`` — every stored ``{key, meta}`` entry."""
        return self._request("/topologies")["topologies"]

    def topology(self, key: str) -> dict:
        """``GET /topologies/<key>`` — one full topology document."""
        return self._request(f"/topologies/{self._k(key)}")

    def query(self, key: str, path: str) -> dict:
        """``GET /topologies/<key>/query?path=...`` — one dotted-path
        attribute lookup (e.g. ``L1.size``)."""
        return self._request(f"/topologies/{self._k(key)}/query",
                             params={"path": path})

    def query_batch(self, pairs) -> list[dict]:
        """``POST /query_batch`` — many ``(key, path)`` lookups in one
        round trip; results align with the request order."""
        body = {"requests": [[k, p] for k, p in pairs]}
        return self._request("/query_batch", body=body)["results"]

    def attributes(self, key: str, *, provenance: str | None = None,
                   min_confidence: float | None = None) -> list[dict]:
        """``GET /topologies/<key>/attributes`` with optional provenance /
        confidence filters."""
        return self._request(
            f"/topologies/{self._k(key)}/attributes",
            params={"provenance": provenance,
                    "min_confidence": min_confidence})["attributes"]

    def adjacency(self, key: str) -> dict:
        """``GET /adjacency/<key>`` — the interconnect adjacency map."""
        return self._request(f"/adjacency/{self._k(key)}")["adjacency"]

    def diff(self, key_a: str, key_b: str, rel_tol: float = 0.0) -> dict:
        """``GET /diff?a=...&b=...`` — attribute-level topology diff."""
        return self._request("/diff", params={"a": key_a, "b": key_b,
                                              "rel_tol": rel_tol})

    # ---------------------------------------------------- remote discovery
    def submit_discovery(self, params: dict) -> dict:
        """POST a serialized discovery request; returns the job document
        (``deduplicated: true`` when it attached to an in-flight
        equivalent).  Wire format: ``docs/HTTP_API.md``."""
        return self._request("/discoveries", body=params)

    def discoveries(self, state: str | None = None) -> list[dict]:
        """``GET /discoveries`` — all known jobs, optionally filtered to
        one state (``queued``/``running``/``done``/``failed``/
        ``cancelled``)."""
        return self._request("/discoveries",
                             params={"state": state})["jobs"]

    def discovery(self, job_id: str) -> dict:
        """``GET /discoveries/<job_id>`` — one job document (poll target)."""
        return self._request(f"/discoveries/{self._k(job_id)}")

    def cancel_discovery(self, job_id: str) -> dict:
        """``DELETE /discoveries/<job_id>`` — idempotent cancellation:
        immediate for queued jobs, best-effort for running ones."""
        return self._request(f"/discoveries/{self._k(job_id)}",
                             method="DELETE")

    def wait(self, job_id: str, timeout_s: float = 120.0,
             poll_s: float = 0.5) -> dict:
        """Poll ``GET /discoveries/<job_id>`` until the job is terminal.

        Sleeps the server's ``Retry-After`` hint between polls when the
        response carries one, else ``poll_s``.  Raises ``TimeoutError``
        when the deadline passes with the job still live — the job keeps
        running server-side; this only abandons the wait.
        """
        deadline = time.monotonic() + timeout_s
        while True:
            payload, headers = self._request_full(
                f"/discoveries/{self._k(job_id)}")
            if payload["state"] in TERMINAL_JOB_STATES:
                return payload
            if time.monotonic() >= deadline:
                raise TimeoutError(
                    f"job {job_id} still {payload['state']} after "
                    f"{timeout_s}s")
            retry_after = headers.get("Retry-After")
            self._sleep(min(float(retry_after) if retry_after else poll_s,
                            max(deadline - time.monotonic(), 0.0)))

    def submit_and_wait(self, params: dict, timeout_s: float = 120.0,
                        poll_s: float = 0.5) -> dict:
        """``submit_discovery`` + ``wait`` in one call; returns the
        terminal job document."""
        job = self.submit_discovery(params)
        return self.wait(job["job_id"], timeout_s=timeout_s, poll_s=poll_s)
