"""Token-serving engine: batched prefill + decode with continuous batching.

Naming note — this repo has three "engines", and this is the *model* one:
``core/engine`` is the probe engine behind the unified
``discover(request)`` core, ``serve/jobs.JobEngine`` is the remote
discovery job engine behind ``POST /discoveries``, and this module serves
LLM tokens for the latency benchmarks.  It shares nothing with the other
two beyond the name.

The engine owns a fixed pool of B sequence slots. ``generate`` services a
request list: prompts are prefilled into free slots, every ``step`` decodes
all active slots at once (one jitted serve_step), finished sequences retire
and their slots are immediately refilled — the standard continuous-batching
loop, minus speculative niceties.

For multi-device serving the same jitted functions are used with the SERVE
sharding rules (sequence-parallel KV cache over "model").
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from ..models import Runtime

__all__ = ["ServeConfig", "Engine"]


@dataclass(frozen=True)
class ServeConfig:
    """Serving knobs: slot-pool size, sequence cap, sampling temperature."""

    max_len: int = 256
    slots: int = 4
    temperature: float = 0.0        # 0 -> greedy
    rt: Runtime = Runtime(q_chunk=0)


class Engine:
    """Continuous-batching token server over a fixed slot pool; the
    ``generate`` loop prefills into free slots and decodes all active
    slots per step with one jitted call."""

    def __init__(self, model, params, cfg: ServeConfig):
        self.model = model
        self.params = params
        self.cfg = cfg
        self._prefill = jax.jit(
            lambda p, b: model.prefill(p, b, cfg.max_len, cfg.rt))
        self._decode = jax.jit(
            lambda p, b, c: model.decode_step(p, b, c, cfg.rt))

    def _sample(self, logits: np.ndarray, rng: np.random.Generator):
        if self.cfg.temperature <= 0:
            return np.argmax(logits, axis=-1)
        z = logits / self.cfg.temperature
        z = z - z.max(-1, keepdims=True)
        p = np.exp(z)
        p /= p.sum(-1, keepdims=True)
        return np.array([rng.choice(p.shape[-1], p=row) for row in p])

    def generate_batch(self, prompts: np.ndarray, max_new: int,
                       eos_id: int | None = None, seed: int = 0):
        """One batch of same-length prompts -> (B, <=max_new) generations."""
        rng = np.random.default_rng(seed)
        batch = {"tokens": jnp.asarray(prompts, jnp.int32)}
        logits, cache = self._prefill(self.params, batch)
        outs = []
        alive = np.ones(prompts.shape[0], bool)
        for _ in range(max_new):
            nxt = self._sample(np.asarray(logits, np.float32), rng)
            outs.append(nxt)
            if eos_id is not None:
                alive &= nxt != eos_id
                if not alive.any():
                    break
            logits, cache = self._decode(
                self.params, {"tokens": jnp.asarray(nxt[:, None], jnp.int32)},
                cache)
        return np.stack(outs, axis=1)

    def serve(self, requests: list[np.ndarray], max_new: int,
              seed: int = 0) -> list[np.ndarray]:
        """Continuous batching over a request queue (equal-length prompts
        grouped into slot-sized waves)."""
        results: dict[int, np.ndarray] = {}
        queue = list(enumerate(requests))
        while queue:
            wave = queue[: self.cfg.slots]
            queue = queue[self.cfg.slots:]
            ids = [i for i, _ in wave]
            prompts = np.stack([p for _, p in wave])
            gen = self.generate_batch(prompts, max_new, seed=seed)
            for j, i in enumerate(ids):
                results[i] = gen[j]
        return [results[i] for i in range(len(requests))]
