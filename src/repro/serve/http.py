"""HTTP front end over ``TopologyService`` (the ROADMAP serving follow-on).

Dependency-free: stdlib ``ThreadingHTTPServer`` threads + the in-process
query service, so discovered topologies become network artifacts — the
paper's §V consumption pattern (performance modeling, bottleneck analysis,
partitioning) can run in a different process, language, or machine from the
discovery runs that produced the store.

Endpoints (all JSON; the full reference with request/response shapes lives
in ``docs/HTTP_API.md``)::

    GET    /healthz                                liveness + entry count
    GET    /metrics                                lru + endpoint + job stats
    GET    /topologies                             [{key, meta}, ...]
    GET    /topologies/<key>                       full topology document
    GET    /topologies/<key>/query?path=L1.size    one dotted-path lookup
    GET    /topologies/<key>/attributes            provenance/min_confidence
    GET    /adjacency/<key>                        sharing/link adjacency
    GET    /diff?a=<key>&b=<key>&rel_tol=0.05      attribute-level diff
    POST   /query_batch   {"requests": [[key, path], ...]}
    POST   /discoveries   serialized discovery request -> 202 + job
    GET    /discoveries                            all known jobs
    GET    /discoveries/<job_id>                   job status (poll target)
    DELETE /discoveries/<job_id>                   cancel a job

The ``/discoveries`` endpoints are the remote **write** path: submissions
are validated, content-address-deduplicated, and executed server-side by
the ``serve.jobs.JobEngine`` worker pool, write-through to the same store
every read endpoint serves (see the module docstring of ``serve/jobs.py``).

Traffic hardening:

* request bodies above ``max_body_bytes`` are refused with **413** before
  being read into memory;
* each connection carries a socket **timeout** (a stuck client cannot pin a
  handler thread forever);
* errors map to structured JSON statuses — missing/invalid parameters
  **400**, unknown endpoint, topology key, or job id **404**, wrong method
  **405**, malformed JSON **400**, quarantined-on-disk entry **503** with a
  ``Retry-After`` hint (re-discovery repopulates the key), full job queue
  **503** with ``Retry-After``;
* **bearer-token auth on mutating endpoints** when the server is started
  with ``auth_token=...``: ``POST /discoveries`` and ``DELETE
  /discoveries/<job_id>`` require ``Authorization: Bearer <token>`` and
  answer **401** (with ``WWW-Authenticate``) on a missing or wrong token;
  read endpoints stay open — discovered topologies are the product, the
  write path is the privilege;
* ``stop()`` shuts down gracefully: the accept loop stops first, then
  in-flight handler threads are joined (drained), never killed mid-write;
  the job engine stops with it (queued jobs cancel, running jobs finish).

Per-item misses inside ``/query_batch`` and unresolvable attribute paths on
a *known* topology are data (``found: false``), not transport errors — the
batch contract mirrors ``TopologyService.query_batch``.
"""
from __future__ import annotations

import hmac
import json
import threading
import time
from dataclasses import asdict
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from urllib.parse import parse_qs, urlparse

from .jobs import JobEngine, QueueFullError
from .topology_service import TopologyService

__all__ = ["HttpError", "ServerMetrics", "TopologyHTTPServer",
           "MAX_BODY_BYTES", "REQUEST_TIMEOUT_S"]

MAX_BODY_BYTES = 1 << 20          # 1 MiB: a query_batch of ~10k pairs
REQUEST_TIMEOUT_S = 30.0
RETRY_AFTER_S = 5
JOB_POLL_S = 1                    # Retry-After hint on unfinished job polls

# Log-spaced latency histogram edges (us); the last bucket is +inf.
LATENCY_BUCKETS_US = (100, 250, 500, 1000, 2500, 5000, 10000, 25000,
                      50000, 100000, 250000, 1000000)


class HttpError(Exception):
    """A structured HTTP error response."""

    def __init__(self, status: int, message: str, *,
                 retry_after_s: int | None = None,
                 headers: dict | None = None):
        super().__init__(message)
        self.status = status
        self.message = message
        self.retry_after_s = retry_after_s
        self.headers = headers or {}


class ServerMetrics:
    """Thread-safe per-endpoint request counts + latency histograms."""

    def __init__(self):
        self._mutex = threading.Lock()
        self._endpoints: dict[str, dict] = {}
        self._statuses: dict[str, int] = {}
        self.started_at = time.time()

    def record(self, endpoint: str, status: int, elapsed_s: float) -> None:
        """Fold one served request into the counters and histograms."""
        us = elapsed_s * 1e6
        with self._mutex:
            ep = self._endpoints.setdefault(endpoint, {
                "requests": 0, "errors": 0, "latency_sum_us": 0.0,
                "latency_buckets_us": [0] * (len(LATENCY_BUCKETS_US) + 1),
            })
            ep["requests"] += 1
            ep["errors"] += status >= 400
            ep["latency_sum_us"] += us
            for i, edge in enumerate(LATENCY_BUCKETS_US):
                if us <= edge:
                    ep["latency_buckets_us"][i] += 1
                    break
            else:
                ep["latency_buckets_us"][-1] += 1
            bucket = f"{status // 100}xx"
            self._statuses[bucket] = self._statuses.get(bucket, 0) + 1

    def snapshot(self) -> dict:
        """Deep-copied metrics state, served by ``GET /metrics``."""
        with self._mutex:
            return {
                "uptime_s": round(time.time() - self.started_at, 3),
                "latency_bucket_edges_us": list(LATENCY_BUCKETS_US),
                "endpoints": json.loads(json.dumps(self._endpoints)),
                "statuses": dict(self._statuses),
            }


class _Handler(BaseHTTPRequestHandler):
    """Routes one request to the service; all responses are JSON."""

    server_version = "mt4g-topod/1.0"
    protocol_version = "HTTP/1.1"

    # -------------------------------------------------------- plumbing
    def setup(self):                        # per-connection socket timeout
        self.timeout = self.server.request_timeout_s
        super().setup()

    def log_message(self, fmt, *args):      # stay quiet; /metrics observes
        pass

    @property
    def svc(self) -> TopologyService:
        return self.server.service

    def _send_json(self, status: int, payload: dict,
                   retry_after_s: int | None = None,
                   headers: dict | None = None) -> None:
        body = json.dumps(payload).encode()
        self.send_response(status)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(body)))
        if retry_after_s is not None:
            self.send_header("Retry-After", str(retry_after_s))
        for name, value in (headers or {}).items():
            self.send_header(name, str(value))
        self.end_headers()
        self.wfile.write(body)

    # ------------------------------------------------------- dispatch
    def do_GET(self):                                          # noqa: N802
        self._dispatch("GET")

    def do_POST(self):                                         # noqa: N802
        self._dispatch("POST")

    def do_DELETE(self):                                       # noqa: N802
        self._dispatch("DELETE")

    def _dispatch(self, method: str) -> None:
        t0 = time.perf_counter()
        url = urlparse(self.path)
        endpoint, status = url.path, 500
        try:
            hook = self.server.on_request
            if hook is not None:
                hook(method, url.path)
            endpoint, handler, kwargs = self._route(method, url.path)
            out = handler(query=parse_qs(url.query), **kwargs)
            # Handlers return a payload dict, or (payload, status, headers)
            # when they need a non-200 success code / extra headers (the
            # job endpoints: 202 Accepted, Retry-After poll hints).
            payload, extra = out, {}
            status = 200
            if isinstance(out, tuple):
                payload, status, extra = out
            self._send_json(status, payload, headers=extra)
        except HttpError as e:
            status = e.status
            self._send_json(e.status, {"error": e.message,
                                       "status": e.status},
                            retry_after_s=e.retry_after_s,
                            headers=e.headers)
        except (BrokenPipeError, ConnectionResetError):
            status = 499                    # client went away mid-response
        except Exception as e:              # noqa: BLE001 — 500, keep serving
            status = 500
            try:
                self._send_json(500, {"error": f"{type(e).__name__}: {e}",
                                      "status": 500})
            except OSError:
                pass
        finally:
            self.server.metrics.record(endpoint, status,
                                       time.perf_counter() - t0)

    def _route(self, method: str, path: str):
        """(metrics label, handler, kwargs) for a request path."""
        parts = [p for p in path.split("/") if p]

        routes = {
            ("GET", ("healthz",)): ("/healthz", self._healthz, {}),
            ("GET", ("metrics",)): ("/metrics", self._metrics, {}),
            ("GET", ("topologies",)): ("/topologies", self._topologies, {}),
            ("GET", ("diff",)): ("/diff", self._diff, {}),
            ("POST", ("query_batch",)): ("/query_batch", self._query_batch,
                                         {}),
            ("GET", ("discoveries",)): ("/discoveries", self._discoveries,
                                        {}),
            ("POST", ("discoveries",)): ("/discoveries",
                                         self._submit_discovery, {}),
        }
        hit = routes.get((method, tuple(parts)))
        if hit is not None:
            return hit
        if len(parts) == 2 and parts[0] == "discoveries":
            if method == "GET":
                return ("/discoveries/{job_id}", self._discovery,
                        {"job_id": parts[1]})
            if method == "DELETE":
                return ("/discoveries/{job_id}", self._cancel_discovery,
                        {"job_id": parts[1]})
            raise HttpError(405, f"{method} not allowed here")
        if len(parts) == 2 and parts[0] == "topologies":
            if method != "GET":
                raise HttpError(405, f"{method} not allowed here")
            return ("/topologies/{key}", self._topology,
                    {"key": parts[1]})
        if len(parts) == 3 and parts[0] == "topologies" \
                and parts[2] in ("query", "attributes"):
            if method != "GET":
                raise HttpError(405, f"{method} not allowed here")
            handler = self._query if parts[2] == "query" else self._attributes
            return (f"/topologies/{{key}}/{parts[2]}", handler,
                    {"key": parts[1]})
        if len(parts) == 2 and parts[0] == "adjacency":
            if method != "GET":
                raise HttpError(405, f"{method} not allowed here")
            return ("/adjacency/{key}", self._adjacency, {"key": parts[1]})
        if tuple(parts) in {r[1] for r in routes}:      # known path, bad verb
            raise HttpError(405, f"{method} not allowed on {path}")
        raise HttpError(404, f"no such endpoint: {method} {path}")

    # ------------------------------------------------------- helpers
    def _topology_or_error(self, key: str):
        topo = self.svc.get(key)
        if topo is not None:
            return topo
        store = self.svc.store
        if store.is_quarantined(key) or store.has(key):
            raise HttpError(
                503, f"topology {key} is quarantined on disk; "
                     f"re-run discovery for this request to repopulate it",
                retry_after_s=self.server.retry_after_s)
        raise HttpError(404, f"unknown topology key: {key}")

    def _read_body_json(self):
        length = self.headers.get("Content-Length")
        if length is None:
            raise HttpError(411, "Content-Length required")
        try:
            length = int(length)
        except ValueError:
            raise HttpError(400, "malformed Content-Length") from None
        if length > self.server.max_body_bytes:
            # Refused before the body is read into memory; the connection
            # is closed (the unread body would poison keep-alive framing).
            self.close_connection = True
            raise HttpError(
                413, f"request body {length}B exceeds the "
                     f"{self.server.max_body_bytes}B limit")
        raw = self.rfile.read(length)
        try:
            return json.loads(raw)
        except (json.JSONDecodeError, UnicodeDecodeError):
            raise HttpError(400, "malformed JSON request body") from None

    def _authorize(self) -> None:
        """Bearer-token gate for mutating endpoints (no-op when the server
        runs without a token).  Constant-time compare; 401 carries a
        ``WWW-Authenticate`` challenge per RFC 6750."""
        token = self.server.auth_token
        if token is None:
            return
        supplied = self.headers.get("Authorization", "")
        if not supplied.startswith("Bearer ") or not hmac.compare_digest(
                supplied[len("Bearer "):], token):
            raise HttpError(
                401, "missing or invalid bearer token (mutating endpoints "
                     "require 'Authorization: Bearer <token>')",
                headers={"WWW-Authenticate": 'Bearer realm="mt4g-topod"'})

    def _jobs_or_404(self) -> "JobEngine":
        engine = self.server.job_engine
        if engine is None:
            raise HttpError(404, "remote discovery is disabled on this "
                                 "server (started with jobs=False)")
        return engine

    def _job_or_404(self, engine, job_id: str):
        job = engine.get(job_id)
        if job is None:
            raise HttpError(404, f"unknown job id: {job_id}")
        return job

    # ------------------------------------------------------ endpoints
    def _healthz(self, query) -> dict:
        engine = self.server.job_engine
        return {"status": "ok", "entries": len(self.svc.keys()),
                "draining": self.server.draining,
                "jobs_enabled": engine is not None,
                "job_queue_depth": (engine.queue_depth()
                                    if engine is not None else None)}

    def _metrics(self, query) -> dict:
        engine = self.server.job_engine
        return {"service": self.svc.stats(),
                "jobs": engine.stats() if engine is not None else None,
                **self.server.metrics.snapshot()}

    def _topologies(self, query) -> dict:
        return {"topologies": [{"key": k, "meta": meta}
                               for k, meta in self.svc.store.index()]}

    def _topology(self, query, key: str) -> dict:
        topo = self._topology_or_error(key)
        return {"key": key, "topology": topo.to_json()}

    def _query(self, query, key: str) -> dict:
        paths = query.get("path", [])
        if len(paths) != 1 or not paths[0]:
            raise HttpError(400, "exactly one non-empty path=... query "
                                 "parameter is required (e.g. path=L1.size)")
        self._topology_or_error(key)        # 404/503 before a found=False
        return asdict(self.svc.query(key, paths[0]))

    def _query_batch(self, query) -> dict:
        body = self._read_body_json()
        reqs = body.get("requests") if isinstance(body, dict) else body
        if not isinstance(reqs, list):
            raise HttpError(400, 'expected {"requests": [[key, path], ...]}')
        pairs = []
        for item in reqs:
            if (not isinstance(item, (list, tuple)) or len(item) != 2
                    or not all(isinstance(x, str) for x in item)):
                raise HttpError(400, f"bad request pair: {item!r} "
                                     f"(want [key, path])")
            pairs.append((item[0], item[1]))
        return {"results": [asdict(r) for r in self.svc.query_batch(pairs)]}

    def _attributes(self, query, key: str) -> dict:
        provenance = query.get("provenance", [None])[0]
        min_conf = query.get("min_confidence", [None])[0]
        if min_conf is not None:
            try:
                min_conf = float(min_conf)
            except ValueError:
                raise HttpError(400, f"min_confidence must be a number, "
                                     f"got {min_conf!r}") from None
        self._topology_or_error(key)
        attrs = self.svc.attributes(key, provenance=provenance,
                                    min_confidence=min_conf)
        return {"key": key, "attributes": [asdict(a) for a in attrs]}

    def _adjacency(self, query, key: str) -> dict:
        self._topology_or_error(key)
        return {"key": key, "adjacency": self.svc.adjacency(key)}

    # --------------------------------------------- remote discovery (jobs)
    def _submit_discovery(self, query):
        """POST /discoveries — validate, authorize, enqueue (or attach).

        202 on a newly created job, 200 when the submission deduplicated
        onto an in-flight equivalent (same content-addressed key); 400 on
        malformed params, 401 unauthorized, 503 + ``Retry-After`` on a
        full queue.
        """
        self._authorize()
        engine = self._jobs_or_404()
        body = self._read_body_json()
        try:
            job, created = engine.submit(body)
        except ValueError as e:
            raise HttpError(400, f"bad discovery request: {e}") from None
        except QueueFullError as e:
            raise HttpError(503, str(e),
                            retry_after_s=self.server.retry_after_s) \
                from None
        payload = {**job.to_json(), "deduplicated": not created,
                   "status_url": f"/discoveries/{job.job_id}"}
        return (payload, 202 if created else 200, {})

    def _discoveries(self, query) -> dict:
        engine = self._jobs_or_404()
        states = query.get("state", [])
        jobs = engine.jobs()
        if states:
            jobs = [j for j in jobs if j.state in states]
        return {"jobs": [j.to_json() for j in jobs]}

    def _discovery(self, query, job_id: str):
        """GET /discoveries/<job_id> — poll target.  Unfinished jobs carry
        a ``Retry-After`` header so clients can pace their polling."""
        engine = self._jobs_or_404()
        job = self._job_or_404(engine, job_id)
        payload = job.to_json()
        if job.terminal:
            return payload
        return (payload, 200, {"Retry-After": self.server.job_poll_s})

    def _cancel_discovery(self, query, job_id: str):
        """DELETE /discoveries/<job_id> — idempotent cancellation: queued
        jobs cancel immediately, running ones best-effort (between retry
        attempts), terminal ones are left as they finished."""
        self._authorize()
        engine = self._jobs_or_404()
        self._job_or_404(engine, job_id)
        job = engine.cancel(job_id)
        return job.to_json()

    def _diff(self, query) -> dict:
        a = query.get("a", [None])[0]
        b = query.get("b", [None])[0]
        if not a or not b:
            raise HttpError(400, "a=<key> and b=<key> query parameters "
                                 "are required")
        rel_tol = query.get("rel_tol", ["0"])[0]
        try:
            rel_tol = float(rel_tol)
        except ValueError:
            raise HttpError(400, f"rel_tol must be a number, "
                                 f"got {rel_tol!r}") from None
        for key in (a, b):
            self._topology_or_error(key)
        d = self.svc.diff(a, b, rel_tol=rel_tol)
        return {"key_a": d.key_a, "key_b": d.key_b,
                "identical": d.identical, "matching": d.matching,
                "only_in_a": d.only_in_a, "only_in_b": d.only_in_b,
                "changed": [asdict(c) for c in d.changed]}


class _Server(ThreadingHTTPServer):
    # Drain on close: handler threads are joined by server_close(), so an
    # in-flight response always finishes before stop() returns.
    daemon_threads = False
    block_on_close = True
    allow_reuse_address = True


class TopologyHTTPServer:
    """Threaded HTTP server over a ``TopologyService`` (or bare store).

    ::

        server = TopologyHTTPServer(store, port=0)   # 0 = ephemeral
        server.start()
        ...                                          # server.url
        server.stop()                                # graceful drain

    Also a context manager.  ``on_request`` is an optional
    ``(method, path) -> None`` observer hook called before routing —
    used by tests to model slow handlers (an ``HttpError`` it raises is
    served as that structured error, which is how tests and the bench
    inject 503-with-``Retry-After`` faults).

    Remote discovery (the write path) is on by default: a ``JobEngine``
    over the service's store starts/stops with the server.  Pass
    ``jobs=False`` to serve read-only, ``job_engine=`` to share a
    pre-configured engine (fault hooks, custom retry policy), and
    ``auth_token=`` to require ``Authorization: Bearer <token>`` on the
    mutating endpoints.
    """

    def __init__(self, service_or_store, host: str = "127.0.0.1",
                 port: int = 0, *, max_body_bytes: int = MAX_BODY_BYTES,
                 request_timeout_s: float = REQUEST_TIMEOUT_S,
                 retry_after_s: int = RETRY_AFTER_S,
                 hot_set: int = 8, on_request=None,
                 auth_token: str | None = None, jobs: bool = True,
                 job_engine: JobEngine | None = None, job_workers: int = 2,
                 job_queue: int = 32, job_poll_s: int = JOB_POLL_S):
        if isinstance(service_or_store, TopologyService):
            self.service = service_or_store
        else:
            self.service = TopologyService(service_or_store, hot_set=hot_set)
        if job_engine is not None:
            self.job_engine = job_engine
        elif jobs:
            self.job_engine = JobEngine(self.service.store,
                                        workers=job_workers,
                                        max_queue=job_queue)
        else:
            self.job_engine = None
        self.metrics = ServerMetrics()
        self._httpd = _Server((host, port), _Handler)
        self._httpd.service = self.service
        self._httpd.metrics = self.metrics
        self._httpd.max_body_bytes = int(max_body_bytes)
        self._httpd.request_timeout_s = float(request_timeout_s)
        self._httpd.retry_after_s = int(retry_after_s)
        self._httpd.job_poll_s = int(job_poll_s)
        self._httpd.auth_token = auth_token
        self._httpd.job_engine = self.job_engine
        self._httpd.on_request = on_request
        self._httpd.draining = False
        self._thread: threading.Thread | None = None

    # --------------------------------------------------------- lifecycle
    @property
    def address(self) -> tuple[str, int]:
        """``(host, port)`` actually bound (resolves port 0)."""
        return self._httpd.server_address[:2]

    @property
    def url(self) -> str:
        """Base URL, e.g. ``http://127.0.0.1:8423``."""
        host, port = self.address
        return f"http://{host}:{port}"

    def start(self) -> "TopologyHTTPServer":
        """Start the job engine (if any) and the serving thread; returns
        ``self`` so ``TopologyHTTPServer(store).start()`` chains."""
        if self._thread is not None:
            raise RuntimeError("server already started")
        if self.job_engine is not None:
            self.job_engine.start()
        self._thread = threading.Thread(
            target=self._httpd.serve_forever, kwargs={"poll_interval": 0.05},
            name="mt4g-topod", daemon=True)
        self._thread.start()
        return self

    def stop(self, drain: bool = True) -> None:
        """Stop accepting, then drain: in-flight requests run to completion
        before this returns (``drain=False`` abandons handler threads).
        The job engine stops first — queued jobs are cancelled, the
        running job of each worker finishes."""
        if self._thread is None:
            return
        if self.job_engine is not None:
            self.job_engine.stop()
        self._httpd.draining = True
        self._httpd.shutdown()              # stops the accept loop
        self._httpd.block_on_close = drain
        self._httpd.server_close()          # joins handler threads
        self._thread.join(timeout=10.0)
        self._thread = None

    def __enter__(self) -> "TopologyHTTPServer":
        return self.start()

    def __exit__(self, *exc) -> bool:
        self.stop()
        return False
