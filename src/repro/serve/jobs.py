"""Server-side discovery job engine (the remote *write* path).

PR 5 made stored topologies network-readable; this module makes discovery
itself a network service: a serialized discovery request (backend + device
identity + budget + gc policy) is accepted, enqueued, and executed
server-side by a small worker pool running the unified ``discover(request)``
core write-through to the shared ``TopologyStore`` — so the artifact a job
produces is immediately served by every read endpoint.

Design points (each one a production concern the HTTP front end surfaces):

* **Bounded FIFO queue + worker pool.**  ``JobEngine(store, workers=N,
  max_queue=M)``; a full queue refuses the submission (``QueueFullError``
  -> HTTP 503 with ``Retry-After``) instead of buffering unboundedly.
* **Per-job state machine** ``queued -> running -> done | failed |
  cancelled``.  Transitions are monotonic and lock-protected; every job
  records created/started/finished timestamps, attempt count, and either a
  result summary or a structured error string.
* **Idempotency by content address.**  A job is keyed by the same
  ``request_key(descriptor)`` that keys the ``TopologyStore``, computed
  with the *same descriptor functions* ``discover()`` uses internally.
  Submitting a request while an equivalent job is queued or running
  *attaches* to the in-flight job (same ``job_id``, no second execution);
  submitting after completion creates a new job whose ``discover()`` call
  is a pure store hit — zero runner probes (``result.store_hit``).
* **Capped retry with exponential backoff** on *transient* runner errors
  (``TransientRunnerError`` by default): attempt ``i`` sleeps
  ``min(backoff_cap_s, backoff_base_s * 2**i)`` before re-running.
  Non-transient exceptions fail immediately — a deterministic bug should
  not be retried into the store.
* **Per-job timeout.**  Each attempt runs on a helper thread joined with
  ``timeout_s``; an overrun marks the job failed and abandons the attempt
  thread (Python cannot preempt it).  Abandonment is safe by construction:
  store writes are atomic and content-addressed, so a late write is
  indistinguishable from a successful run of the same request.
* **Cancellation** is immediate for queued jobs and best-effort for
  running ones (checked between retry attempts — a probe sweep in flight
  cannot be preempted).
* **Metrics**: submission/dedup/terminal-state counters, retry and
  timeout totals, queue depth, and a log-bucketed job-latency histogram,
  folded into the HTTP server's ``/metrics``.

The wire format accepted by ``resolve_discovery`` is documented in
``docs/HTTP_API.md`` (``POST /discoveries``).
"""
from __future__ import annotations

import queue
import threading
import time
import uuid
from dataclasses import dataclass, field
from typing import Callable

__all__ = ["TransientRunnerError", "QueueFullError", "Job", "JobEngine",
           "resolve_discovery", "JOB_STATES", "TERMINAL_STATES"]

JOB_STATES = ("queued", "running", "done", "failed", "cancelled")
TERMINAL_STATES = ("done", "failed", "cancelled")

# Log-spaced job-duration histogram edges (seconds); last bucket is +inf.
JOB_LATENCY_BUCKETS_S = (0.01, 0.025, 0.05, 0.1, 0.25, 0.5, 1.0, 2.5,
                         5.0, 10.0, 30.0, 120.0)


# Promoted to core.errors (ISSUE 9) so the probe/engine layers can share
# the retry taxonomy without importing from serve; re-exported here for
# compatibility with existing callers.
from ..core.errors import TransientRunnerError  # noqa: E402  (compat)


class QueueFullError(Exception):
    """The engine's bounded job queue refused a submission (HTTP 503)."""


# --------------------------------------------------------------------------
# Wire-format parsing: serialized request -> (descriptor, key, run thunk)
# --------------------------------------------------------------------------
_SIM_ALIASES = {"h100": "sim-h100", "mi210": "sim-mi210", "v5e": "sim-v5e"}

_COMMON_FIELDS = {"backend", "device", "seed", "n_samples", "elements",
                  "budget", "gc_policy", "refresh", "survey"}
_BACKEND_FIELDS = {
    "sim": _COMMON_FIELDS,
    "pallas": _COMMON_FIELDS - {"device", "seed"},
    "host": {"backend", "n_samples", "gc_policy", "refresh", "max_bytes",
             "quick"},
}


def _parse_budget(raw):
    """``None`` | ``"default"`` | ``{SweepBudget kwargs}`` -> SweepBudget."""
    from ..core.engine.planner import SweepBudget

    if raw is None:
        return None
    if raw == "default":
        return SweepBudget()
    if not isinstance(raw, dict):
        raise ValueError(f"budget must be null, 'default', or an object of "
                         f"SweepBudget fields, got {raw!r}")
    allowed = {"max_rounds", "max_rows", "target_resolution", "ladder_chunk"}
    bad = set(raw) - allowed
    if bad:
        raise ValueError(f"unknown budget field(s): {sorted(bad)}")
    return SweepBudget(**raw)


def _parse_gc_policy(raw):
    from ..core.engine.store import GcPolicy

    if raw is None:
        return None
    if not isinstance(raw, dict):
        raise ValueError(f"gc_policy must be null or an object, got {raw!r}")
    bad = set(raw) - {"max_entries", "max_age_s"}
    if bad:
        raise ValueError(f"unknown gc_policy field(s): {sorted(bad)}")
    return GcPolicy(**raw)


def _parse_elements(raw):
    if raw is None:
        return None
    if (not isinstance(raw, list) or not raw
            or not all(isinstance(e, str) for e in raw)):
        raise ValueError("elements must be null or a non-empty list of "
                         "space names")
    return list(raw)


def resolve_discovery(params: dict, store, parallel=None):
    """Validate a wire-format discovery request and bind it to the store.

    Returns ``(descriptor, key, run)`` where ``descriptor`` is the
    content-address document (computed by the *same* functions the
    discovery wrappers use, so the job key equals the store key the run
    will persist under), ``key = request_key(descriptor)``, and ``run()``
    executes the discovery write-through to ``store`` and returns
    ``(topology, timings)``.

    ``parallel`` (an ``engine.parallel.ParallelConfig``, normally the
    owning ``JobEngine``'s) threads multiprocess probe execution into the
    run thunk.  It never appears in the descriptor: pooled and inline
    runs are bit-identical, so they must share a request key.

    Raises ``ValueError`` on any malformed field — the HTTP layer maps
    this to a 400 before anything is enqueued.
    """
    from ..core.discover import (default_sweep_budget,
                                 host_request_descriptor,
                                 pallas_request_descriptor,
                                 sim_request_descriptor)
    from ..core.engine.store import request_key
    from ..core.simulate import SIM_DEVICES

    if not isinstance(params, dict):
        raise ValueError("discovery request must be a JSON object")
    backend = params.get("backend", "sim")
    allowed = _BACKEND_FIELDS.get(backend)
    if allowed is None:
        raise ValueError(f"unknown backend {backend!r} "
                         f"(want one of {sorted(_BACKEND_FIELDS)})")
    bad = set(params) - allowed
    if bad:
        raise ValueError(f"unknown field(s) for backend {backend!r}: "
                         f"{sorted(bad)}")

    n_samples = int(params.get("n_samples", 9))
    if n_samples < 1:
        raise ValueError(f"n_samples must be >= 1, got {n_samples}")
    refresh = bool(params.get("refresh", False))
    survey = bool(params.get("survey", False))
    gc_policy = _parse_gc_policy(params.get("gc_policy"))

    if backend == "sim":
        from ..core.discover import discover_sim

        name = params.get("device")
        make = SIM_DEVICES.get(_SIM_ALIASES.get(name, name))
        if make is None:
            raise ValueError(f"unknown simulated device {name!r} (want one "
                             f"of {sorted(SIM_DEVICES)} or aliases "
                             f"{sorted(_SIM_ALIASES)})")
        device = make(seed=int(params.get("seed", 0)))
        elements = _parse_elements(params.get("elements"))
        budget = _parse_budget(params.get("budget"))
        descriptor = sim_request_descriptor(device, n_samples, elements,
                                            budget, survey=survey)

        run = lambda: discover_sim(  # noqa: E731 — close over parsed args
            device, n_samples, elements, store=store, refresh=refresh,
            budget=budget, gc_policy=gc_policy, survey=survey,
            parallel=parallel)

    elif backend == "pallas":
        from ..core.discover import discover_pallas

        elements = _parse_elements(params.get("elements"))
        budget = (_parse_budget(params["budget"])
                  if "budget" in params and params["budget"] != "default"
                  else default_sweep_budget())
        from ..core.probes.pallas_runner import make_pallas_model
        model = make_pallas_model()
        descriptor = pallas_request_descriptor(model, n_samples, elements,
                                               budget, survey=survey)
        run = lambda: discover_pallas(  # noqa: E731
            model, n_samples, elements, store=store, refresh=refresh,
            budget=budget, gc_policy=gc_policy, survey=survey,
            parallel=parallel)

    else:                                                   # host
        from ..core.discover import discover_host

        max_bytes = int(params.get("max_bytes", 128 * 1024**2))
        quick = bool(params.get("quick", True))
        descriptor = host_request_descriptor(max_bytes, n_samples, quick)
        run = lambda: discover_host(  # noqa: E731
            max_bytes, n_samples, quick, store=store, refresh=refresh,
            gc_policy=gc_policy, parallel=parallel)

    return descriptor, request_key(descriptor), run


# --------------------------------------------------------------------------
# Jobs
# --------------------------------------------------------------------------
@dataclass
class Job:
    """One submitted discovery: identity, state machine, outcome.

    ``state`` moves ``queued -> running -> done|failed|cancelled`` and never
    backwards; all mutation happens under the owning engine's lock.
    """

    job_id: str
    key: str                       # content-addressed request key (store key)
    params: dict                   # the wire request, as submitted
    backend: str
    timeout_s: float | None
    state: str = "queued"
    created_at: float = field(default_factory=time.time)
    started_at: float | None = None
    finished_at: float | None = None
    attempts: int = 0              # run attempts started (1 = no retry)
    error: str | None = None
    result: dict | None = None
    done_event: threading.Event = field(default_factory=threading.Event,
                                        repr=False)
    cancel_event: threading.Event = field(default_factory=threading.Event,
                                          repr=False)

    @property
    def terminal(self) -> bool:
        """True once the job reached done/failed/cancelled (final)."""
        return self.state in TERMINAL_STATES

    def to_json(self) -> dict:
        """Wire shape served by ``GET /discoveries/<job_id>``."""
        return {
            "job_id": self.job_id, "state": self.state, "key": self.key,
            "backend": self.backend, "params": self.params,
            "created_at": self.created_at, "started_at": self.started_at,
            "finished_at": self.finished_at, "attempts": self.attempts,
            "error": self.error, "result": self.result,
        }


class _JobMetrics:
    """Thread-safe job counters + a log-bucketed run-duration histogram."""

    def __init__(self):
        self._mutex = threading.Lock()
        self.counters = {"submitted": 0, "deduplicated": 0, "rejected": 0,
                         "done": 0, "failed": 0, "cancelled": 0,
                         "retries": 0, "timeouts": 0}
        self.buckets = [0] * (len(JOB_LATENCY_BUCKETS_S) + 1)
        self.duration_sum_s = 0.0

    def bump(self, counter: str, n: int = 1) -> None:
        with self._mutex:
            self.counters[counter] += n

    def observe(self, seconds: float) -> None:
        with self._mutex:
            self.duration_sum_s += seconds
            for i, edge in enumerate(JOB_LATENCY_BUCKETS_S):
                if seconds <= edge:
                    self.buckets[i] += 1
                    break
            else:
                self.buckets[-1] += 1

    def snapshot(self) -> dict:
        with self._mutex:
            return {**self.counters,
                    "duration_sum_s": round(self.duration_sum_s, 6),
                    "duration_bucket_edges_s": list(JOB_LATENCY_BUCKETS_S),
                    "duration_buckets": list(self.buckets)}


class JobEngine:
    """Bounded-queue worker pool running discovery jobs against one store.

    ::

        engine = JobEngine(store, workers=2).start()
        job, created = engine.submit({"backend": "sim", "device": "h100"})
        engine.wait(job.job_id, timeout_s=60)
        engine.stop()

    ``on_attempt`` is an optional ``(job, attempt_index) -> None`` hook
    called on the worker thread immediately before each run attempt; an
    exception it raises is handled exactly as if the runner raised it —
    tests and the ``remote_discovery`` bench use it to inject
    ``TransientRunnerError`` faults deterministically.  ``sleep`` is the
    backoff sleep function (injectable for tests).
    """

    def __init__(self, store, *, workers: int = 2, max_queue: int = 32,
                 default_timeout_s: float | None = 300.0,
                 max_retries: int = 2, backoff_base_s: float = 0.25,
                 backoff_cap_s: float = 10.0,
                 retryable: tuple = (TransientRunnerError,),
                 on_attempt: Callable | None = None,
                 sleep: Callable[[float], None] = time.sleep,
                 max_history: int = 512, parallel=None):
        self.store = store
        self.workers = max(1, int(workers))
        # Multiprocess probe execution (engine/parallel.ParallelConfig):
        # threaded into every discovery thunk this engine resolves.  All
        # concurrent jobs share ONE process pool (the config-keyed global
        # pool), so N remote discoveries never spawn N pools.
        self.parallel = parallel
        self.max_retries = int(max_retries)
        self.default_timeout_s = default_timeout_s
        self.backoff_base_s = float(backoff_base_s)
        self.backoff_cap_s = float(backoff_cap_s)
        self.retryable = tuple(retryable)
        self.on_attempt = on_attempt
        self.max_history = int(max_history)
        self._sleep = sleep
        self._queue: queue.Queue = queue.Queue(maxsize=int(max_queue))
        self._mutex = threading.Lock()
        self._jobs: dict[str, Job] = {}          # job_id -> job (insertion order)
        self._active: dict[str, Job] = {}        # request key -> live job
        self._runs: dict[str, Callable] = {}     # job_id -> run thunk
        self._threads: list[threading.Thread] = []
        self._stopping = False
        self.metrics = _JobMetrics()

    # --------------------------------------------------------- lifecycle
    def start(self) -> "JobEngine":
        """Spawn the worker pool (idempotent); returns ``self``."""
        if self._threads:
            return self
        self._stopping = False
        for i in range(self.workers):
            t = threading.Thread(target=self._worker, name=f"mt4g-job-{i}",
                                 daemon=True)
            t.start()
            self._threads.append(t)
        return self

    def stop(self, *, timeout_s: float = 30.0) -> None:
        """Stop the pool: queued jobs are cancelled, the running job of each
        worker finishes (no mid-probe preemption), workers then exit."""
        self._stopping = True
        with self._mutex:
            for job in list(self._active.values()):
                if job.state == "queued":
                    self._finish(job, "cancelled",
                                 error="engine stopped before the job ran")
        # Drain the now-cancelled queued jobs so the wake sentinels below
        # always fit — a full queue must not swallow a sentinel, or a
        # worker would sit in ``get()`` until the join timeout.  Safe:
        # ``_stopping`` blocks new submissions and everything still queued
        # was just marked terminal (workers skip terminal jobs anyway).
        while True:
            try:
                self._queue.get_nowait()
            except queue.Empty:
                break
        for _ in self._threads:
            self._queue.put(None, timeout=timeout_s)         # wake sentinel
        for t in self._threads:
            t.join(timeout=timeout_s)
        self._threads = []

    # -------------------------------------------------------- submission
    def submit(self, params: dict) -> tuple[Job, bool]:
        """Enqueue a discovery request; returns ``(job, created)``.

        ``created=False`` means an equivalent request (same content-
        addressed key) is already queued or running and the caller was
        attached to it.  Raises ``ValueError`` on malformed params and
        ``QueueFullError`` when the bounded queue refuses the job.
        """
        descriptor, key, run = resolve_discovery(params, self.store,
                                                 parallel=self.parallel)
        with self._mutex:
            live = self._active.get(key)
            if live is not None and not live.terminal:
                self.metrics.bump("deduplicated")
                return live, False
            if self._stopping:
                raise QueueFullError("engine is stopping")
            job = Job(job_id=uuid.uuid4().hex[:12], key=key,
                      params=dict(params),
                      backend=params.get("backend", "sim"),
                      timeout_s=self.default_timeout_s)
            try:
                self._queue.put_nowait(job)
            except queue.Full:
                self.metrics.bump("rejected")
                raise QueueFullError(
                    f"job queue full ({self._queue.maxsize} pending)") \
                    from None
            self._jobs[job.job_id] = job
            self._active[key] = job
            self._runs[job.job_id] = run
            self.metrics.bump("submitted")
            self._trim_history()
            return job, True

    def _trim_history(self) -> None:
        # Terminal jobs beyond max_history age out oldest-first so a
        # long-lived server's job table stays bounded (the queue bounds
        # live jobs already).  Caller holds the lock.
        excess = len(self._jobs) - self.max_history
        if excess <= 0:
            return
        for job_id in [jid for jid, j in self._jobs.items()
                       if j.terminal][:excess]:
            del self._jobs[job_id]

    # ------------------------------------------------------------ lookup
    def get(self, job_id: str) -> Job | None:
        """The job with this id, or None if unknown / aged out."""
        with self._mutex:
            return self._jobs.get(job_id)

    def jobs(self) -> list[Job]:
        """All known jobs, oldest first (bounded by ``max_history``)."""
        with self._mutex:
            return list(self._jobs.values())

    def queue_depth(self) -> int:
        """Jobs currently waiting for a worker (approximate, racy)."""
        return self._queue.qsize()

    def wait(self, job_id: str, timeout_s: float = 60.0) -> Job:
        """Block until the job reaches a terminal state (in-process path;
        remote callers poll ``GET /discoveries/<job_id>`` instead)."""
        job = self.get(job_id)
        if job is None:
            raise KeyError(f"unknown job {job_id!r}")
        if not job.done_event.wait(timeout=timeout_s):
            raise TimeoutError(f"job {job_id} still {job.state} after "
                               f"{timeout_s}s")
        return job

    # ------------------------------------------------------ cancellation
    def cancel(self, job_id: str) -> Job:
        """Cancel a job: immediate for queued, best-effort for running
        (takes effect between retry attempts), a no-op once terminal."""
        job = self.get(job_id)
        if job is None:
            raise KeyError(f"unknown job {job_id!r}")
        with self._mutex:
            job.cancel_event.set()
            if job.state == "queued":
                self._finish(job, "cancelled", error="cancelled while queued")
        return job

    # ----------------------------------------------------------- workers
    def _finish(self, job: Job, state: str, *, error: str | None = None,
                result: dict | None = None) -> None:
        """Terminal transition; caller holds the lock (or is the sole
        owner of a just-dequeued job)."""
        if job.terminal:
            return
        job.state = state
        job.error = error
        job.result = result
        job.finished_at = time.time()
        self._runs.pop(job.job_id, None)
        if self._active.get(job.key) is job:
            del self._active[job.key]
        self.metrics.bump(state)
        job.done_event.set()

    def _worker(self) -> None:
        while True:
            job = self._queue.get()
            if job is None:                                  # stop sentinel
                return
            if job.terminal:                                 # cancelled queued
                continue
            with self._mutex:
                if job.terminal:
                    continue
                job.state = "running"
                job.started_at = time.time()
                run = self._runs.get(job.job_id)
            self._run_job(job, run)

    def _run_job(self, job: Job, run: Callable) -> None:
        t_start = time.perf_counter()
        for attempt in range(self.max_retries + 1):
            if job.cancel_event.is_set():
                with self._mutex:
                    self._finish(job, "cancelled",
                                 error="cancelled before attempt "
                                       f"{attempt + 1}")
                return
            job.attempts = attempt + 1
            try:
                if self.on_attempt is not None:
                    self.on_attempt(job, attempt)
                topo, timings = self._attempt_with_timeout(job, run)
            except TimeoutError as e:
                self.metrics.bump("timeouts")
                with self._mutex:
                    self._finish(job, "failed", error=str(e))
                self.metrics.observe(time.perf_counter() - t_start)
                return
            except self.retryable as e:
                if attempt >= self.max_retries:
                    with self._mutex:
                        self._finish(
                            job, "failed",
                            error=f"transient error persisted through "
                                  f"{job.attempts} attempts: "
                                  f"{type(e).__name__}: {e}")
                    self.metrics.observe(time.perf_counter() - t_start)
                    return
                self.metrics.bump("retries")
                self._sleep(min(self.backoff_cap_s,
                                self.backoff_base_s * (2 ** attempt)))
                continue
            except Exception as e:          # noqa: BLE001 — deterministic
                with self._mutex:
                    self._finish(job, "failed",
                                 error=f"{type(e).__name__}: {e}")
                self.metrics.observe(time.perf_counter() - t_start)
                return
            else:
                # A store hit reconstructs only per-family timings —
                # ``meta`` stays empty — which is exactly the "zero runner
                # probes" signal the idempotency contract exposes.
                result = {
                    "model": topo.model, "vendor": topo.vendor,
                    "backend": topo.backend,
                    "store_hit": "cache" not in timings.meta,
                    "probe_rows": timings.probe_rows,
                    "families": {k: round(v, 6)
                                 for k, v in timings.per_family.items()},
                }
                with self._mutex:
                    self._finish(job, "done", result=result)
                self.metrics.observe(time.perf_counter() - t_start)
                return

    def _attempt_with_timeout(self, job: Job, run: Callable):
        """One attempt, bounded by the job timeout.

        The attempt runs on a daemon helper thread joined with
        ``timeout_s``; an overrun raises ``TimeoutError`` and abandons the
        thread.  The abandoned attempt may still complete and write
        through — harmless, because store writes are atomic and the key is
        content-addressed (a late write equals a successful run of the
        same request).
        """
        if job.timeout_s is None:
            return run()
        box: dict = {}

        def target():
            try:
                box["value"] = run()
            except BaseException as e:      # noqa: BLE001 — re-raised below
                box["error"] = e

        t = threading.Thread(target=target, daemon=True,
                             name=f"mt4g-job-attempt-{job.job_id}")
        t.start()
        t.join(timeout=job.timeout_s)
        if t.is_alive():
            raise TimeoutError(f"attempt {job.attempts} exceeded the "
                               f"{job.timeout_s}s job timeout")
        if "error" in box:
            raise box["error"]
        return box["value"]

    # ------------------------------------------------------------- stats
    def stats(self) -> dict:
        """Counter snapshot + live queue/worker state (for ``/metrics``)."""
        with self._mutex:
            states: dict[str, int] = {}
            for j in self._jobs.values():
                states[j.state] = states.get(j.state, 0) + 1
        return {**self.metrics.snapshot(), "queue_depth": self.queue_depth(),
                "workers": self.workers, "states": states}
