"""Topology query service: attribute lookups over many stored topologies.

MT4G's value downstream is that discovered topologies feed other workflows —
performance modeling, bottleneck analysis, dynamic partitioning (paper §V).
That requires topologies to be *queryable artifacts*, not one-shot console
dumps.  ``TopologyService`` serves them from a ``TopologyStore``:

* **attribute lookups** by dotted path — ``query(key, "L1.size")``,
  ``"hbm.bandwidth"`` (element and attribute aliases resolve HBM/DRAM and
  bandwidth/latency spellings), ``"general.clock_domain"``,
  ``"compute.cores_per_sm"`` — each answer carrying the stored value, unit,
  provenance, and K-S confidence;
* **batched lookups** (``query_batch``) that group requests by topology so
  every stored artifact is parsed at most once per batch;
* an **LRU hot set** of deserialized topologies, so repeat traffic over a
  working set of devices never re-reads disk;
* **provenance/confidence filters** (``attributes``) and a **link/sharing
  adjacency** view;
* a **diff endpoint** comparing two stored topologies attribute-by-attribute
  (the regression-tracking workflow: same device, new driver/firmware run).

The service is deliberately in-process and dependency-free — the same layer
an HTTP front end would wrap, exercised directly by tests and benchmarks.
"""
from __future__ import annotations

import threading
from collections import OrderedDict
from dataclasses import dataclass, field

from ..core.topology import Topology

__all__ = ["QueryResult", "AttrDelta", "TopologyDiff", "TopologyService"]

# Element-name aliases: query spellings -> candidate element names, tried in
# order after exact and case-insensitive matching fail.
ELEMENT_ALIASES: dict[str, tuple[str, ...]] = {
    "hbm": ("DeviceMemory", "HBM", "DRAM"),
    "dram": ("DRAM", "DeviceMemory"),
    "device_memory": ("DeviceMemory",),
    "l1": ("L1", "vL1"),
}

ATTR_ALIASES: dict[str, str] = {
    "bandwidth": "read_bw",
    "latency": "load_latency",
}


@dataclass(frozen=True)
class QueryResult:
    """One answered attribute lookup."""

    key: str                     # store key of the topology
    path: str                    # the query as asked
    found: bool
    value: object = None
    unit: str = ""
    provenance: str = ""
    confidence: float | None = None
    element: str = ""            # resolved element name (after aliasing)


@dataclass(frozen=True)
class AttrDelta:
    """One attribute that differs between two topologies."""

    element: str
    attr: str
    a: object
    b: object
    rel_delta: float | None = None   # for numeric values; None otherwise


@dataclass
class TopologyDiff:
    """Structured comparison of two stored topologies."""

    key_a: str
    key_b: str
    only_in_a: list[str] = field(default_factory=list)   # "element" or "element.attr"
    only_in_b: list[str] = field(default_factory=list)
    changed: list[AttrDelta] = field(default_factory=list)
    matching: int = 0                                    # attrs equal within tol

    @property
    def identical(self) -> bool:
        return not (self.only_in_a or self.only_in_b or self.changed)


class TopologyService:
    """Query front end over a ``TopologyStore`` with an LRU hot set.

    Safe under concurrent callers (the threaded HTTP front end): all LRU
    mutation and the hit/miss counters sit behind an internal lock, and
    every cached topology is validated against the store's per-key
    *generation* token before being served — a ``discover(refresh=True)``
    rewrite, a ``gc()`` eviction, or a cross-process writer invalidates the
    hot-set entry instead of pinning the stale object forever.
    """

    def __init__(self, store, hot_set: int = 8):
        self.store = store
        self.hot_set = max(int(hot_set), 1)
        # key -> (store generation at load time, deserialized topology)
        self._lru: OrderedDict[str, tuple[object, Topology]] = OrderedDict()
        self._mutex = threading.Lock()
        self.lru_hits = 0
        self.lru_misses = 0

    # ----------------------------------------------------------- loading
    def get(self, key: str) -> Topology | None:
        """The topology for ``key``, through the generation-checked LRU."""
        with self._mutex:
            cached = self._lru.get(key)
            if cached is not None:
                gen, topo = cached
                if self.store.generation(key) == gen:
                    self.lru_hits += 1
                    self._lru.move_to_end(key)
                    return topo
                del self._lru[key]      # refreshed, GC'd, or quarantined
            self.lru_misses += 1
        # Disk read outside the mutex so misses on different keys do not
        # serialize on each other.  The generation is snapshotted *before*
        # the read: if a writer lands in between, the fresh object is cached
        # under the pre-write token and simply reloads on the next request —
        # the stale direction (new token, old object) cannot happen.
        gen = self.store.generation(key)
        entry = self.store.get(key)
        if entry is None:
            return None
        with self._mutex:
            self._lru[key] = (gen, entry.topology)
            self._lru.move_to_end(key)
            while len(self._lru) > self.hot_set:
                self._lru.popitem(last=False)
        return entry.topology

    def keys(self) -> list[str]:
        return self.store.keys()

    # ----------------------------------------------------------- queries
    @staticmethod
    def _resolve_element(topo: Topology, name: str):
        me = topo.find_memory(name)
        if me is not None:
            return me
        lowered = name.lower()
        for m in topo.memory:
            if m.name.lower() == lowered:
                return m
        for cand in ELEMENT_ALIASES.get(lowered, ()):
            me = topo.find_memory(cand)
            if me is not None:
                return me
        return None

    def query(self, key: str, path: str) -> QueryResult:
        """Answer one dotted-path lookup, e.g. ``"L1.size"`` or
        ``"hbm.bandwidth"``; missing topology/element/attr -> found=False."""
        topo = self.get(key)
        if topo is None:
            return QueryResult(key, path, False)
        root, _, rest = path.partition(".")

        if root == "general":
            a = topo.general.get(rest)
            if a is None:
                return QueryResult(key, path, False)
            return QueryResult(key, path, True, a.value, a.unit,
                               a.provenance, a.confidence, "general")
        if root == "compute":
            ce = topo.find_compute(rest)
            if ce is not None:
                return QueryResult(key, path, True, ce.count, "",
                                   "api", None, ce.name)
            return QueryResult(key, path, False)

        me = self._resolve_element(topo, root)
        if me is None or not rest:
            return QueryResult(key, path, False)
        attr = ATTR_ALIASES.get(rest, rest)
        a = me.attrs.get(attr)
        if a is None:
            return QueryResult(key, path, False)
        return QueryResult(key, path, True, a.value, a.unit, a.provenance,
                           a.confidence, me.name)

    def query_batch(self, requests) -> list[QueryResult]:
        """Answer many ``(key, path)`` lookups, loading each topology once.

        Requests are grouped by key so a batch over K topologies costs K
        loads (at most — the hot set usually absorbs them), not len(requests).
        """
        by_key: dict[str, list[int]] = {}
        for i, (key, _path) in enumerate(requests):
            by_key.setdefault(key, []).append(i)
        out: list[QueryResult | None] = [None] * len(requests)
        for key, idxs in by_key.items():
            self.get(key)            # one load; query() then hits the LRU
            for i in idxs:
                out[i] = self.query(key, requests[i][1])
        return out

    def attributes(self, key: str, *, provenance: str | None = None,
                   min_confidence: float | None = None) -> list[QueryResult]:
        """All memory attributes of a topology, filtered by provenance and/or
        minimum confidence (paper-style reliability filtering)."""
        topo = self.get(key)
        if topo is None:
            return []
        out = []
        for me in topo.memory:
            for attr, a in me.attrs.items():
                if provenance is not None and a.provenance != provenance:
                    continue
                if min_confidence is not None and (
                        a.confidence is None or a.confidence < min_confidence):
                    continue
                out.append(QueryResult(key, f"{me.name}.{attr}", True,
                                       a.value, a.unit, a.provenance,
                                       a.confidence, me.name))
        return out

    def adjacency(self, key: str) -> dict[str, list[str]]:
        """Sharing/link adjacency: element -> peers it physically shares
        silicon or an interconnect edge with."""
        topo = self.get(key)
        if topo is None:
            return {}
        adj: dict[str, list[str]] = {}
        for me in topo.memory:
            if me.shared_with:
                adj[me.name] = list(me.shared_with)
        for link in topo.links:
            a, b = link.endpoints
            adj.setdefault(a, []).append(b)
            adj.setdefault(b, []).append(a)
        return adj

    # -------------------------------------------------------------- diff
    def diff(self, key_a: str, key_b: str,
             rel_tol: float = 0.0) -> TopologyDiff:
        """Attribute-level comparison of two stored topologies.

        Numeric attributes within ``rel_tol`` relative difference count as
        matching (measurement jitter between runs of the same device);
        non-numeric attributes must be equal.
        """
        ta, tb = self.get(key_a), self.get(key_b)
        if ta is None or tb is None:
            missing = [k for k, t in ((key_a, ta), (key_b, tb)) if t is None]
            raise KeyError(f"topologies not in store: {missing}")
        d = TopologyDiff(key_a=key_a, key_b=key_b)

        names_a = {m.name for m in ta.memory}
        names_b = {m.name for m in tb.memory}
        d.only_in_a += sorted(names_a - names_b)
        d.only_in_b += sorted(names_b - names_a)

        for name in sorted(names_a & names_b):
            ma, mb = ta.find_memory(name), tb.find_memory(name)
            for attr in sorted(set(ma.attrs) | set(mb.attrs)):
                aa, ab = ma.attrs.get(attr), mb.attrs.get(attr)
                if aa is None:
                    d.only_in_b.append(f"{name}.{attr}")
                    continue
                if ab is None:
                    d.only_in_a.append(f"{name}.{attr}")
                    continue
                rel = _rel_delta(aa.value, ab.value)
                if rel is not None:
                    if rel <= rel_tol:
                        d.matching += 1
                    else:
                        d.changed.append(AttrDelta(name, attr, aa.value,
                                                   ab.value, rel))
                elif aa.value == ab.value:
                    d.matching += 1
                else:
                    d.changed.append(AttrDelta(name, attr, aa.value, ab.value))
            if ma.shared_with != mb.shared_with:
                d.changed.append(AttrDelta(name, "shared_with",
                                           list(ma.shared_with),
                                           list(mb.shared_with)))
        return d

    # ------------------------------------------------------------- stats
    def stats(self) -> dict:
        with self._mutex:
            return {"lru_hits": self.lru_hits,
                    "lru_misses": self.lru_misses,
                    "hot_set": len(self._lru), "store": self.store.stats()}


def _rel_delta(a, b) -> float | None:
    """Relative difference for numeric scalars; None if not comparable."""
    if isinstance(a, bool) or isinstance(b, bool):
        return None
    if not (isinstance(a, (int, float)) and isinstance(b, (int, float))):
        return None
    denom = max(abs(a), abs(b))
    if denom == 0:
        return 0.0
    return abs(a - b) / denom
