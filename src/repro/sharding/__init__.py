from .specs import (Rules, SERVE_RULES, TRAIN_RULES, batch_spec, resolve_spec,
                    tree_shardings, tree_specs)
from .context import activation_sharding, constrain

__all__ = ["Rules", "SERVE_RULES", "TRAIN_RULES", "batch_spec",
           "resolve_spec", "tree_shardings", "tree_specs",
           "activation_sharding", "constrain"]
