"""Activation-sharding constraints via a trace-time context.

GSPMD propagates parameter shardings into activations, but for FSDP-style
layouts the propagated choice is often wrong (e.g. activations inherit the
d_model/data sharding from the embedding instead of batch/data — observed
directly in the qwen3-14b dry-run: unsharded (B, H, S, S) attention temps).
Production frameworks anchor activations with explicit constraints; models
here call ``constrain(x, logical_axes)`` at block boundaries. Outside an
``activation_sharding(mesh, rules)`` context the call is a no-op, so the
same model code runs in single-device tests unchanged.
"""
from __future__ import annotations

import contextlib
import contextvars

import jax
from jax.sharding import NamedSharding

from .specs import Rules, resolve_spec

__all__ = ["activation_sharding", "constrain", "current_mesh"]

_CTX: contextvars.ContextVar = contextvars.ContextVar("act_sharding",
                                                      default=None)


@contextlib.contextmanager
def activation_sharding(mesh, rules: Rules):
    """Enable activation constraints for everything traced inside."""
    token = _CTX.set((mesh, rules))
    try:
        yield
    finally:
        _CTX.reset(token)


def current_mesh():
    """Mesh of the active activation-sharding context (None outside)."""
    ctx = _CTX.get()
    return ctx[0] if ctx is not None else None


def constrain(x: jax.Array, logical: tuple[str, ...]) -> jax.Array:
    """Apply with_sharding_constraint per the active (mesh, rules); no-op
    outside the context or for mismatched ranks (defensive)."""
    ctx = _CTX.get()
    if ctx is None:
        return x
    mesh, rules = ctx
    if len(logical) != x.ndim:
        return x
    spec = resolve_spec(tuple(x.shape), logical, rules, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
