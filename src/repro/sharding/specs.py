"""Logical-axis -> mesh-axis sharding rules with divisibility fallbacks.

Models annotate every parameter / cache / activation dimension with a logical
axis name (models/common.py). This module maps those names onto the physical
mesh ("pod", "data", "model") with MT4G's philosophy applied to distribution:
*measure, don't assume* — a rule is applied only if the dimension is actually
divisible by the mesh axes, otherwise the next-best subset of axes is used,
and replication is the final fallback. This is what lets one rule set cover
40-head and 8-head models on the same (16, 16) mesh.

Two rule sets:
  * TRAIN — FSDP-style: "embed" rows over the data axis (ZeRO-3-ish weight
    sharding), tensor-parallel columns over "model", experts over "data".
  * SERVE — weights replicated over "data" for throughput (except experts,
    which must stay sharded to fit 235B), KV-cache sequence over "model"
    (flash-decoding style).
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["Rules", "TRAIN_RULES", "SERVE_RULES", "resolve_spec",
           "tree_specs", "tree_shardings", "batch_spec"]


@dataclass(frozen=True)
class Rules:
    name: str
    mapping: dict[str, tuple[str, ...]] = field(default_factory=dict)
    # Cross-dim fallback: when no dim of a tensor could take the "model"
    # axis (e.g. 40 heads on a 16-wide axis), allow an "embed" dim to carry
    # it in addition to its own axes — row-parallel attention instead of
    # replicated attention compute (EXPERIMENTS.md §Perf, hillclimb C).
    model_fallback: bool = False

    def axes_for(self, logical: str) -> tuple[str, ...]:
        return self.mapping.get(logical, ())


TRAIN_RULES = Rules("train", {
    "embed": ("data",),            # FSDP rows
    "embed2": ("data",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "ff": ("model",),
    "vocab": ("model",),
    "inner": ("model",),
    "experts": ("data",),          # EP shares the FSDP axis
    "vision": ("data",),
    "codebooks": (),
    "batch": ("pod", "data"),
    "seq": (),
    "kv_seq": ("model",),
    "state_heads": ("model",),
})

SERVE_RULES = Rules("serve", {
    "embed": ("model",),           # fallback TP when heads/ff can't divide
    "embed2": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "ff": ("model",),
    "vocab": ("model",),
    "inner": ("model",),
    "experts": ("data",),
    "vision": (),
    "codebooks": (),
    "batch": ("pod", "data"),
    "seq": (),
    "kv_seq": ("model",),          # sequence-parallel KV cache
    "state_heads": ("model",),
})


# Lower value = assigned first. Preferred TP dims (heads/ff/vocab/experts)
# claim mesh axes before the "embed" fallback dims, regardless of the order
# the dimensions appear in the tensor.
_PRIORITY = {
    "batch": 0, "experts": 0,
    "heads": 1, "kv_heads": 1, "ff": 1, "vocab": 1, "inner": 1,
    "kv_seq": 1, "state_heads": 1,
    "embed": 3, "embed2": 3, "vision": 3,
}


def _subsets_by_product(axes: tuple[str, ...], sizes: dict[str, int]):
    """Non-empty ordered subsets of ``axes``, largest shard-product first."""
    out = []
    for r in range(len(axes), 0, -1):
        for comb in itertools.combinations(axes, r):
            prod = 1
            for a in comb:
                prod *= sizes[a]
            out.append((prod, comb))
    out.sort(key=lambda t: -t[0])
    return [c for _, c in out]


def resolve_spec(shape: tuple[int, ...], logical: tuple[str, ...],
                 rules: Rules, mesh: Mesh) -> P:
    """PartitionSpec for one tensor, honoring divisibility and axis reuse."""
    assert len(shape) == len(logical), (shape, logical)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    used: set[str] = set()
    parts: list = [None] * len(shape)
    order = sorted(range(len(shape)),
                   key=lambda i: (_PRIORITY.get(logical[i], 2), i))
    for i in order:
        dim, name = shape[i], logical[i]
        cand = tuple(a for a in rules.axes_for(name)
                     if a in sizes and a not in used)
        chosen: tuple[str, ...] = ()
        for subset in _subsets_by_product(cand, sizes) if cand else []:
            prod = 1
            for a in subset:
                prod *= sizes[a]
            if prod > 1 and dim % prod == 0:
                chosen = subset
                break
        if chosen:
            used.update(chosen)
            parts[i] = chosen if len(chosen) > 1 else chosen[0]
    if rules.model_fallback and "model" in sizes and "model" not in used:
        msize = sizes["model"]
        for i in order:
            if logical[i] not in ("embed", "embed2"):
                continue
            cur = parts[i]
            cur_axes = (() if cur is None
                        else (cur if isinstance(cur, tuple) else (cur,)))
            prod = msize
            for a in cur_axes:
                prod *= sizes[a]
            if prod > 1 and shape[i] % prod == 0:
                parts[i] = cur_axes + ("model",) if cur_axes else "model"
                used.add("model")
                break
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def tree_specs(shape_tree, logical_tree, rules: Rules, mesh: Mesh):
    """Map (ShapeDtypeStruct tree, logical-axes tree) -> PartitionSpec tree."""
    def one(sds, axes):
        if not isinstance(axes, tuple):
            raise TypeError(f"bad logical axes {axes!r}")
        return resolve_spec(tuple(sds.shape), axes, rules, mesh)

    return jax.tree.map(one, shape_tree, logical_tree,
                        is_leaf=lambda x: isinstance(x, tuple))


def tree_shardings(shape_tree, logical_tree, rules: Rules, mesh: Mesh):
    specs = tree_specs(shape_tree, logical_tree, rules, mesh)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def batch_spec(shape: tuple[int, ...], rules: Rules, mesh: Mesh,
               seq_axes: tuple[str, ...] = ()) -> P:
    """Spec for a [batch, seq, ...] input tensor."""
    logical = ("batch",) + seq_axes + ("",) * (len(shape) - 1 - len(seq_axes))
    return resolve_spec(shape, logical[: len(shape)], rules, mesh)
