from .optimizer import OptConfig, apply_updates, init_opt_state, lr_at
from .train_loop import (TrainConfig, init_train_state, make_train_step,
                         train_loop)
from .grad_compress import (compress_with_feedback, compressed_psum,
                            dequantize, quantize)

__all__ = ["OptConfig", "apply_updates", "init_opt_state", "lr_at",
           "TrainConfig", "init_train_state", "make_train_step", "train_loop",
           "compress_with_feedback", "compressed_psum", "dequantize",
           "quantize"]
