"""Gradient compression with error feedback (cross-pod DP optimization).

At 2+ pods the data-parallel all-reduce crosses DCN (25 GB/s/host vs
4x50 GB/s ICI), so gradient bytes dominate the collective roofline term.
int8 quantization with per-tensor max-abs scaling halves (bf16) or quarters
(f32) the bytes; the quantization error is fed back into the next step's
gradient (error feedback keeps SGD convergence guarantees).

``compressed_psum`` is the shard_map building block for an explicit-DP loop;
``quantize``/``dequantize`` are also used standalone by the tests and by the
checkpointing layer (compressed checkpoints).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["quantize", "dequantize", "compress_with_feedback",
           "compressed_psum"]


def quantize(x: jax.Array, bits: int = 8):
    """Symmetric per-tensor quantization. Returns (q int8/int16, scale f32)."""
    assert bits in (8, 16)
    qmax = float(2 ** (bits - 1) - 1)
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / qmax
    q = jnp.clip(jnp.round(xf / scale), -qmax, qmax)
    dt = jnp.int8 if bits == 8 else jnp.int16
    return q.astype(dt), scale


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_with_feedback(grad: jax.Array, error: jax.Array, bits: int = 8):
    """Quantize (grad + carried error); return (q, scale, new_error)."""
    target = grad.astype(jnp.float32) + error
    q, scale = quantize(target, bits)
    new_error = target - dequantize(q, scale)
    return q, scale, new_error


def compressed_psum(grad: jax.Array, error: jax.Array, axis: str,
                    bits: int = 8):
    """All-reduce a gradient in int8/16 across ``axis`` (inside shard_map).

    Two tiny f32 collectives (scale agreement) + one integer psum replace the
    full-width psum: bytes on the wire drop ~2x vs bf16, ~4x vs f32.
    Returns (mean-reduced f32 gradient, new error-feedback buffer).
    """
    n = jax.lax.psum(jnp.ones(()), axis)
    target = grad.astype(jnp.float32) + error
    # Shared scale: max |g| across peers so the integer sum cannot overflow.
    qmax = float(2 ** (bits - 1) - 1)
    local_max = jnp.maximum(jnp.max(jnp.abs(target)), 1e-12)
    global_max = jax.lax.pmax(local_max, axis)
    scale = global_max / qmax
    q = jnp.clip(jnp.round(target / scale), -qmax, qmax)
    new_error = target - q * scale
    q_sum = jax.lax.psum(q.astype(jnp.int32), axis)
    return q_sum.astype(jnp.float32) * scale / n, new_error
