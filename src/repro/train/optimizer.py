"""AdamW with warmup+cosine schedule, global-norm clipping, and ZeRO-style
sharded state (pure JAX — no optax in this environment).

Optimizer moments are f32 and inherit the parameter sharding (with the
TRAIN_RULES FSDP mapping this is ZeRO-1/3 combined: params, grads, and
moments are all sharded over the data axis). An optional f32 master copy is
kept when params are low-precision.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

__all__ = ["OptConfig", "init_opt_state", "opt_state_specs", "apply_updates",
           "lr_at"]


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    master_f32: bool = True


def lr_at(cfg: OptConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
        1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * cos


def _needs_master(params) -> bool:
    return any(l.dtype != jnp.float32 for l in jax.tree.leaves(params))


def init_opt_state(params, cfg: OptConfig):
    zeros32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    state = {
        "m": jax.tree.map(zeros32, params),
        "v": jax.tree.map(zeros32, params),
        "step": jnp.zeros((), jnp.int32),
    }
    # Master copy only when params are low-precision: for f32 params the
    # cast would alias the same buffer (and break donation) for zero benefit.
    if cfg.master_f32 and _needs_master(params):
        state["master"] = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    return state


def opt_state_specs(param_specs_tree, cfg: OptConfig, has_master: bool = True):
    """Logical-axis tree for the optimizer state (mirrors the params)."""
    is_axes = lambda x: isinstance(x, tuple)
    ident = lambda a: a
    state = {
        "m": jax.tree.map(ident, param_specs_tree, is_leaf=is_axes),
        "v": jax.tree.map(ident, param_specs_tree, is_leaf=is_axes),
        "step": (),
    }
    if cfg.master_f32 and has_master:
        state["master"] = jax.tree.map(ident, param_specs_tree, is_leaf=is_axes)
    return state


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(l.astype(jnp.float32)))
              for l in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def apply_updates(params, grads, state, cfg: OptConfig):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    lr = lr_at(cfg, step)

    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    masters = state.get("master", params)

    def upd(p, pm, g, m, v):
        g = g.astype(jnp.float32) * scale
        m2 = b1 * m + (1 - b1) * g
        v2 = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m2 / bc1
        vhat = v2 / bc2
        pm32 = pm.astype(jnp.float32)
        new_master = pm32 - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps)
                                  + cfg.weight_decay * pm32)
        return new_master.astype(p.dtype), new_master, m2, v2

    out = jax.tree.map(upd, params, masters, grads, state["m"], state["v"])
    # Unzip the 4-tuples.
    is4 = lambda x: isinstance(x, tuple) and len(x) == 4
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=is4)
    new_master = jax.tree.map(lambda t: t[1], out, is_leaf=is4)
    new_m = jax.tree.map(lambda t: t[2], out, is_leaf=is4)
    new_v = jax.tree.map(lambda t: t[3], out, is_leaf=is4)

    new_state = {"m": new_m, "v": new_v, "step": step}
    if "master" in state:
        new_state["master"] = new_master
    metrics = {"lr": lr, "grad_norm": gnorm}
    return new_params, new_state, metrics
