"""Training loop: jitted step with microbatch accumulation, remat, sharded
state, metrics, checkpoint hooks.

``make_train_step`` builds the jitted (state, batch) -> (state, metrics)
function with donated state buffers. Gradient accumulation splits the batch
into ``microbatches`` chunks and folds them with ``lax.scan`` — trace size is
O(1) in the chunk count, and the MoE dispatch buffers shrink by the same
factor (the reason the 235B train cell fits; DESIGN.md §5).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import jax.numpy as jnp

from ..models import Runtime
from .optimizer import OptConfig, apply_updates, init_opt_state

__all__ = ["TrainConfig", "TrainState", "make_train_step", "init_train_state",
           "train_loop"]


@dataclass(frozen=True)
class TrainConfig:
    opt: OptConfig = OptConfig()
    microbatches: int = 1
    runtime: Runtime = Runtime()
    log_every: int = 10
    ckpt_every: int = 50


TrainState = dict  # {"params": ..., "opt": ...}


def init_train_state(model, key, tc: TrainConfig):
    params, specs = model.init(key)
    return {"params": params, "opt": init_opt_state(params, tc.opt)}, specs


def _split_batch(batch, n: int):
    def split(x):
        b = x.shape[0]
        assert b % n == 0, f"batch {b} not divisible by microbatches {n}"
        # Strided split: each microbatch takes every n-th sequence, so under a
        # batch-sharded layout every microbatch still spans all data shards
        # evenly (no resharding inside the accumulation scan).
        return x.reshape((b // n, n) + x.shape[1:]).swapaxes(0, 1)
    return jax.tree.map(split, batch)


def make_train_step(model, tc: TrainConfig) -> Callable:
    """Returns step(state, batch) -> (state, metrics); jit with donation."""

    def loss_fn(params, mb):
        return model.train_loss(params, mb, tc.runtime)

    def step(state, batch):
        params = state["params"]
        if tc.microbatches > 1:
            mbs = _split_batch(batch, tc.microbatches)
            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)

            def body(acc, mb):
                loss_acc, g_acc = acc
                loss, g = jax.value_and_grad(loss_fn)(params, mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                return (loss_acc + loss, g_acc), None

            (loss_sum, grads), _ = jax.lax.scan(body, (0.0, zero), mbs)
            loss = loss_sum / tc.microbatches
            grads = jax.tree.map(lambda g: g / tc.microbatches, grads)
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)

        new_params, new_opt, om = apply_updates(params, grads, state["opt"],
                                                tc.opt)
        metrics = {"loss": loss.astype(jnp.float32), **om}
        return {"params": new_params, "opt": new_opt}, metrics

    return step


def train_loop(model, tc: TrainConfig, data, steps: int, *,
               state=None, start_step: int = 0, checkpointer=None,
               step_fn=None, callbacks: list[Callable] | None = None,
               straggler=None):
    """Host-side loop: data feed, metrics, periodic (async) checkpoints.

    Pure function of (state, start_step, data) -> deterministic restart.
    ``callbacks`` receive (step, metrics) — used by tests to inject failures.
    """
    import time as _time

    if state is None:
        state, _ = init_train_state(model, jax.random.PRNGKey(0), tc)
    step_fn = step_fn or jax.jit(make_train_step(model, tc), donate_argnums=0)

    history = []
    for step in range(start_step, steps):
        t0 = _time.perf_counter()
        batch = data.batch_at(step)
        state, metrics = step_fn(state, batch)
        metrics = {k: float(v) for k, v in metrics.items()}
        dt = _time.perf_counter() - t0
        metrics["step_time_s"] = dt
        history.append((step, metrics))
        if straggler is not None:
            straggler.record(step, dt)
        for cb in callbacks or []:
            cb(step, metrics)
        if checkpointer is not None and (step + 1) % tc.ckpt_every == 0:
            checkpointer.save_async(step + 1, state)
    if checkpointer is not None:
        checkpointer.wait()
    return state, history
