"""Minimal deterministic stand-in for ``hypothesis`` when it is not installed.

The suite's property tests use a small slice of the hypothesis API:
``given``/``settings`` decorators and the ``integers``/``floats``/``lists``/
``sampled_from`` strategies.  This shim replays ``max_examples`` seeded,
deterministic examples through the same decorator surface, so the property
tests collect and run on machines without the real package (the container
image does not ship it).  When hypothesis *is* importable it is re-exported
unchanged, so nothing is lost where it exists.

The example seed is derived from the test's qualified name, making failures
reproducible run-to-run without any shared state between tests.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    import functools
    import hashlib

    import numpy as np

    class _Strategy:
        """A draw function over a seeded numpy Generator."""

        def __init__(self, draw):
            self._draw = draw

        def example(self, rng: np.random.Generator):
            return self._draw(rng)

    class _Strategies:
        @staticmethod
        def integers(min_value: int, max_value: int) -> _Strategy:
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1)))

        @staticmethod
        def floats(min_value: float, max_value: float) -> _Strategy:
            return _Strategy(
                lambda rng: float(rng.uniform(min_value, max_value)))

        @staticmethod
        def sampled_from(elements) -> _Strategy:
            seq = list(elements)
            return _Strategy(lambda rng: seq[int(rng.integers(len(seq)))])

        @staticmethod
        def lists(elements: _Strategy, min_size: int = 0,
                  max_size: int = 10) -> _Strategy:
            def draw(rng):
                n = int(rng.integers(min_size, max_size + 1))
                return [elements.example(rng) for _ in range(n)]
            return _Strategy(draw)

    st = _Strategies()

    def settings(max_examples: int = 10, deadline=None, **_ignored):
        def deco(fn):
            fn._compat_max_examples = max_examples
            return fn
        return deco

    def given(*arg_strategies, **kw_strategies):
        def deco(fn):
            max_examples = getattr(fn, "_compat_max_examples", 10)

            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                seed = int.from_bytes(hashlib.blake2b(
                    fn.__qualname__.encode(), digest_size=8).digest(), "big")
                rng = np.random.default_rng(seed)
                for _ in range(max_examples):
                    pos = tuple(s.example(rng) for s in arg_strategies)
                    kws = {k: s.example(rng) for k, s in kw_strategies.items()}
                    fn(*args, *pos, **kwargs, **kws)

            # pytest follows __wrapped__ when inspecting signatures and would
            # otherwise mistake the strategy parameters for fixtures.
            del wrapper.__wrapped__
            return wrapper
        return deco
