"""Shared test configuration.

Two things must happen before any test module runs:

1. ``XLA_FLAGS`` must force a multi-device host platform *before* jax is
   first imported anywhere in the process.  Individual test modules used to
   set this themselves, but pytest imports modules in collection order, so
   whichever module touched jax first won — and every mesh test after it
   failed on a single-device CPU.  conftest is imported before all of them.
2. ``src/`` must be importable so the suite runs with a plain ``pytest``
   invocation as well as the tier-1 ``PYTHONPATH=src`` form.
"""
import os
import sys

_COUNT_FLAG = "--xla_force_host_platform_device_count"
if _COUNT_FLAG not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "") + f" {_COUNT_FLAG}=8").strip()

_SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                    "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: long-running test (subprocess drivers, full dry-runs); "
        "deselect with -m 'not slow'")
