"""Bench-regression-gate tests: derived-field parsing, the compare rules
(hard-fail correctness + ratio regressions, warn-only wall time), and the
CLI contract CI relies on — nonzero exit on an injected regression."""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "benchmarks"))

from check_regression import (GateReport, as_number, compare,  # noqa: E402
                              parse_derived)

BASELINE = [
    {"name": "engine_speedup", "us": 160000.0,
     "derived": "legacy=560000us_speedup=3.60x_identical=True"},
    {"name": "adaptive_speedup", "us": 300000.0,
     "derived": "rows_dense=4800_rows_planned=3300_row_ratio=1.45x_"
                "identical=True"},
    {"name": "topology_query", "us": 600.0,
     "derived": "cold=320000us_warm_speedup=500.0x_batched_qps=170000_"
                "found=2000/2000_identical=True"},
    {"name": "pallas_interp", "us": 3000000.0,
     "derived": "discrete_ok=True_store_hit=True_eviction_fusion=True_"
                "warm_speedup=9000.0x_kernel_calls=470"},
]


def _rows(**overrides):
    rows = json.loads(json.dumps(BASELINE))
    for name, derived in overrides.items():
        for r in rows:
            if r["name"] == name:
                r["derived"] = derived
    return rows


class TestParsing:
    def test_underscored_metric_names(self):
        d = parse_derived("cold=320000us_warm_speedup=500.0x_batched_qps="
                          "170000_found=2000/2000_identical=True")
        assert d == {"cold": "320000us", "warm_speedup": "500.0x",
                     "batched_qps": "170000", "found": "2000/2000",
                     "identical": "True"}

    def test_free_text_rows_do_not_crash(self):
        assert parse_derived("25/25_attrs") == {}
        d = parse_derived("size=238B_conf=0.95_pts=40")
        assert d["size"] == "238B"

    def test_as_number(self):
        assert as_number("2.23x") == pytest.approx(2.23)
        assert as_number("538529us") == pytest.approx(538529.0)
        assert as_number("2000/2000") == pytest.approx(1.0)
        assert as_number("1900/2000") == pytest.approx(0.95)
        assert as_number("True") is None


class TestCompareRules:
    def test_clean_run_passes(self):
        assert compare(_rows(), BASELINE).ok

    def test_ratio_regression_fails(self):
        report = compare(_rows(
            engine_speedup="legacy=530000us_speedup=2.40x_identical=True"),
            BASELINE)
        assert not report.ok
        assert any("speedup regressed" in f for f in report.failures)

    def test_small_ratio_drift_passes(self):
        assert compare(_rows(
            engine_speedup="legacy=530000us_speedup=3.30x_identical=True"),
            BASELINE).ok

    def test_engine_speedup_hard_floor(self):
        """ISSUE 4 acceptance: engine >=3x over legacy, outright."""
        report = compare(_rows(
            engine_speedup="legacy=530000us_speedup=2.95x_identical=True"),
            BASELINE)
        assert any("below hard floor" in f for f in report.failures)

    def test_correctness_flip_fails(self):
        report = compare(_rows(
            engine_speedup="legacy=530000us_speedup=3.60x_identical=False"),
            BASELINE)
        assert any("identical" in f for f in report.failures)

    def test_planner_identity_flip_fails(self):
        report = compare(_rows(
            adaptive_speedup="rows_dense=4800_rows_planned=3300_"
                             "row_ratio=1.45x_identical=False"), BASELINE)
        assert any("identical" in f for f in report.failures)

    def test_kernel_calls_ceiling_and_regression(self):
        """ISSUE 8 acceptance: pallas_interp kernel_calls <= 500, and
        creeping regressions beyond tol hard-fail even under the ceiling."""
        report = compare(_rows(
            pallas_interp="discrete_ok=True_store_hit=True_"
                          "eviction_fusion=True_"
                          "warm_speedup=9000.0x_kernel_calls=1200"), BASELINE)
        assert any("above hard ceiling" in f for f in report.failures)
        assert any("kernel_calls regressed" in f for f in report.failures)
        report = compare(_rows(
            pallas_interp="discrete_ok=True_store_hit=True_"
                          "eviction_fusion=True_"
                          "warm_speedup=9000.0x_kernel_calls=495"), BASELINE)
        assert report.ok                  # within tol and under the ceiling

    def test_eviction_fusion_flip_fails(self):
        """ISSUE 8: eviction rows quietly leaving the fused grids is a
        correctness-of-structure regression, not a timing one."""
        report = compare(_rows(
            pallas_interp="discrete_ok=True_store_hit=True_"
                          "eviction_fusion=False_"
                          "warm_speedup=9000.0x_kernel_calls=470"), BASELINE)
        assert any("eviction_fusion" in f for f in report.failures)

    def test_found_fraction_drop_fails(self):
        report = compare(_rows(
            topology_query="cold=320000us_warm_speedup=500.0x_batched_qps="
                           "170000_found=1500/2000_identical=True"),
            BASELINE)
        assert any("found dropped" in f for f in report.failures)

    def test_warm_hit_floor(self):
        report = compare(_rows(
            topology_query="cold=320000us_warm_speedup=6.0x_batched_qps="
                           "170000_found=2000/2000_identical=True"),
            BASELINE)
        assert any("below hard floor" in f for f in report.failures)

    def test_wall_time_is_warn_only(self):
        rows = _rows()
        for r in rows:
            r["us"] *= 10            # 10x slower wall clock
        report = compare(rows, BASELINE)
        assert report.ok
        assert any("wall time" in w for w in report.warnings)

    def test_qps_is_warn_only(self):
        report = compare(_rows(
            topology_query="cold=320000us_warm_speedup=500.0x_batched_qps="
                           "50000_found=2000/2000_identical=True"),
            BASELINE)
        assert report.ok
        assert any("batched_qps" in w for w in report.warnings)

    def test_missing_gated_row_fails(self):
        report = compare([_rows()[0]], BASELINE)
        assert any("missing" in f for f in report.failures)

    def test_errored_row_fails(self):
        report = compare(_rows(
            topology_query="ERROR_RuntimeError_boom"), BASELINE)
        assert any("errored" in f for f in report.failures)


@pytest.mark.slow
class TestCli:
    """The CI contract: exit 0 clean, nonzero on an injected regression."""

    def _run(self, *args):
        return subprocess.run(
            [sys.executable, os.path.join(REPO, "benchmarks",
                                          "check_regression.py"), *args],
            capture_output=True, text=True)

    def test_exits_nonzero_on_injected_regression(self, tmp_path):
        base = tmp_path / "base.json"
        cur = tmp_path / "cur.json"
        base.write_text(json.dumps(BASELINE))
        cur.write_text(json.dumps(_rows(
            engine_speedup="legacy=530000us_speedup=1.10x_identical=True")))
        proc = self._run(str(cur), str(base))
        assert proc.returncode != 0
        assert "FAIL" in proc.stdout

    def test_exits_zero_on_clean_run(self, tmp_path):
        base = tmp_path / "base.json"
        base.write_text(json.dumps(BASELINE))
        proc = self._run(str(base), str(base))
        assert proc.returncode == 0
        assert "OK" in proc.stdout

    def test_self_test_passes(self):
        proc = self._run("--self-test")
        assert proc.returncode == 0
        assert "self-test passed" in proc.stdout

    def test_committed_baseline_is_well_formed(self):
        """Every gated row is present and its declared correctness bools
        hold in the committed budgets (identical= for the sim rows,
        discrete_ok=/store_hit= for the Pallas backend row)."""
        from check_regression import GATES

        with open(os.path.join(REPO, "BENCH_BASELINE.json")) as f:
            rows = json.load(f)
        names = {r["name"] for r in rows}
        assert names >= {"engine_speedup", "topology_query", "pallas_interp"}
        for r in rows:
            d = parse_derived(r["derived"])
            for metric in GATES.get(r["name"], {}).get("bools", ()):
                assert d.get(metric) == "True", (r["name"], metric)
