"""End-to-end discovery + topology report + perf model tests (paper C1, §VI-A)."""
import json

import numpy as np
import pytest

from repro.core import (Topology, discover_sim, make_h100_like,
                        make_mi210_like, spec_from_topology, TPU_V5E)
from repro.core.perfmodel import (AppParams, GpuParams, evaluate,
                                  gpu_params_from_topology)

KIB, MIB = 1024, 1024**2


@pytest.fixture(scope="module")
def h100_report():
    topo, timings = discover_sim(make_h100_like(seed=11), n_samples=17)
    return topo, timings


class TestDiscovery:
    def test_l1_attributes(self, h100_report):
        topo, _ = h100_report
        l1 = topo.find_memory("L1")
        assert l1 is not None
        assert abs(l1.get("size") - 238 * KIB) <= 2 * KIB
        assert abs(l1.get("load_latency") - 38.0) < 4.0
        assert l1.get("line_size") == 128
        assert l1.get("fetch_granularity") == 32
        assert l1.get("amount") == 1

    def test_l2_segmentation(self, h100_report):
        topo, _ = h100_report
        l2 = topo.find_memory("L2")
        assert l2 is not None
        assert l2.get("amount") == 2                      # paper §IV-F.1
        assert abs(l2.get("segment_size") - 25 * MIB) <= MIB
        assert l2.get("read_bw") > 0

    def test_unified_l1_sharing(self, h100_report):
        topo, _ = h100_report
        l1 = topo.find_memory("L1")
        assert set(l1.shared_with) >= {"Texture", "Readonly"}
        const = topo.find_memory("ConstL1")
        assert "L1" not in const.shared_with

    def test_device_memory(self, h100_report):
        topo, _ = h100_report
        dm = topo.find_memory("DeviceMemory")
        assert abs(dm.get("load_latency") - 843) < 60
        assert abs(dm.get("read_bw") - 2500) / 2500 < 0.15   # GB/s

    def test_timings_recorded(self, h100_report):
        _, timings = h100_report
        assert timings.total > 0
        assert "size" in timings.per_family and "latency" in timings.per_family

    def test_mi210_cu_sharing(self):
        topo, _ = discover_sim(make_mi210_like(seed=12), n_samples=17)
        sl1d = topo.find_memory("sL1d")
        assert sl1d is not None
        assert sl1d.get("exclusive_cus")  # disabled partners -> exclusive CUs
        assert any("," in g for g in sl1d.shared_with)  # some CU pairs share

    def test_provenance_and_confidence(self, h100_report):
        topo, _ = h100_report
        l1 = topo.find_memory("L1")
        assert l1.attrs["size"].provenance == "benchmark"
        assert l1.attrs["size"].confidence is not None


class TestTopologySerialization:
    def test_json_roundtrip(self, h100_report):
        topo, _ = h100_report
        s = topo.dumps()
        back = Topology.loads(s)
        assert back.model == topo.model
        assert {m.name for m in back.memory} == {m.name for m in topo.memory}
        l1a, l1b = topo.find_memory("L1"), back.find_memory("L1")
        assert l1a.get("size") == l1b.get("size")
        assert l1b.attrs["size"].confidence == pytest.approx(
            l1a.attrs["size"].confidence, rel=1e-3)

    def test_json_is_valid(self, h100_report):
        topo, _ = h100_report
        parsed = json.loads(topo.dumps())
        assert parsed["vendor"] == "NVIDIA"

    def test_markdown_report(self, h100_report):
        topo, _ = h100_report
        md = topo.to_markdown()
        assert "| L1 |" in md and "## Memory" in md

    def test_spec_overlay(self, h100_report):
        topo, _ = h100_report
        spec = spec_from_topology(topo, TPU_V5E)
        assert spec.hbm_bandwidth != TPU_V5E.hbm_bandwidth  # overridden
        assert spec.peak_bf16_flops == TPU_V5E.peak_bf16_flops


class TestPerfModel:
    def test_memory_bound_detection(self):
        gpu = GpuParams(mem_latency=400, mem_bandwidth=800e9, mem_freq=1e9,
                        departure_delay=100)
        app = AppParams(comp_cycles=10, mem_cycles=4000, loads_per_warp=32,
                        active_warps_per_sm=32)
        res = evaluate(app, gpu)
        assert res.memory_bound
        assert res.cwp == 32  # capped at active warps

    def test_compute_bound_detection(self):
        gpu = GpuParams(mem_latency=40, mem_bandwidth=3e12, mem_freq=1e9,
                        departure_delay=1)
        app = AppParams(comp_cycles=10000, mem_cycles=40, loads_per_warp=1,
                        active_warps_per_sm=8)
        res = evaluate(app, gpu)
        assert not res.memory_bound

    def test_mwp_capped_by_warps(self):
        gpu = GpuParams(mem_latency=1000, mem_bandwidth=1e15, mem_freq=1e9,
                        departure_delay=0.1)
        app = AppParams(comp_cycles=100, mem_cycles=100, loads_per_warp=1,
                        active_warps_per_sm=4)
        assert evaluate(app, gpu).mwp <= 4

    def test_params_from_topology(self, h100_report):
        topo, _ = h100_report
        gpu = gpu_params_from_topology(topo)
        assert gpu.mem_latency > 500      # discovered DRAM latency
        assert gpu.mem_bandwidth > 1e12   # discovered bandwidth
