"""Tests for the docstring-coverage linter (``benchmarks/check_docstrings.py``).

The linter is CI infrastructure: the warn lane must never fail the build,
the strict set must hard-fail on any public object with no docstring, and
the AST walk must exempt private and nested scope.  Plus the end-to-end
check CI relies on: the real tree currently passes.
"""
import ast
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "benchmarks"))

from check_docstrings import (STRICT_FILES, WARN_LANE,  # noqa: E402
                              check_file, public_objects, self_test)


class TestPublicObjects:
    def test_module_and_public_defs_counted(self):
        objs = public_objects(ast.parse(
            '"""doc"""\ndef f():\n    pass\nclass C:\n'
            '    def m(self):\n        pass\n'))
        assert [(n, ok) for n, _, ok in objs] == [
            ("<module>", True), ("f", False), ("C", False),
            ("C.m", False)]

    def test_private_and_nested_defs_exempt(self):
        objs = public_objects(ast.parse(
            "def _hidden():\n    pass\n"
            "def outer():\n    '''doc'''\n"
            "    def inner():\n        pass\n"
            "class C:\n    '''doc'''\n"
            "    def _p(self):\n        pass\n"))
        names = {n for n, _, _ in objs}
        assert names == {"<module>", "outer", "C"}

    def test_async_defs_counted(self):
        objs = public_objects(ast.parse(
            '"""doc"""\nasync def fetch():\n    pass\n'))
        assert ("fetch", 2, False) in objs


class TestTreeContract:
    def test_strict_files_exist_and_are_fully_documented(self):
        """The hard CI guarantee: every strict file has zero undocumented
        public objects right now."""
        for rel in STRICT_FILES:
            path = os.path.join(REPO, rel)
            assert os.path.exists(path), rel
            _, _, missing = check_file(path)
            assert missing == [], f"{rel}: {missing}"

    def test_warn_lanes_exist(self):
        for lane in WARN_LANE:
            assert os.path.isdir(os.path.join(REPO, lane)), lane

    def test_self_test_passes(self, capsys):
        assert self_test() == 0
        assert "self-test passed" in capsys.readouterr().out

    def test_cli_exit_zero_on_current_tree(self):
        proc = subprocess.run(
            [sys.executable,
             os.path.join(REPO, "benchmarks", "check_docstrings.py")],
            capture_output=True, text=True)
        assert proc.returncode == 0, proc.stdout + proc.stderr
        assert "docstring lint: OK" in proc.stdout
