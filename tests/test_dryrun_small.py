"""In-CI dry-run: subprocess with 8 forced host devices, (2,4) mesh.

The full 512-device x 40-cell run lives in artifacts/dryrun (see
EXPERIMENTS.md §Dry-run); this test keeps the machinery honest in CI using
one cell per step kind, plus the HLO collective parser and roofline math on
the produced artifacts.
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

# Subprocess drivers that compile multi-device programs: the suite's
# slowest tests, deselected by `make test-fast`.
pytestmark = pytest.mark.slow

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_cells(cells, mesh_shape=(2, 4)):
    code = textwrap.dedent(f"""
        import os, sys, json
        os.environ["REPRO_DRYRUN_DEVICES"] = "8"
        sys.path.insert(0, {os.path.join(ROOT, 'src')!r})
        from repro.launch import dryrun
        from repro.launch.mesh import make_debug_mesh
        mesh = make_debug_mesh({mesh_shape!r}, ("data", "model"))
        out = []
        for arch, shape in {cells!r}:
            out.append(dryrun.run_cell(arch, shape, mesh, "ci"))
        print("===JSON===")
        print(json.dumps(out))
    """)
    proc = subprocess.run([sys.executable, "-c", code], capture_output=True,
                          text=True, timeout=1200)
    assert proc.returncode == 0, proc.stderr[-3000:]
    payload = proc.stdout.split("===JSON===")[1]
    return json.loads(payload)


@pytest.fixture(scope="module")
def ci_cells():
    return _run_cells([
        ("internlm2-1.8b", "train_4k"),
        ("internlm2-1.8b", "decode_32k"),
        ("rwkv6-3b", "long_500k"),
    ])


def test_all_ci_cells_compile(ci_cells):
    for rec in ci_cells:
        assert rec["ok"], f"{rec['arch']}/{rec['shape']}: {rec.get('error')}"


def test_cost_and_memory_recorded(ci_cells):
    for rec in ci_cells:
        assert rec["cost"].get("flops", 0) > 0
        assert rec["memory"]["argument_bytes"] > 0


def test_train_cell_has_collectives(ci_cells):
    train = next(r for r in ci_cells if r["shape"] == "train_4k")
    assert train["collectives"]["total_bytes"] > 0
    assert "all-reduce" in train["collectives"]["bytes_by_op"]


def test_roofline_terms_from_ci_cells(ci_cells):
    from repro.analysis.roofline import roofline_from_cell
    from repro.configs import get_config, shape_for
    from repro.core.catalog import TPU_V5E

    train = next(r for r in ci_cells if r["shape"] == "train_4k")
    terms = roofline_from_cell(train, get_config("internlm2-1.8b"),
                               shape_for("train_4k"), TPU_V5E, chips=8)
    assert terms.compute_s > 0 and terms.memory_s > 0
    assert terms.bound in ("compute", "memory", "collective")
    assert 0 < terms.roofline_fraction <= 1.0
    assert terms.useful_ratio > 0.1, "HLO flops wildly above model flops"


def test_long500k_rwkv_state_bound(ci_cells):
    long = next(r for r in ci_cells if r["shape"] == "long_500k")
    assert long["ok"]
    # attention-free decode: the cache is O(1); arguments stay modest.
    assert long["memory"]["argument_bytes"] < 20e9


class TestHloParser:
    def test_parse_canned_hlo(self):
        from repro.analysis.hlo import parse_collectives
        hlo = """
          %ag = f32[256,128]{1,0} all-gather(%x), replica_groups=...
          %ar = bf16[1024]{0} all-reduce(%y), to_apply=%add
          %arს = (f32[8]{0}, f32[16]{0}) all-reduce-start(%a, %b)
          %ard = (f32[8]{0}, f32[16]{0}) all-reduce-done(%ars)
          %cp = u32[64]{0} collective-permute(%z)
          %nothing = f32[2]{0} add(%p, %q)
        """
        st = parse_collectives(hlo)
        assert st.bytes_by_op["all-gather"] == 256 * 128 * 4
        assert st.bytes_by_op["all-reduce"] == 1024 * 2 + (8 + 16) * 4
        assert st.bytes_by_op["collective-permute"] == 64 * 4
        assert st.count_by_op["all-reduce"] == 2    # start counted, done not

    def test_full_artifacts_if_present(self):
        """If the 512-device artifacts exist, they must all be ok."""
        art = os.path.join(ROOT, "artifacts", "dryrun")
        if not os.path.isdir(art):
            pytest.skip("full dry-run artifacts not generated yet")
        import glob
        recs = [json.load(open(f)) for f in glob.glob(art + "/*/*.json")]
        assert len(recs) >= 80
        bad = [r for r in recs if not r.get("ok")]
        assert not bad, [(r["arch"], r["shape"], r.get("error")) for r in bad]
