"""Elastic resize + multi-device launch drivers (subprocess, 8 devices).

The MIG-analogue scenario (paper §VI-C): lose half the data axis, rebuild a
sub-slice mesh, restore the same sharded checkpoint onto it, and continue
training deterministically.
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

# Subprocess drivers that compile multi-device programs: the suite's
# slowest tests, deselected by `make test-fast`.
pytestmark = pytest.mark.slow

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str, timeout=900):
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout,
        cwd=ROOT,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    return proc.stdout


def test_elastic_restore_onto_smaller_mesh(tmp_path):
    out = _run(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys
        sys.path.insert(0, "src")
        import jax, numpy as np
        from repro.checkpoint import Checkpointer
        from repro.configs import get_config
        from repro.launch.mesh import make_subslice_mesh
        from repro.models import get_model
        from repro.sharding import TRAIN_RULES, tree_shardings
        from repro.train import TrainConfig, init_train_state
        from repro.train.optimizer import opt_state_specs

        cfg = get_config("internlm2-1.8b").smoke().replace(dtype="float32")
        model = get_model(cfg)
        tc = TrainConfig()
        from repro.compat import make_mesh
        mesh_big = make_mesh((4, 2), ("data", "model"))

        state, pspecs = init_train_state(model, jax.random.PRNGKey(0), tc)
        ospecs = opt_state_specs(pspecs, tc.opt,
                                 has_master="master" in state["opt"])
        logical = {{"params": pspecs, "opt": ospecs}}
        sh_big = tree_shardings(jax.eval_shape(lambda: state), logical,
                                TRAIN_RULES, mesh_big)
        state = jax.tree.map(jax.device_put, state, sh_big)

        ck = Checkpointer({str(tmp_path)!r})
        ck.save(3, state)

        # Lose half the data axis -> (2, 2) sub-slice mesh; restore onto it.
        mesh_small = make_subslice_mesh(base_shape=(4, 2), drop_data_rows=2)
        sh_small = tree_shardings(jax.eval_shape(lambda: state), logical,
                                  TRAIN_RULES, mesh_small)
        restored, _ = ck.restore(state, step=3, shardings=sh_small)
        w = restored["params"]["layers"]["wq"]
        assert w.sharding.mesh.devices.shape == (2, 2)
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        print("ELASTIC_OK")
    """)
    assert "ELASTIC_OK" in out


def test_train_driver_multidevice():
    out = _run("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys
        sys.path.insert(0, "src")
        from repro.launch.train import main
        rc = main(["--arch", "internlm2-1.8b-smoke", "--steps", "6",
                   "--mesh", "4x2", "--global-batch", "8", "--seq", "32",
                   "--ckpt-dir", "/tmp/elastic_train_ck"])
        assert rc == 0
    """)
    assert "loss" in out


def test_serve_driver_multidevice():
    out = _run("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys
        sys.path.insert(0, "src")
        from repro.launch.serve import main
        rc = main(["--arch", "internlm2-1.8b-smoke", "--mesh", "2x4",
                   "--requests", "4", "--max-new", "4", "--prompt-len", "4",
                   "--max-len", "16"])
        assert rc == 0
    """)
    assert "throughput" in out
