"""Probe-engine tests: scheduler contract, batch equivalence, golden topologies.

The engine's correctness claim is strong: batching, caching, and concurrent
scheduling must be *invisible* in the results — the engine-based
``discover_sim`` returns the same topology as the legacy sequential loop for
a fixed device seed, and matches ground truth within the same tolerances.
"""
import threading

import numpy as np
import pytest

from repro.core import discover_sim, discover_sim_legacy, make_h100_like, \
    make_mi210_like, topology_equivalent
from repro.core.engine import (CachingRunner, SampleCache, WorkItem,
                               run_probes, run_work_items)
from repro.core.probes import SimRunner
from repro.core.stats import ks_change_point, ks_statistic
from repro.core.stats.batch import ks_change_point_scan, ks_statistic_rows

KIB, MIB = 1024, 1024**2


# --------------------------------------------------------------- scheduler
class TestScheduler:
    def _items(self, log):
        def mk(name):
            def fn(_results):
                log.append(name)
                return name
            return fn
        return [
            WorkItem(key="a", fn=mk("a"), family="fam"),
            WorkItem(key="b", fn=mk("b"), deps=("a",), family="fam"),
            WorkItem(key="c", fn=mk("c"), deps=("b",), family="fam"),
            WorkItem(key="x", fn=mk("x")),
            WorkItem(key="y", fn=mk("y"), deps=("a", "x")),
        ]

    @pytest.mark.parametrize("workers", [0, 1, 4])
    def test_dependency_order_respected(self, workers):
        log = []
        sched = run_work_items(self._items(log), max_workers=workers)
        order = sched.order
        assert set(order) == {"a", "b", "c", "x", "y"}
        assert order.index("a") < order.index("b") < order.index("c")
        assert order.index("a") < order.index("y")
        assert order.index("x") < order.index("y")
        assert sched.results == {k: k for k in "abcxy"}

    def test_unknown_dep_raises(self):
        with pytest.raises(ValueError, match="unknown deps"):
            run_work_items([WorkItem(key="a", fn=lambda r: 1,
                                     deps=("ghost",))])

    def test_cycle_raises(self):
        items = [WorkItem(key="a", fn=lambda r: 1, deps=("b",)),
                 WorkItem(key="b", fn=lambda r: 1, deps=("a",))]
        with pytest.raises(ValueError, match="cycle"):
            run_work_items(items, max_workers=0)

    def test_timings_accumulate_per_family(self):
        from repro.core.discover import DiscoveryTimings
        timings = DiscoveryTimings()
        log = []
        run_work_items(self._items(log), max_workers=0, timings=timings)
        assert timings.per_family.get("fam", 0) > 0
        assert timings.total >= timings.per_family["fam"]

    def test_concurrent_runs_independent_items_in_parallel(self):
        """Two GIL-releasing items must overlap under a 2-worker pool."""
        barrier = threading.Barrier(2, timeout=5)

        def fn(_results):
            barrier.wait()   # deadlocks unless both run concurrently
            return True

        items = [WorkItem(key=i, fn=fn) for i in range(2)]
        sched = run_work_items(items, max_workers=2)
        assert all(sched.results.values())


# ----------------------------------------------------------- sample cache
class TestSampleCache:
    def test_batch_serves_cached_rows(self):
        runner = CachingRunner(SimRunner(make_h100_like(seed=3)))
        sizes = [32 * KIB, 64 * KIB, 128 * KIB]
        one = runner.pchase("L1", sizes[1], 32, 9)
        rows = runner.pchase_batch("L1", sizes, 32, 9)
        assert runner.cache.hits >= 1          # middle row came from cache
        assert np.array_equal(rows[1], one)
        again = runner.pchase_batch("L1", sizes, 32, 9)
        assert np.array_equal(rows, again)
        assert runner.cache.stats()["entries"] == 3

    def test_cache_hit_equals_rerun(self):
        """Keyed sampling: a cache hit is indistinguishable from re-probing."""
        base = SimRunner(make_h100_like(seed=3))
        cached = CachingRunner(base, cache=SampleCache())
        a = cached.pchase("L1", 96 * KIB, 32, 17)
        b = base.pchase("L1", 96 * KIB, 32, 17)      # fresh, uncached
        assert np.array_equal(a, b)


# ------------------------------------------------------- batched equivalence
class TestBatchedRunner:
    def test_pchase_batch_rows_match_individual_calls(self):
        runner = SimRunner(make_h100_like(seed=9))
        sizes = list(range(64 * KIB, 64 * KIB + 32 * 40, 32))
        batch = runner.pchase_batch("L1", sizes, 32, 17)
        for i, ab in enumerate(sizes):
            assert np.array_equal(batch[i], runner.pchase("L1", ab, 32, 17))

    def test_cold_chase_batch_rows_match_individual_calls(self):
        """The §IV-D sweep batch: per-row strides AND array sizes (unlike
        ``pchase_batch``, which varies only the size)."""
        runner = SimRunner(make_h100_like(seed=9))
        strides = [4, 8, 32, 64, 128]
        arrs = [max(64 * KIB, s * 65) for s in strides]
        batch = runner.cold_chase_batch("L1", arrs, strides, 64)
        for i, (ab, s) in enumerate(zip(arrs, strides)):
            assert np.array_equal(batch[i],
                                  runner.cold_chase("L1", ab, s, 64))

    def test_cold_chase_batch_served_through_cache(self):
        runner = CachingRunner(SimRunner(make_h100_like(seed=9)))
        strides = [4, 8, 32]
        arrs = [max(64 * KIB, s * 65) for s in strides]
        one = runner.cold_chase("L1", arrs[1], strides[1], 64)
        rows = runner.cold_chase_batch("L1", arrs, strides, 64)
        assert runner.cache.hits >= 1              # middle row from cache
        assert np.array_equal(rows[1], one)
        again = runner.cold_chase_batch("L1", arrs, strides, 64)
        assert np.array_equal(rows, again)

    def test_fetch_granularity_batched_equals_sequential(self):
        from repro.core.probes import find_fetch_granularity

        for make, space in ((make_h100_like, "L1"), (make_mi210_like, "vL1")):
            seq = find_fetch_granularity(SimRunner(make(seed=7)), space,
                                         n_samples=17)
            bat = find_fetch_granularity(
                CachingRunner(SimRunner(make(seed=7))), space,
                n_samples=17, batched=True)
            assert (seq.granularity, seq.found) == (bat.granularity, bat.found)
            assert np.array_equal(seq.strides, bat.strides)
            assert np.array_equal(seq.mixed, bat.mixed)

    def test_vectorized_ks_scan_matches_sequential_scan(self):
        rng = np.random.default_rng(1)
        for trial in range(20):
            n = int(rng.integers(10, 100))
            s = rng.normal(20, 1, n)
            if trial % 2:
                s[n // 2:] += rng.uniform(5, 50)
            for mode in ("best", "first"):
                a = ks_change_point(s, alpha=0.01, mode=mode)
                b = ks_change_point_scan(s, alpha=0.01, mode=mode)
                assert (a.index, a.found, a.statistic, a.pvalue,
                        a.confidence, a.candidates) == \
                       (b.index, b.found, b.statistic, b.pvalue,
                        b.confidence, b.candidates)

    def test_ks_statistic_rows_matches_per_row(self):
        rng = np.random.default_rng(2)
        rows = np.round(rng.normal(0, 1, (12, 33)), 1)   # ties included
        ref = np.round(rng.normal(0.5, 1, 25), 1)
        got = ks_statistic_rows(rows, ref)
        want = np.array([ks_statistic(r, ref) for r in rows])
        assert np.array_equal(got, want)


# --------------------------------------------------- engine == legacy, golden
def _topo_signature(topo):
    out = []
    for me in topo.memory:
        attrs = {k: (a.value if not isinstance(a.value, list)
                     else tuple(a.value), a.unit, a.provenance, a.confidence)
                 for k, a in me.attrs.items()}
        out.append((me.name, me.kind, me.scope, tuple(sorted(attrs.items())),
                    tuple(me.shared_with)))
    return out


class TestEngineEqualsLegacy:
    @pytest.mark.parametrize("make,seed", [
        (make_h100_like, 11), (make_h100_like, 48),
        (make_mi210_like, 12), (make_mi210_like, 48),
    ])
    def test_equivalent_topology_for_fixed_seed(self, make, seed):
        """Engine == legacy, per the ROADMAP-prescribed contract: discrete
        attributes (sizes, line sizes, granularities, amounts, sharing)
        exactly equal, float metrics within relative tolerance — vectorized
        statistics (the ``_l1_refine`` window) cannot promise bit-equal
        float summation order, only equal decisions."""
        topo_l, tl = discover_sim_legacy(make(seed=seed), n_samples=17)
        topo_e, te = discover_sim(make(seed=seed), n_samples=17)
        assert topology_equivalent(topo_l, topo_e, rel_tol=1e-6)
        # per-family accounting preserved: same buckets measured
        assert set(te.per_family) >= {"size", "latency", "bandwidth"}

    def test_equivalence_is_discrete_strict(self):
        """The relaxed contract still rejects discrete drift: a one-byte
        size change or a provenance flip must not count as equivalent."""
        topo_a, _ = discover_sim(make_h100_like(seed=5), n_samples=9)
        topo_b, _ = discover_sim(make_h100_like(seed=5), n_samples=9)
        assert topology_equivalent(topo_a, topo_b)
        l1 = topo_b.find_memory("L1")
        l1.attrs["size"].value += 1
        assert not topology_equivalent(topo_a, topo_b)
        l1.attrs["size"].value -= 1
        assert topology_equivalent(topo_a, topo_b)
        # floats move within tolerance ... and only within it
        l1.attrs["load_latency"].value *= 1.0 + 1e-9
        assert topology_equivalent(topo_a, topo_b)
        l1.attrs["load_latency"].value *= 1.01
        assert not topology_equivalent(topo_a, topo_b)

    def test_concurrent_equals_inline(self):
        dev = make_h100_like
        topo_inline, _ = discover_sim(dev(seed=5), n_samples=9, max_workers=0)
        topo_pool, _ = discover_sim(dev(seed=5), n_samples=9, max_workers=4)
        assert _topo_signature(topo_inline) == _topo_signature(topo_pool)

    def test_cache_hits_counted_during_discovery(self):
        eng = run_probes(SimRunner(make_h100_like(seed=6)), n_samples=9,
                         device_families=("sharing", "device_memory_latency",
                                          "device_memory_bandwidth"))
        assert eng.cache_stats["hits"] > 0
        assert eng.cache_stats["misses"] > 0
        # every scheduled item completed
        assert len(eng.order) == sum(len(v) for v in
                                     eng.space_results.values()) + 3


class TestGoldenTopology:
    """Engine-based discovery vs ground truth, same tolerances as the legacy
    path's test_discovery assertions (in-repo Table III)."""

    @pytest.fixture(scope="class")
    def h100(self):
        topo, _ = discover_sim(make_h100_like(seed=11), n_samples=17)
        return topo

    @pytest.fixture(scope="class")
    def mi210(self):
        topo, _ = discover_sim(make_mi210_like(seed=12), n_samples=17)
        return topo

    def test_h100_l1(self, h100):
        l1 = h100.find_memory("L1")
        assert abs(l1.get("size") - 238 * KIB) <= 2 * KIB
        assert abs(l1.get("load_latency") - 38.0) < 4.0
        assert l1.get("line_size") == 128
        assert l1.get("fetch_granularity") == 32
        assert l1.get("amount") == 1

    def test_h100_l2_and_device_memory(self, h100):
        l2 = h100.find_memory("L2")
        assert l2.get("amount") == 2
        assert abs(l2.get("segment_size") - 25 * MIB) <= MIB
        dm = h100.find_memory("DeviceMemory")
        assert abs(dm.get("load_latency") - 843) < 60

    def test_h100_unified_l1_sharing(self, h100):
        l1 = h100.find_memory("L1")
        assert set(l1.shared_with) >= {"Texture", "Readonly"}
        assert "L1" not in h100.find_memory("ConstL1").shared_with

    def test_mi210_levels_and_cu_sharing(self, mi210):
        vl1 = mi210.find_memory("vL1")
        assert abs(vl1.get("size") - 16 * KIB) <= KIB
        assert vl1.get("fetch_granularity") == 64
        sl1d = mi210.find_memory("sL1d")
        assert sl1d.get("exclusive_cus")
        assert any("," in g for g in sl1d.shared_with)
