"""Fault-tolerant discovery suite (chaos -> retry -> degrade -> resume).

Covers the resilience layer end to end against chaos-injected runners:

* scheduler-level transient retry with capped backoff, and graceful
  degradation past the budget (unfused and fused paths);
* engine-level degradation: a family past its retry budget lands as an
  ``"unknown"`` attribute with ``degraded`` provenance instead of
  aborting, and dependents keep working;
* the reliability headline: a discovery under a value-preserving
  transient fault schedule is ``topology_equivalent`` to the clean run;
* checkpoint/resume: an interrupted discovery resumes from the persisted
  sample-cache checkpoint with ZERO re-probed rows (exact miss
  arithmetic), including through a ``JobEngine`` retry;
* the statistical hardening knobs (MAD gating, confidence-driven
  resampling) and the promoted ``core.errors`` taxonomy.
"""
import threading

import numpy as np
import pytest

from repro.core import make_h100_like
from repro.core.discover import (DiscoveryRequest, discover, discover_sim,
                                 sim_request_descriptor)
from repro.core.engine.cache import CachingRunner, SampleCache
from repro.core.engine.fusion import FusionDispatcher, run_fused
from repro.core.engine.scheduler import WorkItem, run_work_items
from repro.core.engine.store import TopologyStore, request_key
from repro.core.errors import DegradedResult, Resilience, TransientRunnerError
from repro.core.probes import ChaosRunner, FaultSchedule, SimRunner
from repro.core.probes.size import ShiftClassifier, find_size
from repro.core.stats import mad_gate
from repro.core.topology import PROVENANCE_DEGRADED, topology_equivalent

KIB = 1024
DEVICE_FAMILIES = ("sharing", "device_memory_latency",
                   "device_memory_bandwidth")


def h100_runner():
    return SimRunner(make_h100_like(seed=3))


def no_sleep_resilience(**kw):
    kw.setdefault("max_retries", 3)
    return Resilience(sleep=lambda _s: None, **kw)


def make_request(make_runner, resilience, n_samples=9):
    dev = make_h100_like(seed=3)
    return DiscoveryRequest(
        descriptor=sim_request_descriptor(dev, n_samples, None,
                                          resilience=resilience),
        vendor=dev.vendor, model=dev.name,
        backend=f"simulated:{dev.name}",
        make_runner=make_runner, n_samples=n_samples,
        device_families=DEVICE_FAMILIES, resilience=resilience)


# --------------------------------------------------------------------------
# Scheduler-level retry / degradation (synthetic work items)
# --------------------------------------------------------------------------
class TestSchedulerRetry:
    def _flaky_item(self, fail_times, key="a"):
        """A work item that raises TransientRunnerError ``fail_times`` times
        before returning; counts its invocations."""
        calls = {"n": 0}

        def fn(_results):
            calls["n"] += 1
            if calls["n"] <= fail_times:
                raise TransientRunnerError(f"flake #{calls['n']}")
            return f"{key}-ok"

        return WorkItem(key=key, fn=fn), calls

    @pytest.mark.parametrize("max_workers", [0, 2])
    def test_transient_retried_to_success(self, max_workers):
        it, calls = self._flaky_item(2)
        res = run_work_items([it], max_workers=max_workers,
                             resilience=no_sleep_resilience())
        assert res.results["a"] == "a-ok"
        assert calls["n"] == 3
        assert res.retries == 2
        assert res.degraded == []

    def test_backoff_schedule_capped(self):
        sleeps = []
        policy = Resilience(max_retries=4, backoff_base_s=1.0,
                            backoff_cap_s=3.0, sleep=sleeps.append)
        it, _ = self._flaky_item(4)
        run_work_items([it], max_workers=0, resilience=policy)
        assert sleeps == [1.0, 2.0, 3.0, 3.0]   # doubling, then the cap

    def test_exhaustion_degrades_via_on_exhausted(self):
        it, calls = self._flaky_item(99)
        seen = []

        def on_exhausted(item, exc, attempts):
            seen.append((item.key, str(exc), attempts))
            return "degraded-stand-in"

        res = run_work_items([it], max_workers=0,
                             resilience=no_sleep_resilience(max_retries=2),
                             on_exhausted=on_exhausted)
        assert res.results["a"] == "degraded-stand-in"
        assert res.degraded == ["a"]
        assert calls["n"] == 3                   # 1 try + 2 retries
        assert seen == [("a", "flake #3", 3)]

    def test_exhaustion_without_degrade_raises(self):
        it, _ = self._flaky_item(99)
        with pytest.raises(TransientRunnerError):
            run_work_items(
                [it], max_workers=0,
                resilience=no_sleep_resilience(max_retries=1, degrade=False))

    def test_no_policy_means_no_retry(self):
        it, calls = self._flaky_item(1)
        with pytest.raises(TransientRunnerError):
            run_work_items([it], max_workers=0)
        assert calls["n"] == 1

    def test_non_transient_never_retried(self):
        calls = {"n": 0}

        def fn(_results):
            calls["n"] += 1
            raise ValueError("deterministic bug")

        with pytest.raises(ValueError):
            run_work_items([WorkItem(key="a", fn=fn)], max_workers=0,
                           resilience=no_sleep_resilience())
        assert calls["n"] == 1

    def test_on_item_done_fires_per_completed_item(self):
        done = []
        items = [WorkItem(key="a", fn=lambda _r: 1),
                 WorkItem(key="b", fn=lambda _r: 2, deps=("a",))]
        run_work_items(items, max_workers=0, on_item_done=done.append)
        assert done == ["a", "b"]


# --------------------------------------------------------------------------
# Fused-mode fault handling (split rounds + item restart)
# --------------------------------------------------------------------------
class TestFusedFaults:
    def _fused_pchase_items(self, dispatcher, sizes):
        proxy = dispatcher.proxy()
        return [
            WorkItem(key=f"p{i}",
                     fn=lambda _r, s=s: proxy.pchase("L1", s, 32, 9))
            for i, s in enumerate(sizes)
        ]

    def test_batch_fault_splits_round_per_row(self):
        """A fused dispatch that faults must be split into single-row
        retries — untouched items keep their results, nothing aborts."""
        sched = FaultSchedule(seed=2, permanent_kinds=("pchase_many",))
        cached = CachingRunner(ChaosRunner(h100_runner(), sched),
                               cache=SampleCache())
        dispatcher = FusionDispatcher(cached)
        sizes = [8 * KIB, 16 * KIB, 24 * KIB]
        out = run_fused(self._fused_pchase_items(dispatcher, sizes),
                        dispatcher)
        assert dispatcher.split_rounds >= 1
        base = h100_runner()
        for i, s in enumerate(sizes):
            assert np.array_equal(out.results[f"p{i}"],
                                  base.pchase("L1", s, 32, 9))

    def test_single_row_transient_restarts_item(self):
        """When the split fallback itself faults, the owning item restarts
        under the policy and converges once the fault budget is spent."""
        sched = FaultSchedule(seed=5, transient_rate=1.0,
                              batch_fault_rate=1.0,
                              max_faults_per_request=1)
        cached = CachingRunner(ChaosRunner(h100_runner(), sched),
                               cache=SampleCache())
        dispatcher = FusionDispatcher(cached)
        sizes = [8 * KIB, 16 * KIB]
        out = run_fused(self._fused_pchase_items(dispatcher, sizes),
                        dispatcher, resilience=no_sleep_resilience())
        assert out.retries >= 1
        assert dispatcher.split_rounds >= 1
        base = h100_runner()
        for i, s in enumerate(sizes):
            assert np.array_equal(out.results[f"p{i}"],
                                  base.pchase("L1", s, 32, 9))

    def test_fused_exhaustion_degrades(self):
        sched = FaultSchedule(seed=5, permanent_kinds=("pchase",
                                                       "pchase_many"))
        cached = CachingRunner(ChaosRunner(h100_runner(), sched),
                               cache=SampleCache())
        dispatcher = FusionDispatcher(cached)
        out = run_fused(
            self._fused_pchase_items(dispatcher, [8 * KIB]), dispatcher,
            resilience=no_sleep_resilience(max_retries=1),
            on_exhausted=lambda it, exc, attempts: ("degraded", attempts))
        assert out.degraded == ["p0"]
        assert out.results["p0"] == ("degraded", 2)


# --------------------------------------------------------------------------
# Discovery-level behavior (the acceptance criteria)
# --------------------------------------------------------------------------
class TestResilientDiscovery:
    @pytest.fixture(scope="class")
    def clean(self):
        return discover_sim(make_h100_like(seed=3), n_samples=9)

    def test_transient_faults_yield_equivalent_topology(self, clean):
        """The headline contract: under a value-preserving transient fault
        schedule, retries reproduce the clean topology exactly."""
        sched = FaultSchedule(seed=11, transient_rate=0.05,
                              max_faults_per_request=1)
        holder = {}

        def mk():
            holder["r"] = ChaosRunner(h100_runner(), sched)
            return holder["r"]

        topo, timings = discover(make_request(mk, no_sleep_resilience()))
        assert holder["r"].faults_injected > 0   # chaos actually fired
        assert topology_equivalent(clean[0], topo, rel_tol=1e-6)
        meta = timings.meta["resilience"]
        assert meta["retries"] >= holder["r"].faults_injected
        assert meta["degraded"] == []

    def test_permanent_fault_degrades_not_aborts(self, clean):
        sched = FaultSchedule(seed=7, permanent_kinds=("bandwidth",))
        topo, timings = discover(make_request(
            lambda: ChaosRunner(h100_runner(), sched),
            no_sleep_resilience(max_retries=1)))
        degraded = timings.meta["resilience"]["degraded"]
        assert "L2/bandwidth" in degraded
        l2 = topo.find_memory("L2")
        attr = l2.attrs["read_bw"]
        assert attr.value == "unknown"
        assert attr.provenance == PROVENANCE_DEGRADED
        assert attr.confidence == 0.0
        # unaffected families still measured normally
        assert l2.get("size") == clean[0].find_memory("L2").get("size")
        assert any("degraded after" in n for n in topo.notes)

    def test_degraded_breaks_equivalence(self, clean):
        """Degradation must be *visible*: a degraded topology is NOT
        equivalent to the clean one (provenance is part of the contract)."""
        sched = FaultSchedule(seed=7, permanent_kinds=("bandwidth",))
        topo, _ = discover(make_request(
            lambda: ChaosRunner(h100_runner(), sched),
            no_sleep_resilience(max_retries=1)))
        assert not topology_equivalent(clean[0], topo, rel_tol=1e-6)

    def test_without_policy_transients_propagate(self):
        sched = FaultSchedule(seed=11, transient_rate=1.0,
                              max_faults_per_request=10)
        with pytest.raises(TransientRunnerError):
            discover(make_request(
                lambda: ChaosRunner(h100_runner(), sched), None))


class TestCheckpointResume:
    def test_interrupt_then_resume_zero_recompute(self, tmp_path):
        """Kill a discovery mid-run; the rerun must (a) preload the
        checkpoint, (b) re-probe ZERO persisted rows (exact miss
        arithmetic), (c) produce the equivalent topology, (d) clear the
        spent checkpoint."""
        clean_topo, clean_t = discover_sim(make_h100_like(seed=3),
                                           n_samples=9)
        clean_misses = clean_t.meta["cache"]["misses"]

        store = TopologyStore(str(tmp_path / "store"))
        policy = no_sleep_resilience()
        holder = {}

        def mk_killed():
            holder["r"] = ChaosRunner(h100_runner(),
                                      FaultSchedule(seed=5, kill_after=40))
            return holder["r"]

        with pytest.raises(RuntimeError, match="chaos kill"):
            discover(make_request(mk_killed, policy), store=store)

        key = request_key(make_request(h100_runner, policy).descriptor)
        ckpt = store.load_checkpoint(key)
        assert ckpt is not None
        entries, families = ckpt
        assert entries and families

        resumed, t = discover(make_request(h100_runner, policy),
                              store=store)
        assert t.meta["resume"] == {"rows": len(entries),
                                    "families_done": len(families)}
        assert t.meta["cache"]["misses"] + len(entries) == clean_misses
        assert topology_equivalent(clean_topo, resumed, rel_tol=1e-6)
        assert not store.has_checkpoint(key)
        # the finished run persisted: a third call is a pure store hit
        _, t3 = discover(make_request(h100_runner, policy), store=store)
        assert "cache" not in t3.meta

    def test_checkpoint_is_per_request_key(self, tmp_path):
        """Different request descriptors never share a checkpoint."""
        store = TopologyStore(str(tmp_path / "store"))
        policy = no_sleep_resilience()

        def mk():
            return ChaosRunner(h100_runner(),
                               FaultSchedule(seed=5, kill_after=40))

        with pytest.raises(RuntimeError):
            discover(make_request(mk, policy), store=store)
        key9 = request_key(make_request(mk, policy).descriptor)
        key7 = request_key(make_request(mk, policy,
                                        n_samples=7).descriptor)
        assert store.has_checkpoint(key9)
        assert not store.has_checkpoint(key7)

    def test_job_engine_retry_resumes_from_checkpoint(self, tmp_path,
                                                      monkeypatch):
        """The serve path: attempt 1 dies on an escaped transient fault
        (engine retry disabled), the JobEngine's capped retry reruns the
        request, and attempt 2 resumes from the checkpoint — probing far
        fewer rows than attempt 1 did."""
        from repro.serve import jobs as jobs_module
        from repro.serve.jobs import JobEngine

        store = TopologyStore(str(tmp_path / "store"))
        # No engine-level retries: the first TransientRunnerError escapes
        # discover(), leaving the checkpoint for the job retry to consume.
        policy = Resilience(max_retries=0, degrade=False,
                            sleep=lambda _s: None)
        # ONE chaos runner across attempts: its per-request fault budget
        # makes attempt 1 fail and attempt 2's retry of the same request
        # succeed (faults are spent, not random).
        chaos = ChaosRunner(h100_runner(),
                            FaultSchedule(seed=23, transient_rate=0.02,
                                          max_faults_per_request=1))
        # dispatch count of a full, clean, storeless run — the work a
        # non-resuming retry would pay every time
        probe = ChaosRunner(h100_runner())
        discover(make_request(lambda: probe, policy))
        full_calls = probe.calls
        calls_per_attempt = []
        timings_seen = []

        def run():
            before = chaos.calls
            try:
                topo, timings = discover(make_request(lambda: chaos,
                                                      policy), store=store)
                timings_seen.append(timings)
                return topo, timings
            finally:
                calls_per_attempt.append(chaos.calls - before)

        request = make_request(lambda: chaos, policy)

        def fake_resolve(params, _store, parallel=None):
            return request.descriptor, request_key(request.descriptor), run

        monkeypatch.setattr(jobs_module, "resolve_discovery", fake_resolve)
        engine = JobEngine(store, workers=1, max_retries=2,
                           sleep=lambda _s: None).start()
        try:
            job, created = engine.submit({"backend": "sim",
                                          "device": "h100"})
            assert created
            engine.wait(job.job_id, timeout_s=120)
        finally:
            engine.stop()
        assert job.state == "done", job.error
        assert job.attempts >= 2            # >= one job-level retry happened
        assert chaos.faults_injected >= 1
        # resume did real work-saving: every attempt (failed early OR
        # resumed from the checkpoint) dispatched fewer probes than a full
        # from-scratch run would have
        assert len(calls_per_attempt) == job.attempts
        assert all(c < full_calls for c in calls_per_attempt)
        # ...and the successful attempt really did preload the checkpoint
        assert timings_seen[-1].meta["resume"]["rows"] > 0
        assert not store.has_checkpoint(job.key)
        assert store.get(job.key) is not None


# --------------------------------------------------------------------------
# Statistical hardening: MAD gating + confidence-driven resampling
# --------------------------------------------------------------------------
class TestStatisticalHardening:
    def test_mad_gate_drops_spike_keeps_body(self):
        rng = np.random.default_rng(0)
        body = rng.normal(100.0, 3.0, 64)
        spiked = np.concatenate([body, [800.0]])     # 8x throttle spike
        gated = mad_gate(spiked, k=5.0)
        assert gated.size == 64
        assert gated.max() < 800.0

    def test_mad_gate_no_ops(self):
        short = np.array([1.0, 2.0, 900.0])
        assert np.array_equal(mad_gate(short), short)      # too short
        const = np.full(16, 7.0)
        assert np.array_equal(mad_gate(const), const)      # zero MAD

    def test_classifier_default_unchanged_by_knobs_off(self):
        rng = np.random.default_rng(1)
        base = rng.normal(100.0, 3.0, 33)
        cur = rng.normal(160.0, 3.0, 33)
        assert ShiftClassifier(base, 0.01, 0.15).shifted(cur)
        assert not ShiftClassifier(base, 0.01, 0.15).shifted(
            rng.normal(100.0, 3.0, 33))

    def test_mad_gating_suppresses_outlier_false_shift(self):
        """A clean row contaminated with throttle spikes must NOT classify
        as shifted once MAD gating is on — and DOES without it (same data,
        same test), proving the gate is what saves the verdict."""
        rng = np.random.default_rng(2)
        base = rng.normal(100.0, 2.0, 96)
        cur = rng.normal(100.0, 2.0, 96)
        cur[:29] = 800.0                  # ~30% throttle-spike contamination
        assert ShiftClassifier(base, 0.01, 0.0).shifted(cur.copy())
        assert not ShiftClassifier(base, 0.01, 0.0,
                                   mad_k=5.0).shifted(cur.copy())

    def test_ambiguous_verdict_triggers_resample(self):
        rng = np.random.default_rng(3)
        base = rng.normal(100.0, 3.0, 33)
        clf = ShiftClassifier(base, 0.01, 0.15, resample_band=1.0)
        called = {"n": 0}

        def resample():
            called["n"] += 1
            return rng.normal(100.0, 3.0, 33)

        # band=1.0 makes EVERY verdict ambiguous -> resample always fires
        clf.shifted(rng.normal(100.0, 3.0, 33), resample=resample)
        assert called["n"] == 1

    def test_find_size_robust_matches_dense_on_clean_runner(self):
        """On a clean runner the hardened dense path must find the same
        boundary as the historical dense path (defaults bit-identical;
        knobs only matter under contamination)."""
        runner = h100_runner()
        plain = find_size(runner, "L1", n_samples=17)
        hard = find_size(runner, "L1", n_samples=17,
                         robust=Resilience(mad_k=5.0, resample_band=0.02,
                                           resample_extra=9))
        assert plain.found and hard.found
        assert hard.size == plain.size


# --------------------------------------------------------------------------
# The promoted error taxonomy
# --------------------------------------------------------------------------
class TestErrorTaxonomy:
    def test_transient_error_single_class(self):
        import repro.serve
        import repro.serve.jobs
        from repro import core

        assert (repro.serve.TransientRunnerError
                is repro.serve.jobs.TransientRunnerError
                is core.TransientRunnerError
                is TransientRunnerError)

    def test_resilience_descriptor_entry(self):
        assert Resilience().descriptor_entry() is None
        assert Resilience(max_retries=9).descriptor_entry() is None
        entry = Resilience(mad_k=5.0, resample_band=0.02,
                           resample_extra=9).descriptor_entry()
        assert entry == {"mad_k": 5.0, "resample_band": 0.02,
                         "resample_extra": 9}

    def test_statistical_knobs_key_the_descriptor(self):
        dev = make_h100_like(seed=3)
        base = sim_request_descriptor(dev, 9, None)
        retry_only = sim_request_descriptor(
            dev, 9, None, resilience=Resilience(max_retries=7))
        hardened = sim_request_descriptor(
            dev, 9, None, resilience=Resilience(mad_k=5.0))
        assert request_key(base) == request_key(retry_only)
        assert request_key(base) != request_key(hardened)

    def test_degraded_result_ducks_as_not_found(self):
        dr = DegradedResult(family="size", key="L1/size", error="boom",
                            attempts=3)
        assert dr.found is False

    def test_backoff_formula(self):
        r = Resilience(backoff_base_s=0.5, backoff_cap_s=2.0)
        assert [r.backoff(i) for i in range(4)] == [0.5, 1.0, 2.0, 2.0]
