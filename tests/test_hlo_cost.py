"""Trip-count-aware HLO cost model vs hand-computable modules."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.hlo_cost import analyze_hlo


def _hlo(f, *args):
    return jax.jit(f).lower(*args).compile().as_text()


class TestDotFlops:
    def test_single_matmul(self):
        txt = _hlo(lambda a, b: a @ b,
                   jnp.zeros((64, 128), jnp.float32),
                   jnp.zeros((128, 32), jnp.float32))
        c = analyze_hlo(txt)
        assert c.dot_flops == pytest.approx(2 * 64 * 128 * 32, rel=0.01)

    def test_scan_multiplies_by_trip_count(self):
        def f(x):
            return jax.lax.scan(lambda c, _: (c @ c, None), x, None,
                                length=5)[0]
        c = analyze_hlo(_hlo(f, jnp.zeros((128, 128), jnp.float32)))
        assert c.dot_flops == pytest.approx(5 * 2 * 128**3, rel=0.01)
        assert c.unknown_trip_loops == 0

    def test_nested_scans(self):
        def g(x):
            def inner(c, _):
                return c @ c, None

            def outer(c, _):
                return jax.lax.scan(inner, c, None, length=4)[0], None
            return jax.lax.scan(outer, x, None, length=3)[0]
        c = analyze_hlo(_hlo(g, jnp.zeros((64, 64), jnp.float32)))
        assert c.dot_flops == pytest.approx(12 * 2 * 64**3, rel=0.01)

    def test_bytes_scale_with_trips(self):
        def f(x):
            return jax.lax.scan(lambda c, _: (c @ c, None), x, None,
                                length=8)[0]
        c8 = analyze_hlo(_hlo(f, jnp.zeros((128, 128), jnp.float32)))

        def f2(x):
            return jax.lax.scan(lambda c, _: (c @ c, None), x, None,
                                length=16)[0]
        c16 = analyze_hlo(_hlo(f2, jnp.zeros((128, 128), jnp.float32)))
        assert c16.bytes_accessed > 1.5 * c8.bytes_accessed


class TestCollectivesWithTrips:
    def test_psum_inside_scan_counts_trips(self):
        import os
        if jax.device_count() < 2:
            pytest.skip("needs >1 device (covered by dryrun artifacts)")

    def test_artifact_consistency(self):
        """On full artifacts: dense-train dot flops within 3x of 6ND/chips
        (remat adds ~1.33x; embedding one-hot etc. add the rest)."""
        import glob
        import json
        import os
        files = glob.glob("artifacts/dryrun/single/internlm2-1.8b__train_4k.json")
        if not files:
            pytest.skip("artifacts not generated")
        d = json.load(open(files[0]))
        if "hlo_cost" not in d:
            pytest.skip("artifact predates hlo_cost")
        from repro.configs import get_config, shape_for
        mf = 6 * get_config("internlm2-1.8b").param_count() \
            * shape_for("train_4k").tokens
        total = d["hlo_cost"]["dot_flops"] * d["devices"]
        assert 0.5 < total / mf < 4.0, (total, mf)
