"""Live-hardware sanity checks: real measurements on this container's CPU.

These mirror the paper's §V 'universality' runs at miniature scale. They are
tolerant by design — CI machines have noisy caches — but they do assert the
physically necessary ordering (DRAM slower than cache, bandwidth positive).
"""
import numpy as np
import pytest

from repro.core.probes import HostRunner, measure_collective
from repro.core.probes.bandwidth import (all_to_all_time, ring_all_gather_time,
                                         ring_all_reduce_time)

MIB = 1024**2


@pytest.fixture(scope="module")
def runner():
    return HostRunner(max_bytes=64 * MIB, iters=1 << 13)


class TestHostPChase:
    def test_small_vs_large_latency_ordering(self, runner):
        # Best-case chase step over 64 MiB must be slower than over 16 KiB.
        # Min, not median: on shared CI hosts a steal-time spike can inflate
        # the small-array samples; the minimum is the uncontended estimate.
        # Virtualized hosts additionally show multi-second slow modes that
        # inflate the small-array chase past the DRAM one for a whole round,
        # so the ordering only needs to be *observable*: pass as soon as any
        # of a few independent rounds shows it, fail only if none does.
        ratios = []
        for _ in range(5):
            small = runner.pchase("host-cache", 16 * 1024, 64, 7)  # L1/L2
            large = runner.pchase("host-cache", 64 * MIB, 64, 7)   # DRAM
            ratios.append(np.min(large) / np.min(small))
            if ratios[-1] > 1.2:
                return
        raise AssertionError(
            f"DRAM chase never slower than cache chase: ratios {ratios}")

    def test_samples_positive_and_finite(self, runner):
        lats = runner.pchase("host-cache", 1 * MIB, 64, 7)
        assert lats.shape == (7,)
        assert np.all(np.isfinite(lats)) and np.all(lats > 0)

    def test_bandwidth_positive(self, runner):
        bw = runner.bandwidth("DRAM", "read", nbytes=32 * MIB, repeats=2)
        assert bw > 1e8  # >0.1 GB/s — any real machine clears this


class TestCollectiveModels:
    def test_ring_all_reduce_formula(self):
        # 2(n-1)/n * bytes / bw
        assert ring_all_reduce_time(100e6, 4, 50e9) == pytest.approx(
            2 * 3 / 4 * 100e6 / 50e9)
        assert ring_all_reduce_time(100e6, 1, 50e9) == 0.0

    def test_all_gather_and_a2a(self):
        assert ring_all_gather_time(1e6, 8, 50e9) == pytest.approx(7e6 / 50e9)
        assert all_to_all_time(8e6, 8, 50e9) == pytest.approx(7e6 / 50e9)

    def test_measure_collective_fallback(self):
        # Single-device container -> analytic path with documented provenance.
        est = measure_collective("all_reduce", 64 * MIB, 16, 50e9)
        expect = ring_all_reduce_time(64 * MIB, 16, 50e9)
        assert est.seconds == pytest.approx(expect)
        assert est.effective_bw > 0
