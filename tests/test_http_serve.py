"""Live-server tests for the HTTP topology front end (ISSUE 6 tentpole).

Every test runs against a real ``TopologyHTTPServer`` bound to an ephemeral
loopback port: endpoint contracts, the structured error mapping
(400/404/405/411/413/503), traffic hardening, graceful-shutdown draining,
and the acceptance end-to-end — concurrent multi-threaded traffic over
every endpoint followed by a ``refresh=True`` rewrite that must be served
fresh (no stale LRU read) with zero 5xx responses.
"""
import http.client
import json
import threading
import time

import pytest

from repro.core import discover_sim, make_h100_like, make_mi210_like
from repro.core.engine.store import TopologyStore
from repro.serve import (TopologyClient, TopologyHTTPError,
                         TopologyHTTPServer)

KIB = 1024


@pytest.fixture(scope="module")
def store(tmp_path_factory):
    store = TopologyStore(str(tmp_path_factory.mktemp("http") / "store"))
    discover_sim(make_h100_like(seed=81), n_samples=9, store=store)
    discover_sim(make_mi210_like(seed=82), n_samples=9, store=store)
    return store


@pytest.fixture(scope="module")
def server(store):
    with TopologyHTTPServer(store) as srv:
        yield srv


@pytest.fixture
def client(server):
    return TopologyClient(server.url)


def _key_of(store, model):
    return next(k for k, meta in store.index() if meta["model"] == model)


def _raw_request(server, method, path, body=None, headers=None):
    """(status, headers, parsed-or-raw body) via a bare http.client."""
    host, port = server.address
    conn = http.client.HTTPConnection(host, port, timeout=10)
    try:
        conn.request(method, path, body=body, headers=headers or {})
        resp = conn.getresponse()
        raw = resp.read()
        try:
            payload = json.loads(raw)
        except (json.JSONDecodeError, UnicodeDecodeError):
            payload = raw
        return resp.status, dict(resp.getheaders()), payload
    finally:
        conn.close()


class TestEndpoints:
    def test_healthz(self, client):
        h = client.healthz()
        assert h["status"] == "ok"
        assert h["entries"] == 2
        assert h["draining"] is False

    def test_topologies_lists_keys_and_meta(self, client, store):
        tops = client.topologies()
        assert {t["key"] for t in tops} == set(store.keys())
        assert {t["meta"]["model"] for t in tops} == {"sim-h100", "sim-mi210"}

    def test_full_topology_document(self, client, store):
        k = _key_of(store, "sim-h100")
        doc = client.topology(k)
        assert doc["key"] == k
        assert doc["topology"] == store.get(k).topology.to_json()

    def test_query_value_and_aliases(self, client, store):
        k = _key_of(store, "sim-h100")
        q = client.query(k, "L1.size")
        assert q["found"] and q["element"] == "L1" and q["unit"] == "B"
        assert abs(q["value"] - 238 * KIB) <= 4 * KIB
        assert q["provenance"] == "benchmark"
        # aliases resolve over HTTP exactly as in-process
        assert client.query(k, "hbm.bandwidth")["element"] == "DeviceMemory"
        assert client.query(k, "general.clock_domain")["value"] == "cycles"

    def test_unresolvable_path_is_found_false_not_an_error(self, client,
                                                           store):
        q = client.query(_key_of(store, "sim-h100"), "L1.no_such_attr")
        assert q["found"] is False

    def test_query_batch_alignment_and_misses(self, client, store):
        k1, k2 = _key_of(store, "sim-h100"), _key_of(store, "sim-mi210")
        pairs = [(k1, "L2.load_latency"), (k2, "vL1.size"),
                 (k1, "nope.nope"), ("unknown-key", "L1.size")]
        results = client.query_batch(pairs)
        assert len(results) == len(pairs)
        assert [r["found"] for r in results] == [True, True, False, False]
        for (k, p), r in zip(pairs, results):
            assert (r["key"], r["path"]) == (k, p)

    def test_attribute_filters(self, client, store):
        k = _key_of(store, "sim-h100")
        api = client.attributes(k, provenance="api")
        assert api and all(a["provenance"] == "api" for a in api)
        confident = client.attributes(k, min_confidence=0.9)
        assert confident
        assert all(a["confidence"] >= 0.9 for a in confident)

    def test_adjacency(self, client, store):
        adj = client.adjacency(_key_of(store, "sim-h100"))
        assert set(adj["L1"]) >= {"Texture", "Readonly"}

    def test_diff(self, client, store):
        d = client.diff(_key_of(store, "sim-h100"),
                        _key_of(store, "sim-mi210"))
        assert d["identical"] is False
        assert "L1" in d["only_in_a"] and "vL1" in d["only_in_b"]
        assert any(c["element"] == "L2" and c["attr"] == "load_latency"
                   for c in d["changed"])

    def test_metrics_shape(self, client, store):
        client.query(_key_of(store, "sim-h100"), "L1.size")
        m = client.metrics()
        assert m["service"]["lru_hits"] + m["service"]["lru_misses"] > 0
        ep = m["endpoints"]["/topologies/{key}/query"]
        assert ep["requests"] >= 1
        assert sum(ep["latency_buckets_us"]) == ep["requests"]
        assert len(ep["latency_buckets_us"]) == \
            len(m["latency_bucket_edges_us"]) + 1
        assert m["statuses"].get("2xx", 0) >= 1


class TestErrorMapping:
    def test_missing_path_param_400(self, client, store):
        with pytest.raises(TopologyHTTPError) as e:
            client.query(_key_of(store, "sim-h100"), "")
        assert e.value.status == 400

    def test_unknown_key_404(self, client):
        with pytest.raises(TopologyHTTPError) as e:
            client.query("no-such-key", "L1.size")
        assert e.value.status == 404
        assert "unknown topology key" in e.value.payload["error"]

    def test_unknown_endpoint_404(self, server):
        status, _, payload = _raw_request(server, "GET", "/no/such/route")
        assert status == 404 and "no such endpoint" in payload["error"]

    def test_wrong_method_405(self, server):
        status, _, _ = _raw_request(server, "GET", "/query_batch")
        assert status == 405
        status, _, _ = _raw_request(server, "POST", "/healthz")
        assert status == 405

    def test_malformed_json_400(self, server):
        status, _, payload = _raw_request(
            server, "POST", "/query_batch", body=b"{not json",
            headers={"Content-Length": "9"})
        assert status == 400 and "malformed JSON" in payload["error"]

    def test_bad_batch_shape_400(self, client):
        with pytest.raises(TopologyHTTPError) as e:
            client._request("/query_batch", body={"requests": [["only-key"]]})
        assert e.value.status == 400

    def test_non_numeric_min_confidence_400(self, client, store):
        with pytest.raises(TopologyHTTPError) as e:
            client.attributes(_key_of(store, "sim-h100"),
                              min_confidence="high")
        assert e.value.status == 400

    def test_diff_missing_params_400(self, client):
        with pytest.raises(TopologyHTTPError) as e:
            client._request("/diff", params={"a": "only-one"})
        assert e.value.status == 400

    def test_oversized_body_413(self, store, tmp_path):
        entry = store.get(store.keys()[0])
        small_store = TopologyStore(str(tmp_path / "small"))
        small_store.put("k", entry.topology)
        with TopologyHTTPServer(small_store, max_body_bytes=2048) as srv:
            client = TopologyClient(srv.url)
            with pytest.raises(TopologyHTTPError) as e:
                client.query_batch([("k", "L1.size")] * 300)
            assert e.value.status == 413
            # the server stays healthy after refusing the body
            assert client.healthz()["status"] == "ok"

    def test_quarantined_entry_503_with_retry_hint(self, store, tmp_path):
        entry = store.get(store.keys()[0])
        qstore = TopologyStore(str(tmp_path / "quarantine"))
        qstore.put("qkey", entry.topology)
        with TopologyHTTPServer(qstore, retry_after_s=7) as srv:
            client = TopologyClient(srv.url)
            assert client.query("qkey", "L1.size")["found"]
            with open(qstore._topo_path("qkey"), "w") as f:
                f.write("{corrupt garbage")
            # first read quarantines the damaged file...
            with pytest.raises(TopologyHTTPError) as e:
                client.query("qkey", "L1.size")
            assert e.value.status == 503
            assert e.value.retry_after_s == 7
            assert "quarantined" in e.value.payload["error"]
            # ...and the key keeps answering 503 (retry-later), not 404
            with pytest.raises(TopologyHTTPError) as e:
                client.query("qkey", "L1.size")
            assert e.value.status == 503
            # re-discovery repopulates: back to 200
            qstore.put("qkey", entry.topology)
            assert client.query("qkey", "L1.size")["found"]


class TestConcurrentServing:
    """The ISSUE 6 acceptance end-to-end: >=8 threads over every endpoint,
    then a refresh of one topology that must be served fresh, with zero
    5xx anywhere."""

    N_THREADS = 8
    REQS_PER_THREAD = 25

    def test_concurrent_traffic_then_refresh_no_stale_reads(self, tmp_path):
        store = TopologyStore(str(tmp_path / "e2e"))
        discover_sim(make_h100_like(seed=83), n_samples=9, store=store)
        discover_sim(make_mi210_like(seed=84), n_samples=9, store=store)
        k1, k2 = (_key_of(store, "sim-h100"), _key_of(store, "sim-mi210"))

        with TopologyHTTPServer(store, hot_set=4) as server:
            client = TopologyClient(server.url)
            errors: list[Exception] = []

            def workload(tid: int) -> None:
                c = TopologyClient(server.url)
                for i in range(self.REQS_PER_THREAD):
                    try:
                        c.healthz()
                        c.topologies()
                        assert c.query(k1, "L1.size")["found"]
                        assert c.query(k2, "vL1.size")["found"]
                        batch = c.query_batch(
                            [(k1, "L2.load_latency"), (k2, "hbm.bandwidth"),
                             (k1, "general.clock_domain")] * 4)
                        assert all(r["found"] for r in batch)
                        assert c.attributes(k1, provenance="benchmark")
                        assert c.adjacency(k1)
                        assert c.diff(k1, k2)["identical"] is False
                        c.metrics()
                    except Exception as e:   # noqa: BLE001 — collected
                        errors.append(e)

            threads = [threading.Thread(target=workload, args=(i,))
                       for i in range(self.N_THREADS)]
            for t in threads:
                t.start()
            for t in threads:
                t.join(timeout=300)
            assert not errors, f"concurrent traffic failed: {errors[:3]}"

            # Service counters survived the hammer coherently.
            svc = server.service.stats()
            assert svc["lru_hits"] + svc["lru_misses"] >= \
                self.N_THREADS * self.REQS_PER_THREAD

            # -- refresh one topology under the live server (same request,
            # so the re-measured values match; the service must RELOAD, not
            # serve the hot cached object of the dead generation).
            before = client.metrics()["service"]["lru_misses"]
            v_before = client.query(k1, "L1.size")["value"]
            discover_sim(make_h100_like(seed=83), n_samples=9, store=store,
                         refresh=True)
            v_after = client.query(k1, "L1.size")["value"]
            assert v_after == v_before
            assert client.metrics()["service"]["lru_misses"] > before

            # -- a divergent rewrite (what a new driver/firmware run looks
            # like) must be visible immediately: no stale LRU read.
            entry = store.get(k1)
            entry.topology.find_memory("L1").set(
                "load_latency", 4242.5, "cyc", "benchmark")
            store.put(k1, entry.topology, meta=entry.meta)
            assert client.query(k1, "L1.load_latency")["value"] == 4242.5

            # Zero 5xx across everything this server handled.
            statuses = client.metrics()["statuses"]
            assert statuses.get("5xx", 0) == 0
            assert statuses.get("2xx", 0) > 0


class TestGracefulShutdown:
    def test_stop_drains_in_flight_requests(self, store):
        release = threading.Event()

        def slow_hook(method, path):
            if path == "/healthz":
                release.wait(timeout=10)

        server = TopologyHTTPServer(store, on_request=slow_hook)
        server.start()
        result: dict = {}

        def request():
            result["health"] = TopologyClient(server.url).healthz()

        t = threading.Thread(target=request)
        t.start()
        time.sleep(0.2)                    # request is now in-flight, parked

        stopper = threading.Thread(target=server.stop)
        stopper.start()
        time.sleep(0.2)
        assert stopper.is_alive()          # stop() is draining, not killing
        release.set()
        stopper.join(timeout=10)
        assert not stopper.is_alive()
        t.join(timeout=10)
        # the in-flight request completed normally during the drain
        assert result["health"]["status"] == "ok"

    def test_stopped_server_refuses_connections(self, store):
        server = TopologyHTTPServer(store).start()
        url = server.url
        assert TopologyClient(url).healthz()["status"] == "ok"
        server.stop()
        with pytest.raises(OSError):
            TopologyClient(url, timeout_s=2).healthz()
