"""Unit tests for the server-side discovery job engine (``serve/jobs.py``).

Covers the wire-format validation (``resolve_discovery``), the job state
machine, content-addressed idempotency (attach-while-in-flight, store-hit
after completion), capped retry with exponential backoff (sleeps recorded
via the injectable ``sleep``), fail-fast on non-transient errors, per-job
timeouts, cancellation, the bounded queue, history trimming, and the
metrics snapshot.  Everything runs in-process against simulated devices —
no HTTP (see ``test_remote_discovery.py`` for the live-server paths).
"""
import threading

import pytest

from repro.core import discover_sim, make_h100_like
from repro.core.engine.store import TopologyStore, request_key
from repro.serve.jobs import (JOB_LATENCY_BUCKETS_S, JobEngine,
                              QueueFullError, TransientRunnerError,
                              resolve_discovery)

SIM_H100 = {"backend": "sim", "device": "h100", "seed": 71, "n_samples": 9}


@pytest.fixture
def store(tmp_path):
    return TopologyStore(str(tmp_path / "store"))


def make_engine(store, **kw):
    """Engine with fast, recorded backoff; caller must ``stop()`` (or never
    ``start()``)."""
    kw.setdefault("workers", 1)
    kw.setdefault("backoff_base_s", 0.01)
    return JobEngine(store, **kw)


class TestResolveDiscovery:
    def test_key_matches_store_key_after_run(self, store):
        descriptor, key, run = resolve_discovery(SIM_H100, store)
        assert key == request_key(descriptor)
        topo, timings = run()
        assert store.has(key)               # job key == store write key
        assert topo.model == "sim-h100"

    def test_device_alias_and_canonical_name_share_a_key(self, store):
        _, key_alias, _ = resolve_discovery(SIM_H100, store)
        _, key_full, _ = resolve_discovery({**SIM_H100, "device": "sim-h100"},
                                           store)
        assert key_alias == key_full

    @pytest.mark.parametrize("params, fragment", [
        ("not-a-dict", "JSON object"),
        ({"backend": "cuda"}, "unknown backend"),
        ({"backend": "sim", "device": "rtx5090"}, "unknown simulated device"),
        ({"backend": "sim", "device": "h100", "max_bytes": 1}, "unknown field"),
        ({"backend": "sim", "device": "h100", "n_samples": 0}, "n_samples"),
        ({"backend": "sim", "device": "h100", "elements": []}, "elements"),
        ({"backend": "sim", "device": "h100", "budget": {"max_probes": 5}},
         "unknown budget field"),
        ({"backend": "sim", "device": "h100", "gc_policy": {"ttl": 5}},
         "unknown gc_policy field"),
    ])
    def test_malformed_requests_raise_value_error(self, store, params,
                                                  fragment):
        with pytest.raises(ValueError, match=fragment):
            resolve_discovery(params, store)

    def test_budget_accepts_default_and_kwargs(self, store):
        _, key_none, _ = resolve_discovery(SIM_H100, store)
        _, key_dflt, _ = resolve_discovery({**SIM_H100, "budget": "default"},
                                           store)
        _, key_cfg, _ = resolve_discovery(
            {**SIM_H100, "budget": {"max_rounds": 3}}, store)
        # budgets are part of the content address
        assert len({key_none, key_dflt, key_cfg}) == 3


class TestLifecycleAndIdempotency:
    def test_submit_runs_to_done_and_writes_through(self, store):
        engine = make_engine(store).start()
        try:
            job, created = engine.submit(SIM_H100)
            assert created and job.state in ("queued", "running")
            job = engine.wait(job.job_id, timeout_s=60)
            assert job.state == "done" and job.terminal
            assert job.attempts == 1
            assert job.started_at >= job.created_at
            assert job.finished_at >= job.started_at
            assert job.result["model"] == "sim-h100"
            assert job.result["store_hit"] is False
            assert job.result["probe_rows"] > 0
            assert store.has(job.key)
        finally:
            engine.stop()

    def test_duplicate_submission_attaches_to_in_flight_job(self, store):
        engine = make_engine(store)          # never started: stays queued
        job_a, created_a = engine.submit(SIM_H100)
        job_b, created_b = engine.submit(dict(SIM_H100))
        assert created_a and not created_b
        assert job_b is job_a                # same job, not a second run
        assert engine.metrics.counters["deduplicated"] == 1
        # a *different* request gets its own job
        job_c, created_c = engine.submit({**SIM_H100, "seed": 72})
        assert created_c and job_c is not job_a

    def test_resubmit_after_done_is_a_store_hit_with_zero_probes(self, store):
        engine = make_engine(store).start()
        try:
            first = engine.wait(engine.submit(SIM_H100)[0].job_id,
                                timeout_s=60)
            assert first.result["store_hit"] is False
            second_job, created = engine.submit(SIM_H100)
            assert created                   # prior job is terminal
            second = engine.wait(second_job.job_id, timeout_s=60)
            assert second.result["store_hit"] is True
            assert second.job_id != first.job_id
            assert second.key == first.key
        finally:
            engine.stop()

    def test_job_to_json_wire_shape(self, store):
        engine = make_engine(store)
        job, _ = engine.submit(SIM_H100)
        doc = job.to_json()
        assert doc["job_id"] == job.job_id
        assert doc["state"] == "queued"
        assert doc["params"] == SIM_H100
        assert doc["backend"] == "sim"
        assert doc["result"] is None and doc["error"] is None


class TestRetryAndFailure:
    def test_transient_errors_retry_with_exponential_backoff(self, store):
        sleeps = []
        fails = {"left": 2}

        def flaky(job, attempt):
            if fails["left"] > 0:
                fails["left"] -= 1
                raise TransientRunnerError("injected blip")

        engine = make_engine(store, on_attempt=flaky, max_retries=2,
                             backoff_base_s=0.01, backoff_cap_s=10.0,
                             sleep=sleeps.append).start()
        try:
            job = engine.wait(engine.submit(SIM_H100)[0].job_id,
                              timeout_s=60)
            assert job.state == "done"
            assert job.attempts == 3
            assert sleeps == [0.01, 0.02]    # base * 2**attempt
            assert engine.metrics.counters["retries"] == 2
        finally:
            engine.stop()

    def test_backoff_is_capped(self, store):
        sleeps = []

        def flaky(job, attempt):
            if attempt < 2:
                raise TransientRunnerError("blip")

        engine = make_engine(store, on_attempt=flaky, max_retries=2,
                             backoff_base_s=1.0, backoff_cap_s=1.5,
                             sleep=sleeps.append).start()
        try:
            engine.wait(engine.submit(SIM_H100)[0].job_id, timeout_s=60)
            assert sleeps == [1.0, 1.5]      # second sleep hit the cap
        finally:
            engine.stop()

    def test_exhausted_retries_fail_with_attempt_count(self, store):
        def always(job, attempt):
            raise TransientRunnerError("persistent fault")

        engine = make_engine(store, on_attempt=always, max_retries=2,
                             sleep=lambda s: None).start()
        try:
            job = engine.wait(engine.submit(SIM_H100)[0].job_id,
                              timeout_s=60)
            assert job.state == "failed"
            assert job.attempts == 3
            assert "3 attempts" in job.error
            assert "persistent fault" in job.error
            assert engine.metrics.counters["failed"] == 1
        finally:
            engine.stop()

    def test_non_transient_errors_fail_fast_without_retry(self, store):
        def boom(job, attempt):
            raise ValueError("deterministic bug")

        engine = make_engine(store, on_attempt=boom, max_retries=5).start()
        try:
            job = engine.wait(engine.submit(SIM_H100)[0].job_id,
                              timeout_s=60)
            assert job.state == "failed"
            assert job.attempts == 1         # no retry on deterministic bugs
            assert "ValueError: deterministic bug" in job.error
            assert engine.metrics.counters["retries"] == 0
        finally:
            engine.stop()

    def test_job_timeout_marks_failed_and_counts(self, store):
        release = threading.Event()
        engine = make_engine(store, default_timeout_s=0.05, max_retries=0)
        job, _ = engine.submit(SIM_H100)
        # swap the run thunk for one that overruns the timeout, then start
        engine._runs[job.job_id] = lambda: release.wait(10)
        engine.start()
        try:
            job = engine.wait(job.job_id, timeout_s=30)
            assert job.state == "failed"
            assert "timeout" in job.error
            assert engine.metrics.counters["timeouts"] == 1
        finally:
            release.set()                    # let the abandoned thread exit
            engine.stop()


class TestCancellationAndBounds:
    def test_cancel_queued_job_is_immediate(self, store):
        engine = make_engine(store)          # not started: job stays queued
        job, _ = engine.submit(SIM_H100)
        engine.cancel(job.job_id)
        assert job.state == "cancelled"
        assert job.done_event.is_set()
        # idempotent: a second cancel leaves the terminal state alone
        engine.cancel(job.job_id)
        assert job.state == "cancelled"
        # the key is free again — a resubmission creates a fresh job
        job2, created = engine.submit(SIM_H100)
        assert created and job2.job_id != job.job_id

    def test_cancel_between_retry_attempts(self, store):
        started = threading.Event()
        cancelled = threading.Event()

        def flaky(job, attempt):
            started.set()
            raise TransientRunnerError("blip")

        # the backoff sleep parks until the cancel below has landed, so the
        # worker deterministically observes it at the top of the next attempt
        engine = make_engine(store, on_attempt=flaky, max_retries=50,
                             sleep=lambda s: cancelled.wait(10)).start()
        try:
            job, _ = engine.submit(SIM_H100)
            assert started.wait(10)
            engine.cancel(job.job_id)
            cancelled.set()
            job = engine.wait(job.job_id, timeout_s=30)
            assert job.state == "cancelled"
            assert "cancelled before attempt" in job.error
        finally:
            engine.stop()

    def test_unknown_job_raises_key_error(self, store):
        engine = make_engine(store)
        with pytest.raises(KeyError):
            engine.cancel("nope")
        with pytest.raises(KeyError):
            engine.wait("nope", timeout_s=0.1)

    def test_bounded_queue_rejects_overflow(self, store):
        engine = make_engine(store, max_queue=1)     # not started
        engine.submit(SIM_H100)
        with pytest.raises(QueueFullError):
            engine.submit({**SIM_H100, "seed": 99})
        assert engine.metrics.counters["rejected"] == 1
        # duplicates still attach even when the queue is full
        _, created = engine.submit(SIM_H100)
        assert not created

    def test_stop_cancels_queued_jobs(self, store):
        engine = make_engine(store)          # never started
        job, _ = engine.submit(SIM_H100)
        engine.stop()
        assert job.state == "cancelled"
        assert "engine stopped" in job.error

    def test_history_trims_oldest_terminal_jobs(self, store):
        engine = make_engine(store, max_history=2).start()
        try:
            ids = []
            for seed in (1, 2, 3, 4):
                job, _ = engine.submit({**SIM_H100, "seed": seed})
                engine.wait(job.job_id, timeout_s=60)
                ids.append(job.job_id)
            known = [j.job_id for j in engine.jobs()]
            assert len(known) <= 3           # trimmed at submit time
            assert ids[-1] in known          # newest survives
            assert ids[0] not in known       # oldest terminal evicted
        finally:
            engine.stop()


class TestMetrics:
    def test_stats_snapshot_shape_and_histogram(self, store):
        engine = make_engine(store).start()
        try:
            engine.wait(engine.submit(SIM_H100)[0].job_id, timeout_s=60)
        finally:
            engine.stop()
        stats = engine.stats()
        assert stats["submitted"] == 1 and stats["done"] == 1
        assert stats["workers"] == 1
        assert stats["states"] == {"done": 1}
        assert stats["duration_bucket_edges_s"] == list(JOB_LATENCY_BUCKETS_S)
        assert sum(stats["duration_buckets"]) == 1
        assert stats["duration_sum_s"] > 0

    def test_result_matches_direct_discovery(self, store):
        """The topology a job persists is bit-identical to a direct
        ``discover_sim`` of the same request (content address equality)."""
        engine = make_engine(store).start()
        try:
            job = engine.wait(engine.submit(SIM_H100)[0].job_id,
                              timeout_s=60)
        finally:
            engine.stop()
        direct_store = TopologyStore(str(store.root) + "-direct")
        topo, _ = discover_sim(make_h100_like(seed=71), n_samples=9,
                               store=direct_store)
        assert direct_store.keys() == [job.key]

        def comparable(s):
            # drop the free-text notes: they embed wall-clock timings
            return {k: v for k, v in s.get(job.key).topology.to_json().items()
                    if k != "notes"}

        assert comparable(direct_store) == comparable(store)
