"""Per-kernel validation: shape/dtype sweeps, interpret=True vs ref oracles
(assignment deliverable (c): assert_allclose against the pure-jnp ref)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

# Pallas interpret-mode kernel sweeps: jit-heavy.
# Deselected by `make test-fast`.
pytestmark = pytest.mark.slow
from _hypothesis_compat import given, settings, st

from repro.core.probes.runners import sattolo_cycle
from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(7)


def _rand(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


# ------------------------------------------------------------ flash attn
SWEEP = [
    # (b, hq, hkv, sq, sk, d, bq, bk, causal, dtype, tol)
    (1, 2, 2, 128, 128, 64, 64, 64, True, jnp.float32, 2e-5),
    (2, 4, 1, 256, 256, 64, 128, 128, True, jnp.float32, 2e-5),
    (1, 8, 2, 256, 256, 128, 128, 64, True, jnp.float32, 2e-5),
    (1, 4, 4, 256, 512, 128, 64, 128, False, jnp.float32, 2e-5),
    (2, 2, 1, 128, 128, 64, 64, 64, True, jnp.bfloat16, 3e-2),
    (1, 4, 2, 256, 256, 64, 128, 128, False, jnp.bfloat16, 3e-2),
]


@pytest.mark.parametrize("b,hq,hkv,sq,sk,d,bq,bk,causal,dtype,tol", SWEEP)
def test_flash_attention_sweep(b, hq, hkv, sq, sk, d, bq, bk, causal, dtype,
                               tol):
    ks = jax.random.split(KEY, 3)
    q = _rand(ks[0], (b, hq, sq, d), dtype)
    k = _rand(ks[1], (b, hkv, sk, d), dtype)
    v = _rand(ks[2], (b, hkv, sk, d), dtype)
    from repro.kernels.flash_attention import flash_attention
    out = flash_attention(q, k, v, causal=causal, block_q=bq, block_k=bk)
    want = ref.attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_flash_attention_model_layout_wrapper():
    ks = jax.random.split(KEY, 3)
    q = _rand(ks[0], (2, 128, 4, 64), jnp.float32)   # (B, S, H, d)
    k = _rand(ks[1], (2, 128, 2, 64), jnp.float32)
    v = _rand(ks[2], (2, 128, 2, 64), jnp.float32)
    out = ops.mha(q, k, v, block_q=64, block_k=64)
    want = jnp.swapaxes(ref.attention_ref(
        jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2), jnp.swapaxes(v, 1, 2)),
        1, 2)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


# ------------------------------------------------------------------ wkv6
@pytest.mark.parametrize("b,t,h,k,v,chunk,dtype,tol", [
    (1, 64, 1, 8, 8, 16, jnp.float32, 1e-4),
    (2, 64, 2, 16, 16, 32, jnp.float32, 1e-4),
    (1, 128, 3, 32, 32, 32, jnp.float32, 1e-4),
    (2, 64, 2, 8, 8, 16, jnp.bfloat16, 5e-2),
])
def test_wkv6_kernel_sweep(b, t, h, k, v, chunk, dtype, tol):
    ks = jax.random.split(KEY, 5)
    r = _rand(ks[0], (b, t, h, k), dtype)
    kk = _rand(ks[1], (b, t, h, k), dtype)
    vv = _rand(ks[2], (b, t, h, v), dtype)
    w = jax.random.uniform(ks[3], (b, t, h, k), jnp.float32, 0.05, 0.98
                           ).astype(dtype)
    u = _rand(ks[4], (h, k), dtype)
    y, s = ops.wkv6(r, kk, vv, w, u, chunk=chunk)
    y_ref, s_ref = ref.wkv6_ref(r, kk, vv, w, u)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref),
                               rtol=tol, atol=tol)


def test_wkv6_kernel_matches_model_chunked():
    """Kernel == models.rwkv6.wkv_chunked (the XLA path it replaces)."""
    from repro.models.rwkv6 import wkv_chunked
    ks = jax.random.split(KEY, 5)
    b, t, h, k = 2, 64, 2, 16
    r = _rand(ks[0], (b, t, h, k), jnp.float32)
    kk = _rand(ks[1], (b, t, h, k), jnp.float32)
    vv = _rand(ks[2], (b, t, h, k), jnp.float32)
    w = jax.random.uniform(ks[3], (b, t, h, k), jnp.float32, 0.05, 0.98)
    u = _rand(ks[4], (h, k), jnp.float32)
    y1, s1 = ops.wkv6(r, kk, vv, w, u, chunk=16)
    y2, s2 = wkv_chunked(r, kk, vv, w, u,
                         jnp.zeros((b, h, k, k), jnp.float32), chunk=16)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=1e-4)


# ----------------------------------------------------------- probes
@pytest.mark.parametrize("n,block,dtype", [
    (1 << 14, 1 << 12, jnp.float32),
    (1 << 16, 1 << 14, jnp.bfloat16),
    (1 << 15, 1 << 15, jnp.int32),
])
def test_stream_read_kernel(n, block, dtype):
    x = (jnp.arange(n) % 97).astype(dtype)
    got = ops.stream_read(x, block=block)
    want = ref.stream_read_ref(x, block)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


@pytest.mark.parametrize("n,block", [(1 << 14, 1 << 12), (1 << 15, 1 << 13)])
def test_stream_write_kernel(n, block):
    x = jnp.arange(n, dtype=jnp.float32)
    got = ops.stream_write(x, block=block)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(ref.stream_write_ref(x)))


@given(n=st.sampled_from([64, 256, 1024]), iters=st.integers(1, 2000),
       seed=st.integers(0, 99))
@settings(max_examples=12, deadline=None)
def test_pchase_kernel_property(n, iters, seed):
    """Kernel chase must agree with the python oracle for any cycle/iters."""
    rng = np.random.default_rng(seed)
    perm = sattolo_cycle(n, rng)
    out = np.asarray(ops.pchase(jnp.asarray(perm), iters=iters))
    cursor, checksum = ref.pchase_ref(perm, iters)
    assert out[0] == cursor
    assert out[1] == checksum


def test_pchase_full_cycle_returns_home():
    """A single cycle of length n returns to 0 after exactly n steps."""
    rng = np.random.default_rng(0)
    perm = sattolo_cycle(128, rng)
    out = np.asarray(ops.pchase(jnp.asarray(perm), iters=128))
    assert out[0] == 0


def test_pchase_batch_matches_single_rows():
    """Grid-batched chase: per-row padded cycles + per-row chain lengths
    must agree with the single kernel (and the python oracle) row by row."""
    rng = np.random.default_rng(3)
    ns = [16, 64, 256]
    steps = np.array([40, 700, 2500], np.int32)
    nmax = max(ns)
    perms = np.zeros((len(ns), nmax), np.int32)
    for i, n in enumerate(ns):
        perms[i, :n] = sattolo_cycle(n, rng)
    out = np.asarray(ops.pchase_batch(jnp.asarray(perms), steps))
    assert out.shape == (3, 2)
    for i, n in enumerate(ns):
        single = np.asarray(ops.pchase(jnp.asarray(perms[i, :n]),
                                       iters=int(steps[i])))
        assert np.array_equal(out[i], single)
        cursor, checksum = ref.pchase_ref(perms[i, :n], int(steps[i]))
        assert out[i, 0] == cursor and out[i, 1] == checksum


def test_pchase_batch_dynamic_steps_no_retrace():
    """Chain lengths are data, not static args: same shapes with new step
    counts must reuse the compiled kernel (steps live in the same jaxpr)."""
    rng = np.random.default_rng(4)
    perms = np.zeros((2, 64), np.int32)
    for i in range(2):
        perms[i] = sattolo_cycle(64, rng)
    p = jnp.asarray(perms)
    a = np.asarray(ops.pchase_batch(p, np.array([64, 128], np.int32)))
    b = np.asarray(ops.pchase_batch(p, np.array([128, 64], np.int32)))
    # full-cycle rows return home; the swapped steps swap the outcomes
    assert a[0, 0] == 0 and b[1, 0] == 0
    assert np.array_equal(a[0], b[1]) and np.array_equal(a[1], b[0])
