"""Per-architecture smoke tests (assignment requirement): a REDUCED config of
each family runs one real forward/train step on CPU — output shapes + no NaNs
— plus decode/prefill consistency for the serving path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

# Full-model jit compiles (one per arch): minutes of XLA time.
# Deselected by `make test-fast`.
pytestmark = pytest.mark.slow

from repro.configs import ARCHS, get_config, shape_for
from repro.models import Runtime, get_model

ALL_ARCHS = sorted(ARCHS)


def _smoke_batch(cfg, rng, b=2, s=16):
    r = np.random.default_rng(rng)
    if cfg.family == "audio":
        toks = r.integers(0, cfg.vocab_size, (b, cfg.n_codebooks, s))
        return {"tokens": jnp.asarray(toks, jnp.int32),
                "targets": jnp.asarray(toks, jnp.int32)}
    if cfg.family == "vlm":
        text = s
        toks = r.integers(0, cfg.vocab_size, (b, text))
        patches = r.normal(size=(b, cfg.n_patches, cfg.vision_embed_dim))
        return {"patches": jnp.asarray(patches, jnp.bfloat16),
                "tokens": jnp.asarray(toks, jnp.int32),
                "targets": jnp.asarray(toks, jnp.int32)}
    toks = r.integers(0, cfg.vocab_size, (b, s))
    return {"tokens": jnp.asarray(toks, jnp.int32),
            "targets": jnp.asarray(toks, jnp.int32)}


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_forward_and_loss(arch):
    cfg = get_config(arch).smoke().replace(dtype="float32")
    model = get_model(cfg)
    params, specs = model.init(jax.random.PRNGKey(0))
    assert jax.tree.structure(params) == jax.tree.structure(
        specs, is_leaf=lambda x: isinstance(x, tuple))
    batch = _smoke_batch(cfg, rng=0)
    loss = jax.jit(lambda p, b: model.train_loss(p, b))(params, batch)
    assert np.isfinite(float(loss)), f"{arch}: loss is not finite"
    assert float(loss) > 0


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_grads_finite(arch):
    cfg = get_config(arch).smoke().replace(dtype="float32")
    model = get_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(1))
    batch = _smoke_batch(cfg, rng=1)
    grads = jax.jit(jax.grad(lambda p: model.train_loss(p, batch)))(params)
    leaves = jax.tree.leaves(grads)
    assert leaves
    assert all(np.all(np.isfinite(np.asarray(l, np.float32))) for l in leaves)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_prefill_decode_consistency(arch):
    """prefill(t tokens) then decode_step must equal forward(t+1 tokens) on
    the next-token logits — the KV-cache/state correctness contract."""
    cfg = get_config(arch).smoke().replace(dtype="float32")
    if cfg.family == "moe":
        # Isolate cache/state correctness from capacity-drop policy: with a
        # tiny decode batch vs an 18-token forward, tight capacity drops
        # DIFFERENT (token,expert) pairs in the two paths by construction.
        cfg = cfg.replace(moe_capacity_factor=16.0)
    model = get_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(2))
    b, s = 2, 8
    batch = _smoke_batch(cfg, rng=2, b=b, s=s + 1)

    if cfg.family == "audio":
        full = batch["tokens"]
        pre = {"tokens": full[:, :, :s]}
        nxt = {"tokens": full[:, :, s:s + 1]}
        whole = {"tokens": full}
    else:
        full = batch["tokens"]
        pre = {k: v for k, v in batch.items() if k != "targets"}
        pre = dict(pre)
        pre["tokens"] = full[:, :s]
        nxt = {"tokens": full[:, s:s + 1]}
        whole = {k: v for k, v in batch.items() if k != "targets"}

    rt = Runtime(q_chunk=0)
    max_len = s + 4 + (cfg.n_patches if cfg.family == "vlm" else 0)
    logits_pre, cache = jax.jit(
        lambda p, bb: model.prefill(p, bb, max_len=max_len, rt=rt))(params, pre)
    logits_dec, cache = jax.jit(
        lambda p, bb, c: model.decode_step(p, bb, c, rt=rt))(params, nxt, cache)
    logits_full, _ = jax.jit(lambda p, bb: model.forward(p, bb, rt=rt))(
        params, whole)

    if cfg.family == "audio":
        want_last = logits_full[:, s - 1]      # logits at position s-1...
        got = logits_pre
        want_next = logits_full[:, s]
    else:
        want_last = logits_full[:, s - 1]
        got = logits_pre
        want_next = logits_full[:, s]
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want_last, np.float32),
                               rtol=2e-2, atol=2e-2)
    np.testing.assert_allclose(np.asarray(logits_dec, np.float32),
                               np.asarray(want_next, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_loss_decreases_tiny_overfit():
    """A few SGD steps on one batch must reduce the loss (dense family)."""
    cfg = get_config("internlm2-1.8b").smoke().replace(dtype="float32")
    model = get_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(3))
    batch = _smoke_batch(cfg, rng=3)

    @jax.jit
    def step(p):
        l, g = jax.value_and_grad(lambda q: model.train_loss(q, batch))(p)
        return l, jax.tree.map(lambda w, gw: w - 0.5 * gw, p, g)

    losses = []
    for _ in range(8):
        l, params = step(params)
        losses.append(float(l))
    assert losses[-1] < losses[0] * 0.9, losses
