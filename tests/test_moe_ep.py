"""shard_map expert-parallel MoE == GSPMD sorted-dispatch MoE (exact)."""
import os

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compat import make_mesh
from repro.configs import get_config
from repro.models.common import ParamBuilder
from repro.models.moe import init_moe, moe_ffn
from repro.models.moe_ep import moe_ffn_ep


def _setup(cf=8.0, experts=8, topk=2, seed=0):
    cfg = get_config("qwen3-moe-30b-a3b").smoke().replace(
        dtype="float32", moe_experts=experts, moe_top_k=topk,
        moe_capacity_factor=cf)
    b = ParamBuilder(jax.random.PRNGKey(seed), jnp.float32)
    init_moe(b, cfg)
    p, _ = b.build()
    x = jax.random.normal(jax.random.PRNGKey(seed + 1),
                          (4, 16, cfg.d_model), jnp.float32) * 0.3
    return cfg, p, x


@pytest.mark.parametrize("mesh_shape,names", [
    ((1,), ("data",)),
    ((4,), ("data",)),
    ((2, 2), ("data", "model")),
])
def test_ep_matches_gspmd(mesh_shape, names):
    if jax.device_count() < int(np.prod(mesh_shape)):
        pytest.skip("not enough devices")
    cfg, p, x = _setup()
    y0, p0 = moe_ffn(p, x, cfg)
    mesh = make_mesh(mesh_shape, names)
    y1, p1 = jax.jit(lambda pp, xx: moe_ffn_ep(pp, xx, cfg, mesh))(p, x)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(p0), np.asarray(p1).reshape(p0.shape),
                               rtol=1e-6, atol=1e-6)


def test_ep_gradients_flow():
    if jax.device_count() < 2:
        pytest.skip("needs 2 devices (full suite may init jax early)")
    cfg, p, x = _setup()
    mesh = make_mesh((2,), ("data",))

    def loss(pp):
        y, _ = moe_ffn_ep(pp, x, cfg, mesh)
        return jnp.sum(jnp.square(y))

    g = jax.jit(jax.grad(loss))(p)
    leaves = jax.tree.leaves(g)
    assert all(np.all(np.isfinite(np.asarray(l))) for l in leaves)
    assert any(float(jnp.abs(l).max()) > 0 for l in leaves)


def test_ep_capacity_drops_are_bounded():
    """With tight capacity the EP path drops tokens but stays finite and
    close to the (equally-dropping) reference in aggregate."""
    if jax.device_count() < 2:
        pytest.skip("needs 2 devices (full suite may init jax early)")
    cfg, p, x = _setup(cf=1.0)
    mesh = make_mesh((2,), ("data",))
    y1, _ = jax.jit(lambda: moe_ffn_ep(p, x, cfg, mesh))()
    assert np.all(np.isfinite(np.asarray(y1)))
