"""End-to-end Pallas-backend discovery (ISSUE 3 acceptance).

``discover_pallas()`` must produce a ``Topology`` through the *shared*
engine path whose discrete attributes match the backend's configured
ground truth, persist it content-addressed in the ``TopologyStore``, and
serve it through ``TopologyService`` — proving the registry/scheduler/
store stack is genuinely backend-neutral.

Everything here executes real Pallas kernels in interpret mode, so the
module is ``slow``-marked; the fast lane keeps its budget.
"""
import json

import numpy as np
import pytest

from repro.core import discover_pallas
from repro.core.discover import pallas_request_descriptor
from repro.core.engine.store import TopologyStore, request_key
from repro.core.probes import PallasRunner, make_pallas_model
from repro.serve.topology_service import TopologyService

pytestmark = pytest.mark.slow

N_SAMPLES = 9


@pytest.fixture(scope="module")
def discovery(tmp_path_factory):
    """One store-backed discovery shared by the whole module.

    One retry on a discrete mismatch: the rows are real timed measurements
    and a sustained steal burst on a shared CI box can defeat even the
    drift-hardened detection (a few-percent tail); a genuine code
    regression fails both independent attempts."""
    for attempt in range(2):
        store = TopologyStore(str(tmp_path_factory.mktemp("pallas-store")))
        model = make_pallas_model()
        runner = PallasRunner(model)
        topo, timings = discover_pallas(runner=runner, n_samples=N_SAMPLES,
                                        store=store)
        gt = model.ground_truth()
        l1 = topo.find_memory("L1")
        clean = l1 is not None \
            and l1.get("size") == gt["L1"]["size"] \
            and l1.get("line_size") == gt["L1"]["line_size"] \
            and l1.get("fetch_granularity") == gt["L1"]["fetch_granularity"] \
            and l1.get("amount") == 1
        if attempt == 0 and not clean:
            continue
        return {"store": store, "model": model, "runner": runner,
                "topo": topo, "timings": timings}


class TestDiscreteGroundTruth:
    """Sizes / line size / fetch granularity / amount vs the configured
    hierarchy: exact for cache spaces (their sweep grids align to the
    power-of-two capacities), one sweep-grid step (<= 64 B) of quantization
    allowed on the word-granular scratchpad."""

    def test_cache_spaces_exact(self, discovery):
        gt = discovery["model"].ground_truth()
        for name in ("L1", "L2"):
            me = discovery["topo"].find_memory(name)
            assert me is not None
            assert me.get("size") == gt[name]["size"]
            assert me.get("line_size") == gt[name]["line_size"]
            assert me.get("fetch_granularity") == gt[name]["fetch_granularity"]

    def test_l1_amount(self, discovery):
        me = discovery["topo"].find_memory("L1")
        assert me.get("amount") == 1

    def test_scratchpad_size_within_grid_step(self, discovery):
        gt = discovery["model"].ground_truth()
        vmem = discovery["topo"].find_memory("VMEM")
        assert vmem is not None
        assert abs(vmem.get("size") - gt["VMEM"]["size"]) <= 64
        # ... and no cold-pass attributes: the capability flag held.
        assert vmem.get("fetch_granularity") is None
        assert vmem.get("line_size") is None

    def test_latencies_in_model_cycle_units(self, discovery):
        """Calibration-normalized samples land near the modeled cycle
        counts (generous bounds: values are real timing ratios)."""
        gt = discovery["model"].ground_truth()
        for name in ("L1", "VMEM", "L2"):
            me = discovery["topo"].find_memory(name)
            want = gt[name]["latency"]
            assert abs(me.get("load_latency") - want) / want < 0.5

    def test_provenance_and_backend_identity(self, discovery):
        topo = discovery["topo"]
        assert topo.backend.startswith("pallas-interp:")
        l1 = discovery["topo"].find_memory("L1")
        assert l1.attrs["size"].provenance == "benchmark"
        assert l1.attrs["size"].confidence is not None

    def test_shared_engine_path_families(self, discovery):
        """The per-family timing buckets prove the run went through the
        same registry/scheduler as the sim backend."""
        fams = set(discovery["timings"].per_family)
        assert fams >= {"size", "latency", "bandwidth",
                        "fetch_granularity", "line_size"}

    def test_kernels_actually_ran(self, discovery):
        assert discovery["runner"].kernel_calls > 100


class TestStoreIntegration:
    def test_content_addressed_persist(self, discovery):
        key = request_key(pallas_request_descriptor(
            discovery["model"], N_SAMPLES, None))
        assert discovery["store"].has(key)
        entry = discovery["store"].get(key)
        assert entry.meta["request"]["kind"] == "discover_pallas"

    def test_store_hit_returns_without_kernels(self, discovery):
        calls_before = discovery["runner"].kernel_calls
        topo2, timings2 = discover_pallas(
            runner=discovery["runner"], n_samples=N_SAMPLES,
            store=discovery["store"])
        assert discovery["runner"].kernel_calls == calls_before
        assert topo2.to_json() == discovery["topo"].to_json()
        # stored per-family timings reconstructed on the hit
        assert timings2.per_family == dict(discovery["timings"].per_family)

    def test_distinct_requests_distinct_keys(self, discovery):
        model = discovery["model"]
        k_a = request_key(pallas_request_descriptor(model, N_SAMPLES, None))
        k_b = request_key(pallas_request_descriptor(model, N_SAMPLES + 2,
                                                    None))
        k_c = request_key(pallas_request_descriptor(model, N_SAMPLES,
                                                    ["L1"]))
        assert len({k_a, k_b, k_c}) == 3


class TestServiceIntegration:
    def test_queryable_through_topology_service(self, discovery):
        svc = TopologyService(discovery["store"])
        key = request_key(pallas_request_descriptor(
            discovery["model"], N_SAMPLES, None))
        gt = discovery["model"].ground_truth()
        res = svc.query(key, "L1.size")
        assert res.found and res.value == gt["L1"]["size"]
        res = svc.query(key, "L2.fetch_granularity")
        assert res.found and res.value == gt["L2"]["fetch_granularity"]
        res = svc.query(key, "hbm.latency")       # DeviceMemory alias
        assert res.found and res.value > 0

    def test_batched_queries_and_attributes_filter(self, discovery):
        svc = TopologyService(discovery["store"])
        key = request_key(pallas_request_descriptor(
            discovery["model"], N_SAMPLES, None))
        answers = svc.query_batch([(key, "L1.size"), (key, "VMEM.latency"),
                                   (key, "L2.read_bw")])
        assert all(a.found for a in answers)
        benchmarked = svc.attributes(key, provenance="benchmark")
        assert {a.path for a in benchmarked} >= {"L1.size", "L1.line_size"}
