"""Adaptive sweep planner + cross-family fusion tests (ISSUE 4 tentpole).

The planner's contract is strong: for any hierarchy the dense sweeps can
discover, a planned search must return *identical discrete attributes*
(sizes, line size, fetch granularity, found-ness) while sampling strictly
fewer grid rows — the dense path stays available behind ``budget=None`` as
the equivalence oracle.  Identity holds by construction (both paths run the
same deterministic classification descent over the same sweep lattice) and
is exercised here over randomized hierarchies via the hypothesis shim,
across the Sim and Host runners, with one slow-marked Pallas case.

Fusion's contract mirrors it: coalescing ready work items' probe rounds
into single batched dispatches must be result-invisible (request-keyed
streams) while reducing dispatch counts.
"""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (GcPolicy, SweepBudget, discover_sim,
                        make_h100_like, make_mi210_like, topology_equivalent)
from repro.core.engine import run_probes
from repro.core.engine.cache import CachingRunner
from repro.core.engine.fusion import FusionDispatcher, run_fused
from repro.core.engine.scheduler import WorkItem
from repro.core.probes import (SimRunner, find_fetch_granularity,
                               find_line_size, find_size)
from repro.core.simulate import SimDevice, SimLevel

KIB, MIB = 1024, 1024**2
BUDGET = SweepBudget()


class RowCountingRunner:
    """Counts grid rows fetched from the wrapped runner (probe volume)."""

    def __init__(self, base):
        self.base = base
        self.rows = 0

    def pchase(self, *a, **k):
        self.rows += 1
        return self.base.pchase(*a, **k)

    def pchase_batch(self, space, sizes, stride, n):
        self.rows += len(sizes)
        return self.base.pchase_batch(space, sizes, stride, n)

    def pchase_many(self, reqs, n):
        self.rows += len(reqs)
        return self.base.pchase_many(reqs, n)

    def cold_chase(self, *a, **k):
        self.rows += 1
        return self.base.cold_chase(*a, **k)

    def cold_chase_batch(self, space, sizes, strides, n):
        self.rows += len(sizes)
        return self.base.cold_chase_batch(space, sizes, strides, n)

    def amount_probe(self, *a, **k):
        self.rows += 1
        return self.base.amount_probe(*a, **k)

    def sharing_probe(self, *a, **k):
        self.rows += 1
        return self.base.sharing_probe(*a, **k)

    def cu_sharing_probe(self, *a, **k):
        self.rows += 1
        return self.base.cu_sharing_probe(*a, **k)

    def cu_sharing_probe_batch(self, cu_a, cu_bs, *a, **k):
        self.rows += len(cu_bs)
        return self.base.cu_sharing_probe_batch(cu_a, cu_bs, *a, **k)

    def eviction_many(self, requests, n):
        self.rows += len(requests)
        return self.base.eviction_many(requests, n)

    def __getattr__(self, name):
        return getattr(self.base, name)


def _device(levels, seed, **kw):
    return SimDevice(name="prop", vendor="x", levels=levels,
                     mem_latency=650.0, read_bw={}, write_bw={},
                     space_of_level={}, seed=seed, **kw)


# --------------------------------------------------------------- find_size
class TestPlannedSizeIdentity:
    @given(size_kib=st.sampled_from([4, 16, 48, 64, 192, 238, 768]),
           seed=st.integers(0, 200))
    @settings(max_examples=14, deadline=None)
    def test_randomized_hierarchies_identical_and_cheaper(self, size_kib,
                                                          seed):
        dev = _device([SimLevel("C", size_kib * KIB, 30.0, 64, 32,
                                noise=1.0)], seed)
        dense = RowCountingRunner(SimRunner(dev))
        d = find_size(dense, "C", lo=1 * KIB, step=32, n_samples=9,
                      batched=True)
        planned = RowCountingRunner(SimRunner(dev))
        p = find_size(planned, "C", lo=1 * KIB, step=32, n_samples=9,
                      budget=BUDGET)
        assert (d.size, d.found) == (p.size, p.found)
        assert planned.rows < dense.rows

    @given(levels=st.sampled_from([(16, 256), (4, 64), (32, 2048)]),
           seed=st.integers(0, 100))
    @settings(max_examples=8, deadline=None)
    def test_multi_level_hierarchies(self, levels, seed):
        """Doubling past an inner level must bracket the same (innermost)
        boundary on both paths — the coarse ladder stops at the first
        shifted octave exactly like the dense doubling loop."""
        l1_kib, l2_kib = levels
        dev = _device(
            [SimLevel("C1", l1_kib * KIB, 25.0, 64, 32, noise=0.8),
             SimLevel("C2", l2_kib * KIB, 140.0, 128, 32, scope="chip",
                      noise=3.0)], seed)
        for space in ("C1", "C2"):
            d = find_size(SimRunner(dev), space, lo=1 * KIB, step=32,
                          n_samples=9, batched=True)
            p = find_size(SimRunner(dev), space, lo=1 * KIB, step=32,
                          n_samples=9, budget=BUDGET)
            assert (d.size, d.found) == (p.size, p.found), space

    def test_not_found_parity(self):
        """No boundary below max_bytes: both paths must report not-found."""
        dev = _device([SimLevel("C", 64 * MIB, 30.0, 64, 32, noise=1.0)],
                      seed=3)
        kw = dict(lo=1 * KIB, step=32, n_samples=9, max_bytes=1 * MIB)
        d = find_size(SimRunner(dev), "C", batched=True, **kw)
        p = find_size(SimRunner(dev), "C", budget=BUDGET, **kw)
        assert d.found is False and p.found is False

    def test_budget_none_is_dense(self):
        """budget=None must be the unchanged dense path (the oracle)."""
        r = RowCountingRunner(SimRunner(make_h100_like(seed=4)))
        res = find_size(r, "L1", n_samples=9, batched=True, budget=None)
        assert res.found and r.rows > 60     # full lattice actually swept

    def test_target_resolution_coarsens(self):
        """target_resolution trades oracle identity for a coarser lattice —
        the detected size must still land within one coarse step of truth,
        for far fewer rows than the dense sweep."""
        dev = _device([SimLevel("C", 192 * KIB, 30.0, 64, 32, noise=1.0)],
                      seed=5)
        dense = RowCountingRunner(SimRunner(dev))
        find_size(dense, "C", n_samples=9, batched=True)
        coarse = RowCountingRunner(SimRunner(dev))
        pc = find_size(coarse, "C", n_samples=9,
                       budget=SweepBudget(target_resolution=4 * KIB))
        assert pc.found
        assert abs(pc.size - 192 * KIB) <= 4 * KIB
        assert coarse.rows < dense.rows

    def test_max_rows_exhaustion_falls_back_to_dense(self):
        """A too-tight row budget may not produce a wrong answer: the
        planner falls back to the dense sweep (slower, identical)."""
        dev = _device([SimLevel("C", 64 * KIB, 30.0, 64, 32, noise=1.0)],
                      seed=6)
        d = find_size(SimRunner(dev), "C", n_samples=9, batched=True)
        pt = find_size(SimRunner(dev), "C", n_samples=9,
                       budget=SweepBudget(max_rows=16))
        assert (pt.size, pt.found) == (d.size, d.found)


# ------------------------------------------- granularity / line size
class TestPlannedGranularityAndLine:
    @given(g=st.sampled_from([16, 32, 64, 128, 256]),
           seed=st.integers(0, 100))
    @settings(max_examples=10, deadline=None)
    def test_granularity_identity(self, g, seed):
        dev = _device([SimLevel("C", 64 * KIB, 30.0, max(g, 32), g,
                                noise=1.0)], seed)
        dense = RowCountingRunner(SimRunner(dev))
        d = find_fetch_granularity(dense, "C", n_samples=9, batched=True)
        planned = RowCountingRunner(SimRunner(dev))
        p = find_fetch_granularity(planned, "C", n_samples=9, budget=BUDGET)
        assert (d.granularity, d.found) == (p.granularity, p.found)

    @given(line=st.sampled_from([32, 64, 128, 256]),
           seed=st.integers(0, 100))
    @settings(max_examples=10, deadline=None)
    def test_line_size_identity_and_cheaper(self, line, seed):
        dev = _device([SimLevel("C", 64 * KIB, 30.0, line, 32, noise=1.0)],
                      seed)
        dense = RowCountingRunner(SimRunner(dev))
        d = find_line_size(dense, "C", 64 * KIB, 32, n_samples=9,
                           batched=True)
        planned = RowCountingRunner(SimRunner(dev))
        p = find_line_size(planned, "C", 64 * KIB, 32, n_samples=9,
                           budget=BUDGET)
        assert (d.line_size, d.found) == (p.line_size, p.found)
        assert planned.rows < dense.rows


# -------------------------------------------------- full discovery parity
class TestPlannedDiscovery:
    @pytest.mark.parametrize("make,seed", [(make_h100_like, 48),
                                           (make_mi210_like, 48),
                                           (make_h100_like, 11)])
    def test_planner_vs_dense_topology(self, make, seed):
        """The bench-gated contract: whole-topology planner-vs-dense
        equivalence with confidence excluded, and strictly fewer rows."""
        topo_d, td = discover_sim(make(seed=seed), n_samples=17,
                                  max_workers=0)
        topo_p, tp = discover_sim(make(seed=seed), n_samples=17,
                                  max_workers=0, budget=SweepBudget())
        assert topology_equivalent(topo_d, topo_p, rel_tol=1e-6,
                                   compare_confidence=False)
        assert tp.probe_rows < td.probe_rows

    def test_budget_addressed_in_store_key(self):
        from repro.core.discover import sim_request_descriptor
        from repro.core.engine.store import request_key

        dev = make_h100_like(seed=1)
        k_dense = request_key(sim_request_descriptor(dev, 9, None))
        k_plan = request_key(sim_request_descriptor(dev, 9, None,
                                                    SweepBudget()))
        k_plan2 = request_key(sim_request_descriptor(
            dev, 9, None, SweepBudget(max_rows=50)))
        assert len({k_dense, k_plan, k_plan2}) == 3


# ------------------------------------- planned eviction families (§IV-F/G/H)
class TestPlannedEvictionFamilies:
    """ISSUE 8: the bisected §IV-F ladder and §IV-G/H lattices must match
    the dense sweeps' discrete answers for fewer eviction rows, with dense
    fallback on any inconsistency."""

    @pytest.mark.parametrize("amount,cores", [(1, 32), (2, 32), (4, 64),
                                              (32, 256)])
    def test_amount_identity_and_cheaper(self, amount, cores):
        from repro.core.probes import find_amount

        per_core = 32 * KIB
        dev = _device([SimLevel("C", per_core * amount, 25.0, 64, 32,
                                amount=amount, noise=0.8)], seed=5,
                      cores_per_sm=cores)
        dense = RowCountingRunner(SimRunner(dev))
        d = find_amount(dense, "C", per_core, cores, n_samples=33,
                        batched=True)
        planned = RowCountingRunner(SimRunner(dev))
        p = find_amount(planned, "C", per_core, cores, n_samples=33,
                        budget=SweepBudget())
        assert (d.amount, d.found) == (p.amount, p.found) == (amount, True)
        assert planned.rows <= dense.rows

    def test_amount_bisection_strictly_cheaper_on_long_ladder(self):
        from repro.core.probes import find_amount

        dev = _device([SimLevel("C", 32 * KIB * 32, 25.0, 64, 32,
                                amount=32, noise=0.8)], seed=9,
                      cores_per_sm=256)
        dense = RowCountingRunner(SimRunner(dev))
        find_amount(dense, "C", 32 * KIB, 256, n_samples=33, batched=True)
        planned = RowCountingRunner(SimRunner(dev))
        find_amount(planned, "C", 32 * KIB, 256, n_samples=33,
                    budget=SweepBudget())
        assert planned.rows < dense.rows

    @staticmethod
    def _sharing_paths(dev, n_samples=17):
        """(dense results+rows, planned results+rows) over a device's
        ordered leader lattice — same pair order on both paths."""
        from repro.core.engine.planner import find_sharing_planned
        from repro.core.probes.amount import find_sharing_batch

        spaces = [i.name for i in SimRunner(dev).spaces()
                  if i.supports_sharing and i.scope == "core"]
        leaders = [(a, dev.level(a).size, spaces[i + 1:])
                   for i, a in enumerate(spaces)]
        dense = RowCountingRunner(SimRunner(dev))
        d = []
        for a, size, partners in leaders:
            d.extend(find_sharing_batch(dense, a, partners, size,
                                        n_samples=n_samples))
        planned = RowCountingRunner(SimRunner(dev))
        p = find_sharing_planned(planned, leaders, n_samples,
                                 budget=SweepBudget())
        return d, dense.rows, p, planned.rows

    def test_sharing_partition_closure_identity(self):
        d, d_rows, p, p_rows = self._sharing_paths(make_h100_like(seed=7))
        assert ([(r.space_a, r.space_b, r.shared) for r in d]
                == [(r.space_a, r.space_b, r.shared) for r in p])
        assert p_rows <= d_rows

    def test_sharing_closure_saves_rows_on_wide_lattice(self):
        """Two unified groups of three: once a group is witnessed, its
        later leaders infer every partner and pay one spot-check row."""
        levels = ([SimLevel(n, 64 * KIB, 30.0, 64, 32, noise=1.0,
                            physical_group="g1") for n in "ABC"]
                  + [SimLevel(n, 8 * MIB, 220.0, 128, 32, noise=6.0,
                              physical_group="g2") for n in "DEF"])
        dev = _device(levels, seed=11)
        d, d_rows, p, p_rows = self._sharing_paths(dev)
        assert ([(r.space_a, r.space_b, r.shared) for r in d]
                == [(r.space_a, r.space_b, r.shared) for r in p])
        assert p_rows < d_rows

    def test_cu_sharing_identity_and_cheaper(self):
        from repro.core.probes import find_cu_sharing

        dev = make_mi210_like(seed=6)
        cus = SimRunner(dev).cu_ids()
        size = dev.level("sL1d").size
        dense = RowCountingRunner(SimRunner(dev))
        d = find_cu_sharing(dense, cus, size, n_samples=17, batched=True)
        planned = RowCountingRunner(SimRunner(dev))
        p = find_cu_sharing(planned, cus, size, n_samples=17,
                            budget=SweepBudget())
        assert [sorted(g) for g in d.groups] == [sorted(g) for g in p.groups]
        assert sorted(d.exclusive) == sorted(p.exclusive)
        assert planned.rows < dense.rows


# ------------------------------------------------------ fleet survey mode
class TestSurveyMode:
    """ISSUE 8: verify a stored sibling with a planned spot-check subset
    instead of a full discovery; any doubt degrades to the full measure."""

    def _store(self, tmp_path):
        from repro.core.engine.store import TopologyStore
        return TopologyStore(tmp_path / "topo")

    def test_survey_verifies_sibling_for_5x_fewer_rows(self, tmp_path):
        store = self._store(tmp_path)
        topo_full, t_full = discover_sim(make_h100_like(seed=48),
                                         n_samples=17, max_workers=0,
                                         store=store)
        topo_s, t_s = discover_sim(make_h100_like(seed=49), n_samples=17,
                                   max_workers=0, store=store, survey=True)
        assert t_s.meta["survey"]["verified"] is True
        assert topology_equivalent(topo_full, topo_s, rel_tol=1e-6,
                                   compare_confidence=False)
        assert t_s.probe_rows * 5 <= t_full.probe_rows

        # the written entry carries survey provenance + its reference key
        from repro.core.discover import sim_request_descriptor
        from repro.core.engine.store import request_key
        key = request_key(sim_request_descriptor(
            make_h100_like(seed=49), 17, None, None, survey=True))
        entry = store.get(key)
        assert entry.meta.get("provenance") == "survey"
        assert entry.meta.get("survey_of")
        # and a repeat of the same survey request is a plain store hit
        _, t_again = discover_sim(make_h100_like(seed=49), n_samples=17,
                                  max_workers=0, store=store, survey=True)
        assert t_again.probe_rows is None

    def test_survey_covers_cu_sharing_device(self, tmp_path):
        store = self._store(tmp_path)
        _, t_full = discover_sim(make_mi210_like(seed=7), n_samples=17,
                                 max_workers=0, store=store)
        _, t_s = discover_sim(make_mi210_like(seed=8), n_samples=17,
                             max_workers=0, store=store, survey=True)
        assert t_s.meta["survey"]["verified"] is True
        assert t_s.probe_rows * 5 <= t_full.probe_rows

    def test_survey_without_sibling_runs_full_discovery(self, tmp_path):
        store = self._store(tmp_path)
        topo, t = discover_sim(make_h100_like(seed=48), n_samples=17,
                               max_workers=0, store=store, survey=True)
        assert t.meta.get("survey") is None
        assert t.probe_rows is not None and t.probe_rows > 500
        assert topo.find_memory("L1") is not None

    def test_survey_mismatch_falls_back_to_full_discovery(self, tmp_path):
        import copy

        from repro.core.discover import sim_request_descriptor
        from repro.core.engine.store import request_key

        store = self._store(tmp_path)
        dev = make_h100_like(seed=48)
        topo, _ = discover_sim(dev, n_samples=17, max_workers=0, store=store)
        # doctor the stored reference's L1 size: the spot check must refuse
        key0 = request_key(sim_request_descriptor(dev, 17, None, None))
        bad = copy.deepcopy(topo)
        bad.find_memory("L1").set("size",
                                  int(bad.find_memory("L1").get("size")) * 2)
        store.put(key0, bad, meta={"request": "doctored"})

        topo_s, t_s = discover_sim(make_h100_like(seed=49), n_samples=17,
                                   max_workers=0, store=store, survey=True)
        assert t_s.probe_rows is not None and t_s.probe_rows > 500
        for m in topo.memory:       # full re-measure, not the doctored copy
            ms = topo_s.find_memory(m.name)
            for k in ("size", "fetch_granularity", "line_size", "amount"):
                assert m.get(k) == ms.get(k), (m.name, k)
            assert m.shared_with == ms.shared_with


# -------------------------------------------------------- host runner
def _grid_step(res) -> int:
    """The final sweep lattice step of a SizeResult (tolerance unit)."""
    s = res.sizes_swept
    return int(s[1] - s[0]) if s.size >= 2 else 1


class TestPlannedHost:
    def test_host_identity_on_shared_cache(self):
        """Host rows are real measurements: the planner descends over
        *cached* rows of the same request keys (a prior dense run's
        samples), but the final boundary window is deliberately
        re-measured fresh (drift robustness), so the discrete contract on
        measuring runners is found-parity plus one-lattice-step agreement
        — bit-exact identity is the request-keyed runners' guarantee."""
        from repro.core.probes import HostRunner

        cached = CachingRunner(HostRunner(max_bytes=8 * MIB, iters=1 << 11))
        kw = dict(lo=64 * KIB, step=16 * KIB, n_samples=5,
                  max_bytes=8 * MIB, max_points=24, max_widenings=1)
        d = find_size(cached, "host-cache", batched=True, **kw)
        p = find_size(cached, "host-cache", budget=SweepBudget(), **kw)
        assert d.found == p.found
        if d.found:
            assert abs(d.size - p.size) <= 2 * max(_grid_step(d),
                                                   _grid_step(p))


# ------------------------------------------------------------- fusion
class TestFusion:
    def test_fused_equals_inline(self):
        fams = ("sharing", "device_memory_latency",
                "device_memory_bandwidth")
        a = run_probes(SimRunner(make_h100_like(seed=7)), n_samples=9,
                       device_families=fams, max_workers=0)
        b = run_probes(SimRunner(make_h100_like(seed=7)), n_samples=9,
                       device_families=fams, fuse=True)
        assert a.space_results.keys() == b.space_results.keys()
        for sp in a.space_results:
            ra, rb = a.space_results[sp], b.space_results[sp]
            assert ra["size"].size == rb["size"].size
            assert np.isclose(ra["latency"].p50, rb["latency"].p50)

    def test_fusion_coalesces_dispatches(self):
        """Concurrently ready items sharing a capability must land on ONE
        fused dispatch per round, not one dispatch per item."""
        base = CachingRunner(SimRunner(make_h100_like(seed=8)))
        dispatcher = FusionDispatcher(base)
        proxy = dispatcher.proxy()

        def probe(space):
            def fn(_results, space=space):
                return proxy.pchase(space, 64 * KIB, 32, 9)
            return fn

        items = [WorkItem(key=s, fn=probe(s))
                 for s in ("L1", "Texture", "Readonly")]
        sched = run_fused(items, dispatcher)
        assert len(sched.results) == 3
        assert dispatcher.rounds == 1          # one round...
        assert dispatcher.fused_calls == 1     # ...one fused dispatch
        for s in ("L1", "Texture", "Readonly"):
            want = SimRunner(make_h100_like(seed=8)).pchase(s, 64 * KIB,
                                                            32, 9)
            assert np.array_equal(sched.results[s], want)

    def test_fusion_dependency_order(self):
        base = CachingRunner(SimRunner(make_h100_like(seed=8)))
        dispatcher = FusionDispatcher(base)
        proxy = dispatcher.proxy()
        log = []

        def leaf(_results):
            log.append("leaf")
            return proxy.pchase("L1", 32 * KIB, 32, 9)

        def dependent(results):
            log.append("dep")
            assert results["leaf"] is not None
            return proxy.pchase("L1", 64 * KIB, 32, 9)

        sched = run_fused([WorkItem(key="leaf", fn=leaf),
                           WorkItem(key="dep", fn=dependent,
                                    deps=("leaf",))], dispatcher)
        assert log == ["leaf", "dep"]
        assert sched.order == ["leaf", "dep"]

    def test_fusion_propagates_item_errors(self):
        dispatcher = FusionDispatcher(
            CachingRunner(SimRunner(make_h100_like(seed=8))))

        def boom(_results):
            raise RuntimeError("probe exploded")

        with pytest.raises(RuntimeError, match="probe exploded"):
            run_fused([WorkItem(key="bad", fn=boom)], dispatcher)

    def test_fused_many_dedupes_shared_reference_rows(self):
        """Two families asking for the same reference distribution in one
        round must cost a single probe (the CachingRunner dedupes)."""
        cached = CachingRunner(SimRunner(make_h100_like(seed=9)))
        req = ("L1", 64 * KIB, 32)
        rows = cached.pchase_many([req, req, ("L2", 1 * MIB, 32)], 9)
        assert rows.shape[0] == 3
        assert np.array_equal(rows[0], rows[1])
        assert cached.cache.stats()["misses"] == 2   # deduped fetch


# ------------------------------------------------------------ store GC
class TestStoreGc:
    def _seed_store(self, tmp_path, n=4):
        from repro.core.engine.store import TopologyStore
        from repro.core.topology import Topology

        store = TopologyStore(str(tmp_path))
        for i in range(n):
            t = Topology(vendor="x", model=f"m{i}", backend="test")
            store.put(f"k{i}", t, meta={"created_at": 1000.0 + i})
            store.put_samples(f"k{i}", {("pchase", "L1", i): np.ones(3)})
        return store

    def test_gc_max_entries_evicts_oldest_pairs(self, tmp_path):
        store = self._seed_store(tmp_path)
        report = store.gc(max_entries=2)
        assert report["evicted"] == ["k0", "k1"]
        assert store.keys() == ["k2", "k3"]
        assert store.load_samples("k0") is None      # samples went with it
        assert store.load_samples("k3") is not None

    def test_gc_max_age(self, tmp_path):
        store = self._seed_store(tmp_path)
        report = store.gc(max_age_s=1.5, now=1004.0)  # horizon 1002.5
        assert report["evicted"] == ["k0", "k1", "k2"]
        assert store.keys() == ["k3"]

    def test_gc_sweeps_orphaned_samples(self, tmp_path):
        store = self._seed_store(tmp_path)
        import os
        os.remove(store._topo_path("k1"))            # orphan k1's samples
        report = store.gc()
        assert report["orphans"] == 1
        assert store.load_samples("k1") is None

    def test_gc_noop_without_limits(self, tmp_path):
        store = self._seed_store(tmp_path)
        report = store.gc()
        assert report["evicted"] == [] and len(store.keys()) == 4

    def test_discover_gc_policy_wired(self, tmp_path):
        from repro.core.engine.store import TopologyStore

        store = TopologyStore(str(tmp_path))
        for seed in (1, 2, 3):
            discover_sim(make_h100_like(seed=seed), n_samples=9,
                         store=store, gc_policy=GcPolicy(max_entries=2))
        assert len(store.keys()) == 2


# -------------------------------------------------------- pallas (slow)
@pytest.mark.slow
class TestPlannedPallas:
    """The third runner.  Pallas rows are real timed measurements, so —
    exactly as for the host runner — planner-vs-dense identity is asserted
    over *shared* rows (one CachingRunner: the dense sweep measures, the
    planner descends over the cached rows plus a handful of fresh ones,
    and its fallback rules absorb fresh-row flukes).  Two fully separate
    measurement runs can only promise agreement with the configured ground
    truth, which `tests/test_pallas_discovery.py` and the `pallas_interp`
    bench row already hard-gate."""

    def test_planner_vs_dense_discrete_identity_shared_rows(self):
        from repro.core.probes import PallasRunner, make_pallas_model

        cached = CachingRunner(PallasRunner(make_pallas_model()))
        for space, step in (("L1", 32), ("VMEM", 4), ("L2", 32)):
            info = {i.name: i for i in cached.spaces()}[space]
            kw = dict(lo=1024, step=step, n_samples=9,
                      max_bytes=info.max_bytes)
            d = find_size(cached, space, batched=True, **kw)
            p = find_size(cached, space, budget=SweepBudget(), **kw)
            assert d.found == p.found, space
            if d.found:
                # boundary windows are re-measured fresh on measuring
                # runners (drift robustness): one-lattice-step agreement
                assert abs(d.size - p.size) <= 2 * max(_grid_step(d),
                                                       _grid_step(p)), space
        dg = find_fetch_granularity(cached, "L1", n_samples=9, batched=True)
        pg = find_fetch_granularity(cached, "L1", n_samples=9,
                                    budget=SweepBudget())
        assert (dg.granularity, dg.found) == (pg.granularity, pg.found)
        dl = find_line_size(cached, "L1", 16 * KIB, 32, n_samples=9,
                            batched=True)
        pl = find_line_size(cached, "L1", 16 * KIB, 32, n_samples=9,
                            budget=SweepBudget())
        assert (dl.line_size, dl.found) == (pl.line_size, pl.found)

    def test_planned_discovery_collapses_kernel_calls(self):
        """ISSUE 4 acceptance: a default (planned + fused) discovery must
        stay under the 950-launch ceiling — >=3x below the 2868 calls the
        PR 3 dense/unfused implementation needed — and strictly below a
        current dense/unfused run (which itself got cheaper from the
        fused line-size chunks and per-loop calibration).  Ground truth is
        checked with one retry (real measurements; steal-burst tail)."""
        from repro.core import discover_pallas
        from repro.core.probes import PallasRunner, make_pallas_model

        model = make_pallas_model()
        rd = PallasRunner(model)
        discover_pallas(runner=rd, n_samples=9, budget=None, fuse=False)
        gt = model.ground_truth()

        def planned_matches_gt():
            rp = PallasRunner(model)
            topo_p, _ = discover_pallas(runner=rp, n_samples=9)
            assert rp.kernel_calls <= 500      # the bench-gated ceiling
            assert rp.kernel_calls < rd.kernel_calls
            for name in ("L1", "L2"):
                me = topo_p.find_memory(name)
                if (me.get("size") != gt[name]["size"]
                        or me.get("line_size") != gt[name]["line_size"]
                        or me.get("fetch_granularity")
                        != gt[name]["fetch_granularity"]):
                    return False
            return True

        assert planned_matches_gt() or planned_matches_gt()
