"""Probe workflows against simulated devices with known ground truth.

This is the in-repo equivalent of the paper's Table III validation: the probe
+ K-S machinery must recover sizes, latencies, line sizes, fetch
granularities, amounts, and sharing layouts of the virtual H100/MI210/v5e.
"""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.probes import (
    SimRunner, align_segments, find_amount, find_cu_sharing,
    find_fetch_granularity, find_line_size, find_sharing, find_size,
    measure_bandwidth, measure_latency, snap_pow2,
)
from repro.core.simulate import (SimDevice, SimLevel, make_h100_like,
                                 make_mi210_like, make_v5e_like)

KIB = 1024
MIB = 1024**2


@pytest.fixture(scope="module")
def h100():
    return SimRunner(make_h100_like(seed=1))


@pytest.fixture(scope="module")
def mi210():
    return SimRunner(make_mi210_like(seed=2))


# ------------------------------------------------------------------ size
class TestSizeProbe:
    def test_h100_l1_size(self, h100):
        r = find_size(h100, "L1", step=32, n_samples=17)
        assert r.found
        assert abs(r.size - 238 * KIB) <= 2 * KIB
        assert r.confidence > 0

    def test_h100_const_l1(self, h100):
        r = find_size(h100, "ConstL1", lo=256, step=32, n_samples=17)
        assert r.found and abs(r.size - 2 * KIB) <= 256

    def test_h100_l2_segment(self, h100):
        # L2: 50MB total in 2 segments -> one core sees 25MB. step = fetch
        # granularity (32 B); find_size coarsens the sweep grid itself.
        r = find_size(h100, "L2", lo=1 * MIB, step=32, n_samples=9,
                      max_bytes=256 * MIB)
        assert r.found
        assert abs(r.size - 25 * MIB) <= 2 * MIB

    def test_mi210_vl1(self, mi210):
        r = find_size(mi210, "vL1", lo=1 * KIB, step=64, n_samples=17)
        assert r.found and abs(r.size - 16 * KIB) <= KIB

    def test_v5e_vmem(self):
        r = find_size(SimRunner(make_v5e_like(seed=3)), "VMEM", lo=64 * KIB,
                      step=512, n_samples=9, max_bytes=256 * MIB)
        assert r.found and abs(r.size - 16 * MIB) <= MIB

    @given(size_kib=st.sampled_from([4, 16, 64, 192, 256, 768]),
           seed=st.integers(0, 100))
    @settings(max_examples=12, deadline=None)
    def test_property_arbitrary_cache_sizes_recovered(self, size_kib, seed):
        dev = SimDevice(
            name="prop", vendor="x",
            levels=[SimLevel("C", size_kib * KIB, 30.0, 64, 32, noise=1.0)],
            mem_latency=400.0, read_bw={}, write_bw={},
            space_of_level={}, seed=seed)
        r = find_size(SimRunner(dev), "C", lo=1 * KIB, step=32, n_samples=9)
        assert r.found
        assert abs(r.size - size_kib * KIB) / (size_kib * KIB) < 0.05


# --------------------------------------------------------------- latency
# Assertions use p50 — the headline stat discovery reports — because the
# simulator injects rare 30x outliers that the mean is (by design) not
# robust to: one outlier in 257 samples shifts the mean by several cycles.
class TestLatencyProbe:
    def test_h100_l1_latency(self, h100):
        lat = measure_latency(h100, "L1", fetch_granularity=32)
        assert abs(lat.p50 - 38.0) < 3.0
        assert lat.p95 >= lat.p50

    def test_mi210_lds_latency(self, mi210):
        lat = measure_latency(mi210, "LDS", fetch_granularity=4)
        assert abs(lat.p50 - 55.0) < 4.0

    def test_device_memory_latency(self, h100):
        lat = measure_latency(h100, "DeviceMemory", fetch_granularity=4096,
                              array_factor=64 * MIB // 4096)
        # DeviceMemory space maps to L2 chain; far above any cache -> DRAM.
        assert lat.mean > 500.0


# ---------------------------------------------- fetch granularity / line
class TestGranularityAndLine:
    def test_h100_l1_fetch_granularity(self, h100):
        g = find_fetch_granularity(h100, "L1", n_samples=33)
        assert g.found and g.granularity == 32

    def test_mi210_vl1_fetch_granularity(self, mi210):
        g = find_fetch_granularity(mi210, "vL1", n_samples=33)
        assert g.found and g.granularity == 64

    def test_h100_l1_line_size(self, h100):
        ls = find_line_size(h100, "L1", 238 * KIB, 32, n_samples=33)
        assert ls.found and ls.line_size == 128

    def test_mi210_l2_line_size(self, mi210):
        ls = find_line_size(mi210, "L2", 8 * MIB, 64, n_samples=17)
        assert ls.found and ls.line_size == 128

    def test_snap_pow2(self):
        assert snap_pow2(120) == 128
        assert snap_pow2(96) == 128     # 96/64=1.5, 128/96=1.33 -> 128
        assert snap_pow2(65) == 64
        assert snap_pow2(1) == 1

    @given(line=st.sampled_from([32, 64, 128, 256]),
           seed=st.integers(0, 50))
    @settings(max_examples=10, deadline=None)
    def test_property_line_sizes_recovered(self, line, seed):
        dev = SimDevice(
            name="prop", vendor="x",
            levels=[SimLevel("C", 64 * KIB, 30.0, line, 32, noise=1.0)],
            mem_latency=400.0, read_bw={}, write_bw={},
            space_of_level={}, seed=seed)
        ls = find_line_size(SimRunner(dev), "C", 64 * KIB, 32, n_samples=17)
        assert ls.found and ls.line_size == line


# ------------------------------------------------------ amount / sharing
class TestAmountSharing:
    def test_h100_l1_amount_is_one(self, h100):
        am = find_amount(h100, "L1", 238 * KIB, h100.cores_per_sm,
                         n_samples=33)
        assert am.found and am.amount == 1

    def test_two_segment_cache_amount(self):
        dev = SimDevice(
            name="seg", vendor="x",
            levels=[SimLevel("C", 64 * KIB, 25.0, 64, 32, amount=2, noise=0.8)],
            mem_latency=300.0, read_bw={}, write_bw={},
            cores_per_sm=32, space_of_level={}, seed=5)
        # One core sees size/amount = 32 KiB.
        sr = find_size(SimRunner(dev), "C", lo=1 * KIB, step=32, n_samples=9)
        assert sr.found and abs(sr.size - 32 * KIB) <= KIB
        am = find_amount(SimRunner(dev), "C", sr.size, 32, n_samples=33)
        assert am.found and am.amount == 2

    def test_align_segments(self):
        k, size, conf = align_segments(50 * MIB, 24 * MIB + 512 * KIB)
        assert k == 2 and size == 25 * MIB and conf > 0.9
        k, _, conf = align_segments(40 * MIB, 20 * MIB)
        assert k == 2 and conf == 1.0

    def test_h100_unified_l1_texture_sharing(self, h100):
        res = find_sharing(h100, "L1", "Texture", 238 * KIB, n_samples=33)
        assert res.shared

    def test_h100_const_not_shared_with_l1(self, h100):
        res = find_sharing(h100, "ConstL1", "L1", 2 * KIB, n_samples=33)
        assert not res.shared

    def test_mi210_cu_sharing_groups(self, mi210):
        # Probe a subset: pairs (0,1) share; 9 is disabled so 8 is exclusive.
        cus = [0, 1, 2, 3, 8]
        res = find_cu_sharing(mi210, cus, 16 * KIB, n_samples=17)
        groups = {tuple(sorted(g)) for g in res.groups}
        assert (0, 1) in groups and (2, 3) in groups
        assert 8 in res.exclusive


# -------------------------------------------------------------- bandwidth
class TestBandwidth:
    def test_h100_l2_bandwidth(self, h100):
        bw = measure_bandwidth(h100, "L2")
        assert abs(bw.read_bw - 4.4e12) / 4.4e12 < 0.1
        assert abs(bw.write_bw - 3.4e12) / 3.4e12 < 0.1


class TestCusumCrossCheck:
    def test_clean_boundary_agrees(self, h100):
        r = find_size(h100, "L1", step=32, n_samples=17)
        assert r.found and r.cusum_agrees

    def test_agreement_field_present_on_all_sim_devices(self, mi210):
        r = find_size(mi210, "vL1", lo=1024, step=64, n_samples=17)
        assert r.found and isinstance(r.cusum_agrees, bool)


class TestLinkAdjacency:
    """Pod-level §IV-H analogue: ICI direct links vs routed paths."""

    def test_torus_neighbors_recovered(self):
        from repro.core.probes.adjacency import SimPod, find_link_adjacency
        pod = SimPod(rows=4, cols=4, seed=3)
        res = find_link_adjacency(pod, n_samples=9)
        assert res.found
        for chip in range(pod.n_chips):
            assert res.neighbors[chip] == pod.neighbors(chip), chip

    def test_degree_is_four_on_2d_torus(self):
        from repro.core.probes.adjacency import SimPod, find_link_adjacency
        pod = SimPod(rows=4, cols=8, seed=5)
        res = find_link_adjacency(pod, chips=list(range(16)), n_samples=9)
        assert res.found
        # probing a sub-slice still finds only true direct links
        for chip, peers in res.neighbors.items():
            assert set(peers) <= set(pod.neighbors(chip))

    @given(rows=st.sampled_from([2, 4]), cols=st.sampled_from([4, 8]),
           seed=st.integers(0, 30))
    @settings(max_examples=10, deadline=None)
    def test_property_any_torus_shape(self, rows, cols, seed):
        from repro.core.probes.adjacency import SimPod, find_link_adjacency
        pod = SimPod(rows=rows, cols=cols, seed=seed)
        res = find_link_adjacency(pod, n_samples=9)
        assert res.found
        ok = sum(res.neighbors[c] == pod.neighbors(c)
                 for c in range(pod.n_chips))
        assert ok >= 0.95 * pod.n_chips
