"""Live-server tests for the remote discovery write path (ISSUE 7).

Every test talks to a real ``TopologyHTTPServer`` on an ephemeral loopback
port.  Covers the acceptance end-to-end (submit over HTTP -> server-side
discovery -> readable via the query endpoints -> idempotent resubmit with
zero runner probes -> survives an injected transient runner fault), the
bearer-auth matrix (missing/bad/good token, mutating vs read endpoints),
HTTP cancellation, queue-full 503s, wire-format 400s, and the client's
retry/backoff loop (fault-injected 503-with-``Retry-After``, recorded
sleeps, eventual success) plus ``wait()``'s ``Retry-After`` pacing.
"""
import http.client
import json
import threading
import time

import pytest

from repro.core.engine.store import TopologyStore
from repro.serve import (HttpError, TopologyClient, TopologyHTTPError,
                         TopologyHTTPServer)
from repro.serve.jobs import JobEngine, TransientRunnerError

TOKEN = "tok-mt4g-test"
SIM_H100 = {"backend": "sim", "device": "h100", "seed": 71, "n_samples": 9}
SIM_MI210 = {"backend": "sim", "device": "mi210", "seed": 72, "n_samples": 9}


def _raw_request(server, method, path, body=None, headers=None):
    """(status, headers, parsed body) via a bare http.client connection."""
    host, port = server.address
    conn = http.client.HTTPConnection(host, port, timeout=10)
    try:
        if isinstance(body, dict):
            body = json.dumps(body)
        conn.request(method, path, body=body, headers=headers or {})
        resp = conn.getresponse()
        raw = resp.read()
        try:
            payload = json.loads(raw)
        except (json.JSONDecodeError, UnicodeDecodeError):
            payload = raw
        return resp.status, dict(resp.getheaders()), payload
    finally:
        conn.close()


def _bearer(token=TOKEN):
    return {"Authorization": f"Bearer {token}",
            "Content-Type": "application/json"}


@pytest.fixture(scope="module")
def server(tmp_path_factory):
    store = TopologyStore(str(tmp_path_factory.mktemp("remote") / "store"))
    # job_poll_s=0 keeps wait() loops tight — sim jobs finish in ~0.2s, so
    # the production 1s Retry-After hint would dominate the test wall time
    with TopologyHTTPServer(store, auth_token=TOKEN, job_workers=2,
                            job_poll_s=0) as srv:
        yield srv


@pytest.fixture
def client(server):
    return TopologyClient(server.url, auth_token=TOKEN)


class TestEndToEnd:
    def test_submit_poll_query_roundtrip(self, server, client):
        """The acceptance path: a discovery submitted over HTTP completes
        server-side and its topology is immediately readable."""
        job = client.submit_discovery(SIM_H100)
        assert job["state"] in ("queued", "running")
        assert job["deduplicated"] is False
        assert job["status_url"] == f"/discoveries/{job['job_id']}"

        final = client.wait(job["job_id"], timeout_s=60)
        assert final["state"] == "done"
        assert final["result"]["model"] == "sim-h100"
        assert final["result"]["store_hit"] is False
        assert final["result"]["probe_rows"] > 0

        # the written topology is served by the read path, same key
        keys = [t["key"] for t in client.topologies()]
        assert final["key"] in keys
        q = client.query(final["key"], "L1.size")
        assert q["found"] and q["value"] > 0

    def test_submit_returns_202_created(self, server):
        status, _, payload = _raw_request(server, "POST", "/discoveries",
                                          body=SIM_MI210, headers=_bearer())
        assert status == 202
        assert payload["deduplicated"] is False

    def test_resubmit_after_done_is_store_hit_zero_probes(self, server,
                                                          client):
        first = client.submit_and_wait(SIM_H100, timeout_s=60)
        assert first["state"] == "done"
        second = client.submit_and_wait(SIM_H100, timeout_s=60)
        assert second["state"] == "done"
        assert second["key"] == first["key"]
        assert second["result"]["store_hit"] is True   # zero runner probes

    def test_discoveries_listing_and_state_filter(self, server, client):
        client.submit_and_wait(SIM_H100, timeout_s=60)
        all_jobs = client.discoveries()
        assert all_jobs and all(j["job_id"] for j in all_jobs)
        done = client.discoveries(state="done")
        assert done and all(j["state"] == "done" for j in done)
        assert client.discoveries(state="failed") == [
            j for j in all_jobs if j["state"] == "failed"]

    def test_unknown_job_404(self, client):
        with pytest.raises(TopologyHTTPError) as ei:
            client.discovery("no-such-job")
        assert ei.value.status == 404

    def test_bad_wire_params_400_before_enqueue(self, server, client):
        before = client.metrics()["jobs"]["submitted"]
        for bad in ({"backend": "cuda"},
                    {"backend": "sim", "device": "rtx5090"},
                    {"backend": "sim", "device": "h100", "n_samples": 0}):
            with pytest.raises(TopologyHTTPError) as ei:
                client.submit_discovery(bad)
            assert ei.value.status == 400
            assert "bad discovery request" in ei.value.payload["error"]
        assert client.metrics()["jobs"]["submitted"] == before

    def test_job_metrics_in_metrics_endpoint(self, server, client):
        client.submit_and_wait(SIM_H100, timeout_s=60)
        jobs = client.metrics()["jobs"]
        assert jobs["submitted"] >= 1 and jobs["done"] >= 1
        assert jobs["workers"] == 2
        assert len(jobs["duration_buckets"]) == \
            len(jobs["duration_bucket_edges_s"]) + 1
        assert sum(jobs["duration_buckets"]) == jobs["done"] + jobs["failed"]

    def test_healthz_reports_job_queue(self, client):
        h = client.healthz()
        assert h["jobs_enabled"] is True
        assert h["job_queue_depth"] == 0


class TestAuthMatrix:
    """Mutating endpoints require the bearer token; reads stay open."""

    MUTATING = [("POST", "/discoveries", SIM_H100),
                ("DELETE", "/discoveries/abc123", None)]
    READ = ["/healthz", "/metrics", "/topologies", "/discoveries"]

    @pytest.mark.parametrize("method, path, body", MUTATING)
    def test_missing_token_401_with_challenge(self, server, method, path,
                                              body):
        status, headers, payload = _raw_request(
            server, method, path, body=body,
            headers={"Content-Type": "application/json"} if body else None)
        assert status == 401
        assert "Bearer" in headers.get("WWW-Authenticate", "")
        assert "bearer token" in payload["error"]

    @pytest.mark.parametrize("method, path, body", MUTATING)
    def test_bad_token_401(self, server, method, path, body):
        status, _, _ = _raw_request(server, method, path, body=body,
                                    headers=_bearer("wrong-token"))
        assert status == 401

    def test_good_token_accepted_on_mutating(self, server):
        status, _, payload = _raw_request(server, "POST", "/discoveries",
                                          body=SIM_H100, headers=_bearer())
        assert status in (200, 202)          # accepted (created or attached)
        # DELETE with a good token reaches the handler (404 = unknown id,
        # i.e. auth passed)
        status, _, _ = _raw_request(server, "DELETE", "/discoveries/zzz",
                                    headers=_bearer())
        assert status == 404

    @pytest.mark.parametrize("path", READ)
    def test_reads_stay_open_without_token(self, server, path):
        status, _, _ = _raw_request(server, "GET", path)
        assert status == 200

    def test_client_sends_token_on_every_request(self, server):
        # a tokenless client can read but not submit
        anon = TopologyClient(server.url)
        assert anon.healthz()["status"] == "ok"
        with pytest.raises(TopologyHTTPError) as ei:
            anon.submit_discovery(SIM_H100)
        assert ei.value.status == 401


class TestCancelAndQueueBounds:
    """These need a wedged worker, so they build their own small server."""

    @pytest.fixture
    def wedged(self, tmp_path):
        release = threading.Event()
        running = threading.Event()

        def block(job, attempt):
            running.set()
            release.wait(30)

        store = TopologyStore(str(tmp_path / "store"))
        engine = JobEngine(store, workers=1, max_queue=2, on_attempt=block)
        srv = TopologyHTTPServer(store, auth_token=TOKEN, job_engine=engine)
        srv.start()
        try:
            yield srv, running, release
        finally:
            release.set()
            srv.stop()

    def test_cancel_queued_job_over_http(self, wedged):
        srv, running, _ = wedged
        c = TopologyClient(srv.url, auth_token=TOKEN)
        c.submit_discovery(SIM_H100)         # occupies the only worker
        assert running.wait(10)
        queued = c.submit_discovery(SIM_MI210)
        assert queued["state"] == "queued"
        out = c.cancel_discovery(queued["job_id"])
        assert out["state"] == "cancelled"
        # idempotent: cancelling again keeps the terminal state
        again = c.cancel_discovery(queued["job_id"])
        assert again["state"] == "cancelled"

    def test_duplicate_submission_attaches_200(self, wedged):
        srv, running, _ = wedged
        c = TopologyClient(srv.url, auth_token=TOKEN)
        first = c.submit_discovery(SIM_H100)
        assert running.wait(10)
        status, _, payload = _raw_request(srv, "POST", "/discoveries",
                                          body=SIM_H100, headers=_bearer())
        assert status == 200                 # attached, not created
        assert payload["deduplicated"] is True
        assert payload["job_id"] == first["job_id"]

    def test_queue_full_503_with_retry_after(self, wedged):
        srv, running, _ = wedged
        c = TopologyClient(srv.url, auth_token=TOKEN)
        c.submit_discovery(SIM_H100)         # worker wedges on this one
        assert running.wait(10)
        c.submit_discovery(SIM_MI210)        # queue slot 1
        c.submit_discovery({**SIM_H100, "seed": 5})      # queue slot 2
        with pytest.raises(TopologyHTTPError) as ei:
            c.submit_discovery({**SIM_H100, "seed": 6})
        assert ei.value.status == 503
        assert ei.value.retry_after_s is not None
        assert "queue full" in ei.value.payload["error"]


class TestClientRetryBackoff:
    """Fault-injecting server: the first N requests get a 503 (optionally
    with ``Retry-After``), later ones pass through."""

    @pytest.fixture
    def flaky_server(self, tmp_path):
        state = {"fail": 0, "retry_after": None, "seen": 0}

        def hook(method, path):
            state["seen"] += 1
            if state["fail"] > 0:
                state["fail"] -= 1
                raise HttpError(503, "injected overload",
                                retry_after_s=state["retry_after"])

        store = TopologyStore(str(tmp_path / "store"))
        srv = TopologyHTTPServer(store, on_request=hook, jobs=True)
        srv.start()
        try:
            yield srv, state
        finally:
            state["fail"] = 0
            srv.stop()

    def test_retry_honors_retry_after_then_succeeds(self, flaky_server):
        srv, state = flaky_server
        state.update(fail=2, retry_after=3)
        sleeps = []
        c = TopologyClient(srv.url, max_retries=3, sleep=sleeps.append)
        assert c.healthz()["status"] == "ok"             # eventual success
        assert sleeps == [3.0, 3.0]          # server-provided pacing, bounded
        assert state["seen"] == 3

    def test_retry_exponential_backoff_without_retry_after(self,
                                                           flaky_server):
        srv, state = flaky_server
        state.update(fail=3, retry_after=None)
        sleeps = []
        c = TopologyClient(srv.url, max_retries=3, backoff_base_s=0.05,
                           backoff_cap_s=0.15, sleep=sleeps.append)
        assert c.healthz()["status"] == "ok"
        assert sleeps == [0.05, 0.1, 0.15]   # base*2**i, capped
        assert state["seen"] == 4

    def test_no_retries_by_default(self, flaky_server):
        srv, state = flaky_server
        state.update(fail=1, retry_after=1)
        c = TopologyClient(srv.url)          # max_retries=0
        with pytest.raises(TopologyHTTPError) as ei:
            c.healthz()
        assert ei.value.status == 503
        assert ei.value.retry_after_s == 1.0
        assert state["seen"] == 1

    def test_retries_exhausted_raises_the_503(self, flaky_server):
        srv, state = flaky_server
        state.update(fail=10, retry_after=None)
        sleeps = []
        c = TopologyClient(srv.url, max_retries=2, backoff_base_s=0.01,
                           sleep=sleeps.append)
        with pytest.raises(TopologyHTTPError) as ei:
            c.healthz()
        assert ei.value.status == 503
        assert len(sleeps) == 2              # bounded: max_retries sleeps
        assert state["seen"] == 3

    def test_non_503_errors_are_not_retried(self, flaky_server):
        srv, state = flaky_server
        state.update(fail=0)
        sleeps = []
        c = TopologyClient(srv.url, max_retries=5, sleep=sleeps.append)
        with pytest.raises(TopologyHTTPError) as ei:
            c.topology("no-such-key")
        assert ei.value.status == 404
        assert sleeps == []


class TestWaitPacing:
    def test_wait_paces_polls_by_retry_after_header(self, tmp_path):
        """Unfinished job polls carry ``Retry-After``; ``wait`` must sleep
        that hint, not its default poll interval."""
        release = threading.Event()
        running = threading.Event()

        def block(job, attempt):
            running.set()
            release.wait(30)

        store = TopologyStore(str(tmp_path / "store"))
        engine = JobEngine(store, workers=1, on_attempt=block)
        srv = TopologyHTTPServer(store, job_engine=engine, job_poll_s=3)
        srv.start()
        sleeps = []

        def fake_sleep(s):
            sleeps.append(s)
            release.set()                    # un-wedge after the first poll
            time.sleep(0.02)

        try:
            c = TopologyClient(srv.url, sleep=fake_sleep)
            job = c.submit_discovery(SIM_H100)
            # header check on a raw poll while the job is still live
            status, headers, _ = _raw_request(
                srv, "GET", f"/discoveries/{job['job_id']}")
            assert status == 200
            if not release.is_set():         # job may already be terminal
                assert headers.get("Retry-After") == "3"
            final = c.wait(job["job_id"], timeout_s=60, poll_s=0.5)
            assert final["state"] == "done"
            assert all(s == 3.0 for s in sleeps)     # header, not poll_s
        finally:
            release.set()
            srv.stop()

    def test_wait_timeout_raises(self, tmp_path):
        release = threading.Event()

        def block(job, attempt):
            release.wait(30)

        store = TopologyStore(str(tmp_path / "store"))
        engine = JobEngine(store, workers=1, on_attempt=block)
        srv = TopologyHTTPServer(store, job_engine=engine, job_poll_s=0)
        srv.start()
        try:
            c = TopologyClient(srv.url)
            job = c.submit_discovery(SIM_H100)
            with pytest.raises(TimeoutError):
                c.wait(job["job_id"], timeout_s=0.3, poll_s=0.05)
        finally:
            release.set()
            srv.stop()
