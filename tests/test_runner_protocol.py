"""ProbeRunner conformance suite — one contract, three backends.

The probe workflows are runner-agnostic; this suite pins down what that
means operationally by running the same assertions against ``SimRunner``,
``HostRunner``, and ``PallasRunner``: protocol shape, sample array
shapes/dtypes, batch==loop equivalence (exact for runners with
request-keyed deterministic streams, structural for runners whose samples
are real wall-time measurements), and ``SpaceInfo`` capability flags being
honored by both the runners and the engine registry.

Pallas parameters are marked ``slow`` (interpret-mode kernels compile on
first touch); the fast lane runs the sim/host rows.
"""
import os

import numpy as np
import pytest

from repro.core import make_h100_like
from repro.core.discover import (DiscoveryRequest, discover,
                                 sim_request_descriptor)
from repro.core.engine.cache import CachingRunner
from repro.core.engine.parallel import (ParallelConfig, ParallelPool,
                                        effective_cpu_count,
                                        get_global_pool,
                                        maybe_parallel_runner,
                                        shutdown_global_pools)
from repro.core.engine.registry import space_probe_specs
from repro.core.errors import Resilience, TransientRunnerError
from repro.core.probes import (ChaosRunner, FaultSchedule, HostRunner,
                               PallasRunner, ProbeRunner, SimRunner,
                               make_pallas_model, random_cycle,
                               sattolo_cycle)
from repro.core.topology import topology_equivalent

KIB, MIB = 1024, 1024**2

# Per backend: runner factory, a bandwidth-capable space, and whether
# cold-pass requests on unsupported spaces must raise (the measuring
# backends have no cold-pass control at all / outside cache spaces; the
# simulator can serve them even where discovery never asks).  The "chaos"
# row is a ``ChaosRunner`` under a zero-fault schedule: the fault-injection
# proxy must itself be a conforming ``ProbeRunner`` (same shapes, same
# batch==loop contract) or every fault-tolerance result built on it would
# be suspect.
BACKENDS = {
    "sim": dict(
        make=lambda: SimRunner(make_h100_like(seed=3)),
        bw_space="L2",
        cold_unsupported_raises=False,
    ),
    "chaos": dict(
        make=lambda: ChaosRunner(SimRunner(make_h100_like(seed=3)),
                                 FaultSchedule(seed=1)),
        bw_space="L2",
        cold_unsupported_raises=False,
    ),
    "host": dict(
        make=lambda: HostRunner(max_bytes=8 * MIB, iters=1 << 12),
        bw_space="DRAM",
        cold_unsupported_raises=True,
    ),
    "pallas": dict(
        make=lambda: PallasRunner(make_pallas_model(), base_steps=2048,
                                  cold_reps=2),
        bw_space="L2",
        cold_unsupported_raises=True,
    ),
}

PARAMS = [
    pytest.param("sim", id="sim"),
    pytest.param("chaos", id="chaos"),
    pytest.param("host", id="host"),
    pytest.param("pallas", id="pallas", marks=pytest.mark.slow),
]


@pytest.fixture(scope="module", params=PARAMS)
def backend(request):
    cfg = BACKENDS[request.param]
    return {"name": request.param, "runner": cfg["make"](), **cfg}


def _probe_space(runner):
    """A (space, in-capacity array size) pair valid for any backend."""
    info = runner.spaces()[0]
    return info, min(info.max_bytes // 8, 64 * KIB)


class TestProtocolSurface:
    def test_satisfies_probe_runner_protocol(self, backend):
        assert isinstance(backend["runner"], ProbeRunner)

    def test_declares_determinism(self, backend):
        # chaos over sim under a value-preserving schedule is still
        # deterministic: replayed faults, unperturbed samples.
        det = backend["runner"].deterministic
        assert isinstance(det, bool)
        assert det == (backend["name"] in ("sim", "chaos"))

    def test_spaces_well_formed(self, backend):
        infos = backend["runner"].spaces()
        assert infos
        names = [i.name for i in infos]
        assert len(set(names)) == len(names)
        for i in infos:
            assert i.kind in ("cache", "scratchpad", "memory")
            assert i.max_bytes > 0


class TestPChase:
    def test_sample_shape_and_domain(self, backend):
        info, ab = _probe_space(backend["runner"])
        out = np.asarray(backend["runner"].pchase(info.name, ab, 32, 7))
        assert out.shape == (7,)
        assert out.dtype.kind == "f"
        assert np.all(np.isfinite(out)) and np.all(out > 0)

    def test_batch_equals_loop(self, backend):
        runner = backend["runner"]
        info, ab = _probe_space(runner)
        sizes = [ab, ab * 2, ab * 3]
        batch = np.asarray(runner.pchase_batch(info.name, sizes, 32, 7))
        assert batch.shape == (3, 7)
        assert np.all(np.isfinite(batch)) and np.all(batch > 0)
        if runner.deterministic:
            for i, size in enumerate(sizes):
                assert np.array_equal(
                    batch[i], runner.pchase(info.name, size, 32, 7))


class TestColdChase:
    def test_supported_spaces_serve_per_load_rows(self, backend):
        runner = backend["runner"]
        cold = [i for i in runner.spaces() if i.supports_cold]
        if not cold:
            pytest.skip("backend advertises no cold-pass space")
        info = cold[0]
        out = np.asarray(runner.cold_chase(info.name, 64 * KIB, 32, 65))
        assert out.ndim == 1 and out.size > 0
        assert np.all(np.isfinite(out)) and np.all(out > 0)

    def test_batch_equals_loop(self, backend):
        runner = backend["runner"]
        cold = [i for i in runner.spaces() if i.supports_cold]
        if not cold:
            pytest.skip("backend advertises no cold-pass space")
        info = cold[0]
        strides = [8, 32, 64]
        arrs = [max(64 * KIB, s * 65) for s in strides]
        batch = np.asarray(runner.cold_chase_batch(info.name, arrs, strides,
                                                   64))
        assert batch.shape[0] == 3
        assert np.all(np.isfinite(batch)) and np.all(batch > 0)
        if runner.deterministic:
            for i, (ab, s) in enumerate(zip(arrs, strides)):
                assert np.array_equal(
                    batch[i], runner.cold_chase(info.name, ab, s, 64))

    def test_capability_flag_respected(self, backend):
        """Spaces without cold-pass support must be refused by measuring
        runners — the engine relies on the flag, and a silent wrong answer
        would be worse than the exception."""
        runner = backend["runner"]
        uncold = [i for i in runner.spaces() if not i.supports_cold]
        if not (uncold and backend["cold_unsupported_raises"]):
            pytest.skip("no refusing space on this backend")
        with pytest.raises(NotImplementedError):
            runner.cold_chase(uncold[0].name, 64 * KIB, 32, 65)


class TestEvictionProbes:
    def test_amount_probe_or_refusal(self, backend):
        runner = backend["runner"]
        amount = [i for i in runner.spaces() if i.supports_amount]
        if amount:
            info = amount[0]
            ab = int(info.max_bytes // 8 * 0.9)
            out = np.asarray(runner.amount_probe(info.name, 0, 1, ab, 7))
            assert out.shape == (7,) and np.all(out > 0)
        else:
            with pytest.raises(NotImplementedError):
                runner.amount_probe("anything", 0, 1, 4 * KIB, 7)

    def test_sharing_probe_or_refusal(self, backend):
        runner = backend["runner"]
        sharing = [i for i in runner.spaces() if i.supports_sharing]
        if sharing:
            info = sharing[0]
            ab = int(info.max_bytes // 8 * 0.9)
            out = np.asarray(
                runner.sharing_probe(info.name, info.name, ab, 7))
            assert out.shape == (7,) and np.all(out > 0)
        else:
            with pytest.raises(NotImplementedError):
                runner.sharing_probe("a", "b", 4 * KIB, 7)


class TestEvictionMany:
    """The heterogeneous eviction-grid capability (§IV-F/G/H fused rows)."""

    @staticmethod
    def _mixed_requests(runner):
        """Mixed amount/sharing/cu rows from whatever the backend supports."""
        reqs = []
        amount = [i for i in runner.spaces() if i.supports_amount]
        if amount:
            info = amount[0]
            ab = int(info.max_bytes // 8 * 0.9)
            reqs += [("amount", info.name, 0, 1, ab),
                     ("amount", info.name, 0, 2, ab)]
        sharing = [i for i in runner.spaces() if i.supports_sharing]
        if sharing:
            info = sharing[0]
            ab = int(info.max_bytes // 8 * 0.9)
            reqs.append(("sharing", info.name, info.name, ab))
        cu_ids = runner.cu_ids() if hasattr(runner, "cu_ids") else []
        if len(cu_ids) >= 2:
            sl1d = next(i for i in runner.spaces() if i.name == "sL1d")
            reqs.append(("cu", "sL1d", cu_ids[0], cu_ids[1],
                         int(sl1d.max_bytes // 8 * 0.9)))
        return reqs

    def test_batch_equals_loop(self, backend):
        """One grid dispatch must reproduce the per-kind single probes —
        bit-identical on deterministic runners, structurally valid on
        measuring ones.  Single-actor backends must refuse instead."""
        runner = backend["runner"]
        reqs = self._mixed_requests(runner)
        if not reqs:
            with pytest.raises(NotImplementedError):
                runner.eviction_many(
                    [("amount", "anything", 0, 1, 4 * KIB)], 7)
            return
        batch = np.asarray(runner.eviction_many(reqs, 7))
        assert batch.shape == (len(reqs), 7)
        assert np.all(np.isfinite(batch)) and np.all(batch > 0)
        if not runner.deterministic:
            return
        for i, req in enumerate(reqs):
            if req[0] == "amount":
                row = runner.amount_probe(req[1], req[2], req[3], req[4], 7)
            elif req[0] == "sharing":
                row = runner.sharing_probe(req[1], req[2], req[3], 7)
            else:
                row = runner.cu_sharing_probe(req[2], req[3], req[4], 7,
                                              space=req[1])
            assert np.array_equal(batch[i], np.asarray(row)), req

    def test_cu_rows_bit_identical_on_cu_device(self):
        """AMD-style device: fused cu rows == cu_sharing_probe, exactly."""
        from repro.core import make_mi210_like

        runner = SimRunner(make_mi210_like(seed=5))
        ids = runner.cu_ids()
        assert len(ids) >= 2
        sl1d = next(i for i in runner.spaces() if i.name == "sL1d")
        ab = int(sl1d.max_bytes // 8 * 0.9)
        reqs = [("cu", "sL1d", ids[0], b, ab) for b in ids[1:4]]
        batch = np.asarray(runner.eviction_many(reqs, 9))
        for i, (_, _, a, b, arr) in enumerate(reqs):
            assert np.array_equal(
                batch[i],
                np.asarray(runner.cu_sharing_probe(a, b, arr, 9)))

    def test_unknown_kind_rejected(self):
        runner = SimRunner(make_h100_like(seed=3))
        with pytest.raises(ValueError):
            runner.eviction_many([("park", "L1", 0, 1, 4 * KIB)], 7)

    def test_caching_runner_dedupes_and_replays(self):
        """Duplicate rows in one grid cost one base fetch; a repeat call —
        or a later single-probe of the same request — costs zero."""
        from repro.core.engine import SampleCache
        from repro.core.engine.cache import CachingRunner

        runner = CachingRunner(SimRunner(make_h100_like(seed=3)),
                               cache=SampleCache())
        reqs = self._mixed_requests(runner)
        assert reqs
        doubled = reqs + [reqs[0]]
        first = np.asarray(runner.eviction_many(doubled, 7))
        assert runner.cache.stats()["misses"] == len(reqs)
        assert np.array_equal(first[0], first[-1])

        again = np.asarray(runner.eviction_many(doubled, 7))
        assert runner.cache.stats()["misses"] == len(reqs)  # all hits now
        assert np.array_equal(first, again)
        # single-probe replay of a grid-fetched row: also a hit
        a = reqs[0]
        runner.amount_probe(a[1], a[2], a[3], a[4], 7)
        assert runner.cache.stats()["misses"] == len(reqs)


class TestChaosRunner:
    """Chaos-specific halves of the contract: transparent when idle,
    deterministic when faulting (the property every fault-tolerance test
    and the ``fault_recovery`` bench gate lean on)."""

    def _base(self):
        return SimRunner(make_h100_like(seed=3))

    def test_zero_fault_schedule_is_bit_transparent(self):
        """No schedule -> every sample identical to the wrapped runner."""
        chaos, base = ChaosRunner(self._base()), self._base()
        info = base.spaces()[0]
        ab = min(info.max_bytes // 8, 64 * KIB)
        assert np.array_equal(chaos.pchase(info.name, ab, 32, 9),
                              base.pchase(info.name, ab, 32, 9))
        assert np.array_equal(
            np.asarray(chaos.pchase_batch(info.name, [ab, 2 * ab], 32, 9)),
            np.asarray(base.pchase_batch(info.name, [ab, 2 * ab], 32, 9)))
        assert chaos.faults_injected == 0

    def test_fault_replay_is_deterministic(self):
        """Two fresh runners over the same schedule fault on exactly the
        same calls — chaos runs are reproducible by construction."""
        sched = FaultSchedule(seed=42, transient_rate=0.3,
                              max_faults_per_request=2)

        def trace():
            chaos = ChaosRunner(self._base(), sched)
            info = chaos.spaces()[0]
            ab = min(info.max_bytes // 8, 64 * KIB)
            events = []
            for size in (ab, 2 * ab, 3 * ab):
                for _ in range(4):             # retries consume the budget
                    try:
                        chaos.pchase(info.name, size, 32, 9)
                        events.append(("ok", size))
                    except TransientRunnerError:
                        events.append(("fault", size))
            return events, chaos.faults_injected

        assert trace() == trace()

    def test_fault_budget_lets_retries_succeed(self):
        """Per-request fault budget: after ``max_faults_per_request``
        raises, the same request must succeed — retry loops terminate."""
        sched = FaultSchedule(seed=0, transient_rate=1.0,
                              max_faults_per_request=2)
        chaos = ChaosRunner(self._base(), sched)
        info = chaos.spaces()[0]
        ab = min(info.max_bytes // 8, 64 * KIB)
        for _ in range(2):
            with pytest.raises(TransientRunnerError):
                chaos.pchase(info.name, ab, 32, 9)
        out = np.asarray(chaos.pchase(info.name, ab, 32, 9))
        assert out.shape == (9,)
        assert chaos.faults_injected == 2

    def test_jitter_preserves_batch_equals_loop(self):
        """Perturbations are keyed by the per-row request signature, so a
        fused row and its single-call twin see the same noise — the
        batch==loop equivalence the engine's caching depends on."""
        sched = FaultSchedule(seed=9, jitter=0.05, outlier_rate=0.05)
        chaos = ChaosRunner(self._base(), sched)
        info = chaos.spaces()[0]
        ab = min(info.max_bytes // 8, 64 * KIB)
        sizes = [ab, 2 * ab, 3 * ab]
        batch = np.asarray(chaos.pchase_batch(info.name, sizes, 32, 9))
        for i, size in enumerate(sizes):
            assert np.array_equal(batch[i],
                                  np.asarray(chaos.pchase(info.name, size,
                                                          32, 9)))
        # ...and the jitter is actually doing something vs the base
        base = self._base()
        assert not np.array_equal(batch[0],
                                  np.asarray(base.pchase(info.name, ab, 32,
                                                         9)))

    def test_permanent_kind_always_faults(self):
        sched = FaultSchedule(seed=3, permanent_kinds=("bandwidth",))
        chaos = ChaosRunner(self._base(), sched)
        for _ in range(4):
            with pytest.raises(TransientRunnerError):
                chaos.bandwidth("L2", "read")
        # other kinds stay clean
        info = chaos.spaces()[0]
        ab = min(info.max_bytes // 8, 64 * KIB)
        assert np.asarray(chaos.pchase(info.name, ab, 32, 9)).shape == (9,)

    def test_kill_after_terminates_run(self):
        sched = FaultSchedule(seed=3, kill_after=2)
        chaos = ChaosRunner(self._base(), sched)
        info = chaos.spaces()[0]
        ab = min(info.max_bytes // 8, 64 * KIB)
        chaos.pchase(info.name, ab, 32, 9)
        chaos.pchase(info.name, 2 * ab, 32, 9)
        with pytest.raises(RuntimeError, match="chaos kill"):
            chaos.pchase(info.name, 3 * ab, 32, 9)


class TestBandwidth:
    def test_read_write_positive(self, backend):
        runner = backend["runner"]
        for mode in ("read", "write"):
            bw = runner.bandwidth(backend["bw_space"], mode)
            assert isinstance(bw, float) and bw > 0


class TestRegistryHonorsFlags:
    """The engine side of the capability contract: families never scheduled
    for spaces that do not support them, for every backend's spaces."""

    def test_cold_families_gated(self, backend):
        for info in backend["runner"].spaces():
            families = {s.family for s in space_probe_specs(info)}
            if not info.supports_cold:
                assert "fetch_granularity" not in families
                assert "line_size" not in families
            else:
                assert "fetch_granularity" in families
            if not (info.supports_amount or info.scope == "chip"):
                assert "amount" not in families


class TestPermutations:
    def test_random_cycle_is_single_cycle(self):
        rng = np.random.default_rng(0)
        for n in (2, 5, 64, 1000):
            perm = random_cycle(n, rng)
            seen, cur = set(), 0
            for _ in range(n):
                cur = int(perm[cur])
                assert cur not in seen
                seen.add(cur)
            assert cur == 0 and len(seen) == n

    def test_matches_sattolo_distribution_property(self):
        # Both constructions produce permutations with exactly one cycle.
        rng = np.random.default_rng(1)
        for n in (8, 33):
            for perm in (sattolo_cycle(n, rng), random_cycle(n, rng)):
                visited = set()
                cur = 0
                while cur not in visited:
                    visited.add(cur)
                    cur = int(perm[cur])
                assert len(visited) == n


# --------------------------------------------------------------------------
# Multiprocess parallel dispatch (engine/parallel.py)
# --------------------------------------------------------------------------
# workers=2 with a one-row shard floor forces every multi-row batch to
# actually split across processes — the strongest form of the sharded ==
# inline claim.  Explicit ``workers`` bypasses the effective-core floor so
# the suite exercises real pooling even on a 1-2 core CI box.
PCFG = ParallelConfig(workers=2, min_rows_per_shard=1)

DEVICE_FAMILIES = ("sharing", "device_memory_latency",
                   "device_memory_bandwidth")


def _shm_residue(prefix):
    """Shared-memory segment names under /dev/shm carrying ``prefix``.

    Empty on platforms that mount no /dev/shm — the residue backstop is
    POSIX-shm specific, and so is the leak it guards against.
    """
    if not os.path.isdir("/dev/shm"):
        return []
    return [n for n in os.listdir("/dev/shm") if n.startswith(prefix)]


@pytest.fixture(scope="module")
def pool():
    """One dedicated pool for the conformance tests (isolated lifecycle)."""
    with ParallelPool(PCFG) as p:
        yield p


# Deterministic request-keyed runners: sharding must be byte-for-byte
# invisible.  The "caching" row wraps the sim runner in ``CachingRunner``,
# whose ``runner_spec`` delegates to its base — workers rebuild the bare
# runner and the cache stays coordinator-side.
DET_RUNNERS = [
    pytest.param(lambda: SimRunner(make_h100_like(seed=3)), id="sim"),
    pytest.param(lambda: ChaosRunner(SimRunner(make_h100_like(seed=3)),
                                     FaultSchedule(seed=1)), id="chaos"),
    pytest.param(lambda: CachingRunner(SimRunner(make_h100_like(seed=3))),
                 id="caching"),
]


def _eviction_reqs(runner):
    """A mixed amount/sharing request grid big enough to shard."""
    reqs = []
    amount = [i for i in runner.spaces() if i.supports_amount][0]
    ab = min(amount.max_bytes // 8, 64 * KIB)
    reqs += [("amount", amount.name, 0, w, ab) for w in range(4)]
    sharing = [i for i in runner.spaces() if i.supports_sharing][0]
    sab = min(sharing.max_bytes // 8, 64 * KIB)
    reqs += [("sharing", sharing.name, sharing.name, sab),
             ("sharing", sharing.name, sharing.name, sab // 2)]
    return reqs


class TestParallelDispatch:
    """Sharded pool execution == inline execution, byte for byte.

    The pool's whole correctness argument rests on request-keyed sampling:
    each probe row derives its stream from (request, sample index) alone,
    so *where* the row runs cannot matter.  These tests pin that down for
    every pooled capability and every spec-publishing runner, then check
    the failure half of the contract: worker death surfaces as
    ``TransientRunnerError`` (the resilience currency), the pool respawns,
    and no shared-memory segment outlives its call.
    """

    @pytest.mark.parametrize("make", DET_RUNNERS)
    def test_five_capabilities_bit_identical(self, pool, make):
        inline = make()
        pooled = maybe_parallel_runner(make(), PCFG, pool=pool)
        assert pooled is not inline and pooled.deterministic

        sizes = [16 * KIB + 4 * KIB * i for i in range(9)]
        assert np.array_equal(inline.pchase_batch("L1", sizes, 32, 7),
                              pooled.pchase_batch("L1", sizes, 32, 7))

        strides = [8 * (i + 1) for i in range(9)]
        assert np.array_equal(
            inline.cold_chase_batch("L1", [64 * KIB] * 9, strides, 7),
            pooled.cold_chase_batch("L1", [64 * KIB] * 9, strides, 7))

        reqs = ([("L1", 16 * KIB + 4 * KIB * i, 32) for i in range(6)]
                + [("L2", MIB + 256 * KIB * i, 64) for i in range(3)])
        assert np.array_equal(inline.pchase_many(reqs, 7),
                              pooled.pchase_many(reqs, 7))
        assert np.array_equal(inline.cold_chase_many(reqs, 7),
                              pooled.cold_chase_many(reqs, 7))

        ev = _eviction_reqs(inline)
        assert np.array_equal(inline.eviction_many(ev, 7),
                              pooled.eviction_many(ev, 7))

    def test_batches_actually_shard_across_workers(self, pool):
        pooled = maybe_parallel_runner(SimRunner(make_h100_like(seed=3)),
                                       PCFG, pool=pool)
        calls0, shards0 = pool.calls, pool.shards
        pooled.pchase_many([("L1", 32 * KIB + 4 * KIB * i, 32)
                            for i in range(16)], 5)
        assert pool.calls == calls0 + 1
        assert pool.shards == shards0 + 2       # both workers took rows
        # A single-row batch cannot split below one row per shard.
        pooled.pchase_many([("L1", 32 * KIB, 32)], 5)
        assert pool.shards == shards0 + 3

    def test_host_structural_through_pool(self, pool):
        """Measuring runners pool too — structurally, never bit-for-bit."""
        pooled = maybe_parallel_runner(
            HostRunner(max_bytes=8 * MIB, iters=1 << 10), PCFG, pool=pool)
        info, ab = _probe_space(pooled)
        rows = np.asarray(pooled.pchase_many(
            [(info.name, ab, 64), (info.name, ab // 2, 64)], 3))
        assert rows.shape == (2, 3) and rows.dtype == np.float64
        assert np.all(np.isfinite(rows)) and np.all(rows > 0)
        # Capability refusals keep their exception type across the pool.
        with pytest.raises(NotImplementedError):
            pooled.cold_chase_many([(info.name, ab, 64)], 3)

    def test_caching_over_pool_serves_repeats_locally(self, pool):
        """Engine ordering: cache above the pool, misses-only cross over."""
        reqs = [("L1", 16 * KIB + 4 * KIB * i, 32) for i in range(8)]
        inline = CachingRunner(SimRunner(make_h100_like(seed=3)))
        cached = CachingRunner(maybe_parallel_runner(
            SimRunner(make_h100_like(seed=3)), PCFG, pool=pool))
        assert np.array_equal(inline.pchase_many(reqs, 7),
                              cached.pchase_many(reqs, 7))
        calls0 = pool.calls
        cached.pchase_many(reqs, 7)             # all rows now cached
        assert pool.calls == calls0

    def test_specless_or_disabled_stays_inline(self):
        runner = SimRunner(make_h100_like(seed=3))
        assert maybe_parallel_runner(runner, None) is runner
        # No RunnerSpec -> identity, even with pooling requested.
        bare = object()
        assert maybe_parallel_runner(bare, PCFG) is bare
        # Below the effective-core floor the auto heuristic opts out...
        auto = ParallelConfig(min_cores=10 ** 6)
        assert auto.resolved_workers() == 0
        assert maybe_parallel_runner(runner, auto) is runner
        # ...but an explicit worker count always pools.
        assert ParallelConfig(workers=3, min_cores=10 ** 6)
        assert ParallelConfig(workers=3,
                              min_cores=10 ** 6).resolved_workers() == 3

    def test_effective_cpu_count_sane(self):
        n = effective_cpu_count()
        assert 1 <= n <= (os.cpu_count() or 1)

    def test_worker_crash_transient_respawn_no_residue(self):
        """A killed worker costs one TransientRunnerError, nothing else."""
        cfg = ParallelConfig(workers=1, min_rows_per_shard=1)
        with ParallelPool(cfg) as crash_pool:
            prefix = crash_pool._prefix
            chaos = ChaosRunner(SimRunner(make_h100_like(seed=3)),
                                FaultSchedule(kill_worker_after=0))
            pooled = maybe_parallel_runner(chaos, cfg, pool=crash_pool)
            with pytest.raises(TransientRunnerError):
                pooled.pchase_many([("L1", 64 * KIB, 32)], 5)
            assert crash_pool.respawns == 1
            # Segment released despite the abnormal exit, pool still live.
            assert _shm_residue(prefix) == []
            clean = maybe_parallel_runner(SimRunner(make_h100_like(seed=3)),
                                          cfg, pool=crash_pool)
            rows = np.asarray(clean.pchase_many([("L1", 64 * KIB, 32)], 5))
            assert rows.shape == (1, 5)
        assert _shm_residue(prefix) == []

    def test_worker_kill_discovery_recovers_clean_topology(self):
        """Mid-round worker death -> resilience retry -> clean topology.

        The chaos schedule kills the worker process a few calls in (the
        ``MT4G_POOL_WORKER`` guard keeps the coordinator alive); the pooled
        fused discovery must converge to exactly the inline clean run —
        everything but the wall-time note, which legitimately differs.
        """
        dev = make_h100_like(seed=3)
        policy = Resilience(max_retries=4, sleep=lambda _s: None)

        def req(make_runner, **kw):
            return DiscoveryRequest(
                descriptor=sim_request_descriptor(dev, 9, None),
                vendor=dev.vendor, model=dev.name,
                backend=f"simulated:{dev.name}",
                make_runner=make_runner, n_samples=9,
                device_families=DEVICE_FAMILIES, fuse=True, **kw)

        clean, _ = discover(req(lambda: SimRunner(dev)))

        sched = FaultSchedule(kill_worker_after=6)
        shared = get_global_pool(PCFG)
        respawns0 = shared.respawns
        try:
            topo, _ = discover(req(
                lambda: ChaosRunner(SimRunner(dev), sched),
                resilience=policy, parallel=PCFG))
        finally:
            shutdown_global_pools()
        assert shared.respawns > respawns0      # kills actually happened
        assert topology_equivalent(clean, topo)
        a, b = clean.to_json(), topo.to_json()
        a.pop("notes"), b.pop("notes")
        assert a == b
        assert _shm_residue(f"mt4g{os.getpid()}") == []
